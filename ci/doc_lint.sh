#!/usr/bin/env bash
# Doc lint: every `DESIGN.md §N` reference in the docs, code comments,
# tests, benches, and CI config must resolve to an actual `## §N` heading
# in rust/DESIGN.md. Catches the classic drift where a section is
# renumbered (or never written) but its references linger.
#
# Run from the repo root: bash ci/doc_lint.sh
set -u

cd "$(dirname "$0")/.."

design=rust/DESIGN.md
if [ ! -f "$design" ]; then
    echo "doc-lint: $design missing" >&2
    exit 1
fi

# The headings that exist, one section number per line.
sections=$(grep -o '^## §[0-9]\+' "$design" | grep -o '[0-9]\+' | sort -un)
if [ -z "$sections" ]; then
    echo "doc-lint: no '## §N' headings found in $design" >&2
    exit 1
fi

# Everywhere references may live. rust/DESIGN.md itself is included:
# cross-references between sections drift too.
targets=(
    README.md ROADMAP.md CHANGES.md ARCHITECTURE.md EXPERIMENTS.md
    rust/CLI.md rust/DESIGN.md ci/baselines/README.md
)
refs_file=$(mktemp)
trap 'rm -f "$refs_file"' EXIT

for f in "${targets[@]}"; do
    [ -f "$f" ] || continue
    grep -Hno 'DESIGN\.md §[0-9]\+' "$f" >>"$refs_file" || true
done
grep -RHno 'DESIGN\.md §[0-9]\+' \
    rust/src rust/tests rust/benches examples .github \
    --include='*.rs' --include='*.yml' --include='*.yaml' \
    >>"$refs_file" 2>/dev/null || true
# ARCHITECTURE.md's subsystem table uses bare §N in its Design column.
grep -Hno '§[0-9]\+' ARCHITECTURE.md >>"$refs_file" || true

status=0
checked=0
while IFS= read -r line; do
    n=$(printf '%s' "$line" | grep -o '§[0-9]\+$' | tr -d '§')
    [ -n "$n" ] || continue
    checked=$((checked + 1))
    if ! printf '%s\n' "$sections" | grep -qx "$n"; then
        echo "doc-lint: dangling reference to DESIGN.md §$n at ${line%:*}" >&2
        status=1
    fi
done <"$refs_file"

if [ "$checked" -eq 0 ]; then
    echo "doc-lint: found no DESIGN.md § references at all — pattern broken?" >&2
    exit 1
fi

if [ "$status" -eq 0 ]; then
    echo "doc-lint: $checked DESIGN.md § references all resolve ($(printf '%s' "$sections" | tr '\n' ' ' | sed 's/ $//' | sed 's/ /, §/g; s/^/§/') exist)"
fi
exit $status
