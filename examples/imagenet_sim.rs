//! ImageNet-twin demo: BSQ on the heterogeneous-architecture models
//! (bottleneck ResNet-50 twin / Inception-V3 twin) — the paper's Table 3
//! setting at laptop scale (DESIGN.md §4 substitutions).
//!
//! ```bash
//! cargo run --release --example imagenet_sim -- --model inception_sim --alpha 1e-2
//! ```
//!
//! The interesting output is *where* the bits land: 1×1 bottleneck reduces
//! vs 3×3 spatial convs, inception branch types — the structure the paper's
//! Tables 6–7 report.

use bsq::coordinator::{run_bsq, BsqConfig};
use bsq::runtime::Engine;
use bsq::util::cli::Args;

fn main() -> anyhow::Result<()> {
    bsq::util::logging::init();
    let mut args = Args::parse(std::env::args().skip(1))?;
    let model = args.str_or("model", "inception_sim")?;
    let alpha: f32 = args.get_or("alpha", 1e-2)?;
    args.finish()?;

    if model != "resnet50_sim" && model != "inception_sim" {
        anyhow::bail!("--model must be resnet50_sim or inception_sim");
    }
    let engine = Engine::cpu()?;
    let mut cfg = BsqConfig::for_model(&model);
    cfg.alpha = alpha;
    cfg.act_bits = if model == "inception_sim" { 6 } else { 4 };
    if model == "inception_sim" {
        cfg.act_first_last = 6; // paper: uniform 6-bit activations
    }

    println!(
        "BSQ on {model}: init {} -bit weights ({}×8-bit stem), {}-bit activations, α = {alpha}",
        cfg.init_bits, cfg.init_8bit_prefix, cfg.act_bits
    );
    let o = run_bsq(&engine, &cfg)?;

    println!("\nper-layer scheme (cf. paper Tables 6–7):");
    for l in &o.scheme.layers {
        println!("  {:<12} {:>8} params {:>2} bits", l.name, l.params, l.bits);
    }
    println!(
        "\n{:.2} bits/param ({:.2}×), top-1 {:.2}% → {:.2}% after finetune",
        o.bits_per_param,
        o.compression,
        100.0 * o.acc_before_ft,
        100.0 * o.acc_after_ft
    );
    Ok(())
}
