//! Quickstart: the whole BSQ pipeline on the tiny test model, in ~2 minutes.
//!
//! ```bash
//! make artifacts            # once: AOT-lower the JAX/Pallas graphs
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's §3.3 pipeline end to end on `tinynet` (4 quantized
//! layers, 16×16 synthetic corpus): float pretrain → 8-bit bit-plane
//! conversion → BSQ training under the bit-level group-Lasso → periodic
//! re-quantization/precision adjustment → DoReFa finetune at the frozen
//! mixed-precision scheme.

use bsq::coordinator::{run_bsq, BsqConfig};
use bsq::runtime::Engine;

fn main() -> anyhow::Result<()> {
    bsq::util::logging::init();

    let engine = Engine::cpu()?;
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.alpha = 2e-4; // the single knob: higher α → fewer bits (tinynet scale)
    cfg.cache_pretrained = false;

    println!("running BSQ on {} (α = {}) …", cfg.model, cfg.alpha);
    let outcome = run_bsq(&engine, &cfg)?;

    println!("\ndiscovered mixed-precision scheme:");
    println!("{}", outcome.scheme);
    println!(
        "\naccuracy: {:.1}% before finetune → {:.1}% after",
        100.0 * outcome.acc_before_ft,
        100.0 * outcome.acc_after_ft
    );
    println!(
        "model size: {:.2} bits/param = {:.1}× smaller than fp32",
        outcome.bits_per_param, outcome.compression
    );
    Ok(())
}
