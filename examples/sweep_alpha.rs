//! α sweep: regenerate the paper's Table 1 / Figure 3 trade-off curve.
//!
//! ```bash
//! cargo run --release --example sweep_alpha -- --model tinynet \
//!     --alphas 1e-3,3e-3,1e-2,3e-2
//! ```
//!
//! Prints one row per α (bits/param, compression, accuracy before/after
//! finetune) plus the per-layer precision profile — the paper's central
//! claim that one hyperparameter traces the whole accuracy-size frontier.

use bsq::coordinator::{run_bsq, BsqConfig};
use bsq::runtime::Engine;
use bsq::util::cli::Args;

fn main() -> anyhow::Result<()> {
    bsq::util::logging::init();
    let mut args = Args::parse(std::env::args().skip(1))?;
    let model = args.str_or("model", "tinynet")?;
    let alphas: Vec<f32> =
        args.list("alphas")?.unwrap_or_else(|| vec![5e-5, 1e-4, 2e-4, 5e-4]);
    let fast = !args.flag("full");
    args.finish()?;

    let engine = Engine::cpu()?;
    println!("{:>9} {:>12} {:>9} {:>11} {:>10}  layer bits", "α", "bits/param", "comp(×)", "preFT acc%", "FT acc%");
    for alpha in alphas {
        let mut cfg = BsqConfig::for_model(&model);
        cfg.alpha = alpha;
        if fast && model == "resnet20" {
            cfg.pretrain_epochs = 3;
            cfg.bsq_epochs = 4;
            cfg.finetune_epochs = 2;
            cfg.train_size = 512;
            cfg.test_size = 256;
        }
        let o = run_bsq(&engine, &cfg)?;
        println!(
            "{alpha:>9.0e} {:>12.2} {:>9.2} {:>11.2} {:>10.2}  {:?}",
            o.bits_per_param,
            o.compression,
            100.0 * o.acc_before_ft,
            100.0 * o.acc_after_ft,
            o.scheme.bits_vec()
        );
    }
    Ok(())
}
