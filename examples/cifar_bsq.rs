//! End-to-end validation driver (DESIGN.md: the repo's headline example).
//!
//! Trains the paper's ResNet-20 on the synthetic-CIFAR corpus through the
//! full three-phase pipeline, logging the loss curve, the evolving
//! quantization scheme at every re-quantization, and the final
//! accuracy/compression pair. The run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example cifar_bsq -- [--alpha 5e-3] [--fast]
//! ```

use bsq::coordinator::{run_bsq, write_result, BsqConfig};
use bsq::runtime::Engine;
use bsq::util::cli::Args;

fn main() -> anyhow::Result<()> {
    bsq::util::logging::init();
    let mut args = Args::parse(std::env::args().skip(1))?;
    let alpha: f32 = args.get_or("alpha", 5e-3)?;
    let fast = args.flag("fast");
    args.finish()?;

    let engine = Engine::cpu()?;
    let mut cfg = BsqConfig::for_model("resnet20");
    cfg.alpha = alpha;
    if fast {
        cfg.pretrain_epochs = 3;
        cfg.bsq_epochs = 4;
        cfg.finetune_epochs = 2;
        cfg.train_size = 512;
        cfg.test_size = 256;
    }

    println!(
        "BSQ end-to-end: resnet20 ({} params, {} layers), α = {alpha}, 4-bit activations",
        268_336, 20
    );
    println!(
        "schedule: {} pretrain + {} BSQ + {} finetune epochs, corpus {}/{} (batch 32)\n",
        cfg.pretrain_epochs, cfg.bsq_epochs, cfg.finetune_epochs, cfg.train_size, cfg.test_size
    );

    let outcome = run_bsq(&engine, &cfg)?;

    println!("\n==== loss curve ====");
    for r in &outcome.history.records {
        println!(
            "{:>9} {:>3}  loss {:>7.4}  acc {:>5.3}{}  [{:.2} b/p]",
            r.phase,
            r.epoch,
            r.loss,
            r.acc,
            r.eval_acc.map(|a| format!("  eval {a:.3}")).unwrap_or_default(),
            r.bits_per_param,
        );
    }
    println!("\n==== final scheme ====\n{}", outcome.scheme);
    println!(
        "\nfinal: {:.2} bits/param ({:.2}×), acc {:.2}% → {:.2}% after finetune",
        outcome.bits_per_param,
        outcome.compression,
        100.0 * outcome.acc_before_ft,
        100.0 * outcome.acc_after_ft
    );
    write_result(std::path::Path::new("results/cifar_bsq_e2e.json"), &outcome.to_json())?;
    println!("record → results/cifar_bsq_e2e.json");
    Ok(())
}
