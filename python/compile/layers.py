"""L2 building blocks: conv / batch-norm / dense over a weight-provider.

The same forward graph must run under four weight modes (fp, BSQ bit
representation, DoReFa, LSQ) and two activation modes (ReLU6, PACT). To keep
one source of truth per architecture, a model's `forward` is written against
a `Forward` context that:

  * resolves weights through a caller-supplied provider (the train step
    injects the quantizer there),
  * applies batch norm from a parameter dict and records updated running
    statistics in train mode (BN stays float — paper App. A),
  * quantizes activations through a caller-supplied site function (so the
    per-site precision vector and ReLU6/PACT choice live with the caller).

Convolutions carry no bias (BN absorbs it); the final dense layer has one.
Layouts: NHWC activations, HWIO conv kernels.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp
from jax import lax

BN_MOMENTUM = 0.1  # running-stat update rate (PyTorch convention, paper impl)
BN_EPS = 1e-5


class Forward:
    """One forward pass; collects BN running-stat updates in train mode."""

    def __init__(
        self,
        weight: Callable[[str], jnp.ndarray],
        bn_params: Dict[str, jnp.ndarray],
        act_site: Callable[[int, jnp.ndarray], jnp.ndarray],
        train: bool,
    ):
        self.weight = weight
        self.bn_params = bn_params
        self.act_site = act_site
        self.train = train
        self.new_stats: Dict[str, jnp.ndarray] = {}
        self._site = 0

    # -- primitives --------------------------------------------------------

    def conv(self, x: jnp.ndarray, name: str, stride: int = 1,
             padding: str = "SAME") -> jnp.ndarray:
        w = self.weight(name)  # HWIO
        return lax.conv_general_dilated(
            x, w,
            window_strides=(stride, stride),
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )

    def bn(self, x: jnp.ndarray, name: str) -> jnp.ndarray:
        gamma = self.bn_params[f"{name}/gamma"]
        beta = self.bn_params[f"{name}/beta"]
        if self.train:
            mean = jnp.mean(x, axis=(0, 1, 2))
            var = jnp.var(x, axis=(0, 1, 2))
            run_m = self.bn_params[f"{name}/mean"]
            run_v = self.bn_params[f"{name}/var"]
            self.new_stats[f"{name}/mean"] = (1 - BN_MOMENTUM) * run_m + BN_MOMENTUM * mean
            self.new_stats[f"{name}/var"] = (1 - BN_MOMENTUM) * run_v + BN_MOMENTUM * var
        else:
            mean = self.bn_params[f"{name}/mean"]
            var = self.bn_params[f"{name}/var"]
        inv = lax.rsqrt(var + BN_EPS)
        return (x - mean) * inv * gamma + beta

    def act(self, x: jnp.ndarray) -> jnp.ndarray:
        """Quantized activation; sites are numbered in call order."""
        out = self.act_site(self._site, x)
        self._site += 1
        return out

    def dense(self, x: jnp.ndarray, name: str) -> jnp.ndarray:
        w = self.weight(name)  # [in, out]
        b = self.weight(f"{name}/b")
        return x @ w + b

    # -- composites --------------------------------------------------------

    def conv_bn_act(self, x, name, stride=1):
        return self.act(self.bn(self.conv(x, name, stride=stride), name))

    def global_avg_pool(self, x):
        return jnp.mean(x, axis=(1, 2))


def pad_shortcut(x: jnp.ndarray, cout: int, stride: int) -> jnp.ndarray:
    """ResNet option-A shortcut: strided subsample + zero channel padding.

    Parameter-free (matches the He et al. 2016 CIFAR ResNet the paper uses —
    its layer count implies no projection shortcuts on CIFAR).
    """
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    cin = x.shape[-1]
    if cout > cin:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cout - cin)))
    return x
