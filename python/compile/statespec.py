"""Flat I/O specification for AOT artifacts — the Python↔Rust contract.

Every lowered entry point takes a *flat, ordered* tuple of arrays and
returns one. `IOItem` describes one slot; a list of them (the spec) is
serialized into `manifest.json` so the Rust coordinator can marshal its own
state without understanding the graph. Roles:

  inputs
    x / y     — the minibatch (images f32 NHWC, labels i32)
    state     — a named model-state tensor (weights, planes, masks, scales,
                BN params, momenta, PACT clips, LSQ steps)
    hyper     — a named scalar hyperparameter (lr, alpha, wd, …)
    vec       — a named per-layer configuration vector (regw, wlv, actlv)
    probe     — HVP direction vectors (v:<layer>)
  outputs
    state     — updated value of the named state tensor
    metric    — a named scalar metric (loss, ce, acc, bgl)
    probe_out — HVP results (hv:<layer>)

State-key naming convention (shared with rust/src/model/state.rs):
    w:<layer>          fp master weight          [HWIO] / [in,out]
    w:<layer>/b        dense bias                [out]
    wp:<layer>         positive bit planes       [NB, *shape]
    wn:<layer>         negative bit planes       [NB, *shape]
    mask:<layer>       active-plane mask         [NB]
    scale:<layer>      dynamic-range scale s     []
    bn:<name>/gamma|beta|mean|var                [C]
    pact:<site>        PACT clip                 []
    step:<layer>       LSQ step size             []
    m:<key>            SGD momentum buffer of a trainable key
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .models import ModelDef
from .quantize import NB


@dataclasses.dataclass(frozen=True)
class IOItem:
    name: str
    shape: Tuple[int, ...]
    dtype: str  # "f32" | "i32"
    role: str

    def sds(self) -> jax.ShapeDtypeStruct:
        dt = jnp.float32 if self.dtype == "f32" else jnp.int32
        return jax.ShapeDtypeStruct(self.shape, dt)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "role": self.role,
        }


def batch_items(model: ModelDef, batch: int) -> List[IOItem]:
    h, w = model.input_hw
    return [
        IOItem("x", (batch, h, w, model.in_ch), "f32", "x"),
        IOItem("y", (batch,), "i32", "y"),
    ]


def fp_weight_items(model: ModelDef) -> List[IOItem]:
    items = []
    for q in model.qlayers:
        items.append(IOItem(f"w:{q.name}", q.shape, "f32", "state"))
    for d in model.dense_bias:
        out = [q.shape[-1] for q in model.qlayers if q.name == d][0]
        items.append(IOItem(f"w:{d}/b", (out,), "f32", "state"))
    return items


def bit_weight_items(model: ModelDef) -> List[IOItem]:
    items = []
    for q in model.qlayers:
        items.append(IOItem(f"wp:{q.name}", (NB,) + q.shape, "f32", "state"))
        items.append(IOItem(f"wn:{q.name}", (NB,) + q.shape, "f32", "state"))
        items.append(IOItem(f"mask:{q.name}", (NB,), "f32", "state"))
        items.append(IOItem(f"scale:{q.name}", (), "f32", "state"))
    for d in model.dense_bias:
        out = [q.shape[-1] for q in model.qlayers if q.name == d][0]
        items.append(IOItem(f"w:{d}/b", (out,), "f32", "state"))
    return items


def bn_items(model: ModelDef, stats: bool = True) -> List[IOItem]:
    items = []
    for n in model.bn_names:
        c = _bn_channels(model, n)
        items.append(IOItem(f"bn:{n}/gamma", (c,), "f32", "state"))
        items.append(IOItem(f"bn:{n}/beta", (c,), "f32", "state"))
        if stats:
            items.append(IOItem(f"bn:{n}/mean", (c,), "f32", "state"))
            items.append(IOItem(f"bn:{n}/var", (c,), "f32", "state"))
    return items


def _bn_channels(model: ModelDef, name: str) -> int:
    for q in model.qlayers:
        if q.name == name and q.kind == "conv":
            return q.shape[-1]
    raise KeyError(f"BN {name} has no matching conv layer")


def pact_items(model: ModelDef) -> List[IOItem]:
    return [IOItem(f"pact:{s}", (), "f32", "state") for s in model.act_sites]


def lsq_items(model: ModelDef) -> List[IOItem]:
    return [IOItem(f"step:{q.name}", (), "f32", "state") for q in model.qlayers]


def momentum_items(trainables: Sequence[IOItem]) -> List[IOItem]:
    return [IOItem(f"m:{t.name}", t.shape, t.dtype, "state") for t in trainables]


def vec_items(model: ModelDef, which: Sequence[str]) -> List[IOItem]:
    out = []
    if "regw" in which:
        out.append(IOItem("regw", (len(model.qlayers),), "f32", "vec"))
    if "wlv" in which:
        out.append(IOItem("wlv", (len(model.qlayers),), "f32", "vec"))
    if "actlv" in which:
        out.append(IOItem("actlv", (len(model.act_sites),), "f32", "vec"))
    return out


def hyper_items(names: Sequence[str]) -> List[IOItem]:
    return [IOItem(n, (), "f32", "hyper") for n in names]


def metric_items(names: Sequence[str]) -> List[IOItem]:
    return [IOItem(n, (), "f32", "metric") for n in names]


def as_state_outputs(items: Sequence[IOItem]) -> List[IOItem]:
    return [IOItem(i.name, i.shape, i.dtype, "state") for i in items]


def env_from_flat(spec: Sequence[IOItem], flat: Sequence[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    assert len(spec) == len(flat), (len(spec), len(flat))
    return {item.name: arr for item, arr in zip(spec, flat)}


def flat_from_env(spec: Sequence[IOItem], env: Dict[str, jnp.ndarray]):
    return tuple(env[item.name] for item in spec)
