"""Model zoo: tinynet / resnet20 / resnet50_sim / inception_sim.

Each model is a `ModelDef`: static metadata (quantized weight layers in
order, BN names, activation-site names, input/class geometry) plus a
`forward(fwd, x) -> logits` written against the layers.Forward context.
The metadata is the single source of truth consumed by aot.py (manifest
generation) and, through the manifest, by the Rust coordinator (state
initialization, scheme accounting, regularizer reweighing).

Architecture notes (DESIGN.md §4 substitutions):
  * resnet20      — the paper's CIFAR-10 model, exactly: 3 stages × 3 basic
                    blocks × 2 convs at widths 16/32/64, option-A shortcuts,
                    global avg-pool, 10-way FC. 20 weighted layers.
  * resnet50_sim  — scaled-down twin of ResNet-50 for the ImageNet rows:
                    bottleneck (1×1→3×3→1×1, 4× expansion) stages [2,2,2]
                    at widths 16/32/64, projection shortcuts, 100 classes.
  * inception_sim — scaled-down Inception-V3 twin: conv stem + 3 mixed
                    blocks with 1×1 / 3×3 / double-3×3 / pool branches.
  * tinynet       — 4 weighted layers on 16×16 inputs; fast-path model for
                    integration tests and the quickstart example.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax.numpy as jnp

from .layers import Forward, pad_shortcut


@dataclasses.dataclass(frozen=True)
class QLayer:
    """One quantized weight layer (conv or dense)."""
    name: str
    shape: Tuple[int, ...]  # HWIO for conv, [in, out] for dense
    kind: str               # "conv" | "dense"

    @property
    def params(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    input_hw: Tuple[int, int]
    in_ch: int
    num_classes: int
    qlayers: Tuple[QLayer, ...]        # ordered; defines wlv/regw vector order
    bn_names: Tuple[str, ...]          # ordered BN parameter groups
    act_sites: Tuple[str, ...]         # ordered activation sites (actlv order)
    dense_bias: Tuple[str, ...]        # dense layers carrying a bias
    forward: Callable[[Forward, jnp.ndarray], jnp.ndarray]

    @property
    def total_params(self) -> int:
        return sum(q.params for q in self.qlayers)


# ---------------------------------------------------------------------------
# resnet20 (paper's CIFAR-10 model)
# ---------------------------------------------------------------------------

def _resnet20_def(width: int = 16, num_classes: int = 10) -> ModelDef:
    widths = (width, 2 * width, 4 * width)
    qlayers: List[QLayer] = [QLayer("conv1", (3, 3, 3, width), "conv")]
    bns = ["conv1"]
    acts = ["conv1"]
    cin = width
    for s, w in enumerate(widths):
        for b in range(3):
            for c in (1, 2):
                nm = f"s{s}b{b}c{c}"
                qlayers.append(QLayer(nm, (3, 3, cin if c == 1 else w, w), "conv"))
                bns.append(nm)
                acts.append(nm)
            cin = w
    qlayers.append(QLayer("fc", (widths[-1], num_classes), "dense"))

    def forward(fwd: Forward, x: jnp.ndarray) -> jnp.ndarray:
        x = fwd.conv_bn_act(x, "conv1")
        cin_ = width
        for s, w in enumerate(widths):
            for b in range(3):
                stride = 2 if (s > 0 and b == 0) else 1
                sc = pad_shortcut(x, w, stride)
                y = fwd.conv_bn_act(x, f"s{s}b{b}c1", stride=stride)
                y = fwd.bn(fwd.conv(y, f"s{s}b{b}c2"), f"s{s}b{b}c2")
                x = fwd.act(y + sc)
                cin_ = w
        x = fwd.global_avg_pool(x)
        return fwd.dense(x, "fc")

    return ModelDef(
        name="resnet20", input_hw=(32, 32), in_ch=3, num_classes=num_classes,
        qlayers=tuple(qlayers), bn_names=tuple(bns), act_sites=tuple(acts),
        dense_bias=("fc",), forward=forward,
    )


# ---------------------------------------------------------------------------
# tinynet (fast integration-test model)
# ---------------------------------------------------------------------------

def _tinynet_def() -> ModelDef:
    qlayers = (
        QLayer("conv1", (3, 3, 3, 8), "conv"),
        QLayer("conv2", (3, 3, 8, 16), "conv"),
        QLayer("conv3", (3, 3, 16, 16), "conv"),
        QLayer("fc", (16, 10), "dense"),
    )
    bns = ("conv1", "conv2", "conv3")
    acts = ("conv1", "conv2", "conv3")

    def forward(fwd: Forward, x: jnp.ndarray) -> jnp.ndarray:
        x = fwd.conv_bn_act(x, "conv1")
        x = fwd.conv_bn_act(x, "conv2", stride=2)
        x = fwd.conv_bn_act(x, "conv3")
        x = fwd.global_avg_pool(x)
        return fwd.dense(x, "fc")

    return ModelDef(
        name="tinynet", input_hw=(16, 16), in_ch=3, num_classes=10,
        qlayers=qlayers, bn_names=bns, act_sites=acts,
        dense_bias=("fc",), forward=forward,
    )


# ---------------------------------------------------------------------------
# resnet50_sim (bottleneck twin for the ImageNet ResNet-50 rows)
# ---------------------------------------------------------------------------

def _resnet50_sim_def(width: int = 16, num_classes: int = 100,
                      blocks: Tuple[int, ...] = (2, 2, 2),
                      expansion: int = 4) -> ModelDef:
    widths = tuple(width * (2 ** i) for i in range(len(blocks)))
    qlayers: List[QLayer] = [QLayer("conv1", (3, 3, 3, width), "conv")]
    bns = ["conv1"]
    acts = ["conv1"]
    cin = width
    for s, (nb, w) in enumerate(zip(blocks, widths)):
        for b in range(nb):
            pre = f"s{s}b{b}"
            cout = w * expansion
            qlayers.append(QLayer(f"{pre}c1", (1, 1, cin, w), "conv"))
            qlayers.append(QLayer(f"{pre}c2", (3, 3, w, w), "conv"))
            qlayers.append(QLayer(f"{pre}c3", (1, 1, w, cout), "conv"))
            bns += [f"{pre}c1", f"{pre}c2", f"{pre}c3"]
            acts += [f"{pre}c1", f"{pre}c2", f"{pre}c3"]
            if b == 0:
                qlayers.append(QLayer(f"{pre}proj", (1, 1, cin, cout), "conv"))
                bns.append(f"{pre}proj")
            cin = cout
    qlayers.append(QLayer("fc", (widths[-1] * expansion, num_classes), "dense"))

    def forward(fwd: Forward, x: jnp.ndarray) -> jnp.ndarray:
        x = fwd.conv_bn_act(x, "conv1")
        cin_ = width
        for s, (nb, w) in enumerate(zip(blocks, widths)):
            for b in range(nb):
                pre = f"s{s}b{b}"
                stride = 2 if (s > 0 and b == 0) else 1
                if b == 0:
                    sc = fwd.bn(fwd.conv(x, f"{pre}proj", stride=stride), f"{pre}proj")
                else:
                    sc = x
                y = fwd.conv_bn_act(x, f"{pre}c1")
                y = fwd.conv_bn_act(y, f"{pre}c2", stride=stride)
                y = fwd.bn(fwd.conv(y, f"{pre}c3"), f"{pre}c3")
                x = fwd.act(y + sc)
        x = fwd.global_avg_pool(x)
        return fwd.dense(x, "fc")

    return ModelDef(
        name="resnet50_sim", input_hw=(32, 32), in_ch=3, num_classes=num_classes,
        qlayers=tuple(qlayers), bn_names=tuple(bns), act_sites=tuple(acts),
        dense_bias=("fc",), forward=forward,
    )


# ---------------------------------------------------------------------------
# inception_sim (mixed-block twin for the ImageNet Inception-V3 rows)
# ---------------------------------------------------------------------------

def _inception_sim_def(num_classes: int = 100) -> ModelDef:
    qlayers: List[QLayer] = []
    bns: List[str] = []
    acts: List[str] = []

    def cba(name, kh, kw, cin, cout):
        qlayers.append(QLayer(name, (kh, kw, cin, cout), "conv"))
        bns.append(name)
        acts.append(name)

    # Stem: the paper quantizes Inception-V3's first 5 convs at 8-bit; the
    # twin keeps a 3-conv stem (32×32 inputs leave no room for 5 strided
    # convs) whose sites the coordinator pins to 8-bit.
    cba("stem1", 3, 3, 3, 16)
    cba("stem2", 3, 3, 16, 16)
    cba("stem3", 3, 3, 16, 32)

    # Three mixed blocks, each with 4 branches (1×1 / 3×3 / double-3×3 /
    # pool-proj) mirroring Inception-V3's Mixed-5 family.
    mixed = []
    cin = 32
    for m in range(3):
        b1 = 16
        b3r, b3 = 12, 16
        d3r, d3 = 12, 16
        pp = 8
        pre = f"mix{m}"
        cba(f"{pre}_b1", 1, 1, cin, b1)
        cba(f"{pre}_b3r", 1, 1, cin, b3r)
        cba(f"{pre}_b3", 3, 3, b3r, b3)
        cba(f"{pre}_d3r", 1, 1, cin, d3r)
        cba(f"{pre}_d3a", 3, 3, d3r, d3)
        cba(f"{pre}_d3b", 3, 3, d3, d3)
        cba(f"{pre}_pp", 1, 1, cin, pp)
        cout = b1 + b3 + d3 + pp
        mixed.append((pre, cin, cout))
        cin = cout
    qlayers.append(QLayer("fc", (cin, num_classes), "dense"))

    def forward(fwd: Forward, x: jnp.ndarray) -> jnp.ndarray:
        x = fwd.conv_bn_act(x, "stem1")
        x = fwd.conv_bn_act(x, "stem2", stride=2)
        x = fwd.conv_bn_act(x, "stem3")
        for m, (pre, _, _) in enumerate(mixed):
            if m == 1:
                x = x[:, ::2, ::2, :]  # stride-2 transition between blocks
            y1 = fwd.conv_bn_act(x, f"{pre}_b1")
            y3 = fwd.conv_bn_act(x, f"{pre}_b3r")
            y3 = fwd.conv_bn_act(y3, f"{pre}_b3")
            yd = fwd.conv_bn_act(x, f"{pre}_d3r")
            yd = fwd.conv_bn_act(yd, f"{pre}_d3a")
            yd = fwd.conv_bn_act(yd, f"{pre}_d3b")
            # 3×3 average-pool branch (SAME), then 1×1 projection.
            yp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode="edge")
            yp = sum(
                yp[:, i:i + x.shape[1], j:j + x.shape[2], :]
                for i in range(3) for j in range(3)
            ) / 9.0
            yp = fwd.conv_bn_act(yp, f"{pre}_pp")
            x = jnp.concatenate([y1, y3, yd, yp], axis=-1)
        x = fwd.global_avg_pool(x)
        return fwd.dense(x, "fc")

    return ModelDef(
        name="inception_sim", input_hw=(32, 32), in_ch=3, num_classes=num_classes,
        qlayers=tuple(qlayers), bn_names=tuple(bns), act_sites=tuple(acts),
        dense_bias=("fc",), forward=forward,
    )


# ---------------------------------------------------------------------------

_REGISTRY = {
    "tinynet": _tinynet_def,
    "resnet20": _resnet20_def,
    "resnet50_sim": _resnet50_sim_def,
    "inception_sim": _inception_sim_def,
}


def get_model(name: str) -> ModelDef:
    """Look up a model definition by registry name."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown model {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()
