"""L2 entry points: the jittable training/eval/HVP graphs that get AOT-lowered.

Each builder returns `(spec_in, spec_out, fn)` where `fn` consumes/produces
the flat tuples described by the specs (statespec.py). Everything that runs
per step — forward, loss (paper Eq. 5), BGL regularizer (Eq. 4 via the L1
kernel), backward, SGD-momentum update, [0,2] plane clamp, BN running-stat
update — lives inside one graph so the Rust hot path is a single PJRT
execute per step.

Entry points:
  fp_train      — float pretraining step (weights fp, activations ReLU6-quant
                  with runtime level vector; levels=0 disables quantization)
  bsq_train     — the paper's BSQ step: bit-rep STE forward, CE + α·Σ c_l·BGL,
                  momentum update on planes/BN/scale/(PACT), plane clamp
  dorefa_train  — DoReFa QAT at a fixed per-layer level vector (finetune and
                  train-from-scratch baseline)
  lsq_train     — learned-step-size QAT (LQ-Nets/LSQ baseline stand-in)
  eval          — loss/accuracy under any weight mode, BN running stats
  hvp           — Hessian-vector product of the CE loss w.r.t. fp weights
                  (HAWQ importance ranking; fp activations, eval-mode BN)
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import statespec as ss
from .layers import Forward
from .models import ModelDef
from .quantize import (act_quant, bgl_layer, bit_weight, dorefa_weight,
                       lsq_weight)

MOMENTUM = 0.9  # SGD momentum (paper App. A)


# ---------------------------------------------------------------------------
# forward-pass assembly
# ---------------------------------------------------------------------------

def _weight_fn(model: ModelDef, mode: str, env: Dict[str, jnp.ndarray]):
    """Weight provider for layers.Forward under a given weight mode."""
    lidx = {q.name: i for i, q in enumerate(model.qlayers)}

    def weight(name: str) -> jnp.ndarray:
        if name.endswith("/b"):          # dense biases stay float
            return env[f"w:{name}"]
        if mode == "fp":
            return env[f"w:{name}"]
        if mode == "bit":
            return bit_weight(env[f"wp:{name}"], env[f"wn:{name}"],
                              env[f"mask:{name}"], env[f"scale:{name}"])
        if mode == "dorefa":
            return dorefa_weight(env[f"w:{name}"], env["wlv"][lidx[name]])
        if mode == "lsq":
            return lsq_weight(env[f"w:{name}"], env[f"step:{name}"],
                              env["wlv"][lidx[name]])
        raise ValueError(mode)

    return weight


def _act_fn(model: ModelDef, act_mode: str, env: Dict[str, jnp.ndarray]):
    """Activation-site provider: ReLU6 bound or trainable PACT clip."""
    sites = model.act_sites

    def act(site: int, x: jnp.ndarray) -> jnp.ndarray:
        if act_mode == "ref":
            # Analysis paths (HVP) differentiate twice; the custom-VJP Pallas
            # kernel has no JVP rule, and HAWQ measures the fp model anyway.
            return jnp.clip(x, 0.0, 6.0)
        lv = env["actlv"][site]
        if act_mode == "pact":
            # Keep the clip strictly positive; gradient flows where α > min.
            bound = jnp.maximum(env[f"pact:{sites[site]}"], 0.05)
        else:
            bound = jnp.asarray(6.0, dtype=jnp.float32)
        return act_quant(x, bound, lv)

    return act


def _bn_view(model: ModelDef, env: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    view = {}
    for n in model.bn_names:
        for p in ("gamma", "beta", "mean", "var"):
            view[f"{n}/{p}"] = env[f"bn:{n}/{p}"]
    return view


def _forward(model: ModelDef, mode: str, act_mode: str,
             env: Dict[str, jnp.ndarray], train: bool):
    fwd = Forward(
        weight=_weight_fn(model, mode, env),
        bn_params=_bn_view(model, env),
        act_site=_act_fn(model, act_mode, env),
        train=train,
    )
    logits = model.forward(fwd, env["x"])
    return logits, fwd.new_stats


def _ce_acc(logits: jnp.ndarray, y: jnp.ndarray):
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return ce, acc


def _bgl_total(model: ModelDef, env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Σ_l regw_l · B_GL(W^l): the reweighed regularizer of paper Eq. 5.

    regw_l = #Para_l · #Bit_l / Σ#Para is recomputed by the Rust coordinator
    after every precision adjustment and fed in as the `regw` vector.
    """
    total = jnp.asarray(0.0, dtype=jnp.float32)
    for i, q in enumerate(model.qlayers):
        total += env["regw"][i] * bgl_layer(
            env[f"wp:{q.name}"], env[f"wn:{q.name}"], env[f"mask:{q.name}"])
    return total


# ---------------------------------------------------------------------------
# SGD-momentum update (shared by all train steps)
# ---------------------------------------------------------------------------

def _sgd_update(env, grads: Dict[str, jnp.ndarray], lr, wd) -> Dict[str, jnp.ndarray]:
    """PyTorch-convention SGD: m ← μm + (g + wd·w); w ← w − lr·m.

    Weight decay applies to float parameters (weights, biases, BN affine,
    PACT clips, LSQ steps) but *not* to bit planes — their shrinkage is the
    BGL regularizer's job (paper Eq. 5) — and not to the dynamic-range
    scales, which re-quantization manages.
    """
    out = {}
    for k, g in grads.items():
        decay = 0.0 if k.startswith(("wp:", "wn:", "scale:")) else wd
        m = MOMENTUM * env[f"m:{k}"] + g + decay * env[k]
        v = env[k] - lr * m
        if k.startswith(("wp:", "wn:")):
            # Paper §3.1: planes live in [0, 2] so re-quantization can grow
            # or shrink precision; trim after every step.
            v = jnp.clip(v, 0.0, 2.0)
        out[k] = v
        out[f"m:{k}"] = m
    return out


# ---------------------------------------------------------------------------
# entry-point builders
# ---------------------------------------------------------------------------

def _build_train(model: ModelDef, batch: int, mode: str, act_mode: str):
    """Shared builder for fp/bsq/dorefa/lsq train steps."""
    if mode == "fp":
        weight_in = ss.fp_weight_items(model)
        vecs = ss.vec_items(model, ["actlv"])
        hypers = ss.hyper_items(["lr", "wd"])
    elif mode == "bit":
        weight_in = ss.bit_weight_items(model)
        vecs = ss.vec_items(model, ["regw", "actlv"])
        hypers = ss.hyper_items(["lr", "wd", "alpha"])
    elif mode == "dorefa":
        weight_in = ss.fp_weight_items(model)
        vecs = ss.vec_items(model, ["wlv", "actlv"])
        hypers = ss.hyper_items(["lr", "wd"])
    elif mode == "lsq":
        weight_in = ss.fp_weight_items(model) + ss.lsq_items(model)
        vecs = ss.vec_items(model, ["wlv", "actlv"])
        hypers = ss.hyper_items(["lr", "wd"])
    else:
        raise ValueError(mode)

    bn_in = ss.bn_items(model)
    pact_in = ss.pact_items(model) if act_mode == "pact" else []

    # Trainables: everything differentiable. Masks and (for non-bit modes)
    # level vectors are configuration, not parameters.
    trainables = [
        i for i in weight_in + bn_in + pact_in
        if not i.name.startswith("mask:")
        and "/mean" not in i.name and "/var" not in i.name
    ]
    momenta = ss.momentum_items(trainables)

    spec_in = (ss.batch_items(model, batch) + weight_in + bn_in + pact_in
               + momenta + vecs + hypers)

    bn_stat_out = [i for i in bn_in if "/mean" in i.name or "/var" in i.name]
    metrics = ["loss", "ce", "acc"] + (["bgl"] if mode == "bit" else [])
    spec_out = (ss.as_state_outputs(trainables) + ss.as_state_outputs(momenta)
                + ss.as_state_outputs(bn_stat_out) + ss.metric_items(metrics))

    tkeys = [t.name for t in trainables]

    def fn(*flat):
        env = ss.env_from_flat(spec_in, flat)
        params = {k: env[k] for k in tkeys}

        def loss_fn(params):
            e = dict(env)
            e.update(params)
            logits, new_stats = _forward(model, mode, act_mode, e, train=True)
            ce, acc = _ce_acc(logits, e["y"])
            if mode == "bit":
                bgl = _bgl_total(model, e)
                loss = ce + e["alpha"] * bgl
            else:
                bgl = jnp.asarray(0.0, dtype=jnp.float32)
                loss = ce
            return loss, (ce, acc, bgl, new_stats)

        (loss, (ce, acc, bgl, new_stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)

        out_env = dict(env)
        out_env.update(_sgd_update(env, grads, env["lr"], env["wd"]))
        for k, v in new_stats.items():
            out_env[f"bn:{k}"] = v
        out_env.update({"loss": loss, "ce": ce, "acc": acc, "bgl": bgl})
        return ss.flat_from_env(spec_out, out_env)

    return spec_in, spec_out, fn


def _build_eval(model: ModelDef, batch: int, mode: str, act_mode: str):
    if mode == "fp":
        weight_in = ss.fp_weight_items(model)
        vecs = ss.vec_items(model, ["actlv"])
    elif mode == "bit":
        weight_in = ss.bit_weight_items(model)
        vecs = ss.vec_items(model, ["actlv"])
    elif mode == "dorefa":
        weight_in = ss.fp_weight_items(model)
        vecs = ss.vec_items(model, ["wlv", "actlv"])
    elif mode == "lsq":
        weight_in = ss.fp_weight_items(model) + ss.lsq_items(model)
        vecs = ss.vec_items(model, ["wlv", "actlv"])
    else:
        raise ValueError(mode)

    bn_in = ss.bn_items(model)
    pact_in = ss.pact_items(model) if act_mode == "pact" else []
    spec_in = ss.batch_items(model, batch) + weight_in + bn_in + pact_in + vecs
    spec_out = ss.metric_items(["loss", "acc"])

    def fn(*flat):
        env = ss.env_from_flat(spec_in, flat)
        logits, _ = _forward(model, mode, act_mode, env, train=False)
        ce, acc = _ce_acc(logits, env["y"])
        return ss.flat_from_env(spec_out, {"loss": ce, "acc": acc})

    return spec_in, spec_out, fn


def _build_hvp(model: ModelDef, batch: int):
    """Hessian-vector product for HAWQ's importance score S_i = λ_i / n_i.

    Differentiates the CE loss twice w.r.t. the fp conv/dense weights at
    eval-mode BN and full-precision activations, matching HAWQ's analysis of
    the pretrained float model. The Rust side runs block power iteration by
    zeroing v outside the layer under analysis.
    """
    weight_in = ss.fp_weight_items(model)
    bn_in = ss.bn_items(model)
    probes = [ss.IOItem(f"v:{q.name}", q.shape, "f32", "probe")
              for q in model.qlayers]
    # NOTE: no actlv input — the "ref" activation path ignores it, and XLA
    # prunes unused entry parameters, which would desync the manifest.
    spec_in = ss.batch_items(model, batch) + weight_in + bn_in + probes
    spec_out = [ss.IOItem(f"hv:{q.name}", q.shape, "f32", "probe_out")
                for q in model.qlayers] + ss.metric_items(["loss"])

    wkeys = [f"w:{q.name}" for q in model.qlayers]

    def fn(*flat):
        env = ss.env_from_flat(spec_in, flat)

        def loss_of(wdict):
            e = dict(env)
            e.update(wdict)
            logits, _ = _forward(model, "fp", "ref", e, train=False)
            ce, _ = _ce_acc(logits, e["y"])
            return ce

        w0 = {k: env[k] for k in wkeys}
        v = {k: env[f"v:{q}"] for k, q in zip(wkeys, [q.name for q in model.qlayers])}
        # jvp of grad: primal out = grad (a dict, unused); tangent out = H·v.
        _, hv = jax.jvp(jax.grad(loss_of), (w0,), (v,))
        out = {f"hv:{q.name}": hv[f"w:{q.name}"] for q in model.qlayers}
        out["loss"] = loss_of(w0)
        return ss.flat_from_env(spec_out, out)

    return spec_in, spec_out, fn


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def build_entry(model: ModelDef, kind: str, batch: int):
    """kind: '<fn>_<actmode>' e.g. 'bsq_train_relu6', or 'hvp'."""
    if kind == "hvp":
        return _build_hvp(model, batch)
    base, act_mode = kind.rsplit("_", 1)
    assert act_mode in ("relu6", "pact"), kind
    mode_map = {
        "fp_train": ("fp", _build_train), "fp_eval": ("fp", _build_eval),
        "bsq_train": ("bit", _build_train), "q_eval": ("bit", _build_eval),
        "dorefa_train": ("dorefa", _build_train),
        "dorefa_eval": ("dorefa", _build_eval),
        "lsq_train": ("lsq", _build_train), "lsq_eval": ("lsq", _build_eval),
    }
    mode, builder = mode_map[base]
    return builder(model, batch, mode, act_mode)
