"""L2 quantization plumbing: STE wrappers over the L1 kernels.

Implements every quantizer the reproduction needs:
  * bit_weight     — BSQ bit-representation weight reconstruction (Eq. 2/3)
  * dorefa_weight  — DoReFa-Net uniform weight quantizer (paper Eq. 1 family),
                     used for finetuning and the train-from-scratch baseline
  * lsq_weight     — learned-step-size quantizer (LQ-Nets/LSQ stand-in)
  * act_quant      — ReLU6 / PACT activation quantization (paper §3.3)
  * bgl_layer      — the bit-level group-Lasso term of one layer (Eq. 4)

All rounding is expressed with the straight-through estimator
`x + stop_gradient(round(x) − x)` so gradients flow as the paper specifies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bgl_sumsq, fakequant, plane_sum
from .kernels.ref import BGL_EPS

NB = 9  # fixed bit-plane count: 8-bit initial precision + 1 overflow plane


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round with identity gradient (Bengio et al., 2013)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def pow2_vec(mask: jnp.ndarray) -> jnp.ndarray:
    """mask ⊙ [1, 2, 4, …]: per-plane weights of the reconstruction."""
    return mask * (2.0 ** jnp.arange(mask.shape[0], dtype=jnp.float32))


def bit_weight(wp: jnp.ndarray, wn: jnp.ndarray, mask: jnp.ndarray,
               scale: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the effective weight from bit planes (paper Eq. 2 + 3).

    wp/wn: [NB, *shape] trainable planes in [0, 2]; mask: [NB] 0/1 with the
    active planes bottom-packed; scale: scalar s.

    W = s · Round[Σ_b mask_b (wp_b − wn_b) 2^b] / max(Σ_b mask_b 2^b, 1)

    The plane reduction runs in the L1 Pallas kernel; with bottom-packed
    masks Σ_b mask_b 2^b = 2^n − 1 so the denominator is the paper's. The
    max(·, 1) guard keeps a fully pruned (n = 0) layer finite (it is exactly
    zero: every plane is masked).
    """
    shape = wp.shape[1:]
    p2 = pow2_vec(mask)
    v = plane_sum(wp.reshape(NB, -1), wn.reshape(NB, -1), p2)
    denom = jnp.maximum(jnp.sum(p2), 1.0)
    return (scale * ste_round(v) / denom).reshape(shape)


def bgl_layer(wp: jnp.ndarray, wn: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Bit-level group Lasso of one layer (paper Eq. 4), eps-smoothed.

    Only active planes are penalized; the sqrt is taken at the JAX level on
    the per-plane sums of squares produced by the L1 kernel.
    """
    ssq = bgl_sumsq(wp.reshape(NB, -1), wn.reshape(NB, -1))
    return jnp.sum(mask * jnp.sqrt(ssq + BGL_EPS))


def dorefa_weight(w: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """DoReFa-style uniform weight quantizer at a fixed level count.

    Follows the paper's finetuning setup (DoReFa-Net, Zhou et al. 2016, with
    the dynamic-range scaling of Polino et al. 2018): scale by max|w|,
    quantize magnitude onto `levels` = 2^n − 1 uniform steps, restore sign
    and range. `levels` is a traced scalar so one artifact serves any
    precision; levels < 1 (an n = 0 layer) collapses the weight to zero.
    """
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    ws = w / s
    lv = jnp.maximum(levels, 1.0)
    wq = ste_round(jnp.abs(ws) * lv) / lv * jnp.sign(ws)
    wq = jnp.where(levels >= 1.0, wq, jnp.zeros_like(wq))
    return s * wq


def lsq_weight(w: jnp.ndarray, step: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Learned-step-size uniform quantizer (LSQ, Esser et al. 2019).

    Stands in for the learned-quantizer baselines (LQ-Nets/LSQ rows of the
    paper's Tables 2–3). Symmetric: codes in [−levels, levels] of width
    `step`, with the LSQ gradient-scale heuristic folded into the caller's
    learning rate.
    """
    lv = jnp.maximum(levels, 1.0)
    st = jnp.maximum(step, 1e-8)
    code = jnp.clip(w / st, -lv, lv)
    return ste_round(code) * st


def act_quant(x: jnp.ndarray, bound: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Quantized clipped activation via the L1 fake-quant kernel.

    `bound` is 6.0 for the ReLU6 path (≥4-bit) or the trainable PACT clip
    (<4-bit). `levels` = 2^a − 1 is a traced scalar; levels ≤ 0 disables
    quantization (full-precision activations) while keeping the clip.
    """
    q = fakequant(x, bound, jnp.maximum(levels, 1.0))
    clipped = jnp.clip(x, 0.0, bound)
    return jnp.where(levels >= 1.0, q, clipped)
