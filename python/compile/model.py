"""Artifact registry: which entry points get lowered for which model.

The sets mirror what the experiments need (DESIGN.md §6–7):
  * tinynet       — fast integration-test model: full BSQ pipeline + HVP.
  * resnet20      — the paper's CIFAR-10 model: everything, including the
                    PACT (2/3-bit activation) variants and the LSQ baseline.
  * resnet50_sim / inception_sim — ImageNet-row twins: ReLU6 path only
                    (the paper uses ≥4-bit activations on ImageNet).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

_RELU6_SET = [
    "fp_train_relu6", "fp_eval_relu6",
    "bsq_train_relu6", "q_eval_relu6",
    "dorefa_train_relu6", "dorefa_eval_relu6",
]
_PACT_SET = [
    "bsq_train_pact", "q_eval_pact",
    "dorefa_train_pact", "dorefa_eval_pact",
]
_LSQ_SET = ["lsq_train_relu6", "lsq_eval_relu6"]

# model → (train/eval batch size, entry list)
REGISTRY: Dict[str, Tuple[int, List[str]]] = {
    "tinynet": (16, _RELU6_SET + ["hvp"]),
    "resnet20": (32, _RELU6_SET + _PACT_SET + _LSQ_SET + ["hvp"]),
    "resnet50_sim": (32, _RELU6_SET),
    "inception_sim": (32, _RELU6_SET),
}
