"""L1 Pallas kernel: bit-level group Lasso (paper Eq. 4).

    B_GL(W^g) = Σ_b ‖[W_p^(b); W_n^(b)]‖_2

The reduction per plane b is a sum of squares over every element of the
layer's positive and negative bit planes. We block along the element axis and
accumulate into a [NB] output across grid steps (Pallas guarantees sequential
grid execution on a core, so read-modify-write accumulation into the same
output block is well-defined). Padded tail elements are masked with an iota
compare so they contribute exactly zero to the norm.

The square root (with eps smoothing at the origin) and the mask product are
composed at the JAX level; the gradient of sqrt(ssq+eps) is analytic there,
while this kernel's own backward (d ssq / d wp = 2·wp) is provided as a
custom VJP with a matching element-wise Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_E = 32768
INTERPRET = True


def _sumsq_kernel(nelem_ref, wp_ref, wn_ref, o_ref):
    """Accumulate per-plane Σ(wp²+wn²) for one element block into o[NB]."""
    i = pl.program_id(0)
    nb, be = wp_ref.shape
    # Mask the padded tail: global element index must be < nelem.
    idx = i * be + jax.lax.broadcasted_iota(jnp.int32, (1, be), 1)
    valid = (idx < nelem_ref[0]).astype(wp_ref.dtype)
    wp = wp_ref[...] * valid
    wn = wn_ref[...] * valid
    part = jnp.sum(wp * wp + wn * wn, axis=1)  # [NB]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def _sumsq_bwd_kernel(g_ref, wp_ref, wn_ref, gp_ref, gn_ref):
    g = g_ref[...].reshape(-1, 1)  # [NB, 1]
    gp_ref[...] = 2.0 * wp_ref[...] * g
    gn_ref[...] = 2.0 * wn_ref[...] * g


def _pad(x):
    rem = (-x.shape[1]) % BLOCK_E
    if rem == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, rem)))


@jax.custom_vjp
def bgl_sumsq(wp: jnp.ndarray, wn: jnp.ndarray) -> jnp.ndarray:
    """ssq[b] = Σ_e wp[b,e]² + wn[b,e]² over a layer's planes ([NB, E])."""
    return _bgl_sumsq_impl(wp, wn)


def _bgl_sumsq_impl(wp, wn):
    nb, e = wp.shape
    wp_p, wn_p = _pad(wp), _pad(wn)
    ep = wp_p.shape[1]
    grid = (ep // BLOCK_E,)
    nelem = jnp.array([e], dtype=jnp.int32)
    return pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((nb,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nb,), wp.dtype),
        interpret=INTERPRET,
    )(nelem, wp_p, wn_p)


def _bgl_sumsq_fwd(wp, wn):
    return _bgl_sumsq_impl(wp, wn), (wp, wn)


def _bgl_sumsq_bwd(res, g):
    wp, wn = res
    nb, e = wp.shape
    wp_p, wn_p = _pad(wp), _pad(wn)
    ep = wp_p.shape[1]
    grid = (ep // BLOCK_E,)
    gp, gn = pl.pallas_call(
        _sumsq_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb,), lambda i: (0,)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, ep), wp.dtype),
            jax.ShapeDtypeStruct((nb, ep), wp.dtype),
        ],
        interpret=INTERPRET,
    )(g, wp_p, wn_p)
    return gp[:, :e], gn[:, :e]


bgl_sumsq.defvjp(_bgl_sumsq_fwd, _bgl_sumsq_bwd)
