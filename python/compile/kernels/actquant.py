"""L1 Pallas kernel: activation fake-quantization (ReLU6 / PACT paths).

Paper §3.3: activations are quantized at a fixed precision chosen per layer
(8-bit first/last, 2–4-bit elsewhere); ReLU6 bounds are used at ≥4 bits and
the trainable PACT clip (Choi et al., 2018) below that. Both reduce to the
same primitive:

    q = Round[clip(x, 0, bound) / bound · levels] / levels · bound

with `levels = 2^a − 1` a runtime scalar and `bound` either the constant 6.0
(ReLU6) or a trained PACT parameter. The STE backward passes the gradient
inside (0, bound) and routes the above-bound mass to the bound (the PACT
clip-parameter gradient).

Forward and backward are element-wise Pallas kernels blocked along a
flattened element axis; wrappers reshape arbitrary activation shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_E = 65536
INTERPRET = True


def _fq_kernel(bound_ref, levels_ref, x_ref, o_ref):
    b = bound_ref[0]
    lv = levels_ref[0]
    xc = jnp.clip(x_ref[...], 0.0, b)
    o_ref[...] = jnp.round(xc / b * lv) / lv * b


def _fq_bwd_kernel(bound_ref, x_ref, g_ref, gx_ref, gb_ref):
    i = pl.program_id(0)
    b = bound_ref[0]
    x = x_ref[...]
    g = g_ref[...]
    inside = jnp.logical_and(x > 0.0, x < b)
    gx_ref[...] = jnp.where(inside, g, 0.0)
    part = jnp.sum(jnp.where(x >= b, g, 0.0))

    @pl.when(i == 0)
    def _init():
        gb_ref[...] = jnp.zeros_like(gb_ref)

    gb_ref[0] += part


def _pad1(x, fill=0.0):
    rem = (-x.shape[0]) % BLOCK_E
    if rem == 0:
        return x
    # Pad with -1 on the forward path: clips to 0 and quantizes to 0; on the
    # backward path a -1 pad falls outside (0, bound) so both gradient
    # contributions of the padded tail are exactly zero.
    return jnp.pad(x, (0, rem), constant_values=fill)


@jax.custom_vjp
def fakequant(x: jnp.ndarray, bound: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize x (any shape) onto `levels` uniform steps of [0, bound]."""
    return _fq_impl(x, bound, levels)


def _fq_impl(x, bound, levels):
    shape = x.shape
    xf = _pad1(x.reshape(-1), fill=-1.0)
    ep = xf.shape[0]
    grid = (ep // BLOCK_E,)
    out = pl.pallas_call(
        _fq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), x.dtype),
        interpret=INTERPRET,
    )(bound.reshape(1), levels.reshape(1), xf)
    return out[: x.size].reshape(shape)


def _fq_fwd(x, bound, levels):
    return _fq_impl(x, bound, levels), (x, bound)


def _fq_bwd(res, g):
    x, bound = res
    shape = x.shape
    xf = _pad1(x.reshape(-1), fill=-1.0)
    gf = _pad1(g.reshape(-1), fill=0.0)
    ep = xf.shape[0]
    grid = (ep // BLOCK_E,)
    gx, gb = pl.pallas_call(
        _fq_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ep,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=INTERPRET,
    )(bound.reshape(1), xf, gf)
    # levels is a fixed configuration input: zero cotangent.
    return gx[: x.size].reshape(shape), gb.reshape(()), jnp.zeros(())


fakequant.defvjp(_fq_fwd, _fq_bwd)
