"""Pure-jnp reference oracles for every Pallas kernel in this package.

These implement the paper's equations directly with jax.numpy and serve as
the correctness ground truth: pytest (python/tests/test_kernels.py) asserts
that each Pallas kernel matches its oracle bit-for-bit (or to fp32 tolerance
where a reduction order differs), for both values and gradients.

Paper: BSQ (Yang et al., ICLR 2021).
  Eq. 2  bit representation    W = sign(W) ⊙ s/(2^n−1) Σ_b W_s^(b) 2^b
  Eq. 3  bit-rep STE           fwd round, bwd scaled pass-through
  Eq. 4  bit-level group Lasso B_GL = Σ_b ‖[W_p^(b); W_n^(b)]‖_2
"""

from __future__ import annotations

import jax.numpy as jnp

# Smoothing constant for the group-Lasso norm at zero (the subgradient at the
# origin is the zero vector; the eps keeps the gradient defined and bounded).
BGL_EPS = 1e-12


def plane_sum_ref(wp: jnp.ndarray, wn: jnp.ndarray, pow2: jnp.ndarray) -> jnp.ndarray:
    """Masked bit-plane reconstruction (the linear part of paper Eq. 2/3).

    Args:
      wp: positive bit planes, shape [NB, E], values in [0, 2].
      wn: negative bit planes, shape [NB, E].
      pow2: per-plane weights, shape [NB]; caller passes mask_b * 2**b so a
        disabled plane contributes nothing.

    Returns:
      v[E] = Σ_b pow2[b] * (wp[b] − wn[b])  (float, *before* rounding).
    """
    return jnp.einsum("b,be->e", pow2, wp - wn)


def bgl_sumsq_ref(wp: jnp.ndarray, wn: jnp.ndarray) -> jnp.ndarray:
    """Per-plane sum of squares over the [W_p^(b); W_n^(b)] concatenation.

    Returns ssq[NB]; the bit-level group-Lasso of paper Eq. 4 is
    Σ_b mask_b * sqrt(ssq[b] + eps) (assembled at the L2 level).
    """
    return jnp.sum(wp * wp + wn * wn, axis=1)


def bgl_ref(wp: jnp.ndarray, wn: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Full bit-level group Lasso (paper Eq. 4), eps-smoothed at 0."""
    ssq = bgl_sumsq_ref(wp, wn)
    return jnp.sum(mask * jnp.sqrt(ssq + BGL_EPS))


def fakequant_ref(x: jnp.ndarray, bound: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Uniform activation fake-quantization on [0, bound] with `levels` steps.

    q = round(clip(x, 0, bound) / bound * levels) / levels * bound
    `levels` = 2^a − 1 for an a-bit activation. Matches Polino et al. (2018)
    as adopted by the paper (§3.3, activation quantization).
    """
    xc = jnp.clip(x, 0.0, bound)
    return jnp.round(xc / bound * levels) / levels * bound


def fakequant_bwd_ref(x: jnp.ndarray, bound: jnp.ndarray, g: jnp.ndarray):
    """STE backward of fake-quant: pass-through inside [0, bound].

    Returns (gx, gbound): gx masks the gradient to the un-clipped region;
    gbound accumulates the PACT clip gradient (Choi et al., 2018): elements
    clipped from above move with the bound.
    """
    inside = jnp.logical_and(x > 0.0, x < bound)
    gx = jnp.where(inside, g, 0.0)
    gbound = jnp.sum(jnp.where(x >= bound, g, 0.0))
    return gx, gbound


def bitrep_quantize_ref(wp, wn, mask, scale):
    """Full paper Eq. 2 reconstruction with rounding (no STE; value only).

    W = scale * Round[Σ_b mask_b (wp_b − wn_b) 2^b] / max(Σ_b mask_b 2^b, 1)
    """
    nb = wp.shape[0]
    pow2 = mask * (2.0 ** jnp.arange(nb, dtype=jnp.float32))
    v = plane_sum_ref(wp.reshape(nb, -1), wn.reshape(nb, -1), pow2)
    denom = jnp.maximum(jnp.sum(pow2), 1.0)
    return (scale * jnp.round(v) / denom).reshape(wp.shape[1:])
