"""L1 Pallas kernel: bit-plane reconstruction with the paper's STE (Eq. 2/3).

The hot spot of BSQ training is reconstructing every layer's weight tensor
from its NB bit planes at every step:

    v      = Σ_b mask_b (W_p^(b) − W_n^(b)) 2^b          (plane reduction)
    W_q    = Round[v] / max(Σ_b mask_b 2^b, 1)           (quantize)
    W      = scale ⊙ W_q                                  (rescale)

The plane reduction is the bandwidth-dominant part (NB+1 tensor reads per
weight element per step) and is implemented here as a Pallas kernel, blocked
along the element axis so each block's planes live in VMEM while the
accumulation runs. The backward of the (linear) reduction is exactly the
paper's Eq. 3 STE backward — ∂L/∂W^(b) = 2^b/(2^n−1) ∂L/∂W_q — and is
implemented as a second (broadcast) Pallas kernel via jax.custom_vjp.

Rounding + denominator + scale are composed at the JAX level (bit_weight in
python/compile/quantize.py) where `stop_gradient` expresses the round STE.

Hardware adaptation (DESIGN.md §3): the paper trains on GPUs with no custom
kernels; on TPU this reduction is VPU work. Block shape [NB, BLOCK_E] keeps
the working set (9·BLOCK_E·4 B ≈ 1.2 MiB per plane tensor at BLOCK_E=32768;
wp+wn+out ≈ 2.5 MiB, ×2 for double-buffering ≈ 5 MiB) comfortably under the
16 MiB VMEM budget while minimizing grid-iteration overhead — the block size
was raised from 4096 after the §Perf pass measured the lowered interpret-mode
grid loop dominating the step (EXPERIMENTS.md §Perf: −46%% step latency).
interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Element-axis block: covers all but the largest resnet layers in one grid
# step; VMEM working set ≈ 5 MiB with double buffering (see module doc).
BLOCK_E = 32768

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _plane_sum_kernel(pow2_ref, wp_ref, wn_ref, o_ref):
    """o[e] = Σ_b pow2[b] * (wp[b, e] − wn[b, e]) for one element block."""
    diff = wp_ref[...] - wn_ref[...]            # [NB, BE]
    w = pow2_ref[...].reshape(-1, 1)            # [NB, 1]
    o_ref[...] = jnp.sum(diff * w, axis=0)      # [BE]


def _plane_sum_bwd_kernel(pow2_ref, g_ref, gp_ref, gn_ref):
    """Paper Eq. 3 backward: broadcast g over planes scaled by 2^b·mask_b."""
    g = g_ref[...].reshape(1, -1)               # [1, BE]
    w = pow2_ref[...].reshape(-1, 1)            # [NB, 1]
    gp_ref[...] = g * w                         # [NB, BE]
    gn_ref[...] = -g * w


def _pad_to_block(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Right-pad `axis` to a multiple of BLOCK_E with zeros."""
    e = x.shape[axis]
    rem = (-e) % BLOCK_E
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def plane_sum(wp: jnp.ndarray, wn: jnp.ndarray, pow2: jnp.ndarray) -> jnp.ndarray:
    """v[E] = Σ_b pow2[b]·(wp[b,:] − wn[b,:]); linear, custom VJP = Eq. 3.

    wp, wn: [NB, E] bit planes; pow2: [NB] = mask ⊙ 2^arange(NB).
    """
    return _plane_sum_fwd_impl(wp, wn, pow2)


def _plane_sum_fwd_impl(wp, wn, pow2):
    nb, e = wp.shape
    wp_p = _pad_to_block(wp, 1)
    wn_p = _pad_to_block(wn, 1)
    ep = wp_p.shape[1]
    grid = (ep // BLOCK_E,)
    out = pl.pallas_call(
        _plane_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb,), lambda i: (0,)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), wp.dtype),
        interpret=INTERPRET,
    )(pow2, wp_p, wn_p)
    return out[:e]


def _plane_sum_fwd(wp, wn, pow2):
    return _plane_sum_fwd_impl(wp, wn, pow2), (pow2, wp.shape)


def _plane_sum_bwd(res, g):
    pow2, (nb, e) = res
    g_p = _pad_to_block(g, 0)
    ep = g_p.shape[0]
    grid = (ep // BLOCK_E,)
    gp, gn = pl.pallas_call(
        _plane_sum_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
            pl.BlockSpec((nb, BLOCK_E), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, ep), g.dtype),
            jax.ShapeDtypeStruct((nb, ep), g.dtype),
        ],
        interpret=INTERPRET,
    )(pow2, g_p)
    # pow2 (mask·2^b) is a non-trained configuration input: zero cotangent.
    return gp[:, :e], gn[:, :e], jnp.zeros_like(pow2)


plane_sum.defvjp(_plane_sum_fwd, _plane_sum_bwd)
