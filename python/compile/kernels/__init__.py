"""L1: Pallas kernels for BSQ's compute hot spots, with pure-jnp oracles.

Exports:
  bitrep.plane_sum   — masked bit-plane reconstruction (paper Eq. 2/3 STE)
  bgl.bgl_sumsq      — per-plane sum-of-squares for the group Lasso (Eq. 4)
  actquant.fakequant — activation fake-quantization (ReLU6 / PACT bounds)
  ref.*              — jnp reference implementations (test oracles)
"""

from . import ref  # noqa: F401
from .actquant import fakequant  # noqa: F401
from .bgl import bgl_sumsq  # noqa: F401
from .bitrep import plane_sum  # noqa: F401
