"""AOT compiler: lower every registered entry point to HLO text + manifest.

Run via `make artifacts` (never at runtime):

    cd python && python -m compile.aot --out-dir ../artifacts [--model NAME]

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model this writes
    artifacts/<model>/<entry>.hlo.txt
    artifacts/<model>/manifest.json     (layer metadata + flat I/O specs)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from .model import REGISTRY
from .models import get_model
from .quantize import NB
from .train import build_entry


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, out_dir: str, batch_override: int | None = None) -> dict:
    batch, entries = REGISTRY[name]
    if batch_override:
        batch = batch_override
    model = get_model(name)
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)

    manifest = {
        "model": name,
        "version": 1,
        "batch": batch,
        "nb": NB,
        "input_hw": list(model.input_hw),
        "in_ch": model.in_ch,
        "num_classes": model.num_classes,
        "qlayers": [
            {"name": q.name, "shape": list(q.shape), "kind": q.kind,
             "params": q.params}
            for q in model.qlayers
        ],
        "bn_names": list(model.bn_names),
        "act_sites": list(model.act_sites),
        "dense_bias": list(model.dense_bias),
        "artifacts": {},
    }

    for entry in entries:
        t0 = time.time()
        spec_in, spec_out, fn = build_entry(model, entry, batch)
        lowered = jax.jit(fn).lower(*[i.sds() for i in spec_in])
        text = to_hlo_text(lowered)
        fname = f"{entry}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][entry] = {
            "file": fname,
            "inputs": [i.to_json() for i in spec_in],
            "outputs": [o.to_json() for o in spec_out],
        }
        print(f"  {name}/{entry}: {len(spec_in)} in / {len(spec_out)} out, "
              f"{len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s")

    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--model", default=None,
                    help="lower a single model (default: all registered)")
    ap.add_argument("--batch", type=int, default=None,
                    help="override the registered batch size")
    args = ap.parse_args()

    names = [args.model] if args.model else list(REGISTRY)
    t0 = time.time()
    for name in names:
        print(f"lowering {name} …")
        lower_model(name, args.out_dir, args.batch)
    print(f"done in {time.time() - t0:.1f}s → {os.path.abspath(args.out_dir)}")


if __name__ == "__main__":
    main()
