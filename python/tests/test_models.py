"""Model-zoo structural checks: shapes, metadata consistency, forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.layers import Forward, pad_shortcut
from compile.models import get_model

MODELS = ["tinynet", "resnet20", "resnet50_sim", "inception_sim"]


def _fp_forward(model, batch=2, seed=0):
    """Run the model float, with random params, identity act clip."""
    rng = np.random.RandomState(seed)
    weights = {}
    for q in model.qlayers:
        fan_in = int(np.prod(q.shape[:-1]))
        weights[q.name] = jnp.asarray(
            (rng.randn(*q.shape) * np.sqrt(2.0 / fan_in)).astype(np.float32))
    for d in model.dense_bias:
        out = [q.shape[-1] for q in model.qlayers if q.name == d][0]
        weights[f"{d}/b"] = jnp.zeros((out,))
    bn = {}
    for n in model.bn_names:
        c = [q.shape[-1] for q in model.qlayers if q.name == n][0]
        bn[f"{n}/gamma"] = jnp.ones((c,))
        bn[f"{n}/beta"] = jnp.zeros((c,))
        bn[f"{n}/mean"] = jnp.zeros((c,))
        bn[f"{n}/var"] = jnp.ones((c,))
    h, w = model.input_hw
    x = jnp.asarray(rng.randn(batch, h, w, model.in_ch).astype(np.float32))
    fwd = Forward(weight=lambda nm: weights[nm], bn_params=bn,
                  act_site=lambda s, a: jnp.clip(a, 0.0, 6.0), train=True)
    return model.forward(fwd, x), fwd


class TestModelZoo:
    @pytest.mark.parametrize("name", MODELS)
    def test_forward_shape_and_finite(self, name):
        model = get_model(name)
        logits, _ = _fp_forward(model)
        assert logits.shape == (2, model.num_classes)
        assert np.isfinite(np.asarray(logits)).all()

    @pytest.mark.parametrize("name", MODELS)
    def test_act_site_count_matches_metadata(self, name):
        model = get_model(name)
        _, fwd = _fp_forward(model)
        assert fwd._site == len(model.act_sites)

    @pytest.mark.parametrize("name", MODELS)
    def test_bn_updates_collected_for_every_bn(self, name):
        model = get_model(name)
        _, fwd = _fp_forward(model)
        got = {k.rsplit("/", 1)[0] for k in fwd.new_stats}
        assert got == set(model.bn_names)

    @pytest.mark.parametrize("name", MODELS)
    def test_qlayer_names_unique(self, name):
        model = get_model(name)
        names = [q.name for q in model.qlayers]
        assert len(names) == len(set(names))

    def test_resnet20_is_the_papers_20_layers(self):
        model = get_model("resnet20")
        assert len(model.qlayers) == 20  # conv1 + 18 block convs + fc
        assert model.qlayers[0].shape == (3, 3, 3, 16)
        assert model.qlayers[-1].kind == "dense"
        # ~0.27M parameters, matching He et al. (2016) ResNet-20
        assert 0.25e6 < model.total_params < 0.30e6

    def test_resnet50_sim_has_bottlenecks_and_projections(self):
        model = get_model("resnet50_sim")
        names = [q.name for q in model.qlayers]
        assert "s0b0proj" in names and "s2b0proj" in names
        k1 = [q for q in model.qlayers if q.name == "s1b0c1"][0]
        assert k1.shape[:2] == (1, 1)  # bottleneck reduce is 1×1

    def test_inception_sim_branch_structure(self):
        model = get_model("inception_sim")
        names = [q.name for q in model.qlayers]
        for br in ("_b1", "_b3r", "_b3", "_d3r", "_d3a", "_d3b", "_pp"):
            assert f"mix0{br}" in names

    def test_pad_shortcut(self):
        x = jnp.ones((1, 8, 8, 4))
        y = pad_shortcut(x, 8, 2)
        assert y.shape == (1, 4, 4, 8)
        np.testing.assert_array_equal(np.asarray(y[..., 4:]), 0.0)
        np.testing.assert_array_equal(np.asarray(y[..., :4]), 1.0)

    def test_eval_mode_uses_running_stats(self):
        model = get_model("tinynet")
        rng = np.random.RandomState(0)
        _, fwd = _fp_forward(model)
        assert fwd.new_stats  # train mode collected stats
        # eval mode must not touch stats
        weights = {q.name: jnp.zeros(q.shape) for q in model.qlayers}
        weights["fc/b"] = jnp.zeros((10,))
        bn = {}
        for n in model.bn_names:
            c = [q.shape[-1] for q in model.qlayers if q.name == n][0]
            bn.update({f"{n}/gamma": jnp.ones((c,)), f"{n}/beta": jnp.zeros((c,)),
                       f"{n}/mean": jnp.zeros((c,)), f"{n}/var": jnp.ones((c,))})
        x = jnp.asarray(rng.randn(1, 16, 16, 3).astype(np.float32))
        fwd2 = Forward(weight=lambda nm: weights[nm], bn_params=bn,
                       act_site=lambda s, a: a, train=False)
        model.forward(fwd2, x)
        assert not fwd2.new_stats
