"""L2 quantizer semantics: bit_weight (paper Eq. 2/3), DoReFa, LSQ, act_quant."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.quantize import (NB, act_quant, bgl_layer, bit_weight,
                              dorefa_weight, lsq_weight, pow2_vec, ste_round)


def bits_mask(n):
    return jnp.asarray([1.0] * n + [0.0] * (NB - n))


class TestBitWeight:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(0, NB), seed=st.integers(0, 2**31 - 1))
    def test_matches_eq2_ref(self, n, seed):
        rng = np.random.RandomState(seed)
        shape = (3, 3, 2, 4)
        wp = jnp.asarray(rng.uniform(0, 2, (NB,) + shape).astype(np.float32))
        wn = jnp.asarray(rng.uniform(0, 2, (NB,) + shape).astype(np.float32))
        mask, scale = bits_mask(n), jnp.asarray(0.37, dtype=jnp.float32)
        got = bit_weight(wp, wn, mask, scale)
        want = ref.bitrep_quantize_ref(wp, wn, mask, scale)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
        assert got.shape == shape

    def test_exact_binary_roundtrip(self):
        """Exact binary planes reconstruct the exact fixed-point value."""
        n = 4
        # code 0b1011 = 11 → w = s * 11 / 15
        wp = jnp.zeros((NB, 1))
        wp = wp.at[0, 0].set(1.0).at[1, 0].set(1.0).at[3, 0].set(1.0)
        wn = jnp.zeros((NB, 1))
        w = bit_weight(wp, wn, bits_mask(n), jnp.asarray(1.5))
        np.testing.assert_allclose(np.asarray(w), 1.5 * 11 / 15, rtol=1e-6)

    def test_zero_bit_layer_is_zero_and_finite(self):
        rng = np.random.RandomState(0)
        wp = jnp.asarray(rng.uniform(0, 2, (NB, 8)).astype(np.float32))
        wn = jnp.asarray(rng.uniform(0, 2, (NB, 8)).astype(np.float32))
        w = bit_weight(wp, wn, bits_mask(0), jnp.asarray(1.0))
        assert np.isfinite(np.asarray(w)).all()
        np.testing.assert_array_equal(np.asarray(w), 0.0)

    def test_ste_gradient_scaling(self):
        """∂Σw/∂wp_b = s·2^b/(2^n−1): Eq. 3's backward through the round."""
        n, s = 3, 2.0
        rng = np.random.RandomState(1)
        wp = jnp.asarray(rng.uniform(0, 2, (NB, 5)).astype(np.float32))
        wn = jnp.zeros((NB, 5))
        g = jax.grad(lambda a: jnp.sum(bit_weight(a, wn, bits_mask(n),
                                                  jnp.asarray(s))))(wp)
        for b in range(NB):
            want = s * (2.0**b) / (2.0**n - 1) if b < n else 0.0
            np.testing.assert_allclose(np.asarray(g[b]), want, rtol=1e-6)

    def test_negative_weights_via_wn(self):
        wp = jnp.zeros((NB, 1))
        wn = jnp.zeros((NB, 1)).at[2, 0].set(1.0)  # code −4
        w = bit_weight(wp, wn, bits_mask(3), jnp.asarray(7.0))
        np.testing.assert_allclose(np.asarray(w), -4.0, rtol=1e-6)


class TestBglLayer:
    def test_value(self):
        rng = np.random.RandomState(0)
        shape = (3, 3, 4, 4)
        wp = jnp.asarray(rng.uniform(0, 2, (NB,) + shape).astype(np.float32))
        wn = jnp.asarray(rng.uniform(0, 2, (NB,) + shape).astype(np.float32))
        got = bgl_layer(wp, wn, bits_mask(8))
        want = ref.bgl_ref(wp.reshape(NB, -1), wn.reshape(NB, -1), bits_mask(8))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_gradient_is_normalized_direction(self):
        """d BGL/d wp_b = wp_b/‖[wp_b;wn_b]‖ — the group-Lasso shrinkage."""
        rng = np.random.RandomState(1)
        wp = jnp.asarray(rng.uniform(0.5, 2, (NB, 20)).astype(np.float32))
        wn = jnp.asarray(rng.uniform(0.5, 2, (NB, 20)).astype(np.float32))
        mask = bits_mask(8)
        g = jax.grad(lambda a: bgl_layer(a, wn, mask))(wp)
        norms = np.sqrt(np.asarray(ref.bgl_sumsq_ref(wp, wn)))
        for b in range(8):
            np.testing.assert_allclose(np.asarray(g[b]),
                                       np.asarray(wp[b]) / norms[b], rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(g[8]), 0.0)

    def test_zero_layer_gradient_is_finite(self):
        z = jnp.zeros((NB, 10))
        g = jax.grad(lambda a: bgl_layer(a, z, bits_mask(8)))(z)
        assert np.isfinite(np.asarray(g)).all()


class TestDorefa:
    @settings(max_examples=15, deadline=None)
    @given(bits=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
    def test_levels_and_range(self, bits, seed):
        rng = np.random.RandomState(seed)
        w = jnp.asarray(rng.randn(64).astype(np.float32))
        lv = float(2**bits - 1)
        wq = np.asarray(dorefa_weight(w, jnp.asarray(lv)))
        s = np.abs(np.asarray(w)).max()
        codes = np.abs(wq) / s * lv
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)
        assert np.abs(wq).max() <= s + 1e-6

    def test_zero_levels_collapses_to_zero(self):
        w = jnp.asarray(np.random.RandomState(0).randn(16).astype(np.float32))
        np.testing.assert_array_equal(np.asarray(dorefa_weight(w, jnp.asarray(0.0))), 0.0)

    def test_ste_passes_gradient(self):
        w = jnp.asarray(np.linspace(-1, 1, 11).astype(np.float32))
        g = jax.grad(lambda a: jnp.sum(dorefa_weight(a, jnp.asarray(15.0))))(w)
        assert np.isfinite(np.asarray(g)).all()
        assert np.abs(np.asarray(g)).sum() > 0


class TestLsqAndAct:
    def test_lsq_quantizes_to_step_grid(self):
        w = jnp.asarray(np.random.RandomState(0).randn(32).astype(np.float32))
        step = jnp.asarray(0.1)
        wq = np.asarray(lsq_weight(w, step, jnp.asarray(7.0)))
        codes = wq / 0.1
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
        assert np.abs(codes).max() <= 7

    def test_lsq_step_is_trainable(self):
        w = jnp.asarray(np.random.RandomState(1).randn(32).astype(np.float32))
        g = jax.grad(lambda s: jnp.sum(lsq_weight(w, s, jnp.asarray(7.0)) ** 2))(
            jnp.asarray(0.1))
        assert np.isfinite(float(g)) and abs(float(g)) > 0

    def test_act_quant_fp_mode_is_clip(self):
        x = jnp.asarray(np.linspace(-2, 9, 23).astype(np.float32))
        out = act_quant(x, jnp.asarray(6.0), jnp.asarray(0.0))
        np.testing.assert_allclose(np.asarray(out),
                                   np.clip(np.asarray(x), 0, 6), rtol=1e-6)

    def test_ste_round_identity_grad(self):
        x = jnp.asarray([0.2, 1.7, -0.4], dtype=jnp.float32)
        g = jax.grad(lambda a: jnp.sum(ste_round(a)))(x)
        np.testing.assert_array_equal(np.asarray(g), 1.0)

    def test_pow2_vec(self):
        m = jnp.asarray([1.0, 1.0, 0.0, 1.0])
        np.testing.assert_array_equal(np.asarray(pow2_vec(m)), [1, 2, 0, 8])
