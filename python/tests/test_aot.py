"""AOT manifest/spec integrity: what the Rust coordinator relies on."""

import json
import os

import pytest

from compile.model import REGISTRY
from compile.models import get_model
from compile.train import build_entry

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestSpecs:
    @pytest.mark.parametrize("model_name", list(REGISTRY))
    def test_unique_names_per_entry(self, model_name):
        batch, entries = REGISTRY[model_name]
        model = get_model(model_name)
        for entry in entries:
            spec_in, spec_out, _ = build_entry(model, entry, batch)
            in_names = [i.name for i in spec_in]
            out_names = [o.name for o in spec_out]
            assert len(in_names) == len(set(in_names)), entry
            assert len(out_names) == len(set(out_names)), entry

    @pytest.mark.parametrize("model_name", list(REGISTRY))
    def test_every_state_output_has_matching_input(self, model_name):
        """The Rust step loop writes outputs back onto inputs by name."""
        batch, entries = REGISTRY[model_name]
        model = get_model(model_name)
        for entry in entries:
            spec_in, spec_out, _ = build_entry(model, entry, batch)
            in_shapes = {i.name: i.shape for i in spec_in}
            for o in spec_out:
                if o.role == "state":
                    assert o.name in in_shapes, (entry, o.name)
                    assert in_shapes[o.name] == o.shape, (entry, o.name)

    def test_train_entries_update_all_trainables(self):
        model = get_model("resnet20")
        spec_in, spec_out, _ = build_entry(model, "bsq_train_relu6", 4)
        outs = {o.name for o in spec_out}
        for q in model.qlayers:
            assert f"wp:{q.name}" in outs and f"wn:{q.name}" in outs
            assert f"m:wp:{q.name}" in outs
            # masks and scales are coordinator-owned in the relu6 graph
            assert f"mask:{q.name}" not in outs
        for n in model.bn_names:
            assert f"bn:{n}/gamma" in outs and f"bn:{n}/mean" in outs

    def test_roles_are_known(self):
        model = get_model("tinynet")
        for entry in REGISTRY["tinynet"][1]:
            spec_in, spec_out, _ = build_entry(model, entry, 4)
            assert {i.role for i in spec_in} <= {"x", "y", "state", "hyper",
                                                 "vec", "probe"}
            assert {o.role for o in spec_out} <= {"state", "metric", "probe_out"}


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
class TestEmittedArtifacts:
    @pytest.mark.parametrize("model_name", list(REGISTRY))
    def test_manifest_matches_registry(self, model_name):
        mpath = os.path.join(ART, model_name, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("model not lowered")
        with open(mpath) as f:
            man = json.load(f)
        batch, entries = REGISTRY[model_name]
        assert set(man["artifacts"]) == set(entries)
        model = get_model(model_name)
        assert [q["name"] for q in man["qlayers"]] == [q.name for q in model.qlayers]
        assert man["nb"] == 9
        for entry, art in man["artifacts"].items():
            hlo = os.path.join(ART, model_name, art["file"])
            assert os.path.getsize(hlo) > 1000, entry
            with open(hlo) as f:
                head = f.read(4000)
            assert head.startswith("HloModule"), entry
