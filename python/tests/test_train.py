"""Entry-point semantics on tinynet: the graphs that get AOT-lowered.

Executes the flat functions exactly as the Rust coordinator will (flat
tuples ordered by spec) and checks training dynamics: loss decreases, planes
stay clamped, BGL shrinks plane norms, HVP matches finite differences.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import get_model
from compile.quantize import NB
from compile.train import build_entry

BATCH = 8


def init_flat(spec, model, seed=0):
    """Random-but-sane initialization for every role (mirrors rust init)."""
    rng = np.random.RandomState(seed)
    out = []
    for item in spec:
        nm, shape = item.name, item.shape
        if item.role == "x":
            a = rng.randn(*shape).astype(np.float32)
        elif item.role == "y":
            a = rng.randint(0, model.num_classes, shape).astype(np.int32)
        elif nm.startswith(("wp:", "wn:")):
            a = rng.uniform(0, 1, shape).astype(np.float32)
        elif nm.startswith("mask:"):
            a = np.asarray([1.0] * 8 + [0.0] * (NB - 8), dtype=np.float32)
        elif nm.startswith("scale:") or nm.startswith("step:"):
            a = np.asarray(0.5, dtype=np.float32)
        elif nm.startswith("pact:"):
            a = np.asarray(6.0, dtype=np.float32)
        elif "/gamma" in nm or "/var" in nm:
            a = np.ones(shape, dtype=np.float32)
        elif nm.startswith(("m:", "v:")) or "/beta" in nm or "/mean" in nm:
            a = np.zeros(shape, dtype=np.float32)
        elif nm.startswith("w:"):
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            a = (rng.randn(*shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)
        elif nm == "regw":
            a = np.full(shape, 1.0 / max(len(model.qlayers), 1), dtype=np.float32)
        elif nm == "wlv":
            a = np.full(shape, 255.0, dtype=np.float32)
        elif nm == "actlv":
            a = np.full(shape, 15.0, dtype=np.float32)
        elif nm == "lr":
            a = np.asarray(0.05, dtype=np.float32)
        elif nm == "wd":
            a = np.asarray(1e-4, dtype=np.float32)
        elif nm == "alpha":
            a = np.asarray(0.0, dtype=np.float32)
        else:
            a = np.zeros(shape, dtype=np.float32)
        out.append(jnp.asarray(a))
    return out


def run_steps(entry, nsteps, seed=0, alpha=0.0, model_name="tinynet"):
    model = get_model(model_name)
    spec_in, spec_out, fn = build_entry(model, entry, BATCH)
    jfn = jax.jit(fn)
    flat = init_flat(spec_in, model, seed)
    idx_in = {i.name: k for k, i in enumerate(spec_in)}
    if "alpha" in idx_in:
        flat[idx_in["alpha"]] = jnp.asarray(alpha, dtype=jnp.float32)
    metrics_hist = []
    for _ in range(nsteps):
        outs = jfn(*flat)
        env_out = {o.name: v for o, v in zip(spec_out, outs)}
        metrics_hist.append({k: float(env_out[k]) for o, k in
                             [(o, o.name) for o in spec_out if o.role == "metric"]})
        for o, v in zip(spec_out, outs):
            if o.role == "state":
                flat[idx_in[o.name]] = v
    return metrics_hist, flat, (spec_in, spec_out), model


class TestTrainSteps:
    @pytest.mark.parametrize("entry", ["fp_train_relu6", "bsq_train_relu6",
                                       "dorefa_train_relu6"])
    def test_loss_decreases_on_fixed_batch(self, entry):
        hist, _, _, _ = run_steps(entry, 12)
        assert all(np.isfinite(h["loss"]) for h in hist)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_bsq_planes_stay_clamped(self):
        _, flat, (spec_in, _), _ = run_steps("bsq_train_relu6", 5, alpha=1e-2)
        for item, v in zip(spec_in, flat):
            if item.name.startswith(("wp:", "wn:")):
                a = np.asarray(v)
                assert a.min() >= 0.0 and a.max() <= 2.0

    def test_bsq_alpha_shrinks_bgl(self):
        """Stronger regularization must reduce the BGL term faster."""
        h0, _, _, _ = run_steps("bsq_train_relu6", 10, alpha=0.0)
        h1, _, _, _ = run_steps("bsq_train_relu6", 10, alpha=5e-2)
        assert h1[-1]["bgl"] < h0[-1]["bgl"]

    def test_bsq_metrics_present(self):
        hist, _, _, _ = run_steps("bsq_train_relu6", 1)
        assert set(hist[0]) == {"loss", "ce", "acc", "bgl"}

    def test_momentum_buffers_update(self):
        _, flat, (spec_in, _), _ = run_steps("fp_train_relu6", 2)
        mom = [np.abs(np.asarray(v)).sum() for item, v in zip(spec_in, flat)
               if item.name.startswith("m:")]
        assert sum(m > 0 for m in mom) > 0

    def test_eval_runs_and_is_deterministic(self):
        model = get_model("tinynet")
        spec_in, spec_out, fn = build_entry(model, "q_eval_relu6", BATCH)
        jfn = jax.jit(fn)
        flat = init_flat(spec_in, model, seed=3)
        a = jfn(*flat)
        b = jfn(*flat)
        assert float(a[0]) == float(b[0]) and float(a[1]) == float(b[1])
        assert 0.0 <= float(a[1]) <= 1.0

    def test_bn_stats_move_in_train(self):
        _, flat, (spec_in, _), _ = run_steps("fp_train_relu6", 3)
        moved = [np.abs(np.asarray(v)).sum() for item, v in zip(spec_in, flat)
                 if "/mean" in item.name]
        assert any(m > 0 for m in moved)


class TestHvp:
    def test_hvp_matches_finite_difference(self):
        model = get_model("tinynet")
        spec_in, spec_out, fn = build_entry(model, "hvp", BATCH)
        jfn = jax.jit(fn)
        flat = init_flat(spec_in, model, seed=5)
        idx = {i.name: k for k, i in enumerate(spec_in)}
        rng = np.random.RandomState(7)

        # random direction on layer conv2 only (block power-iteration style)
        probe = "v:conv2"
        v = rng.randn(*spec_in[idx[probe]].shape).astype(np.float32)
        v /= np.linalg.norm(v)
        flat[idx[probe]] = jnp.asarray(v)

        outs = jfn(*flat)
        env = {o.name: np.asarray(val) for o, val in zip(spec_out, outs)}
        hv = env["hv:conv2"]

        # finite difference of the gradient along v
        eps = 1e-3
        wkey = "w:conv2"

        def grad_at(wval):
            f2 = list(flat)
            f2[idx[wkey]] = jnp.asarray(wval)
            # gradient via jax on the same loss: reuse hvp fn? use jnp grad
            from compile.train import _forward, _ce_acc
            from compile import statespec as ss
            env_in = ss.env_from_flat(spec_in, f2)

            def loss_of(w):
                e = dict(env_in)
                e[wkey] = w
                logits, _ = _forward(model, "fp", "ref", e, train=False)
                ce, _ = _ce_acc(logits, e["y"])
                return ce
            return np.asarray(jax.grad(loss_of)(jnp.asarray(wval)))

        w0 = np.asarray(flat[idx[wkey]])
        fd = (grad_at(w0 + eps * v) - grad_at(w0 - eps * v)) / (2 * eps)
        np.testing.assert_allclose(hv, fd, rtol=0.05, atol=5e-3)

    def test_hvp_zero_direction_gives_zero(self):
        model = get_model("tinynet")
        spec_in, spec_out, fn = build_entry(model, "hvp", BATCH)
        flat = init_flat(spec_in, model, seed=1)  # all v: default to zeros
        outs = jax.jit(fn)(*flat)
        for o, val in zip(spec_out, outs):
            if o.role == "probe_out":
                np.testing.assert_array_equal(np.asarray(val), 0.0)
