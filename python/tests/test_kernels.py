"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including block-boundary and padded-tail cases) and
value ranges; both forward values and custom-VJP gradients must match the
oracles (exactly for the linear/elementwise kernels, to fp32 tolerance for
the reduction whose order differs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bgl_sumsq, fakequant, plane_sum, ref
from compile.kernels.bitrep import BLOCK_E as BITREP_BLOCK
from compile.kernels.actquant import BLOCK_E as ACT_BLOCK

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, lo=0.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# interesting element counts: tiny, just below/at/above the block size
ECOUNTS = st.sampled_from(
    [1, 7, 100, BITREP_BLOCK - 1, BITREP_BLOCK, BITREP_BLOCK + 1, 2 * BITREP_BLOCK + 5]
)
NBITS = st.integers(min_value=1, max_value=9)


class TestPlaneSum:
    @settings(max_examples=20, deadline=None)
    @given(e=ECOUNTS, nb=NBITS, seed=st.integers(0, 2**31 - 1), nact=st.integers(0, 9))
    def test_matches_ref(self, e, nb, seed, nact):
        rng = np.random.RandomState(seed)
        wp, wn = rand(rng, nb, e), rand(rng, nb, e)
        mask = jnp.asarray([1.0] * min(nact, nb) + [0.0] * max(nb - nact, 0))[:nb]
        pow2 = mask * 2.0 ** jnp.arange(nb)
        got = plane_sum(wp, wn, pow2)
        want = ref.plane_sum_ref(wp, wn, pow2)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    @settings(max_examples=10, deadline=None)
    @given(e=ECOUNTS, seed=st.integers(0, 2**31 - 1))
    def test_vjp_is_paper_eq3(self, e, seed):
        """∂⟨g, v⟩/∂wp_b = g·2^b·mask_b — the paper's STE backward."""
        rng = np.random.RandomState(seed)
        nb = 9
        wp, wn = rand(rng, nb, e), rand(rng, nb, e)
        mask = jnp.asarray([1.0] * 8 + [0.0])
        pow2 = mask * 2.0 ** jnp.arange(nb)
        g = jnp.asarray(rng.randn(e).astype(np.float32))
        gp, gn = jax.grad(lambda a, b: jnp.vdot(plane_sum(a, b, pow2), g),
                          argnums=(0, 1))(wp, wn)
        want = g[None, :] * pow2[:, None]
        np.testing.assert_allclose(gp, want, rtol=0, atol=0)
        np.testing.assert_allclose(gn, -want, rtol=0, atol=0)

    def test_all_masked_is_zero(self):
        rng = np.random.RandomState(0)
        wp, wn = rand(rng, 9, 100), rand(rng, 9, 100)
        out = plane_sum(wp, wn, jnp.zeros(9))
        np.testing.assert_array_equal(np.asarray(out), 0.0)


class TestBglSumsq:
    @settings(max_examples=20, deadline=None)
    @given(e=ECOUNTS, nb=NBITS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, e, nb, seed):
        rng = np.random.RandomState(seed)
        wp, wn = rand(rng, nb, e), rand(rng, nb, e)
        got = bgl_sumsq(wp, wn)
        want = ref.bgl_sumsq_ref(wp, wn)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(e=ECOUNTS, seed=st.integers(0, 2**31 - 1))
    def test_grad_matches_ref(self, e, seed):
        rng = np.random.RandomState(seed)
        wp, wn = rand(rng, 9, e), rand(rng, 9, e)
        co = jnp.asarray(rng.randn(9).astype(np.float32))
        gp, gn = jax.grad(lambda a, b: jnp.vdot(bgl_sumsq(a, b), co),
                          argnums=(0, 1))(wp, wn)
        np.testing.assert_allclose(gp, 2.0 * wp * co[:, None], rtol=0)
        np.testing.assert_allclose(gn, 2.0 * wn * co[:, None], rtol=0)

    def test_padded_tail_contributes_zero(self):
        """The iota mask must exclude block-padding elements exactly."""
        rng = np.random.RandomState(1)
        e = BITREP_BLOCK + 3
        wp, wn = rand(rng, 9, e), rand(rng, 9, e)
        got = bgl_sumsq(wp, wn)
        want = ref.bgl_sumsq_ref(wp, wn)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_zero_planes(self):
        z = jnp.zeros((9, 50))
        np.testing.assert_array_equal(np.asarray(bgl_sumsq(z, z)), 0.0)


class TestFakequant:
    @settings(max_examples=20, deadline=None)
    @given(
        e=st.sampled_from([1, 5, ACT_BLOCK - 1, ACT_BLOCK, ACT_BLOCK + 3]),
        bits=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
        bound=st.sampled_from([1.0, 6.0, 3.7]),
    )
    def test_matches_ref(self, e, bits, seed, bound):
        rng = np.random.RandomState(seed)
        x = jnp.asarray((rng.randn(e) * 4).astype(np.float32))
        b, lv = jnp.asarray(bound), jnp.asarray(float(2**bits - 1))
        got = fakequant(x, b, lv)
        want = ref.fakequant_ref(x, b, lv)
        np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_grad_matches_ref(self, seed):
        rng = np.random.RandomState(seed)
        x = jnp.asarray((rng.randn(300) * 4).astype(np.float32))
        b, lv = jnp.asarray(6.0), jnp.asarray(15.0)
        g = jnp.asarray(rng.randn(300).astype(np.float32))
        gx, gb = jax.grad(lambda a, bb: jnp.vdot(fakequant(a, bb, lv), g),
                          argnums=(0, 1))(x, b)
        gxr, gbr = ref.fakequant_bwd_ref(x, b, g)
        np.testing.assert_allclose(gx, gxr, rtol=0)
        # gb is a padded-block reduction: allow reduction-order noise
        np.testing.assert_allclose(gb, gbr, rtol=1e-4, atol=1e-6)

    def test_multi_dim_shapes(self):
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(2, 9, 5, 3).astype(np.float32))
        got = fakequant(x, jnp.asarray(6.0), jnp.asarray(15.0))
        assert got.shape == x.shape
        want = ref.fakequant_ref(x, jnp.asarray(6.0), jnp.asarray(15.0))
        np.testing.assert_allclose(got, want)

    def test_quantized_values_are_grid_points(self):
        rng = np.random.RandomState(3)
        x = jnp.asarray((rng.rand(1000) * 6).astype(np.float32))
        lv = 7.0
        q = np.asarray(fakequant(x, jnp.asarray(6.0), jnp.asarray(lv)))
        codes = q / 6.0 * lv
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
