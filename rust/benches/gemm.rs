//! Dense vs bit-plane GEMM: the sparsity-vs-speedup sweep behind the BSQ
//! compute story.
//!
//! For each workload shape, a base 8-bit weight matrix is trimmed 0–8 LSB
//! planes (the §3.3 adjustment image: magnitudes shift right, δ doubles)
//! and the bit-plane kernel is timed against the blocked dense f32 kernel
//! on the *same* represented weights. Bit-plane work is proportional to
//! set weight bits, so throughput must rise monotonically with the trim
//! count; the dense path costs the same at every precision.
//!
//! Two weight corpora are swept:
//! * `bsq` — plane occupancy ≈ 12% per plane, the bit-level sparsity
//!   regime BSQ's regularizer drives surviving planes into (MSQ,
//!   arXiv:2507.22349, reports ~90% zero bits post-training). This is the
//!   headline curve: the regime the kernel is built for.
//! * `dense8` — uniform random 8-bit codes (≈ 50% per plane), the
//!   adversarial worst case: even here the trim skip keeps the curve
//!   monotone.
//!
//! Emits `BENCH_gemm.json` (per-run stats + a `sweeps` summary with
//! speedups and set-bit counts) — the record EXPERIMENTS.md §Perf tracks.

use bsq::tensor::gemm::{matmul, transpose, BitPlaneMatrix};
use bsq::util::bench::{black_box, Bench, JsonReport};
use bsq::util::json::Json;
use bsq::util::Pcg32;

/// Per-plane occupancy of the BSQ-sparse corpus (see module docs).
const BSQ_PLANE_DENSITY: f32 = 0.12;

struct Shape {
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

/// resnet20-flavoured GEMMs: a mid-stage conv (im2col rows × patch × cout)
/// and the wider final-stage conv.
const SHAPES: [Shape; 2] = [
    Shape { label: "conv16x16", m: 1024, k: 576, n: 64 },
    Shape { label: "conv8x8", m: 512, k: 288, n: 32 },
];

fn sparse_codes(rng: &mut Pcg32, len: usize, density: f32) -> Vec<i16> {
    (0..len)
        .map(|_| {
            let mut mag = 0u16;
            for b in 0..8 {
                if rng.bool(density) {
                    mag |= 1 << b;
                }
            }
            if rng.bool(0.5) {
                mag as i16
            } else {
                -(mag as i16)
            }
        })
        .collect()
}

fn uniform_codes(rng: &mut Pcg32, len: usize) -> Vec<i16> {
    (0..len)
        .map(|_| {
            let mag = rng.below(256) as i16;
            if rng.bool(0.5) {
                mag
            } else {
                -mag
            }
        })
        .collect()
}

fn shr_mag(c: i16, t: usize) -> i16 {
    let m = (c.unsigned_abs() >> t) as i16;
    if c < 0 {
        -m
    } else {
        m
    }
}

fn main() {
    let bench = Bench::from_env();
    let mut rng = Pcg32::seeded(0);
    let mut report = JsonReport::new("gemm");
    let mut sweeps: Vec<(String, Json)> = Vec::new();

    println!("== gemm: dense f32 vs bit-plane ==");
    for shape in &SHAPES {
        let (m, k, n) = (shape.m, shape.k, shape.n);
        let macs = (m * k * n) as u64;
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let xt = transpose(&x, m, k);

        for corpus in ["bsq", "dense8"] {
            let base = match corpus {
                "bsq" => sparse_codes(&mut rng, k * n, BSQ_PLANE_DENSITY),
                _ => uniform_codes(&mut rng, k * n),
            };
            // dense baseline: cost is precision-independent; measure once
            let wdense: Vec<f32> = base.iter().map(|&c| c as f32 * 0.01).collect();
            let dense_stats =
                bench.run_elems(&format!("dense/{}/{corpus}", shape.label), macs, || {
                    black_box(matmul(&x, &wdense, m, k, n));
                });
            println!("{}", dense_stats.report());
            report.push(&dense_stats);

            let mut rows = Vec::new();
            let mut last_tp = 0.0f64;
            let mut monotone = true;
            for t in 0..=8usize {
                let codes: Vec<i16> = base.iter().map(|&c| shr_mag(c, t)).collect();
                let delta = 0.01 * (1u32 << t) as f32;
                let bpm = BitPlaneMatrix::from_codes(&codes, k, n, 8 - t, delta);
                let s = bench.run_elems(
                    &format!("bitplane/{}/{corpus}/trim{t}", shape.label),
                    macs,
                    || {
                        black_box(bpm.matmul_t(&xt, m));
                    },
                );
                println!("{}  [{} set bits]", s.report(), bpm.nnz_bits());
                report.push(&s);
                let tp = s.throughput_per_sec().unwrap_or(0.0);
                if tp + 1e-9 < last_tp {
                    monotone = false;
                }
                last_tp = tp;
                let speedup = dense_stats.mean.as_secs_f64() / s.mean.as_secs_f64().max(1e-12);
                rows.push(Json::obj(vec![
                    ("trimmed_planes", Json::num(t as f64)),
                    ("occupied_planes", Json::num(bpm.occupied_planes() as f64)),
                    ("nnz_bits", Json::num(bpm.nnz_bits() as f64)),
                    ("bits_per_weight", Json::num(bpm.nnz_bits() as f64 / (k * n) as f64)),
                    ("mean_ns", Json::num(s.mean.as_nanos() as f64)),
                    ("throughput_macs_per_sec", Json::num(tp)),
                    ("speedup_vs_dense", Json::num(speedup)),
                ]));
                if t == 4 {
                    println!(
                        "    -> {}/{corpus}: {speedup:.2}x vs dense at 4 trimmed planes",
                        shape.label
                    );
                }
            }
            println!(
                "    -> {}/{corpus}: throughput monotone with trimming: {monotone}",
                shape.label
            );
            sweeps.push((
                format!("{}/{corpus}", shape.label),
                Json::obj(vec![
                    ("m", Json::num(m as f64)),
                    ("k", Json::num(k as f64)),
                    ("n", Json::num(n as f64)),
                    ("dense_mean_ns", Json::num(dense_stats.mean.as_nanos() as f64)),
                    ("monotone_throughput", Json::Bool(monotone)),
                    ("points", Json::Arr(rows)),
                ]),
            ));
        }
    }

    report.extra("plane_density_bsq", Json::num(BSQ_PLANE_DENSITY as f64));
    report.extra("sweeps", Json::Obj(sweeps));
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
