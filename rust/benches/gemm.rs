//! Dense vs bit-plane GEMM: per-kernel backend columns plus the
//! sparsity-vs-speedup sweep behind the BSQ compute story.
//!
//! Two comparisons live in one record:
//!
//! * **Backend columns** — every workload is timed twice at GEMM
//!   parallelism cap 1 (kernel time, not threading): once pinned to the
//!   scalar backend and once on AVX2/FMA when the host has it
//!   (`with_backend`). The scalar/simd mean ratio lands in a `speedups`
//!   object, and a matching `speedup_floors` object (≥4× dense, ≥2×
//!   bit-plane at 0 trims, DESIGN.md §13) ships in the record so seeding
//!   it as a `ci/baselines/` baseline arms the bench-diff gate's absolute
//!   floor check automatically.
//! * **Trim sweep** — for each shape, a base 8-bit weight matrix is
//!   trimmed 0–8 LSB planes (the §3.3 adjustment image: magnitudes shift
//!   right, δ doubles) and the bit-plane kernel is timed against the dense
//!   f32 kernel on the *same* represented weights. Bit-plane work is
//!   proportional to set weight bits, so throughput must rise
//!   monotonically with the trim count; the dense path costs the same at
//!   every precision.
//!
//! Two weight corpora are swept:
//! * `bsq` — plane occupancy ≈ 12% per plane, the bit-level sparsity
//!   regime BSQ's regularizer drives surviving planes into (MSQ,
//!   arXiv:2507.22349, reports ~90% zero bits post-training). This is the
//!   headline curve: the regime the kernel is built for.
//! * `dense8` — uniform random 8-bit codes (≈ 50% per plane), the
//!   adversarial worst case: even here the trim skip keeps the curve
//!   monotone.
//!
//! Emits `BENCH_gemm.json` (per-run stats + `sweeps`/`speedups`/
//! `speedup_floors`) — the record EXPERIMENTS.md §Perf tracks.

use bsq::tensor::gemm::{
    matmul, set_thread_parallelism_cap, simd_available, transpose, with_backend, Backend,
    BitPlaneMatrix,
};
use bsq::util::bench::{black_box, Bench, JsonReport, Stats};
use bsq::util::json::Json;
use bsq::util::Pcg32;

/// Per-plane occupancy of the BSQ-sparse corpus (see module docs).
const BSQ_PLANE_DENSITY: f32 = 0.12;

/// The acceptance floors the SIMD rewrite must hold (DESIGN.md §13).
const DENSE_FLOOR: f64 = 4.0;
const BITPLANE_FLOOR: f64 = 2.0;

struct Shape {
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

/// resnet20-flavoured GEMMs: a mid-stage conv (im2col rows × patch × cout)
/// and the wider final-stage conv.
const SHAPES: [Shape; 2] = [
    Shape { label: "conv16x16", m: 1024, k: 576, n: 64 },
    Shape { label: "conv8x8", m: 512, k: 288, n: 32 },
];

fn sparse_codes(rng: &mut Pcg32, len: usize, density: f32) -> Vec<i16> {
    (0..len)
        .map(|_| {
            let mut mag = 0u16;
            for b in 0..8 {
                if rng.bool(density) {
                    mag |= 1 << b;
                }
            }
            if rng.bool(0.5) {
                mag as i16
            } else {
                -(mag as i16)
            }
        })
        .collect()
}

fn uniform_codes(rng: &mut Pcg32, len: usize) -> Vec<i16> {
    (0..len)
        .map(|_| {
            let mag = rng.below(256) as i16;
            if rng.bool(0.5) {
                mag
            } else {
                -mag
            }
        })
        .collect()
}

fn shr_mag(c: i16, t: usize) -> i16 {
    let m = (c.unsigned_abs() >> t) as i16;
    if c < 0 {
        -m
    } else {
        m
    }
}

/// Time `f` once per backend: always scalar, plus AVX2/FMA when present.
/// Returns `(scalar, simd)`; pushes both into the report under
/// `{name}/scalar` and `{name}/simd`.
fn per_backend(
    bench: &Bench,
    report: &mut JsonReport,
    name: &str,
    macs: u64,
    mut f: impl FnMut(),
) -> (Stats, Option<Stats>) {
    let scalar =
        with_backend(Backend::Scalar, || bench.run_elems(&format!("{name}/scalar"), macs, &mut f));
    println!("{}", scalar.report());
    report.push(&scalar);
    let simd = simd_available().then(|| {
        let s = with_backend(Backend::Avx2Fma, || {
            bench.run_elems(&format!("{name}/simd"), macs, &mut f)
        });
        println!("{}", s.report());
        report.push(&s);
        s
    });
    (scalar, simd)
}

fn kernel_speedup(scalar: &Stats, simd: &Option<Stats>) -> Option<f64> {
    simd.as_ref().map(|s| scalar.mean.as_secs_f64() / s.mean.as_secs_f64().max(1e-12))
}

fn main() {
    let bench = Bench::from_env();
    let mut rng = Pcg32::seeded(0);
    let mut report = JsonReport::new("gemm");
    let mut sweeps: Vec<(String, Json)> = Vec::new();
    let mut speedups: Vec<(String, Json)> = Vec::new();
    let mut floors: Vec<(String, Json)> = Vec::new();

    // Kernel time, not threading: both backends run single-threaded so the
    // columns compare instruction streams, not fan-out.
    set_thread_parallelism_cap(1);

    println!(
        "== gemm: dense f32 vs bit-plane (simd {}) ==",
        if simd_available() { "on" } else { "off" }
    );
    for shape in &SHAPES {
        let (m, k, n) = (shape.m, shape.k, shape.n);
        let macs = (m * k * n) as u64;
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let xt = transpose(&x, m, k);

        // Dense baseline: cost is precision- and corpus-independent;
        // measure once per shape, per backend.
        let wdense: Vec<f32> =
            uniform_codes(&mut rng, k * n).iter().map(|&c| c as f32 * 0.01).collect();
        let (dense_scalar, dense_simd) =
            per_backend(&bench, &mut report, &format!("dense/{}", shape.label), macs, || {
                black_box(matmul(&x, &wdense, m, k, n));
            });
        if let Some(sp) = kernel_speedup(&dense_scalar, &dense_simd) {
            println!("    -> dense/{}: {sp:.2}x simd over scalar", shape.label);
            speedups.push((format!("dense_{}", shape.label), Json::num(sp)));
            floors.push((format!("dense_{}", shape.label), Json::num(DENSE_FLOOR)));
        }
        // The dense mean the sweep's speedup-vs-dense column is against:
        // the backend dispatch would actually pick (simd when present).
        let dense_active = dense_simd.as_ref().unwrap_or(&dense_scalar);

        for corpus in ["bsq", "dense8"] {
            let base = match corpus {
                "bsq" => sparse_codes(&mut rng, k * n, BSQ_PLANE_DENSITY),
                _ => uniform_codes(&mut rng, k * n),
            };
            let mut rows = Vec::new();
            let mut last_tp = 0.0f64;
            let mut monotone = true;
            for t in 0..=8usize {
                let codes: Vec<i16> = base.iter().map(|&c| shr_mag(c, t)).collect();
                let delta = 0.01 * (1u32 << t) as f32;
                let bpm = BitPlaneMatrix::from_codes(&codes, k, n, 8 - t, delta);
                let (scalar, simd) = per_backend(
                    &bench,
                    &mut report,
                    &format!("bitplane/{}/{corpus}/trim{t}", shape.label),
                    macs,
                    || {
                        black_box(bpm.matmul_t(&xt, m));
                    },
                );
                let ksp = kernel_speedup(&scalar, &simd);
                if t == 0 {
                    if let Some(sp) = ksp {
                        println!(
                            "    -> bitplane/{}/{corpus}/trim0: {sp:.2}x simd over scalar \
                             [{} set bits]",
                            shape.label,
                            bpm.nnz_bits()
                        );
                        let key = format!("bitplane_{}_{corpus}_trim0", shape.label);
                        speedups.push((key.clone(), Json::num(sp)));
                        floors.push((key, Json::num(BITPLANE_FLOOR)));
                    }
                }
                // Monotonicity is judged on the backend dispatch would pick.
                let active = simd.as_ref().unwrap_or(&scalar);
                let tp = active.throughput_per_sec().unwrap_or(0.0);
                if tp + 1e-9 < last_tp {
                    monotone = false;
                }
                last_tp = tp;
                let speedup =
                    dense_active.mean.as_secs_f64() / active.mean.as_secs_f64().max(1e-12);
                let mut row = vec![
                    ("trimmed_planes", Json::num(t as f64)),
                    ("occupied_planes", Json::num(bpm.occupied_planes() as f64)),
                    ("nnz_bits", Json::num(bpm.nnz_bits() as f64)),
                    ("bits_per_weight", Json::num(bpm.nnz_bits() as f64 / (k * n) as f64)),
                    ("scalar_mean_ns", Json::num(scalar.mean.as_nanos() as f64)),
                    ("mean_ns", Json::num(active.mean.as_nanos() as f64)),
                    ("throughput_macs_per_sec", Json::num(tp)),
                    ("speedup_vs_dense", Json::num(speedup)),
                ];
                if let Some(sp) = ksp {
                    row.push(("kernel_speedup", Json::num(sp)));
                }
                rows.push(Json::obj(row));
            }
            println!(
                "    -> {}/{corpus}: throughput monotone with trimming: {monotone}",
                shape.label
            );
            sweeps.push((
                format!("{}/{corpus}", shape.label),
                Json::obj(vec![
                    ("m", Json::num(m as f64)),
                    ("k", Json::num(k as f64)),
                    ("n", Json::num(n as f64)),
                    ("dense_mean_ns", Json::num(dense_active.mean.as_nanos() as f64)),
                    ("monotone_throughput", Json::Bool(monotone)),
                    ("points", Json::Arr(rows)),
                ]),
            ));
        }
    }

    report.extra("plane_density_bsq", Json::num(BSQ_PLANE_DENSITY as f64));
    report.extra("simd_available", Json::Bool(simd_available()));
    report.extra("sweeps", Json::Obj(sweeps));
    if !speedups.is_empty() {
        report.extra("speedups", Json::Obj(speedups));
        report.extra("speedup_floors", Json::Obj(floors));
    }
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
