//! Data-parallel shard scaling of the native train step: shards ∈
//! {1, 2, 4, 8} over the synthetic corpora, fp and BSQ entries, on the
//! small (tinynet) and medium (resnet20 / CIFAR-profile) models.
//!
//! Training results are bit-identical at every shard count (see
//! `tests/shard_train.rs`), so the only question this answers is wall
//! clock: the `speedup_over_1shard` map in `BENCH_train_shard.json` is the
//! record EXPERIMENTS.md §Shard-scaling tracks, and CI's bench gate diffs
//! the smoke version against `ci/baselines/`.
//!
//! Two overlap records ride along (DESIGN.md §16): `epoch_time_pause` vs
//! `epoch_time_overlap` time a requant boundary (rebuild + eval window)
//! pause-the-world vs overlapped, and the `prefetch` block times a full
//! train epoch with the synchronous loader vs the background prefetcher.

use bsq::coordinator::{corpus_for_model, requantize_overlapped, RequantBuffers, Session};
use bsq::data::{train_source, BatchSource, Loader};
use bsq::model::{momentum_slots, ModelState};
use bsq::runtime::{Engine, RunInputs};
use bsq::util::bench::{Bench, JsonReport};
use bsq::util::json::Json;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();
    let mut report = JsonReport::new("train_shard");
    let mut speedups: Vec<(String, Json)> = Vec::new();
    println!("== train_shard: data-parallel scaling of the native train step ==");

    for (model, entry) in [
        ("tinynet", "fp_train_relu6"),
        ("tinynet", "bsq_train_relu6"),
        ("resnet20", "fp_train_relu6"),
        ("resnet20", "bsq_train_relu6"),
    ] {
        let mut base_mean: Option<f64> = None;
        for &shards in &SHARD_COUNTS {
            let engine = Engine::native_with_shards(shards);
            let man = engine.manifest(model)?;
            let exe = engine.load(man.artifact(entry)?)?;

            let spec = corpus_for_model(model, 0).with_sizes(man.batch * 2, man.batch);
            let corpus = bsq::data::Corpus::generate(spec);
            let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 1);
            let batch = loader.next_batch();

            let mut state = ModelState::init_fp(&man, 0);
            if entry.starts_with("bsq") {
                state.to_bit_representation(&man, 8)?;
            }
            state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
            let inputs = RunInputs::default()
                .hyper("lr", 0.05)
                .hyper("wd", 1e-4)
                .hyper("alpha", 1e-3)
                .vec("regw", vec![1.0; man.qlayers.len()])
                .vec("actlv", vec![15.0; man.act_sites.len()]);

            let label = format!("{model}/{entry}/shards{shards}");
            let s = bench.run_elems(&label, man.batch as u64, || {
                exe.run(&mut state, Some(&batch), &inputs).unwrap();
            });
            report.push(&s);
            let mean = s.mean.as_secs_f64();
            let speedup = match base_mean {
                None => {
                    base_mean = Some(mean);
                    1.0
                }
                Some(base) => base / mean,
            };
            println!("{}  ({speedup:.2}x over 1 shard)", s.report());
            speedups.push((label, Json::num(speedup)));
        }
    }

    report.extra("speedup_over_1shard", Json::Obj(speedups));
    report.extra("host_parallelism", Json::num(bsq::tensor::gemm::max_parallelism() as f64));

    // ---- requant boundary: pause-the-world vs overlapped (DESIGN.md §16)
    // One boundary = rebuild every layer's planes + the epoch-end eval
    // window. Both modes produce bit-identical state (tests/overlap_train),
    // so the delta is pure wall clock: sync pays rebuild + eval serially,
    // overlap hides the rebuild behind the eval.
    println!("== requant boundary: pause vs overlap (tinynet) ==");
    let engine = Engine::native();
    let session = Session::open(&engine, "tinynet", 128, 64, 0)?;
    let exe = session.artifact("bsq_train_relu6")?;
    let eval = session.artifact("q_eval_relu6")?;
    let mut state = ModelState::init_fp(&session.man, 0);
    state.to_bit_representation(&session.man, 8)?;
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    let actlv = session.act_levels(4, 8);
    let eval_inputs = RunInputs::default().vec("actlv", actlv.clone());

    let mut bufs = RequantBuffers::new();
    let s_pause = bench.run("epoch_time_pause", || {
        requantize_overlapped(&session, &mut state, &mut bufs, true, |st| {
            session.evaluate(&eval, st, &eval_inputs, 2)
        })
        .unwrap();
    });
    println!("{}", s_pause.report());
    report.push(&s_pause);

    let s_overlap = bench.run("epoch_time_overlap", || {
        requantize_overlapped(&session, &mut state, &mut bufs, false, |st| {
            session.evaluate(&eval, st, &eval_inputs, 2)
        })
        .unwrap();
    });
    let requant_speedup = s_pause.mean.as_secs_f64() / s_overlap.mean.as_secs_f64();
    println!("{}  ({requant_speedup:.2}x over pause)", s_overlap.report());
    report.push(&s_overlap);
    report.extra("requant_overlap_speedup", Json::num(requant_speedup));

    // ---- train epoch: synchronous loader vs background prefetcher
    println!("== train epoch: sync loader vs prefetcher (tinynet) ==");
    let train_inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .hyper("alpha", 1e-3)
        .vec("regw", vec![1.0; session.man.qlayers.len()])
        .vec("actlv", actlv);
    let mut prefetch_block: Vec<(&str, Json)> = Vec::new();
    let mut sync_mean = 0.0f64;
    for (tag, depth) in [("epoch-sync", 0usize), ("epoch-prefetch2", 2)] {
        let mut src =
            train_source(&session.corpus.train, session.man.batch, Default::default(), 1, depth);
        let s = bench.run(tag, || {
            src.next_epoch();
            for _ in 0..src.batches_per_epoch() {
                let b = src.next_batch();
                exe.run(&mut state, Some(&b), &train_inputs).unwrap();
            }
        });
        let mean = s.mean.as_secs_f64();
        if depth == 0 {
            sync_mean = mean;
            println!("{}", s.report());
        } else {
            println!("{}  ({:.2}x over sync)", s.report(), sync_mean / mean);
            prefetch_block.push(("speedup", Json::num(sync_mean / mean)));
        }
        report.push(&s);
        prefetch_block.push((if depth == 0 { "sync_ns" } else { "prefetch_ns" },
            Json::num(s.mean.as_nanos() as f64)));
    }
    report.extra("prefetch", Json::Obj(
        prefetch_block.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    ));

    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
