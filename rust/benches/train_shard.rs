//! Data-parallel shard scaling of the native train step: shards ∈
//! {1, 2, 4, 8} over the synthetic corpora, fp and BSQ entries, on the
//! small (tinynet) and medium (resnet20 / CIFAR-profile) models.
//!
//! Training results are bit-identical at every shard count (see
//! `tests/shard_train.rs`), so the only question this answers is wall
//! clock: the `speedup_over_1shard` map in `BENCH_train_shard.json` is the
//! record EXPERIMENTS.md §Shard-scaling tracks, and CI's bench gate diffs
//! the smoke version against `ci/baselines/`.

use bsq::coordinator::corpus_for_model;
use bsq::data::Loader;
use bsq::model::{momentum_slots, ModelState};
use bsq::runtime::{Engine, RunInputs};
use bsq::util::bench::{Bench, JsonReport};
use bsq::util::json::Json;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();
    let mut report = JsonReport::new("train_shard");
    let mut speedups: Vec<(String, Json)> = Vec::new();
    println!("== train_shard: data-parallel scaling of the native train step ==");

    for (model, entry) in [
        ("tinynet", "fp_train_relu6"),
        ("tinynet", "bsq_train_relu6"),
        ("resnet20", "fp_train_relu6"),
        ("resnet20", "bsq_train_relu6"),
    ] {
        let mut base_mean: Option<f64> = None;
        for &shards in &SHARD_COUNTS {
            let engine = Engine::native_with_shards(shards);
            let man = engine.manifest(model)?;
            let exe = engine.load(man.artifact(entry)?)?;

            let spec = corpus_for_model(model, 0).with_sizes(man.batch * 2, man.batch);
            let corpus = bsq::data::Corpus::generate(spec);
            let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 1);
            let batch = loader.next_batch();

            let mut state = ModelState::init_fp(&man, 0);
            if entry.starts_with("bsq") {
                state.to_bit_representation(&man, 8)?;
            }
            state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
            let inputs = RunInputs::default()
                .hyper("lr", 0.05)
                .hyper("wd", 1e-4)
                .hyper("alpha", 1e-3)
                .vec("regw", vec![1.0; man.qlayers.len()])
                .vec("actlv", vec![15.0; man.act_sites.len()]);

            let label = format!("{model}/{entry}/shards{shards}");
            let s = bench.run_elems(&label, man.batch as u64, || {
                exe.run(&mut state, Some(&batch), &inputs).unwrap();
            });
            report.push(&s);
            let mean = s.mean.as_secs_f64();
            let speedup = match base_mean {
                None => {
                    base_mean = Some(mean);
                    1.0
                }
                Some(base) => base / mean,
            };
            println!("{}  ({speedup:.2}x over 1 shard)", s.report());
            speedups.push((label, Json::num(speedup)));
        }
    }

    report.extra("speedup_over_1shard", Json::Obj(speedups));
    report.extra("host_parallelism", Json::num(bsq::tensor::gemm::max_parallelism() as f64));
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
