//! Batched serving throughput: the closed-loop sweep behind the BSQ
//! deployment story, as a bench target (the CLI twin is `bsq-repro
//! serve-bench`; both share `serve::sweep` and the `BENCH_serve.json`
//! schema).
//!
//! A deterministic quantized tinynet checkpoint is synthesized, loaded
//! through the serving registry (prebuilt bit-plane weights + per-layer
//! effective precision), and driven through a {batch} × {workers} grid of
//! closed-loop pools. The record carries throughput, p50/p99 latency, mean
//! batch occupancy and set-weight-bits-per-sample per cell — the serving
//! half of the sparsity-vs-speedup story EXPERIMENTS.md §Serving tracks.
//!
//! `BSQ_BENCH_QUICK=1` shrinks the request count for the CI smoke.

use std::time::Duration;

use bsq::runtime::Engine;
use bsq::serve::{self, Registry};

fn main() {
    let quick = std::env::var_os("BSQ_BENCH_QUICK").is_some();
    let requests = if quick { 96 } else { 512 };
    let batches = [1usize, 8, 32];
    let workers = [1usize, 4];
    let seed = 0u64;

    let engine = Engine::cpu().expect("engine");
    let dir = std::env::temp_dir().join(format!("bsq_serve_bench_{}", std::process::id()));
    let ckpt = dir.join("tinynet_serve.ckpt");
    serve::synthesize_quantized_checkpoint(&engine, "tinynet", 8, seed, &ckpt)
        .expect("synthesize checkpoint");

    let registry = Registry::new(&engine);
    let servable = registry.load("tinynet", &ckpt, 4, 8).expect("load servable");
    println!(
        "== serve: tinynet, {} set weight bits/sample, {requests} requests/cell ==",
        servable.weight_bits()
    );

    let cells = serve::sweep(
        &servable,
        &batches,
        &workers,
        requests,
        Duration::from_millis(2),
        seed,
    )
    .expect("sweep");
    for cell in &cells {
        println!(
            "batch {:>3} × {} workers: {}",
            cell.max_batch,
            cell.workers,
            cell.summary.report()
        );
    }
    for &w in &workers {
        let tp = |b: usize| {
            cells
                .iter()
                .find(|c| c.workers == w && c.max_batch == b)
                .map(|c| c.summary.throughput_rps)
                .unwrap_or(0.0)
        };
        println!(
            "    -> workers {w}: batch 32 is {:.2}x batch 1 throughput",
            tp(32) / tp(1).max(1e-9)
        );
    }

    let json = serve::sweep_json(&servable, &cells);
    match serve::write_bench_json(&json) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    std::fs::remove_dir_all(dir).ok();
}
