//! Planned-executor forward throughput: {tinynet, resnet20} ×
//! {planned-arena vs. alloc-per-pass}, plus the memory planner's
//! arena-vs-naive activation footprint → `BENCH_graph.json`.
//!
//! `planned_arena` is the production configuration: one persistent arena,
//! zero steady-state allocations. `alloc_per_pass` runs the *same* bound
//! plan but hands every pass a fresh arena — the allocation discipline of
//! the pre-IR per-pass graph walk, and the baseline the planner's win is
//! measured against. CI runs the quick mode and diffs the record against
//! `ci/baselines/BENCH_graph.smoke.json`.

use bsq::ir::{self, Arena};
use bsq::model::ModelState;
use bsq::runtime::native::manifest_for;
use bsq::runtime::native::models;
use bsq::runtime::native::step::{eval_weights, AMode, WMode};
use bsq::tensor::Tensor;
use bsq::util::bench::{black_box, Bench, JsonReport};
use bsq::util::json::Json;
use bsq::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();
    let mut report = JsonReport::new("graph");
    let mut extras: Vec<(String, Json)> = Vec::new();
    let mut speedups: Vec<(String, Json)> = Vec::new();
    println!("== graph_exec: planned-arena forward vs alloc-per-pass ==");

    for model_name in ["tinynet", "resnet20"] {
        let man = manifest_for(model_name)?;
        let model = models::get(model_name)?;
        let plans = ir::plans_for(model_name)?;

        // Quantized state on the sparsity-proportional bit-plane path —
        // the configuration the serving layer runs.
        let mut state = ModelState::init_fp(&man, 0);
        state.to_bit_representation(&man, 6)?;
        let actlv = vec![15.0f32; model.act_sites.len()];
        let reps = eval_weights(&model, &state, WMode::Bit, None, true)?;
        let bound = ir::bind(&plans.infer, &model, &state, reps, &actlv, AMode::Relu6)?;

        let m = man.batch;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::new(
            vec![m, man.input_hw.0, man.input_hw.1, man.in_ch],
            (0..m * man.input_hw.0 * man.input_hw.1 * man.in_ch).map(|_| rng.normal()).collect(),
        )?;

        let plan = bound.plan();
        let (arena_b, naive_b, scratch_b) =
            (plan.arena_bytes(m), plan.naive_bytes(m), plan.scratch_bytes(m));
        assert!(
            arena_b < naive_b,
            "{model_name}: arena {arena_b} B must be strictly below naive {naive_b} B"
        );
        println!(
            "{model_name}: {} nodes, {} fused, arena {arena_b} B vs naive {naive_b} B \
             ({:.1}x reuse), scratch {scratch_b} B  [batch {m}]",
            plan.schedule_len(),
            plan.fused,
            naive_b as f64 / arena_b.max(1) as f64
        );
        extras.push((
            format!("{model_name}_memory"),
            Json::obj(vec![
                ("arena_bytes", Json::num(arena_b as f64)),
                ("naive_bytes", Json::num(naive_b as f64)),
                ("scratch_bytes", Json::num(scratch_b as f64)),
                ("fused_nodes", Json::num(plan.fused as f64)),
                ("reuse_factor", Json::num(naive_b as f64 / arena_b.max(1) as f64)),
            ]),
        ));

        // Production shape: one persistent arena, grown once.
        let mut arena = Arena::default();
        let s_planned = bench.run_elems(&format!("{model_name}/planned_arena"), m as u64, || {
            let logits = bound.execute(x.data(), m, &mut arena).unwrap();
            black_box(logits[0]);
        });
        println!("{}", s_planned.report());
        report.push(&s_planned);

        // Baseline: the same plan paying a fresh allocation every pass.
        let s_alloc = bench.run_elems(&format!("{model_name}/alloc_per_pass"), m as u64, || {
            let mut fresh = Arena::default();
            let logits = bound.execute(x.data(), m, &mut fresh).unwrap();
            black_box(logits[0]);
        });
        println!("{}", s_alloc.report());
        report.push(&s_alloc);

        let speedup = s_alloc.mean.as_secs_f64() / s_planned.mean.as_secs_f64().max(1e-12);
        println!("{model_name}: planned arena {speedup:.2}x over alloc-per-pass");
        speedups.push((format!("{model_name}_planned_over_alloc"), Json::num(speedup)));
    }

    for (k, v) in extras {
        report.extra(&k, v);
    }
    report.extra("speedups", Json::Obj(speedups));
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
