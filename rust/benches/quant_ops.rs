//! Micro-benchmarks of the coordinator-side quantization hot paths:
//! bit-plane packing, reconstruction, integer re-quantization codes and the
//! full precision adjustment — the work that runs between training epochs.
//!
//! These dominate the re-quantization pause (paper §3.3), so their
//! throughput bounds how often re-quantization can run. §Perf in
//! EXPERIMENTS.md tracks before/after numbers.

use bsq::quant::{from_bitplanes, requantize, to_bitplanes};
use bsq::quant::bitplane::integer_codes;
use bsq::tensor::Tensor;
use bsq::util::bench::{black_box, Bench};
use bsq::util::Pcg32;

fn main() {
    let bench = Bench::default();
    let mut rng = Pcg32::seeded(0);

    println!("== quant_ops ==");
    // resnet20's biggest layer is 36 864 params; resnet50_sim's ~131 072.
    for &elems in &[4_096usize, 36_864, 131_072] {
        let w = Tensor::randn(&[elems], 0.5, &mut rng);

        let s = bench.run_elems(&format!("to_bitplanes/{elems}"), elems as u64, || {
            black_box(to_bitplanes(&w, 8).unwrap());
        });
        println!("{}", s.report());

        let rep = to_bitplanes(&w, 8).unwrap();
        let s = bench.run_elems(&format!("from_bitplanes/{elems}"), elems as u64, || {
            black_box(from_bitplanes(&rep));
        });
        println!("{}", s.report());

        let s = bench.run_elems(&format!("integer_codes/{elems}"), elems as u64, || {
            black_box(integer_codes(&rep));
        });
        println!("{}", s.report());

        let s = bench.run_elems(&format!("requantize/{elems}"), elems as u64, || {
            let mut r = rep.clone();
            black_box(requantize(&mut r));
        });
        println!("{}", s.report());
    }

    // whole-model requantization pause (resnet20 shape mix)
    let shapes: Vec<usize> =
        std::iter::once(432).chain((0..18).map(|i| if i < 6 { 2_304 } else if i < 12 { 9_216 } else { 36_864 })).chain(std::iter::once(640)).collect();
    let reps: Vec<_> = shapes
        .iter()
        .map(|&e| to_bitplanes(&Tensor::randn(&[e], 0.5, &mut rng), 8).unwrap())
        .collect();
    let total: usize = shapes.iter().sum();
    let s = bench.run_elems("requantize/resnet20-all-layers", total as u64, || {
        for rep in &reps {
            let mut r = rep.clone();
            black_box(requantize(&mut r));
        }
    });
    println!("{}", s.report());
}
