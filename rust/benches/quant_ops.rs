//! Micro-benchmarks of the coordinator-side quantization hot paths:
//! bit-plane packing, reconstruction, integer re-quantization codes and the
//! full precision adjustment — the work that runs between training epochs.
//!
//! These dominate the re-quantization pause (paper §3.3), so their
//! throughput bounds how often re-quantization can run. Each packed-engine
//! entry is paired with a `*_ref/` run of the retained scalar path
//! (`quant::reference`) and the speedup is recorded alongside the raw
//! numbers in `BENCH_quant_ops.json` — the machine-readable record the
//! §Perf pass in EXPERIMENTS.md tracks across PRs.
//!
//! State-restoring setup (cloning the rep an in-place `requantize` is about
//! to consume) runs through `Bench::run_prepared`, *outside* the timed
//! region — the numbers report requantization, not allocation.

use bsq::quant::bitplane::integer_codes;
use bsq::quant::{from_bitplanes, reference, requantize, to_bitplanes, BitRep};
use bsq::tensor::Tensor;
use bsq::util::bench::{black_box, Bench, JsonReport, Stats};
use bsq::util::json::Json;
use bsq::util::Pcg32;

struct Recorder {
    report: JsonReport,
    speedups: Vec<(String, Json)>,
}

impl Recorder {
    fn record(&mut self, s: &Stats) {
        println!("{}", s.report());
        self.report.push(s);
    }

    /// Record a packed/reference pair and log the speedup.
    fn record_pair(&mut self, fast: &Stats, slow: &Stats) {
        self.record(fast);
        self.record(slow);
        let speedup = slow.mean.as_secs_f64() / fast.mean.as_secs_f64().max(1e-12);
        println!("    -> {} speedup vs reference: {speedup:.2}x", fast.name);
        self.speedups.push((fast.name.clone(), Json::num(speedup)));
    }
}

fn main() {
    let bench = Bench::from_env();
    let mut rng = Pcg32::seeded(0);
    let mut rec = Recorder { report: JsonReport::new("quant_ops"), speedups: Vec::new() };

    println!("== quant_ops ==");
    // resnet20's biggest layer is 36 864 params; resnet50_sim's ~131 072.
    for &elems in &[4_096usize, 36_864, 131_072] {
        let w = Tensor::randn(&[elems], 0.5, &mut rng);

        let s = bench.run_elems(&format!("to_bitplanes/{elems}"), elems as u64, || {
            black_box(to_bitplanes(&w, 8).unwrap());
        });
        let s_ref = bench.run_elems(&format!("to_bitplanes_ref/{elems}"), elems as u64, || {
            black_box(reference::to_bitplanes(&w, 8).unwrap());
        });
        rec.record_pair(&s, &s_ref);

        // Perturb into mid-training continuous planes so code extraction
        // does real rounding work (exact binary planes are the easy case).
        let mut rep = to_bitplanes(&w, 8).unwrap();
        for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
            *v = (*v + rng.range(-0.2, 0.2)).clamp(0.0, 2.0);
        }

        let s = bench.run_elems(&format!("from_bitplanes/{elems}"), elems as u64, || {
            black_box(from_bitplanes(&rep));
        });
        let s_ref = bench.run_elems(&format!("from_bitplanes_ref/{elems}"), elems as u64, || {
            black_box(reference::from_bitplanes(&rep));
        });
        rec.record_pair(&s, &s_ref);

        let s = bench.run_elems(&format!("integer_codes/{elems}"), elems as u64, || {
            black_box(integer_codes(&rep));
        });
        let s_ref = bench.run_elems(&format!("integer_codes_ref/{elems}"), elems as u64, || {
            black_box(reference::integer_codes(&rep));
        });
        rec.record_pair(&s, &s_ref);

        let s = bench.run_prepared(
            &format!("requantize/{elems}"),
            elems as u64,
            || rep.clone(),
            |r| {
                black_box(requantize(r));
            },
        );
        let s_ref = bench.run_prepared(
            &format!("requantize_ref/{elems}"),
            elems as u64,
            || rep.clone(),
            |r| {
                black_box(reference::requantize(r));
            },
        );
        rec.record_pair(&s, &s_ref);
    }

    // Whole-model requantization pause (resnet20 shape mix) — the pause the
    // coordinator takes every `requant_interval` epochs.
    let shapes: Vec<usize> = std::iter::once(432)
        .chain((0..18).map(|i| if i < 6 { 2_304 } else if i < 12 { 9_216 } else { 36_864 }))
        .chain(std::iter::once(640))
        .collect();
    let reps: Vec<BitRep> = shapes
        .iter()
        .map(|&e| {
            let mut rep = to_bitplanes(&Tensor::randn(&[e], 0.5, &mut rng), 8).unwrap();
            for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
                *v = (*v + rng.range(-0.2, 0.2)).clamp(0.0, 2.0);
            }
            rep
        })
        .collect();
    let total: usize = shapes.iter().sum();
    let s = bench.run_prepared(
        "requantize/resnet20-all-layers",
        total as u64,
        || reps.clone(),
        |rs| {
            for r in rs.iter_mut() {
                black_box(requantize(r));
            }
        },
    );
    let s_ref = bench.run_prepared(
        "requantize_ref/resnet20-all-layers",
        total as u64,
        || reps.clone(),
        |rs| {
            for r in rs.iter_mut() {
                black_box(reference::requantize(r));
            }
        },
    );
    rec.record_pair(&s, &s_ref);

    let Recorder { mut report, speedups } = rec;
    report.extra("speedups", Json::Obj(speedups));
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
