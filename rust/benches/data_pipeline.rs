//! Data-pipeline throughput: corpus synthesis, augmented batch assembly,
//! epoch turnover, and the prefetcher's overlap win (DESIGN.md §16). The
//! loader must never be the bottleneck against a ~1.3 s/step device
//! (resnet20 on this CPU) — §Perf records the margin.
//!
//! The `epoch/*` pair interleaves a fixed synthetic compute step between
//! batches, the shape of a real training loop: synchronous assembly pays
//! augment + compute serially, the prefetcher hides the augment behind
//! the compute. `prefetch_speedup` in `BENCH_data_pipeline.json` is the
//! overlap win CI's bench gate tracks.

use bsq::data::{train_source, AugmentCfg, Batch, BatchSource, Corpus, CorpusSpec, Loader};
use bsq::util::bench::{black_box, Bench, JsonReport};
use bsq::util::json::Json;

/// Stand-in for a train step: a few deterministic passes over the batch
/// pixels, heavy enough (~augment-sized) that overlap has something to
/// hide behind.
fn compute_step(batch: &Batch) -> f32 {
    let mut acc = 0.0f32;
    for pass in 0..4u32 {
        let k = 1.0 + pass as f32 * 1e-3;
        for &v in batch.x.data() {
            acc = acc.mul_add(0.999_9, v * k);
        }
    }
    acc
}

/// Drain `epochs` full epochs from a source, running the synthetic
/// compute step between batches (the pattern `bsq_train` runs).
fn drain_epochs(src: &mut impl BatchSource, epochs: usize) -> f32 {
    let mut acc = 0.0f32;
    for _ in 0..epochs {
        src.next_epoch();
        for _ in 0..src.batches_per_epoch() {
            let batch = src.next_batch();
            acc += compute_step(&batch);
        }
    }
    acc
}

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();
    let mut report = JsonReport::new("data_pipeline");
    println!("== data_pipeline ==");

    let s = bench.run_elems("corpus/synthetic-cifar-1k", 1024, || {
        black_box(Corpus::generate(CorpusSpec::cifar().with_sizes(1024, 64)));
    });
    println!("{}", s.report());
    report.push(&s);

    let corpus = Corpus::generate(CorpusSpec::cifar().with_sizes(4096, 512));
    for (name, cfg) in [("augmented", AugmentCfg::default()), ("eval", AugmentCfg::off())] {
        let mut loader = Loader::new(&corpus.train, 32, cfg, 7);
        let s = bench.run_elems(&format!("loader/batch32-{name}"), 32, || {
            black_box(loader.next_batch());
        });
        println!("{}  ({:.1} imgs/ms)", s.report(), 32.0 / s.mean.as_secs_f64() / 1e3);
        report.push(&s);
    }

    // epoch turnover (shuffle) cost
    let mut loader = Loader::new(&corpus.train, 32, AugmentCfg::default(), 7);
    let s = bench.run("loader/next_epoch-4096", || {
        loader.next_epoch();
    });
    println!("{}", s.report());
    report.push(&s);
    drop(loader);

    // Overlap win: one epoch of assemble+compute, synchronous vs
    // prefetched. Same seed, same stream (bit-identity is asserted in
    // src/data/prefetch.rs tests — here we only time it).
    let epoch_corpus = Corpus::generate(CorpusSpec::cifar().with_sizes(1024, 64));
    let elems = 1024u64;
    let mut sync_src = train_source(&epoch_corpus.train, 32, AugmentCfg::default(), 7, 0);
    let s_sync = bench.run_elems("epoch/sync-batch32", elems, || {
        black_box(drain_epochs(&mut sync_src, 1));
    });
    println!("{}", s_sync.report());
    report.push(&s_sync);

    let mut pf_src = train_source(&epoch_corpus.train, 32, AugmentCfg::default(), 7, 2);
    let s_pf = bench.run_elems("epoch/prefetch-batch32-depth2", elems, || {
        black_box(drain_epochs(&mut pf_src, 1));
    });
    let speedup = s_sync.mean.as_secs_f64() / s_pf.mean.as_secs_f64();
    println!("{}  ({speedup:.2}x over sync)", s_pf.report());
    report.push(&s_pf);

    report.extra("prefetch_speedup", Json::num(speedup));
    let path = report.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
