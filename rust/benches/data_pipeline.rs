//! Data-pipeline throughput: corpus synthesis, augmented batch assembly,
//! eval batch assembly. The loader must never be the bottleneck against a
//! ~1.3 s/step device (resnet20 on this CPU) — §Perf records the margin.

use bsq::data::{AugmentCfg, Corpus, CorpusSpec, Loader};
use bsq::util::bench::{black_box, Bench};

fn main() {
    let bench = Bench::default();
    println!("== data_pipeline ==");

    let s = bench.run_elems("corpus/synthetic-cifar-1k", 1024, || {
        black_box(Corpus::generate(CorpusSpec::cifar().with_sizes(1024, 64)));
    });
    println!("{}", s.report());

    let corpus = Corpus::generate(CorpusSpec::cifar().with_sizes(4096, 512));
    for (name, cfg) in
        [("augmented", AugmentCfg::default()), ("eval", AugmentCfg::off())]
    {
        let mut loader = Loader::new(&corpus.train, 32, cfg, 7);
        let s = bench.run_elems(&format!("loader/batch32-{name}"), 32, || {
            black_box(loader.next_batch());
        });
        println!(
            "{}  ({:.1} imgs/ms)",
            s.report(),
            32.0 / s.mean.as_secs_f64() / 1e3
        );
    }

    // epoch turnover (shuffle) cost
    let mut loader = Loader::new(&corpus.train, 32, AugmentCfg::default(), 7);
    let s = bench.run("loader/next_epoch-4096", || {
        loader.next_epoch();
    });
    println!("{}", s.report());
}
