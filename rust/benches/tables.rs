//! End-to-end table workloads at miniature scale — one benchmark per paper
//! table/figure family, measuring the *whole pipeline* (data → device steps
//! → re-quantization → scheme) the corresponding experiment harness runs.
//!
//! These use tinynet so a full suite completes in minutes; the resnet-scale
//! numbers live in results/*.json (see EXPERIMENTS.md). Skips without
//! artifacts.

use bsq::baselines::{self, HawqConfig, QatConfig};
use bsq::coordinator::{run_bsq, BsqConfig, Session};
use bsq::model::ModelState;
use bsq::quant::{QuantScheme, Reweigh};
use bsq::runtime::Engine;
use bsq::util::bench::Bench;

fn tiny_cfg(alpha: f32) -> BsqConfig {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.alpha = alpha;
    cfg.pretrain_epochs = 2;
    cfg.bsq_epochs = 3;
    cfg.finetune_epochs = 1;
    cfg.train_size = 256;
    cfg.test_size = 128;
    cfg.cache_pretrained = false;
    cfg
}

fn main() -> anyhow::Result<()> {
    if !bsq::runtime::artifacts_root().join("tinynet/manifest.json").exists() {
        eprintln!("skipping tables bench: run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::cpu()?;
    let bench = Bench { warmup: 0, iters: 1, max_time: std::time::Duration::from_secs(300) };
    println!("== tables (end-to-end pipeline workloads, tinynet miniature) ==");

    // Table 1 / Fig 3 family: one full BSQ pipeline run per α point.
    let s = bench.run("table1/bsq-pipeline-per-alpha", || {
        run_bsq(&engine, &tiny_cfg(2e-4)).unwrap();
    });
    println!("{}", s.report());

    // Table 1 scratch row / Table 2 DoReFa rows: from-scratch QAT run.
    let session = Session::open(&engine, "tinynet", 256, 128, 0)?;
    let names: Vec<(String, usize)> =
        session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
    let uni = QuantScheme::uniform(&names, 3);
    let s = bench.run("table2/dorefa-from-scratch", || {
        baselines::dorefa::train_from_scratch(&session, &uni, &QatConfig::from_scratch(3, 4, 0))
            .unwrap();
    });
    println!("{}", s.report());

    // Fig 2 family: reweighing ablation = two pipeline runs.
    let s = bench.run("fig2/reweigh-pair", || {
        let mut a = tiny_cfg(2e-4);
        a.reweigh = Reweigh::MemoryAware;
        let mut b = tiny_cfg(9e-5);
        b.reweigh = Reweigh::None;
        run_bsq(&engine, &a).unwrap();
        run_bsq(&engine, &b).unwrap();
    });
    println!("{}", s.report());

    // Fig 4 family: one extra arm (interval = 0).
    let s = bench.run("fig4/no-requant-arm", || {
        let mut cfg = tiny_cfg(2e-4);
        cfg.requant_interval = 0;
        run_bsq(&engine, &cfg).unwrap();
    });
    println!("{}", s.report());

    // Fig 7 / Table 2 HAWQ row: Hessian block power iteration.
    let state = ModelState::init_fp(&session.man, 0);
    let s = bench.run("fig7/hawq-analysis", || {
        let cfg = HawqConfig { power_iters: 4, batches: 1, seed: 0 };
        baselines::hawq::analyze(&session, &state, &cfg).unwrap();
    });
    println!("{}", s.report());

    // Tables 4/5: PACT-path pipeline (resnet20-only artifact; report eval
    // via the relu6 miniature at 4-bit instead so the bench stays tiny).
    let s = bench.run("table45/bsq-4bit-act", || {
        let mut cfg = tiny_cfg(4e-4);
        cfg.act_bits = 4;
        run_bsq(&engine, &cfg).unwrap();
    });
    println!("{}", s.report());

    Ok(())
}
