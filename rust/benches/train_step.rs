//! Device-step latency: one PJRT execute of each artifact per model — the
//! end-to-end hot path (literal marshalling + XLA execution + writeback).
//!
//! Skips silently when `make artifacts` has not run. Pass --model to limit.

use bsq::data::{Corpus, Loader};
use bsq::coordinator::corpus_for_model;
use bsq::model::{momentum_slots, ModelState};
use bsq::quant::{reg_weights, LayerPrec, QuantScheme, Reweigh};
use bsq::runtime::{load_manifest, Engine, RunInputs};
use bsq::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let models: Vec<String> = {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match args.iter().position(|a| a == "--model") {
            Some(i) => vec![args[i + 1].clone()],
            None => vec!["tinynet".into(), "resnet20".into()],
        }
    };
    if !bsq::runtime::artifacts_root().join("tinynet/manifest.json").exists() {
        eprintln!("skipping train_step bench: run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::cpu()?;
    let bench = Bench::quick();
    println!("== train_step ==");

    for model in &models {
        let man = load_manifest(model)?;
        let spec = corpus_for_model(model, 0).with_sizes(man.batch * 2, man.batch);
        let corpus = Corpus::generate(spec);
        let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 1);
        let batch = loader.next_batch();
        let scheme = QuantScheme::new(
            man.qlayers
                .iter()
                .map(|q| LayerPrec { name: q.name.clone(), params: q.params, bits: 8 })
                .collect(),
        );
        for art in ["fp_train_relu6", "bsq_train_relu6", "dorefa_train_relu6", "q_eval_relu6"] {
            let exe = match man.artifact(art) {
                Ok(spec) => engine.load(spec)?,
                Err(_) => continue,
            };
            let mut state = ModelState::init_fp(&man, 0);
            if art.starts_with("bsq") || art.starts_with("q_eval") {
                state.to_bit_representation(&man, 8)?;
            }
            state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
            let inputs = RunInputs::default()
                .hyper("lr", 0.05)
                .hyper("wd", 1e-4)
                .hyper("alpha", 5e-3)
                .vec("regw", reg_weights(&scheme, Reweigh::MemoryAware))
                .vec("wlv", scheme.levels_vec())
                .vec("actlv", vec![15.0; man.act_sites.len()]);
            let s = bench.run_elems(&format!("{model}/{art}"), man.batch as u64, || {
                exe.run(&mut state, Some(&batch), &inputs).unwrap();
            });
            println!(
                "{}  ({:.1} imgs/s)",
                s.report(),
                man.batch as f64 / s.mean.as_secs_f64()
            );
        }
    }
    Ok(())
}
