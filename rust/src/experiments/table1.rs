//! Table 1 + Figure 3: accuracy-#bits trade-off of ResNet-20 under
//! different regularization strengths α (4-bit activations), plus the
//! "train from scratch at the BSQ scheme" comparison row.

use anyhow::Result;

use crate::baselines::dorefa;
use crate::coordinator::{run_bsq, write_result, BsqConfig, Session, StepDecay};
use crate::experiments::ExpOpts;
use crate::runtime::Engine;
use crate::util::json::{parse, Json};

pub const DEFAULT_ALPHAS: &[f32] = &[3e-3, 5e-3, 7e-3, 1e-2, 2e-2];

pub fn run(engine: &Engine, opts: &ExpOpts) -> Result<()> {
    let alphas = opts.alphas.clone().unwrap_or_else(|| {
        if opts.is_fast() {
            vec![3e-3, 5e-3, 2e-2] // fast recorded profile: ends + middle
        } else {
            DEFAULT_ALPHAS.to_vec()
        }
    });
    let mut rows = Vec::new();

    for &alpha in &alphas {
        let mut cfg = BsqConfig::for_model("resnet20");
        cfg.alpha = alpha;
        cfg.act_bits = 4;
        opts.scale_cfg(&mut cfg);
        let outcome = run_bsq(engine, &cfg)?;

        // "Train from scratch" row: DoReFa QAT at the BSQ-discovered scheme.
        let session = Session::open(engine, "resnet20", cfg.train_size, cfg.test_size, cfg.seed)?;
        let scratch_epochs =
            (cfg.pretrain_epochs + cfg.bsq_epochs + cfg.finetune_epochs).max(1);
        let mut qat = dorefa::QatConfig::from_scratch(scratch_epochs, 4, cfg.seed);
        qat.schedule = StepDecay::pretrain();
        let scratch = dorefa::train_from_scratch(&session, &outcome.scheme, &qat)?;

        println!(
            "α={alpha:7.0e}  {:.2} bits/para  {:6.2}x  BSQ acc {:.2}%/{:.2}%  scratch {:.2}%",
            outcome.bits_per_param,
            outcome.compression,
            100.0 * outcome.acc_before_ft,
            100.0 * outcome.acc_after_ft,
            100.0 * scratch.final_acc,
        );
        rows.push(Json::obj(vec![
            ("alpha", Json::num(alpha as f64)),
            ("bits_per_param", Json::num(outcome.bits_per_param)),
            ("compression", Json::num(outcome.compression)),
            ("acc_before_ft", Json::num(outcome.acc_before_ft as f64)),
            ("acc_after_ft", Json::num(outcome.acc_after_ft as f64)),
            ("train_from_scratch_acc", Json::num(scratch.final_acc as f64)),
            (
                "scheme_bits",
                Json::arr_num(outcome.scheme.bits_vec().iter().map(|&b| b as f64)),
            ),
            ("outcome", outcome.to_json()),
        ]));
    }

    print_table(&rows);
    write_result(&opts.out_dir.join("table1.json"), &Json::Arr(rows))?;
    Ok(())
}

fn print_table(rows: &[Json]) {
    println!("\nTable 1 — Accuracy-#Bits trade-off (resnet20, 4-bit act, synthetic CIFAR)");
    println!(
        "{:>9} {:>14} {:>9} {:>12} {:>11} {:>13}",
        "α", "#bits/para", "Comp(×)", "acc preFT%", "acc FT%", "scratch acc%"
    );
    for r in rows {
        println!(
            "{:>9.0e} {:>14.2} {:>9.2} {:>12.2} {:>11.2} {:>13.2}",
            r.get("alpha").unwrap().as_f64().unwrap(),
            r.get("bits_per_param").unwrap().as_f64().unwrap(),
            r.get("compression").unwrap().as_f64().unwrap(),
            100.0 * r.get("acc_before_ft").unwrap().as_f64().unwrap(),
            100.0 * r.get("acc_after_ft").unwrap().as_f64().unwrap(),
            100.0 * r.get("train_from_scratch_acc").unwrap().as_f64().unwrap(),
        );
    }
}

/// Figure 3: per-layer precision by α, printed from the table1 record.
pub fn print_fig3(opts: &ExpOpts) -> Result<()> {
    let path = opts.out_dir.join("table1.json");
    let rows = parse(&std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("{e}: run `experiment table1` first to produce {}", path.display())
    })?)?;
    println!("\nFigure 3 — layer-wise precision vs α (resnet20)");
    for r in rows.as_arr()? {
        let bits: Vec<String> = r
            .req("scheme_bits")?
            .as_arr()?
            .iter()
            .map(|b| format!("{}", b.as_usize().unwrap_or(0)))
            .collect();
        println!("α={:7.0e}  [{}]", r.req("alpha")?.as_f64()?, bits.join(" "));
    }
    Ok(())
}
