//! Figure 4: re-quantization interval ablation (paper App. B.1).
//!
//! Arms: no re-quantization during training (final only), and intervals
//! {short, medium, long} in epochs — scaled analogues of the paper's
//! 20/50/100 on its 350-epoch schedule. Each arm repeats over seeds and
//! reports mean/min/max accuracy and compression.

use anyhow::Result;

use crate::coordinator::{run_bsq, write_result, BsqConfig};
use crate::experiments::ExpOpts;
use crate::runtime::Engine;
use crate::util::json::Json;

pub fn run(engine: &Engine, opts: &ExpOpts) -> Result<()> {
    let base = {
        let mut cfg = BsqConfig::for_model("resnet20");
        opts.scale_cfg(&mut cfg);
        cfg
    };
    // paper: 350-epoch schedule with intervals {none, 20, 50, 100} →
    // fractions of the phase: {0, 0.06, 0.14, 0.29}
    let intervals: Vec<(String, usize)> = [0.0f32, 0.06, 0.14, 0.29]
        .iter()
        .map(|f| {
            let iv = (*f * base.bsq_epochs as f32).round() as usize;
            let label = if *f == 0.0 {
                "none".to_string()
            } else {
                format!("int {}", iv.max(1))
            };
            (label, if *f == 0.0 { 0 } else { iv.max(1) })
        })
        .collect();

    let mut record = Vec::new();
    println!("\nFigure 4 — re-quantization interval ablation (resnet20)");
    println!(
        "{:>8} {:>7} {:>9} {:>9} {:>9} {:>9}",
        "arm", "seeds", "acc mean", "acc min", "acc max", "comp"
    );
    for (label, interval) in intervals {
        let mut accs = Vec::new();
        let mut comps = Vec::new();
        for &seed in &opts.seeds {
            let mut cfg = base.clone();
            cfg.requant_interval = interval;
            cfg.seed = seed;
            let o = run_bsq(engine, &cfg)?;
            accs.push(o.acc_after_ft as f64);
            comps.push(o.compression);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let min = accs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = accs.iter().cloned().fold(0.0, f64::max);
        let comp = comps.iter().sum::<f64>() / comps.len() as f64;
        println!(
            "{label:>8} {:>7} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            accs.len(),
            100.0 * mean,
            100.0 * min,
            100.0 * max,
            comp
        );
        record.push(Json::obj(vec![
            ("arm", Json::str(label)),
            ("interval_epochs", Json::num(interval as f64)),
            ("acc_mean", Json::num(mean)),
            ("acc_min", Json::num(min)),
            ("acc_max", Json::num(max)),
            ("compression_mean", Json::num(comp)),
            ("accs", Json::arr_num(accs)),
            ("compressions", Json::arr_num(comps)),
        ]));
    }
    write_result(&opts.out_dir.join("fig4.json"), &Json::Arr(record))?;
    Ok(())
}
