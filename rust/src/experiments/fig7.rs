//! Figure 7 (App. B.3): BSQ's discovered precision ranking vs HAWQ's
//! Hessian-importance ranking on ResNet-20, with Spearman correlation.

use anyhow::Result;

use crate::baselines::hawq::{analyze, HawqConfig};
use crate::coordinator::bsq::pretrain;
use crate::coordinator::{BsqConfig, History, Session};
use crate::experiments::ExpOpts;
use crate::quant::spearman;
use crate::runtime::Engine;
use crate::util::json::{parse, Json};

pub fn run(engine: &Engine, opts: &ExpOpts) -> Result<()> {
    let mut cfg = BsqConfig::for_model("resnet20");
    opts.scale_cfg(&mut cfg);
    let session = Session::open(engine, "resnet20", cfg.train_size, cfg.test_size, cfg.seed)?;

    // HAWQ importance on the pretrained fp model (cached pretrain reused).
    let mut hist = History::default();
    let state = pretrain(&session, &cfg, &mut hist, None, None)?;
    let report = analyze(&session, &state, &HawqConfig::default())?;

    println!("\nFigure 7 — BSQ precision vs HAWQ importance (resnet20)");
    println!("HAWQ importance S_i = λ_i/n_i (log10):");
    for (q, s) in session.man.qlayers.iter().zip(&report.importance) {
        println!("  {:<10} λ/n = {:10.3e}", q.name, s);
    }

    // BSQ schemes from the table1 record (if present) for the correlation.
    let t1 = opts.out_dir.join("table1.json");
    let mut record = vec![(
        "hawq_importance".to_string(),
        Json::arr_num(report.importance.clone()),
    )];
    if let Ok(text) = std::fs::read_to_string(&t1) {
        let rows = parse(&text)?;
        for r in rows.as_arr()? {
            let alpha = r.req("alpha")?.as_f64()?;
            let bits: Vec<f64> = r
                .req("scheme_bits")?
                .as_arr()?
                .iter()
                .map(|b| b.as_f64().unwrap())
                .collect();
            let rho = spearman(&bits, &report.importance);
            println!("α={alpha:7.0e}: Spearman(BSQ bits, HAWQ importance) = {rho:+.3}");
            record.push((format!("spearman_alpha_{alpha:e}"), Json::num(rho)));
        }
    } else {
        println!("(run `experiment table1` first for the BSQ-vs-HAWQ correlation rows)");
    }

    let obj = Json::Obj(record.into_iter().map(|(k, v)| (k, v)).collect());
    crate::coordinator::write_result(&opts.out_dir.join("fig7.json"), &obj)?;
    Ok(())
}
