//! Tables 4/5 + Figures 8/9: accuracy-#bits trade-off at 2-bit and 3-bit
//! activations (PACT path), across α (paper App. B.4).

use anyhow::Result;

use crate::coordinator::{run_bsq, write_result, BsqConfig};
use crate::experiments::ExpOpts;
use crate::runtime::Engine;
use crate::util::json::Json;

pub fn run(engine: &Engine, opts: &ExpOpts, act_bits: usize) -> Result<()> {
    let default: &[f32] = match act_bits {
        2 => &[1e-3, 2e-3, 3e-3, 5e-3], // paper Table 4
        _ => &[2e-3, 5e-3, 8e-3, 1e-2], // paper Table 5
    };
    let alphas = opts.alphas.clone().unwrap_or_else(|| {
        if opts.is_fast() {
            vec![default[0], default[default.len() - 1]] // grid endpoints
        } else {
            default.to_vec()
        }
    });
    let (table, fig) = if act_bits == 2 { ("Table 4", "Fig 8") } else { ("Table 5", "Fig 9") };

    println!("\n{table} / {fig} — {act_bits}-bit activation (PACT), resnet20");
    println!(
        "{:>9} {:>12} {:>9} {:>11} {:>10}",
        "α", "#bits/para", "Comp(×)", "preFT acc%", "FT acc%"
    );
    let mut rows = Vec::new();
    for &alpha in &alphas {
        let mut cfg = BsqConfig::for_model("resnet20");
        cfg.alpha = alpha;
        cfg.act_bits = act_bits;
        opts.scale_cfg(&mut cfg);
        let o = run_bsq(engine, &cfg)?;
        println!(
            "{alpha:>9.0e} {:>12.2} {:>9.2} {:>11.2} {:>10.2}",
            o.bits_per_param,
            o.compression,
            100.0 * o.acc_before_ft,
            100.0 * o.acc_after_ft
        );
        rows.push(Json::obj(vec![
            ("alpha", Json::num(alpha as f64)),
            ("act_bits", Json::num(act_bits as f64)),
            ("bits_per_param", Json::num(o.bits_per_param)),
            ("compression", Json::num(o.compression)),
            ("acc_before_ft", Json::num(o.acc_before_ft as f64)),
            ("acc_after_ft", Json::num(o.acc_after_ft as f64)),
            ("scheme_bits", Json::arr_num(o.scheme.bits_vec().iter().map(|&b| b as f64))),
        ]));
    }
    println!("{fig} — layer-wise precision per α:");
    for r in &rows {
        let bits: Vec<String> = r
            .get("scheme_bits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| format!("{}", b.as_usize().unwrap_or(0)))
            .collect();
        println!("α={:7.0e}  [{}]", r.get("alpha").unwrap().as_f64().unwrap(), bits.join(" "));
    }
    let out = opts.out_dir.join(format!("table{}.json", if act_bits == 2 { 4 } else { 5 }));
    write_result(&out, &Json::Arr(rows))?;
    Ok(())
}
