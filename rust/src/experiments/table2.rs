//! Table 2: ResNet-20 comparison against baselines at 32/4/3/2-bit
//! activations.
//!
//! Locally-run rows: BSQ (from the table1 record or fresh runs), DoReFa,
//! PACT (DoReFa weights + trainable PACT clip), LSQ (LQ-Nets stand-in),
//! HAWQ (Hessian ranking → scheme → finetune). Rows we cannot rebuild
//! offline (DNAS) are printed as paper-cited reference values and marked.

use anyhow::Result;

use crate::baselines::{dorefa, hawq, lsq, QatConfig};
use crate::coordinator::{run_bsq, write_result, BsqConfig, Session};
use crate::experiments::ExpOpts;
use crate::quant::QuantScheme;
use crate::runtime::Engine;
use crate::util::json::Json;

pub fn run(engine: &Engine, opts: &ExpOpts) -> Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    println!("\nTable 2 — resnet20 vs baselines (synthetic CIFAR; accuracies are testbed-scale)");
    println!("{:<10} {:<14} {:>6} {:>9} {:>8}", "act", "method", "wbits", "Comp(×)", "acc%");

    let mut cfg0 = BsqConfig::for_model("resnet20");
    opts.scale_cfg(&mut cfg0);
    let session = Session::open(engine, "resnet20", cfg0.train_size, cfg0.test_size, 0)?;
    let names: Vec<(String, usize)> =
        session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
    let scratch_epochs = cfg0.pretrain_epochs + cfg0.bsq_epochs + cfg0.finetune_epochs;

    let mut push = |act: &str, method: &str, wbits: &str, comp: f64, acc: f64, cited: bool| {
        println!(
            "{act:<10} {method:<14} {wbits:>6} {comp:>9.2} {:>8.2}{}",
            100.0 * acc,
            if cited { "  (paper-cited)" } else { "" }
        );
        rows.push(Json::obj(vec![
            ("act", Json::str(act)),
            ("method", Json::str(method)),
            ("wbits", Json::str(wbits)),
            ("compression", Json::num(comp)),
            ("acc", Json::num(acc)),
            ("cited", Json::Bool(cited)),
        ]));
    };

    // -- 4-bit activation block ---------------------------------------------
    {
        let mut cfg = cfg0.clone();
        cfg.alpha = 5e-3;
        cfg.act_bits = 4;
        let bsq = run_bsq(engine, &cfg)?;
        push("4-bit", "BSQ 5e-3", "MP", bsq.compression, bsq.acc_after_ft as f64, false);

        // HAWQ: rank on the pretrained model, assign to match BSQ's budget.
        let mut hist = crate::coordinator::History::default();
        let state = crate::coordinator::bsq::pretrain(&session, &cfg, &mut hist, None, None)?;
        let report = hawq::analyze(&session, &state, &hawq::HawqConfig::default())?;
        let scheme = hawq::assign_scheme(&session, &report, bsq.bits_per_param, &[8, 4, 2]);
        let out = dorefa::train_from_scratch(
            &session,
            &scheme,
            &QatConfig::from_scratch(scratch_epochs, 4, 0),
        )?;
        push("4-bit", "HAWQ", "MP", scheme.compression(), out.final_acc as f64, false);

        // DoReFa / LSQ at uniform 3-bit weights.
        let u3 = QuantScheme::uniform(&names, 3);
        let qat3 = QatConfig::from_scratch(scratch_epochs, 4, 0);
        let d = dorefa::train_from_scratch(&session, &u3, &qat3)?;
        push("4-bit", "DoReFa", "3", u3.compression(), d.final_acc as f64, false);
        let l = lsq::train_from_scratch(&session, &u3, &qat3)?;
        push("4-bit", "LSQ/LQ-Nets", "3", u3.compression(), l.final_acc as f64, false);

        // paper-cited anchors for comparators we cannot rebuild offline
        push("4-bit", "DNAS (cited)", "MP", 11.60, 0.9272, true);
        push("4-bit", "HAWQ (cited)", "MP", 13.11, 0.9222, true);
    }

    // -- 3-bit / 2-bit activation blocks (PACT path) -------------------------
    for act_bits in [3usize, 2] {
        let alpha = if act_bits == 3 { 2e-3 } else { 5e-3 };
        let mut cfg = cfg0.clone();
        cfg.alpha = alpha;
        cfg.act_bits = act_bits;
        let bsq = run_bsq(engine, &cfg)?;
        let act = format!("{act_bits}-bit");
        let label = format!("BSQ {alpha:.0e}");
        push(&act, &label, "MP", bsq.compression, bsq.acc_after_ft as f64, false);

        let uni = QuantScheme::uniform(&names, act_bits);
        let d = dorefa::train_from_scratch(
            &session,
            &uni,
            &QatConfig::from_scratch(scratch_epochs, act_bits, 0),
        )?;
        let ab = act_bits.to_string();
        push(&act, "DoReFa+PACT", &ab, uni.compression(), d.final_acc as f64, false);
        let cited = if act_bits == 3 { 0.916 } else { 0.902 };
        push(&act, "LQ-Nets (cited)", &ab, 32.0 / act_bits as f64, cited, true);
    }

    write_result(&opts.out_dir.join("table2.json"), &Json::Arr(rows))?;
    Ok(())
}
