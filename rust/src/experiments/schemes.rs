//! Tables 6/7: detailed per-layer quantization schemes of the Table 3 runs
//! (ResNet-50 twin for Table 6, Inception twin for Table 7), printed from
//! the table3 record.

use anyhow::{anyhow, Result};

use crate::experiments::ExpOpts;
use crate::util::json::parse;

pub fn run(opts: &ExpOpts, id: &str) -> Result<()> {
    let model = if id == "table6" { "resnet50_sim" } else { "inception_sim" };
    let path = opts.out_dir.join("table3.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("{e}: run `experiment table3` first ({})", path.display()))?;
    let rows = parse(&text)?;

    let title = if id == "table6" { "Table 6" } else { "Table 7" };
    println!("\n{title} — per-layer schemes of the {model} runs");
    for r in rows.as_arr()? {
        if r.req("model")?.as_str()? != model || r.get("scheme").is_none() {
            continue;
        }
        println!("\n{}:", r.req("method")?.as_str()?);
        for l in r.req("scheme")?.as_arr()? {
            println!("  {:<12} {:>2} bits", l.req("name")?.as_str()?, l.req("bits")?.as_usize()?);
        }
    }
    Ok(())
}
