//! Figures 2 / 5 / 6: quantization schemes with vs. without the
//! memory-consumption-aware regularization reweighing (paper §4.1, App B.2).
//!
//! The paper pairs α values chosen for comparable compression:
//!   Fig 2: (5e-3 reweighed, 2e-3 plain)
//!   Fig 5: (6e-3 reweighed, 3e-3 plain)
//!   Fig 6: (1.5e-2 reweighed, 5e-3 plain)

use anyhow::Result;

use crate::coordinator::{run_bsq, write_result, BsqConfig};
use crate::experiments::ExpOpts;
use crate::quant::Reweigh;
use crate::runtime::Engine;
use crate::util::json::Json;

pub fn run(engine: &Engine, opts: &ExpOpts, id: &str) -> Result<()> {
    let (a_rw, a_plain) = match id {
        "fig5" => (6e-3f32, 3e-3f32),
        "fig6" => (1.5e-2, 5e-3),
        _ => (5e-3, 2e-3),
    };
    let mut record = Vec::new();
    let mut lines = Vec::new();
    for (label, alpha, policy) in [
        ("with reweighing", a_rw, Reweigh::MemoryAware),
        ("without reweighing", a_plain, Reweigh::None),
    ] {
        let mut cfg = BsqConfig::for_model("resnet20");
        cfg.alpha = alpha;
        cfg.reweigh = policy;
        opts.scale_cfg(&mut cfg);
        let o = run_bsq(engine, &cfg)?;
        lines.push(format!(
            "{label:<20} α={alpha:7.0e}  comp {:6.2}x  acc {:.2}%  bits {:?}",
            o.compression,
            100.0 * o.acc_after_ft,
            o.scheme.bits_vec()
        ));
        record.push(Json::obj(vec![
            ("label", Json::str(label)),
            ("alpha", Json::num(alpha as f64)),
            ("compression", Json::num(o.compression)),
            ("acc_after_ft", Json::num(o.acc_after_ft as f64)),
            ("scheme_bits", Json::arr_num(o.scheme.bits_vec().iter().map(|&b| b as f64))),
            (
                "params",
                Json::arr_num(o.scheme.layers.iter().map(|l| l.params as f64)),
            ),
        ]));
    }
    println!("\n{} — reweighing ablation (resnet20, 4-bit act)", id);
    for l in &lines {
        println!("{l}");
    }
    // The paper's observation: without reweighing, small early layers get
    // over-penalized and the big late layers keep too many bits. Quantify:
    summarize_shift(&record);
    write_result(&opts.out_dir.join(format!("{id}.json")), &Json::Arr(record))?;
    Ok(())
}

fn summarize_shift(record: &[Json]) {
    let bits = |r: &Json| -> Vec<f64> {
        let arr = r.get("scheme_bits").unwrap().as_arr().unwrap();
        arr.iter().map(|b| b.as_f64().unwrap()).collect()
    };
    let params = |r: &Json| -> Vec<f64> {
        r.get("params").unwrap().as_arr().unwrap().iter().map(|b| b.as_f64().unwrap()).collect()
    };
    if record.len() != 2 {
        return;
    }
    let (rw, plain) = (bits(&record[0]), bits(&record[1]));
    let p = params(&record[0]);
    let half = p.len() / 2;
    let avg = |v: &[f64], lo: usize, hi: usize| {
        v[lo..hi].iter().sum::<f64>() / (hi - lo).max(1) as f64
    };
    println!(
        "early-layer avg bits: reweighed {:.2} vs plain {:.2}; late-layer: {:.2} vs {:.2}",
        avg(&rw, 0, half),
        avg(&plain, 0, half),
        avg(&rw, half, p.len()),
        avg(&plain, half, p.len()),
    );
}
