//! Experiment harnesses: one per paper table/figure (DESIGN.md §7).
//!
//! Each harness runs the workload, prints the same rows/series the paper
//! reports, and writes a machine-readable record under `results/`.
//! Defaults are the testbed-scaled fast profiles recorded in
//! EXPERIMENTS.md; `--epochs-scale`/`--data-scale` grow them toward the
//! paper's full schedules.

pub mod act_sweep;
pub mod fig2;
pub mod fig4;
pub mod fig7;
pub mod schemes;
pub mod table1;
pub mod table2;
pub mod table3;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::runtime::Engine;

/// Shared experiment options (from CLI flags).
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// Scale factor on the default (fast) epoch counts.
    pub epochs_scale: f32,
    /// Scale factor on the default corpus sizes.
    pub data_scale: f32,
    /// Override α list where applicable.
    pub alphas: Option<Vec<f32>>,
    /// Seeds for repeated runs (Fig. 4).
    pub seeds: Vec<u64>,
    pub out_dir: PathBuf,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            epochs_scale: 1.0,
            data_scale: 1.0,
            alphas: None,
            seeds: vec![0],
            out_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"),
        }
    }
}

impl ExpOpts {
    /// Abbreviated recorded profile? (smaller default α grids)
    pub fn is_fast(&self) -> bool {
        self.epochs_scale < 1.0 || self.data_scale < 1.0
    }

    pub fn scale_cfg(&self, cfg: &mut crate::coordinator::BsqConfig) {
        let e = |n: usize| ((n as f32 * self.epochs_scale).round() as usize).max(1);
        cfg.pretrain_epochs = e(cfg.pretrain_epochs);
        cfg.bsq_epochs = e(cfg.bsq_epochs);
        cfg.finetune_epochs = e(cfg.finetune_epochs);
        let d = |n: usize| ((n as f32 * self.data_scale).round() as usize).max(64);
        cfg.train_size = d(cfg.train_size);
        cfg.test_size = d(cfg.test_size);
    }
}

/// Dispatch by experiment id.
pub fn run(engine: &Engine, id: &str, opts: &ExpOpts) -> Result<()> {
    match id {
        "table1" => table1::run(engine, opts),
        "table2" => table2::run(engine, opts),
        "table3" => table3::run(engine, opts),
        "table4" | "fig8" => act_sweep::run(engine, opts, 2),
        "table5" | "fig9" => act_sweep::run(engine, opts, 3),
        "table6" | "table7" => schemes::run(opts, id),
        "fig2" | "fig5" | "fig6" => fig2::run(engine, opts, id),
        "fig3" => table1::print_fig3(opts),
        "fig4" => fig4::run(engine, opts),
        "fig7" => fig7::run(engine, opts),
        "all" => {
            for id in [
                "table1", "fig3", "fig2", "fig4", "fig7", "table4", "table5", "table3",
                "table6", "table7", "table2",
            ] {
                log::info!("=== experiment {id} ===");
                if let Err(e) = run(engine, id, opts) {
                    log::error!("experiment {id} failed: {e:#}");
                }
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?} (see DESIGN.md §7)"),
    }
}
