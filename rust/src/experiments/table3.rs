//! Table 3: ImageNet rows via the scaled-down twins (DESIGN.md §4) —
//! resnet50_sim at α ∈ {5e-3, 7e-3}, inception_sim at α ∈ {1e-2, 2e-2},
//! with a DoReFa uniform-3-bit local baseline and paper-cited anchors.

use anyhow::Result;

use crate::baselines::{dorefa, QatConfig};
use crate::coordinator::{run_bsq, write_result, BsqConfig, Session};
use crate::experiments::ExpOpts;
use crate::quant::QuantScheme;
use crate::runtime::Engine;
use crate::util::json::Json;

pub fn run(engine: &Engine, opts: &ExpOpts) -> Result<()> {
    let mut rows = Vec::new();
    println!("\nTable 3 — ImageNet twins (synthetic-imagenet corpus, 100 classes)");
    println!("{:<14} {:<12} {:>9} {:>8}", "model", "method", "Comp(×)", "top1%");

    for (model, alphas, act_bits) in [
        ("resnet50_sim", [5e-3f32, 7e-3], 4usize),
        ("inception_sim", [1e-2, 2e-2], 6),
    ] {
        // Local DoReFa baseline at uniform 3-bit.
        let mut cfg0 = BsqConfig::for_model(model);
        opts.scale_cfg(&mut cfg0);
        let session = Session::open(engine, model, cfg0.train_size, cfg0.test_size, 0)?;
        let names: Vec<(String, usize)> =
            session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
        let uni = QuantScheme::uniform(&names, 3);
        let epochs = cfg0.pretrain_epochs + cfg0.bsq_epochs + cfg0.finetune_epochs;
        let mut qat = QatConfig::from_scratch(epochs, act_bits, 0);
        qat.act_first_last = if model == "inception_sim" { act_bits } else { 8 };
        let d = dorefa::train_from_scratch(&session, &uni, &qat)?;
        println!(
            "{model:<14} {:<12} {:>9.2} {:>8.2}",
            "DoReFa-3",
            uni.compression(),
            100.0 * d.final_acc
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("method", Json::str("DoReFa-3")),
            ("compression", Json::num(uni.compression())),
            ("acc", Json::num(d.final_acc as f64)),
        ]));

        for alpha in alphas {
            let mut cfg = cfg0.clone();
            cfg.alpha = alpha;
            cfg.act_bits = act_bits;
            if model == "inception_sim" {
                cfg.act_first_last = act_bits; // paper: uniform 6-bit acts
            }
            let o = run_bsq(engine, &cfg)?;
            let label = format!("BSQ {alpha:.0e}");
            println!(
                "{model:<14} {label:<12} {:>9.2} {:>8.2}",
                o.compression,
                100.0 * o.acc_after_ft
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("method", Json::str(label)),
                ("compression", Json::num(o.compression)),
                ("acc", Json::num(o.acc_after_ft as f64)),
                ("bits_per_param", Json::num(o.bits_per_param)),
                (
                    "scheme",
                    Json::Arr(
                        o.scheme
                            .layers
                            .iter()
                            .map(|l| {
                                Json::obj(vec![
                                    ("name", Json::str(l.name.clone())),
                                    ("bits", Json::num(l.bits as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
    }
    // paper-cited anchors (real ImageNet, for shape reference only)
    for (model, method, comp, acc) in [
        ("resnet50", "PACT-3 (cited)", 10.67, 0.7530),
        ("resnet50", "LSQ-3 (cited)", 10.67, 0.7580),
        ("resnet50", "BSQ 5e-3 (paper)", 11.90, 0.7529),
        ("inception_v3", "HAWQ (cited)", 12.04, 0.7552),
        ("inception_v3", "BSQ 2e-2 (paper)", 12.89, 0.7590),
    ] {
        println!("{model:<14} {method:<12} {comp:>9.2} {:>8.2}  (paper-cited)", 100.0 * acc);
    }
    write_result(&opts.out_dir.join("table3.json"), &Json::Arr(rows))?;
    Ok(())
}
