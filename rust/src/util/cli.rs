//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed accessors record which keys were consumed so `finish()` can reject
//! typos instead of silently ignoring them.
//!
//! Grammar note: a non-`--` token following `--key` binds as its value, so
//! positionals (the subcommand) must precede flags — which is how every
//! `bsq-repro` invocation reads anyway (`bsq-repro bsq --model resnet20`).
//! Boolean flags are safe in any position when followed by another flag or
//! the end of the line; use `--flag=true` style if you must interleave.

use std::collections::BTreeSet;

use anyhow::{anyhow, bail, Result};

#[derive(Debug)]
pub struct Args {
    pairs: Vec<(String, Option<String>)>, // --key [value]
    positional: Vec<String>,
    used: BTreeSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut pairs = Vec::new();
        let mut positional = Vec::new();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    pairs.push((k.to_string(), Some(v.to_string())));
                } else {
                    // Peek: a following token that is not itself a flag is
                    // this key's value.
                    let take = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    let v = if take { it.next() } else { None };
                    pairs.push((body.to_string(), v));
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { pairs, positional, used: BTreeSet::new() })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn take_positional(&mut self, idx: usize) -> Option<String> {
        self.positional.get(idx).cloned()
    }

    fn raw(&mut self, key: &str) -> Option<Option<String>> {
        self.used.insert(key.to_string());
        self.pairs.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.clone())
    }

    /// `--key` present (with or without a value)?
    pub fn flag(&mut self, key: &str) -> bool {
        self.raw(key).is_some()
    }

    pub fn opt_str(&mut self, key: &str) -> Result<Option<String>> {
        match self.raw(key) {
            None => Ok(None),
            Some(Some(v)) => Ok(Some(v)),
            Some(None) => bail!("--{key} requires a value"),
        }
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> Result<String> {
        Ok(self.opt_str(key)?.unwrap_or_else(|| default.to_string()))
    }

    pub fn opt<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key)? {
            None => Ok(None),
            Some(v) => {
                Ok(Some(v.parse().map_err(|e| anyhow!("--{key}: invalid value {v:?}: {e}"))?))
            }
        }
    }

    pub fn get_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// Comma-separated list, e.g. `--alphas 3e-3,5e-3,1e-2`.
    pub fn list<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key)? {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().map_err(|e| anyhow!("--{key}: bad item {s:?}: {e}")))
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Error on any `--key` that no accessor consumed (typo guard), with a
    /// did-you-mean suggestion against the flags the command actually asked
    /// for and the full list of those flags — a typo'd `serve-bench
    /// --bathces 1,8` must fail loudly and helpfully, never run a sweep at
    /// silently-defaulted settings.
    pub fn finish(self) -> Result<()> {
        let unknown: Vec<&String> =
            self.pairs.iter().map(|(k, _)| k).filter(|k| !self.used.contains(*k)).collect();
        if unknown.is_empty() {
            return Ok(());
        }
        let described: Vec<String> = unknown
            .iter()
            .map(|k| match nearest(k.as_str(), &self.used) {
                Some(sugg) => format!("--{k} (did you mean --{sugg}?)"),
                None => format!("--{k}"),
            })
            .collect();
        let known: Vec<String> = self.used.iter().map(|k| format!("--{k}")).collect();
        if known.is_empty() {
            bail!("unknown flags: {} (this command takes no flags)", described.join(", "));
        }
        bail!(
            "unknown flags: {}; this command accepts: {}",
            described.join(", "),
            known.join(" ")
        );
    }
}

/// Closest consumed flag within edit distance 2 (ties broken by the
/// candidates' sorted order — `used` is a BTreeSet).
fn nearest<'a>(key: &str, candidates: &'a BTreeSet<String>) -> Option<&'a str> {
    candidates
        .iter()
        .map(|c| (edit_distance(key, c), c.as_str()))
        .min_by_key(|&(d, _)| d)
        .filter(|&(d, _)| d <= 2)
        .map(|(_, c)| c)
}

/// Levenshtein distance, O(|a|·|b|) with a rolling row.
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_key_value_styles() {
        let mut a = args("run --model resnet20 --alpha=5e-3 --verbose");
        assert_eq!(a.str_or("model", "x").unwrap(), "resnet20");
        assert_eq!(a.get_or("alpha", 0.0f64).unwrap(), 5e-3);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), ["run"]);
        a.finish().unwrap();
    }

    #[test]
    fn last_occurrence_wins() {
        let mut a = args("--n 1 --n 2");
        assert_eq!(a.get_or("n", 0u32).unwrap(), 2);
    }

    #[test]
    fn lists_parse() {
        let mut a = args("--alphas 3e-3,5e-3,1e-2");
        assert_eq!(a.list::<f64>("alphas").unwrap().unwrap(), vec![3e-3, 5e-3, 1e-2]);
    }

    #[test]
    fn missing_value_is_error() {
        let mut a = args("--model --other x");
        assert!(a.opt_str("model").unwrap_err().to_string().contains("requires a value"));
    }

    #[test]
    fn unknown_flags_rejected() {
        let mut a = args("--model m --typo 3");
        let _ = a.opt_str("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn unknown_flag_suggests_nearest_known() {
        // the serve-bench regression: a typo'd multi-flag invocation must
        // name the offender, suggest the intended flag, and list the rest
        let mut a = args("serve-bench --bathces 1,8,32 --workers 1,4 --requests 64");
        let _ = a.list::<usize>("batches");
        let _ = a.list::<usize>("workers");
        let _ = a.get_or("requests", 0usize);
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--bathces"), "{err}");
        assert!(err.contains("did you mean --batches?"), "{err}");
        assert!(err.contains("--workers") && err.contains("--requests"), "{err}");
    }

    #[test]
    fn unknown_flag_without_close_match_lists_known() {
        let mut a = args("--model m --zzzzzzzz 1");
        let _ = a.opt_str("model");
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--zzzzzzzz") && !err.contains("did you mean"), "{err}");
        assert!(err.contains("accepts: --model"), "{err}");
    }

    #[test]
    fn flagless_command_reports_no_flags_taken() {
        let a = args("info --bogus");
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("takes no flags"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("batches", "batches"), 0);
        assert_eq!(edit_distance("bathces", "batches"), 2); // transposition = 2 edits
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn defaults_apply() {
        let mut a = args("");
        assert_eq!(a.get_or("epochs", 5u32).unwrap(), 5);
        assert_eq!(a.str_or("out", "results").unwrap(), "results");
    }
}
