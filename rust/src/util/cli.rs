//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed accessors record which keys were consumed so `finish()` can reject
//! typos instead of silently ignoring them.
//!
//! Grammar note: a non-`--` token following `--key` binds as its value, so
//! positionals (the subcommand) must precede flags — which is how every
//! `bsq-repro` invocation reads anyway (`bsq-repro bsq --model resnet20`).
//! Boolean flags are safe in any position when followed by another flag or
//! the end of the line; use `--flag=true` style if you must interleave.

use std::collections::BTreeSet;

use anyhow::{anyhow, bail, Result};

#[derive(Debug)]
pub struct Args {
    pairs: Vec<(String, Option<String>)>, // --key [value]
    positional: Vec<String>,
    used: BTreeSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut pairs = Vec::new();
        let mut positional = Vec::new();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    pairs.push((k.to_string(), Some(v.to_string())));
                } else {
                    // Peek: a following token that is not itself a flag is
                    // this key's value.
                    let take = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    let v = if take { it.next() } else { None };
                    pairs.push((body.to_string(), v));
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { pairs, positional, used: BTreeSet::new() })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn take_positional(&mut self, idx: usize) -> Option<String> {
        self.positional.get(idx).cloned()
    }

    fn raw(&mut self, key: &str) -> Option<Option<String>> {
        self.used.insert(key.to_string());
        self.pairs.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.clone())
    }

    /// `--key` present (with or without a value)?
    pub fn flag(&mut self, key: &str) -> bool {
        self.raw(key).is_some()
    }

    pub fn opt_str(&mut self, key: &str) -> Result<Option<String>> {
        match self.raw(key) {
            None => Ok(None),
            Some(Some(v)) => Ok(Some(v)),
            Some(None) => bail!("--{key} requires a value"),
        }
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> Result<String> {
        Ok(self.opt_str(key)?.unwrap_or_else(|| default.to_string()))
    }

    pub fn opt<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key)? {
            None => Ok(None),
            Some(v) => {
                Ok(Some(v.parse().map_err(|e| anyhow!("--{key}: invalid value {v:?}: {e}"))?))
            }
        }
    }

    pub fn get_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// Comma-separated list, e.g. `--alphas 3e-3,5e-3,1e-2`.
    pub fn list<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key)? {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().map_err(|e| anyhow!("--{key}: bad item {s:?}: {e}")))
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Error on any `--key` that no accessor consumed (typo guard).
    pub fn finish(self) -> Result<()> {
        let unknown: Vec<_> =
            self.pairs.iter().map(|(k, _)| k).filter(|k| !self.used.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_key_value_styles() {
        let mut a = args("run --model resnet20 --alpha=5e-3 --verbose");
        assert_eq!(a.str_or("model", "x").unwrap(), "resnet20");
        assert_eq!(a.get_or("alpha", 0.0f64).unwrap(), 5e-3);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), ["run"]);
        a.finish().unwrap();
    }

    #[test]
    fn last_occurrence_wins() {
        let mut a = args("--n 1 --n 2");
        assert_eq!(a.get_or("n", 0u32).unwrap(), 2);
    }

    #[test]
    fn lists_parse() {
        let mut a = args("--alphas 3e-3,5e-3,1e-2");
        assert_eq!(a.list::<f64>("alphas").unwrap().unwrap(), vec![3e-3, 5e-3, 1e-2]);
    }

    #[test]
    fn missing_value_is_error() {
        let mut a = args("--model --other x");
        assert!(a.opt_str("model").unwrap_err().to_string().contains("requires a value"));
    }

    #[test]
    fn unknown_flags_rejected() {
        let mut a = args("--model m --typo 3");
        let _ = a.opt_str("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = args("");
        assert_eq!(a.get_or("epochs", 5u32).unwrap(), 5);
        assert_eq!(a.str_or("out", "results").unwrap(), "results");
    }
}
