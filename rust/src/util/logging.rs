//! Minimal `log` backend: timestamped stderr lines, level via `BSQ_LOG`.

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = START.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger once; level from `BSQ_LOG` (error|warn|info|debug|trace).
pub fn init() {
    INIT.call_once(|| {
        Lazy::force(&START);
        let level = match std::env::var("BSQ_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_boxed_logger(Box::new(StderrLogger));
        log::set_max_level(level);
    });
}
