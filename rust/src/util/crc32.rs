//! CRC32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — table-driven and
//! dependency-free (the build is offline), streaming so checkpoint
//! sections can be hashed as they are read without double-buffering.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC32 state.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

/// One-shot convenience.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard IEEE check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"BSQCKPT2 streaming section bytes";
        for split in 0..data.len() {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finalize(), crc32(data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let data = b"checkpoint entry payload";
        let base = crc32(data);
        let mut buf = data.to_vec();
        for i in 0..buf.len() {
            for bit in 0..8 {
                buf[i] ^= 1 << bit;
                assert_ne!(crc32(&buf), base, "flip byte {i} bit {bit} went undetected");
                buf[i] ^= 1 << bit;
            }
        }
    }
}
