//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/stddev/median/min and a
//! criterion-style one-line report. Used by every target in `rust/benches/`
//! and by the §Perf pass in EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Stats {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput_per_sec() {
            Some(t) if t >= 1e9 => format!("  {:7.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:7.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:7.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:7.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, min {:>12}, n={}){}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark runner: fixed warmup count, then `iters` timed runs (or until
/// `max_time` elapses, whichever comes first — at least 3 samples).
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 30, max_time: Duration::from_secs(20) }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, iters: 10, max_time: Duration::from_secs(10) }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        self.run_with_elements(name, None, &mut f)
    }

    pub fn run_elems<F: FnMut()>(&self, name: &str, elements: u64, mut f: F) -> Stats {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    fn run_with_elements<F: FnMut()>(&self, name: &str, elements: Option<u64>, f: &mut F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut samples: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time && samples.len() >= 3 {
                break;
            }
        }
        stats_from_samples(name, &mut samples, elements)
    }
}

fn stats_from_samples(name: &str, samples: &mut [Duration], elements: Option<u64>) -> Stats {
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples.iter().map(|s| (s.as_secs_f64() - mean_s).powi(2)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples[0],
        max: samples[n - 1],
        elements,
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_iterations() {
        let b = Bench { warmup: 1, iters: 5, max_time: Duration::from_secs(60) };
        let mut count = 0usize;
        let s = b.run("noop", || count += 1);
        assert_eq!(s.iters, 5);
        assert_eq!(count, 6); // warmup + timed
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn throughput_computed() {
        let b = Bench::quick();
        let s = b.run_elems("spin", 1000, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.throughput_per_sec().unwrap() > 0.0);
        assert!(s.report().contains("elem/s"));
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains("s"));
    }
}
