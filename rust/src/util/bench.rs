//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/stddev/median/min, a
//! criterion-style one-line report, per-iteration setup excluded from the
//! timed region (`run_prepared`), and machine-readable output: every bench
//! target collects its [`Stats`] into a [`JsonReport`] and writes
//! `BENCH_<target>.json` so the perf trajectory is tracked across PRs.
//! Used by every target in `rust/benches/` and by the §Perf pass in
//! EXPERIMENTS.md.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Stats {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean.as_secs_f64())
    }

    pub fn ns_per_elem(&self) -> Option<f64> {
        self.elements.map(|e| self.mean.as_nanos() as f64 / e.max(1) as f64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean.as_nanos() as f64)),
            ("median_ns", Json::num(self.median.as_nanos() as f64)),
            ("stddev_ns", Json::num(self.stddev.as_nanos() as f64)),
            ("min_ns", Json::num(self.min.as_nanos() as f64)),
            ("ns_per_elem", self.ns_per_elem().map(Json::num).unwrap_or(Json::Null)),
            (
                "throughput_elems_per_sec",
                self.throughput_per_sec().map(Json::num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput_per_sec() {
            Some(t) if t >= 1e9 => format!("  {:7.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:7.2} Melem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:7.2} Kelem/s", t / 1e3),
            Some(t) => format!("  {t:7.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, min {:>12}, n={}){}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters,
            tp
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark runner: fixed warmup count, then `iters` timed runs (or until
/// `max_time` elapses, whichever comes first — at least 3 samples).
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 30, max_time: Duration::from_secs(20) }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, iters: 10, max_time: Duration::from_secs(10) }
    }

    /// Default runner, or `quick()` when `BSQ_BENCH_QUICK` is set (CI smoke).
    pub fn from_env() -> Self {
        if std::env::var_os("BSQ_BENCH_QUICK").is_some() {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        self.run_with_elements(name, None, &mut f)
    }

    pub fn run_elems<F: FnMut()>(&self, name: &str, elements: u64, mut f: F) -> Stats {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    /// Like `run_elems`, but rebuilds the routine's input with `setup`
    /// before every iteration, *outside* the timed region — for routines
    /// that consume or mutate their input (e.g. in-place re-quantization).
    /// The measured span covers only `f`; setup and drop are excluded.
    pub fn run_prepared<T, S, F>(&self, name: &str, elements: u64, mut setup: S, mut f: F) -> Stats
    where
        S: FnMut() -> T,
        F: FnMut(&mut T),
    {
        for _ in 0..self.warmup {
            let mut x = setup();
            f(&mut x);
        }
        let start = Instant::now();
        let mut samples: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let mut x = setup();
            let t0 = Instant::now();
            f(&mut x);
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time && samples.len() >= 3 {
                break;
            }
        }
        stats_from_samples(name, &mut samples, Some(elements))
    }

    fn run_with_elements<F: FnMut()>(&self, name: &str, elements: Option<u64>, f: &mut F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut samples: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time && samples.len() >= 3 {
                break;
            }
        }
        stats_from_samples(name, &mut samples, elements)
    }
}

fn stats_from_samples(name: &str, samples: &mut [Duration], elements: Option<u64>) -> Stats {
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples.iter().map(|s| (s.as_secs_f64() - mean_s).powi(2)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples[0],
        max: samples[n - 1],
        elements,
    }
}

/// Accumulates a bench target's [`Stats`] plus free-form extras (e.g.
/// packed-vs-reference speedups) and writes them as `BENCH_<target>.json`
/// in the working directory (`BSQ_BENCH_OUT` overrides the path). The file
/// is the machine-readable perf record EXPERIMENTS.md §Perf tracks per PR.
#[derive(Debug, Default)]
pub struct JsonReport {
    target: String,
    stats: Vec<Stats>,
    extra: Vec<(String, Json)>,
}

impl JsonReport {
    pub fn new(target: &str) -> JsonReport {
        JsonReport { target: target.to_string(), stats: Vec::new(), extra: Vec::new() }
    }

    pub fn push(&mut self, s: &Stats) {
        self.stats.push(s.clone());
    }

    pub fn extra(&mut self, key: &str, value: Json) {
        self.extra.push((key.to_string(), value));
    }

    /// Default output path: `BENCH_<target>.json` in the working directory,
    /// or wherever `BSQ_BENCH_OUT` points (read once, at write time, from
    /// the bench binary's own environment).
    pub fn out_path(&self) -> PathBuf {
        std::env::var_os("BSQ_BENCH_OUT")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(format!("BENCH_{}.json", self.target)))
    }

    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(self.out_path())
    }

    pub fn write_to(&self, path: PathBuf) -> std::io::Result<PathBuf> {
        let mut kv = vec![
            ("target".to_string(), Json::str(self.target.clone())),
            ("results".to_string(), Json::Arr(self.stats.iter().map(Stats::to_json).collect())),
        ];
        kv.extend(self.extra.iter().cloned());
        std::fs::write(&path, Json::Obj(kv).to_string_pretty() + "\n")?;
        Ok(path)
    }
}

/// Nearest-rank percentile of an ascending-sorted sample slice (`q` in
/// [0, 1]; q = 0.5 is the median, q = 0.99 the p99). `None` on empty input.
/// Shared by the bench harness and the serving stats (`serve::stats`).
pub fn percentile<T: Copy>(sorted: &[T], q: f64) -> Option<T> {
    if sorted.is_empty() {
        return None;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    Some(sorted[rank.max(1) - 1])
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_iterations() {
        let b = Bench { warmup: 1, iters: 5, max_time: Duration::from_secs(60) };
        let mut count = 0usize;
        let s = b.run("noop", || count += 1);
        assert_eq!(s.iters, 5);
        assert_eq!(count, 6); // warmup + timed
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn throughput_computed() {
        let b = Bench::quick();
        let s = b.run_elems("spin", 1000, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.throughput_per_sec().unwrap() > 0.0);
        assert!(s.report().contains("elem/s"));
    }

    #[test]
    fn run_prepared_excludes_setup() {
        let b = Bench { warmup: 1, iters: 4, max_time: Duration::from_secs(60) };
        let mut setups = 0usize;
        let mut runs = 0usize;
        let s = b.run_prepared(
            "consume",
            10,
            || {
                setups += 1;
                vec![1u8; 8]
            },
            |v| {
                runs += 1;
                v.clear(); // routine may consume its input
            },
        );
        assert_eq!(s.iters, 4);
        assert_eq!(setups, 5); // warmup + timed, one fresh input each
        assert_eq!(runs, 5);
    }

    #[test]
    fn json_report_roundtrips() {
        let b = Bench::quick();
        let s = b.run_elems("spin2", 100, || {
            black_box((0..100).sum::<u64>());
        });
        let mut rep = JsonReport::new("selftest");
        rep.push(&s);
        rep.extra("speedups", Json::obj(vec![("spin2", Json::num(1.0))]));
        let dir = std::env::temp_dir().join(format!("bsq_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // explicit path: no process-global env mutation under parallel tests
        let path = rep.write_to(dir.join("BENCH_selftest.json")).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.req("target").unwrap().as_str().unwrap(), "selftest");
        let results = parsed.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].req("name").unwrap().as_str().unwrap(), "spin2");
        assert!(results[0].req("ns_per_elem").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.5), Some(50));
        assert_eq!(percentile(&v, 0.99), Some(99));
        assert_eq!(percentile(&v, 1.0), Some(100));
        assert_eq!(percentile(&v, 0.0), Some(1));
        assert_eq!(percentile(&[7u64], 0.99), Some(7));
        assert_eq!(percentile::<u64>(&[], 0.5), None);
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains("s"));
    }
}
