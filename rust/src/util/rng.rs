//! Deterministic PRNG substrate (PCG-XSH-RR 64/32) — no external deps.
//!
//! Every stochastic component of the coordinator (synthetic corpus,
//! augmentation, initialization, shuffling, HAWQ probe vectors) draws from a
//! seeded `Pcg32` so experiment runs are exactly reproducible; Figure-4-style
//! multi-seed sweeps just vary the seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014): 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a stream id; different `(seed, stream)` pairs are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * n as u64;
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            v.swap(i, j);
        }
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
