//! Offline-friendly substrates: JSON, PRNG, CLI parsing, benching, logging.
//!
//! The build environment has no crates.io access beyond the `xla` crate's
//! dependency closure, so serde/clap/criterion equivalents are implemented
//! here from scratch (DESIGN.md §5).

pub mod bench;
pub mod benchdiff;
pub mod cli;
pub mod crc32;
pub mod json;
pub mod logging;
pub mod rng;

pub use json::Json;
pub use rng::Pcg32;
