//! Minimal JSON parser/serializer (no external deps — the build is offline).
//!
//! Covers exactly what this crate needs: the AOT `manifest.json` files
//! written by `python/compile/aot.py` and the machine-readable experiment
//! records under `results/`. Supports the full JSON value grammar with
//! f64 numbers; rejects trailing garbage; serializes with stable key order
//! (insertion order preserved via `Vec<(String, Json)>`).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Convenience: `["a", "b"]`-style string array.
    pub fn as_str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Numeric value. NaN/±Inf have no JSON spelling — they collapse to
    /// `Null` here (and again at serialization time for `Json::Num` built
    /// directly), so a percentile over an empty stats window can never
    /// emit a `BENCH_*.json` this module's own parser rejects.
    pub fn num(n: impl Into<f64>) -> Json {
        let n = n.into();
        if n.is_finite() {
            Json::Num(n)
        } else {
            Json::Null
        }
    }

    pub fn arr_num<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    pub fn arr_str<I: IntoIterator<Item = String>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Str).collect())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // `NaN`/`inf` are not JSON tokens; emitting them would
                    // silently corrupt the record for every later reader.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, indent, false); // arrays stay compact
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kv.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document; trailing whitespace allowed, garbage not.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: find the char boundary and copy it.
                    let start = self.pos - 1;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|e| anyhow!("invalid utf-8 in string: {e}"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        let n: f64 = text.parse().map_err(|_| anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("bsq")),
            ("dims", Json::arr_num([1.0, 2.0, 3.0])),
            ("nested", Json::obj(vec![("x", Json::Bool(false))])),
        ]);
        for s in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let v = Json::Str("quote\" slash\\ tab\t π".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(32.0).to_string_compact(), "32");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    /// Regression: a non-finite number must never serialize to a token the
    /// parser rejects (previously `NaN`/`inf` leaked straight into
    /// `BENCH_*.json`, e.g. a percentile over an empty stats window).
    #[test]
    fn non_finite_numbers_round_trip_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::num(v), Json::Null, "constructor clamps");
            // even a directly built Num serializes parseably
            let direct = Json::Obj(vec![("p99".into(), Json::Num(v))]);
            for text in [direct.to_string_compact(), direct.to_string_pretty()] {
                let back = parse(&text).expect("serialized JSON must re-parse");
                assert_eq!(back.req("p99").unwrap(), &Json::Null);
            }
        }
        // finite values are untouched
        assert_eq!(Json::num(1.25), Json::Num(1.25));
        let rec = Json::obj(vec![("a", Json::num(f64::NAN)), ("b", Json::num(3.0))]);
        assert_eq!(parse(&rec.to_string_compact()).unwrap(), rec);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tinynet/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert_eq!(v.req("model").unwrap().as_str().unwrap(), "tinynet");
            assert!(v.req("artifacts").unwrap().as_obj().unwrap().len() >= 6);
        }
    }
}
