//! Bench-record regression diffing — the engine behind `bsq-repro
//! bench-diff <baseline> <current> --tolerance-pct N` and CI's bench-gate
//! job (EXPERIMENTS.md §Shard-scaling runbook).
//!
//! Both inputs are `BENCH_*.json` records written by
//! [`JsonReport`](crate::util::bench::JsonReport): a `results` array of
//! per-benchmark stats. Metrics are
//! matched by `name` and compared on `mean_ns`; a metric is a regression
//! when the current mean exceeds the baseline by more than the tolerance.
//! Improvements and newly added metrics never fail the gate; a metric that
//! *disappeared* from the current record does — silently dropping a bench
//! is how perf regressions hide.
//!
//! Beyond relative drift, a baseline may carry **absolute floors**: a
//! `speedup_floors` object (`{"dense_conv16x16_m1024": 4.0, ...}`) makes
//! the gate require the current record's matching `speedups` entry to
//! meet each floor. This is how the SIMD acceptance bar (≥4× dense, ≥2×
//! bit-plane at 0 trims vs the scalar backend, DESIGN.md §13) stays
//! machine-checked on every run, not just the one that landed it: a
//! future change that quietly de-vectorizes a kernel still beats the
//! noise tolerance (both columns slow down together) but cannot beat a
//! floor. A floor whose metric is missing from the current record fails,
//! same rationale as missing means.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// One metric's baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct MetricDiff {
    pub name: String,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// Signed change in percent (positive = slower than baseline).
    pub delta_pct: f64,
    pub regressed: bool,
}

/// One absolute speedup-floor check (baseline `speedup_floors` entry vs
/// the current record's `speedups` value).
#[derive(Debug, Clone)]
pub struct FloorCheck {
    pub name: String,
    pub floor: f64,
    /// Current value; `None` when the metric vanished from the record.
    pub actual: Option<f64>,
    pub passed: bool,
}

/// Full comparison of two bench records.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub target: String,
    pub tolerance_pct: f64,
    pub rows: Vec<MetricDiff>,
    /// Metrics present in the baseline but missing from the current record.
    pub missing: Vec<String>,
    /// Metrics new in the current record (informational only).
    pub added: Vec<String>,
    /// Absolute floors declared by the baseline (empty when none).
    pub floors: Vec<FloorCheck>,
}

impl DiffReport {
    /// Does this comparison fail the gate?
    pub fn failed(&self) -> bool {
        !self.missing.is_empty()
            || self.rows.iter().any(|r| r.regressed)
            || self.floors.iter().any(|f| !f.passed)
    }

    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }

    /// Human-readable per-metric table.
    pub fn table(&self) -> String {
        let mut out = format!(
            "bench-diff [{}], tolerance +{:.0}%\n{:<44} {:>14} {:>14} {:>9}  verdict\n",
            self.target, self.tolerance_pct, "metric", "baseline", "current", "delta"
        );
        for r in &self.rows {
            let verdict = if r.regressed {
                "REGRESSED"
            } else if r.delta_pct < 0.0 {
                "improved"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "{:<44} {:>12.0}ns {:>12.0}ns {:>+8.1}%  {}\n",
                r.name, r.base_ns, r.cur_ns, r.delta_pct, verdict
            ));
        }
        for m in &self.missing {
            out.push_str(&format!("{m:<44} {:>14} {:>14} {:>9}  MISSING\n", "-", "-", "-"));
        }
        for m in &self.added {
            out.push_str(&format!("{m:<44} {:>14} {:>14} {:>9}  new\n", "-", "-", "-"));
        }
        for f in &self.floors {
            let actual =
                f.actual.map(|v| format!("{v:.2}x")).unwrap_or_else(|| "missing".to_string());
            let verdict = if f.passed { "ok" } else { "BELOW FLOOR" };
            out.push_str(&format!(
                "{:<44} {:>13.2}x {:>14} {:>9}  {}\n",
                format!("floor:{}", f.name),
                f.floor,
                actual,
                "-",
                verdict
            ));
        }
        out
    }
}

fn metric_means(record: &Json) -> Result<BTreeMap<String, f64>> {
    let mut means = BTreeMap::new();
    for entry in record.req("results")?.as_arr()? {
        let name = entry.req("name")?.as_str()?.to_string();
        let mean = entry.req("mean_ns")?.as_f64()?;
        if mean <= 0.0 || !mean.is_finite() {
            bail!("metric {name:?} has a non-positive mean ({mean})");
        }
        means.insert(name, mean);
    }
    Ok(means)
}

/// Compare two parsed bench records at the given tolerance.
pub fn compare(baseline: &Json, current: &Json, tolerance_pct: f64) -> Result<DiffReport> {
    if tolerance_pct < 0.0 {
        bail!("tolerance must be non-negative, got {tolerance_pct}");
    }
    let target = baseline
        .get("target")
        .and_then(|t| t.as_str().ok())
        .unwrap_or("unknown")
        .to_string();
    let base = metric_means(baseline)?;
    let cur = metric_means(current)?;
    if base.is_empty() {
        bail!("baseline record carries no metrics — refusing to vacuously pass");
    }

    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for (name, &base_ns) in &base {
        match cur.get(name) {
            Some(&cur_ns) => {
                let delta_pct = (cur_ns - base_ns) / base_ns * 100.0;
                rows.push(MetricDiff {
                    name: name.clone(),
                    base_ns,
                    cur_ns,
                    delta_pct,
                    regressed: delta_pct > tolerance_pct,
                });
            }
            None => missing.push(name.clone()),
        }
    }
    let added = cur.keys().filter(|k| !base.contains_key(*k)).cloned().collect();
    let floors = floor_checks(baseline, current)?;
    Ok(DiffReport { target, tolerance_pct, rows, missing, added, floors })
}

/// Evaluate the baseline's `speedup_floors` (if any) against the current
/// record's `speedups` object. Floors are part of the *baseline* so they
/// arm with the seeded record and cannot be weakened by the run under test.
fn floor_checks(baseline: &Json, current: &Json) -> Result<Vec<FloorCheck>> {
    let Some(floors) = baseline.get("speedup_floors") else {
        return Ok(Vec::new());
    };
    let empty: &[(String, Json)] = &[];
    let speedups = match current.get("speedups") {
        Some(s) => s.as_obj().context("current record's `speedups` is not an object")?,
        None => empty,
    };
    let mut checks = Vec::new();
    for (name, floor) in floors.as_obj().context("`speedup_floors` is not an object")? {
        let floor = floor.as_f64().with_context(|| format!("floor {name:?} is not a number"))?;
        if floor <= 0.0 || !floor.is_finite() {
            bail!("floor {name:?} must be a positive finite speedup, got {floor}");
        }
        let actual = speedups
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_f64().with_context(|| format!("speedup {name:?} is not a number")))
            .transpose()?;
        let passed = actual.is_some_and(|v| v >= floor);
        checks.push(FloorCheck { name: name.clone(), floor, actual, passed });
    }
    Ok(checks)
}

/// Compare two bench-record files on disk.
pub fn compare_files(baseline: &Path, current: &Path, tolerance_pct: f64) -> Result<DiffReport> {
    let read = |p: &Path| -> Result<Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading bench record {}", p.display()))?;
        json::parse(&text).with_context(|| format!("parsing bench record {}", p.display()))
    };
    compare(&read(baseline)?, &read(current)?, tolerance_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(pairs: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("target", Json::str("t")),
            (
                "results",
                Json::Arr(
                    pairs
                        .iter()
                        .map(|&(n, m)| {
                            Json::obj(vec![("name", Json::str(n)), ("mean_ns", Json::num(m))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn within_tolerance_passes_and_reports_deltas() {
        let base = record(&[("a", 100.0), ("b", 200.0)]);
        let cur = record(&[("a", 110.0), ("b", 150.0)]);
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert!(!rep.failed(), "{}", rep.table());
        assert_eq!(rep.rows.len(), 2);
        assert!((rep.rows[0].delta_pct - 10.0).abs() < 1e-9);
        assert!(rep.rows[1].delta_pct < 0.0); // improvement
        assert!(rep.table().contains("improved"));
    }

    #[test]
    fn regression_past_tolerance_fails() {
        let base = record(&[("a", 100.0)]);
        let cur = record(&[("a", 126.0)]);
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert!(rep.failed());
        assert_eq!(rep.regressions(), 1);
        assert!(rep.table().contains("REGRESSED"));
        // exactly at tolerance still passes (strict inequality)
        let rep = compare(&base, &record(&[("a", 125.0)]), 25.0).unwrap();
        assert!(!rep.failed());
    }

    #[test]
    fn missing_metric_fails_and_added_metric_is_informational() {
        let base = record(&[("a", 100.0), ("gone", 50.0)]);
        let cur = record(&[("a", 100.0), ("fresh", 70.0)]);
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert!(rep.failed());
        assert_eq!(rep.missing, vec!["gone".to_string()]);
        assert_eq!(rep.added, vec!["fresh".to_string()]);
        assert!(rep.table().contains("MISSING"));
    }

    fn with_extra(rec: Json, key: &str, val: Json) -> Json {
        match rec {
            Json::Obj(mut kv) => {
                kv.push((key.to_string(), val));
                Json::Obj(kv)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn speedup_floors_gate_on_current_speedups() {
        let base = with_extra(
            record(&[("a", 100.0)]),
            "speedup_floors",
            Json::obj(vec![("dense", Json::num(4.0)), ("bitplane", Json::num(2.0))]),
        );
        // both floors met
        let cur = with_extra(
            record(&[("a", 100.0)]),
            "speedups",
            Json::obj(vec![("dense", Json::num(5.1)), ("bitplane", Json::num(2.0))]),
        );
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert!(!rep.failed(), "{}", rep.table());
        assert_eq!(rep.floors.len(), 2);

        // one floor violated
        let cur = with_extra(
            record(&[("a", 100.0)]),
            "speedups",
            Json::obj(vec![("dense", Json::num(3.9)), ("bitplane", Json::num(2.5))]),
        );
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert!(rep.failed());
        assert!(rep.table().contains("BELOW FLOOR"), "{}", rep.table());

        // floor metric missing from the current record fails too
        let rep = compare(&base, &record(&[("a", 100.0)]), 25.0).unwrap();
        assert!(rep.failed());
        assert!(rep.floors.iter().all(|f| f.actual.is_none() && !f.passed));

        // a baseline without floors never checks them
        let rep = compare(&record(&[("a", 100.0)]), &cur, 25.0).unwrap();
        assert!(rep.floors.is_empty() && !rep.failed());
    }

    #[test]
    fn malformed_floors_are_rejected() {
        let base = with_extra(
            record(&[("a", 100.0)]),
            "speedup_floors",
            Json::obj(vec![("dense", Json::num(0.0))]),
        );
        assert!(compare(&base, &record(&[("a", 100.0)]), 25.0).is_err());
    }

    #[test]
    fn degenerate_records_are_rejected() {
        let empty = record(&[]);
        assert!(compare(&empty, &empty, 25.0).is_err());
        let bad = record(&[("a", 0.0)]);
        assert!(compare(&bad, &bad, 25.0).is_err());
        let base = record(&[("a", 1.0)]);
        assert!(compare(&base, &base, -1.0).is_err());
    }
}
