//! Shared training-loop machinery: sessions, epoch runners, evaluation.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::data::{BatchSource, Corpus, CorpusSpec, Loader};
use crate::model::ModelState;
use crate::runtime::{Engine, Executable, Manifest, RunInputs};

/// A model + corpus bound to an engine: the context every phase runs in.
pub struct Session<'e> {
    pub engine: &'e Engine,
    pub man: Manifest,
    pub corpus: Corpus,
    pub seed: u64,
}

impl<'e> Session<'e> {
    /// Open a session: resolve the manifest through the engine (disk
    /// artifacts on PJRT, synthesized on the native backend) and synthesize
    /// the matching corpus.
    pub fn open(
        engine: &'e Engine,
        model: &str,
        train_size: usize,
        test_size: usize,
        seed: u64,
    ) -> Result<Session<'e>> {
        let man = engine.manifest(model)?;
        let spec = corpus_for_model(model, seed).with_sizes(train_size, test_size);
        if spec.hw.0 != man.input_hw.0 || spec.channels != man.in_ch {
            bail!("corpus {:?} does not match model geometry", spec.name);
        }
        if spec.classes != man.num_classes {
            bail!("corpus classes {} ≠ model classes {}", spec.classes, man.num_classes);
        }
        Ok(Session { engine, man, corpus: Corpus::generate(spec), seed })
    }

    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        self.engine.load(self.man.artifact(name)?)
    }

    /// Resolved data-parallel shard count every train step of this session
    /// fans across (native backend; 1 on PJRT). Purely a throughput knob:
    /// training results are bit-identical at any count.
    pub fn shards(&self) -> usize {
        self.engine.shards()
    }

    /// Per-site activation level vector (2^a − 1): the paper pins the first
    /// and last sites to `first_last` bits (8 on CIFAR/ResNet; pass the same
    /// value as `bits` for Inception's uniform 6-bit setting). `bits == 0`
    /// disables activation quantization (float activations, clip only).
    pub fn act_levels(&self, bits: usize, first_last: usize) -> Vec<f32> {
        let n = self.man.act_sites.len();
        let lv = |b: usize| if b == 0 { 0.0 } else { ((1u64 << b) - 1) as f32 };
        (0..n)
            .map(|i| if i == 0 || i == n - 1 { lv(first_last) } else { lv(bits) })
            .collect()
    }

    /// Average (loss, acc) over up to `max_batches` of the test split.
    pub fn evaluate(
        &self,
        exe: &Executable,
        state: &mut ModelState,
        inputs: &RunInputs,
        max_batches: usize,
    ) -> Result<(f32, f32)> {
        let mut loader = Loader::eval(&self.corpus.test, self.man.batch);
        let n = loader.batches_per_epoch().min(max_batches.max(1));
        let (mut loss, mut acc) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let b = loader.next_batch();
            let out = exe.run(state, Some(&b), inputs)?;
            loss += out.metric("loss")? as f64;
            acc += out.metric("acc")? as f64;
        }
        Ok(((loss / n as f64) as f32, (acc / n as f64) as f32))
    }
}

/// Map a model to its corpus profile (DESIGN.md §4 substitutions).
pub fn corpus_for_model(model: &str, seed: u64) -> CorpusSpec {
    let base = match model {
        "tinynet" => CorpusSpec::tiny(),
        "resnet20" => CorpusSpec::cifar(),
        "resnet50_sim" | "inception_sim" => CorpusSpec::imagenet_sim(),
        _ => CorpusSpec::cifar(),
    };
    // vary only the corpus *rendering* seed stream with the session seed so
    // multi-seed repeats (Fig. 4) see different draws of the same task
    let base_seed = base.seed;
    base.with_seed(base_seed ^ (seed.wrapping_mul(0x9e3779b97f4a7c15)))
}

/// Averaged metrics of one training epoch.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochMetrics {
    pub loss: f32,
    pub ce: f32,
    pub acc: f32,
    pub bgl: f32,
}

/// Run one epoch of a train artifact over a batch source (the synchronous
/// [`Loader`] or the threaded [`crate::data::Prefetcher`] — both deliver
/// bit-identical streams, so this loop is oblivious to which it got).
///
/// On the native backend each `exe.run` is a data-parallel sharded step
/// (`runtime::native::shard`): the minibatch fans across the engine's shard
/// count and the gradients come back through the deterministic fixed-order
/// tree reduce, so the epoch's numbers do not depend on the shard count.
pub fn train_epoch(
    exe: &Executable,
    loader: &mut impl BatchSource,
    state: &mut ModelState,
    inputs: &RunInputs,
) -> Result<EpochMetrics> {
    loader.next_epoch();
    let steps = loader.batches_per_epoch();
    let mut m = EpochMetrics::default();
    for _ in 0..steps {
        let b = loader.next_batch();
        let out = exe.run(state, Some(&b), inputs)?;
        m.loss += out.metric("loss")?;
        m.ce += out.metric("ce")?;
        m.acc += out.metric("acc")?;
        m.bgl += out.metrics.get("bgl").copied().unwrap_or(0.0);
    }
    let n = steps.max(1) as f32;
    m.loss /= n;
    m.ce /= n;
    m.acc /= n;
    m.bgl /= n;
    if !m.loss.is_finite() {
        bail!("training diverged (loss = {})", m.loss);
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_mapping() {
        assert_eq!(corpus_for_model("resnet20", 0).classes, 10);
        assert_eq!(corpus_for_model("resnet50_sim", 0).classes, 100);
        assert_eq!(corpus_for_model("tinynet", 0).hw, (16, 16));
        // seed perturbs rendering
        assert_ne!(corpus_for_model("resnet20", 1).seed, corpus_for_model("resnet20", 2).seed);
    }
}
