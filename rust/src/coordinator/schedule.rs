//! Learning-rate schedules (paper App. A: step decay at fixed epochs).

/// Piecewise-constant LR: `base` until the first milestone, then ×`gamma`
/// at each milestone — the paper's "decay by 0.1 at epoch 150, 250, 325"
/// style, expressed in *fractions* of the phase length so abbreviated
/// schedules keep the same shape.
#[derive(Debug, Clone)]
pub struct StepDecay {
    pub base: f32,
    pub gamma: f32,
    /// Milestones as fractions of total epochs (e.g. [0.43, 0.71, 0.93]).
    pub milestones: Vec<f32>,
}

impl StepDecay {
    /// The paper's pretrain/from-scratch schedule: 0.1 → ×0.1 at
    /// 150/350, 250/350, 325/350.
    pub fn pretrain() -> StepDecay {
        StepDecay {
            base: 0.1,
            gamma: 0.1,
            milestones: vec![150.0 / 350.0, 250.0 / 350.0, 325.0 / 350.0],
        }
    }

    /// The paper's BSQ schedule: 0.1 for the first 250/350, then 0.01.
    pub fn bsq() -> StepDecay {
        StepDecay { base: 0.1, gamma: 0.1, milestones: vec![250.0 / 350.0] }
    }

    /// The paper's finetune schedule: 0.01 → ×0.1 at 150/300, 250/300.
    pub fn finetune() -> StepDecay {
        StepDecay { base: 0.01, gamma: 0.1, milestones: vec![0.5, 250.0 / 300.0] }
    }

    /// LR for `epoch` (0-based) of a phase lasting `total` epochs.
    pub fn lr(&self, epoch: usize, total: usize) -> f32 {
        let frac = if total == 0 { 0.0 } else { epoch as f32 / total as f32 };
        let decays = self.milestones.iter().filter(|&&m| frac >= m).count();
        self.base * self.gamma.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pretrain_shape_matches_paper_at_350() {
        let s = StepDecay::pretrain();
        assert_eq!(s.lr(0, 350), 0.1);
        assert_eq!(s.lr(149, 350), 0.1);
        assert!((s.lr(150, 350) - 0.01).abs() < 1e-9);
        assert!((s.lr(250, 350) - 0.001).abs() < 1e-9);
        assert!((s.lr(325, 350) - 0.0001).abs() < 1e-9);
    }

    #[test]
    fn scales_to_abbreviated_runs() {
        let s = StepDecay::bsq();
        // 10-epoch run: switch at ~epoch 7 (250/350 ≈ 0.714)
        assert_eq!(s.lr(6, 10), 0.1);
        assert!((s.lr(8, 10) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn zero_total_is_safe() {
        assert_eq!(StepDecay::finetune().lr(0, 0), 0.01);
    }
}
