//! L3 coordinator: the training pipelines that orchestrate AOT artifacts.
//!
//! `bsq` implements the paper's full §3.3 pipeline (pretrain → bit
//! conversion → regularized training with periodic re-quantization →
//! finetune); `trainer` holds the shared session/epoch machinery;
//! `schedule` the paper's LR shapes; `metrics` telemetry + result files.

pub mod bsq;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use bsq::{run_bsq, ActMode, BsqConfig, BsqOutcome};
pub use metrics::{write_result, EpochRecord, History};
pub use schedule::StepDecay;
pub use trainer::{corpus_for_model, train_epoch, Session};
