//! L3 coordinator: the training pipelines that orchestrate AOT artifacts.
//!
//! `bsq` implements the paper's full §3.3 pipeline (pretrain → bit
//! conversion → regularized training with periodic re-quantization →
//! finetune); `trainer` holds the shared session/epoch machinery;
//! `schedule` the paper's LR shapes; `metrics` telemetry + result files;
//! `snapshot` epoch-granular crash-safe snapshots with bit-identical
//! resume (DESIGN.md §12); `requant` the overlapped double-buffered
//! re-quantization protocol (DESIGN.md §16).

pub mod bsq;
pub mod metrics;
pub mod requant;
pub mod schedule;
pub mod snapshot;
pub mod trainer;

pub use bsq::{run_bsq, ActMode, BsqConfig, BsqOutcome};
pub use metrics::{write_result, EpochRecord, History};
pub use requant::{requantize_overlapped, RequantBuffers};
pub use schedule::StepDecay;
pub use snapshot::{ResumePoint, SnapshotCfg, Snapshotter, StorePublisher};
pub use trainer::{corpus_for_model, train_epoch, Session};
