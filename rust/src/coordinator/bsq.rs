//! The BSQ pipeline (paper §3.3): pretrain → bit conversion → regularized
//! BSQ training with periodic re-quantization → final scheme → finetune.
//!
//! This is the paper's coordination contribution as a state machine:
//!
//! ```text
//!  fp pretrain ──► to_bitplanes(init bits) ──► BSQ epochs ──► final requant
//!   (cached)                                    │    ▲              │
//!                                 every `requant_interval` epochs   ▼
//!                                requantize + adjust + regw (Eq.5)  finetune
//!                                                                (DoReFa, frozen
//!                                                                 scheme) ──► acc
//! ```
//!
//! Every device step is one PJRT execute of an AOT artifact; everything
//! between steps (precision adjustment, reweighing, scheme tracking,
//! schedules, checkpoints) runs here.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::{EpochRecord, History};
use crate::coordinator::requant::{requantize_overlapped, RequantBuffers};
use crate::coordinator::schedule::StepDecay;
use crate::coordinator::snapshot::{self, ResumePoint, SnapshotCfg, Snapshotter};
use crate::coordinator::trainer::{train_epoch, Session};
use crate::data::{train_source, BatchSource};
use crate::model::{checkpoint, momentum_slots, ModelState};
use crate::quant::{reg_weights, requantize, LayerPrec, QuantScheme, Reweigh};
use crate::runtime::{Engine, RunInputs};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActMode {
    Relu6,
    Pact,
}

impl ActMode {
    pub fn suffix(self) -> &'static str {
        match self {
            ActMode::Relu6 => "relu6",
            ActMode::Pact => "pact",
        }
    }

    /// Paper §3.3: ReLU6 at ≥4-bit activations, PACT below.
    pub fn for_bits(bits: usize) -> ActMode {
        if bits == 0 || bits >= 4 {
            ActMode::Relu6
        } else {
            ActMode::Pact
        }
    }
}

#[derive(Debug, Clone)]
pub struct BsqConfig {
    pub model: String,
    /// Regularization strength α — the paper's single trade-off knob.
    pub alpha: f32,
    /// Activation precision for middle layers (0 = float activations).
    pub act_bits: usize,
    /// Activation precision of the first/last sites (paper: 8).
    pub act_first_last: usize,
    /// Initial weight precision before BSQ training (paper: 8 on CIFAR).
    pub init_bits: usize,
    /// Leading layers initialized at 8-bit regardless (paper's ImageNet
    /// setting: ResNet-50 first conv, Inception first 5 convs).
    pub init_8bit_prefix: usize,
    pub pretrain_epochs: usize,
    pub bsq_epochs: usize,
    pub finetune_epochs: usize,
    /// Re-quantize + adjust precision every this many BSQ epochs (0 = only
    /// at the end — the Fig. 4 "No requant" ablation arm).
    pub requant_interval: usize,
    pub reweigh: Reweigh,
    pub weight_decay: f32,
    pub seed: u64,
    pub train_size: usize,
    pub test_size: usize,
    /// Cap on eval batches per epoch-end probe (full test at phase ends).
    pub eval_batches: usize,
    /// Reuse a cached pretrained checkpoint when available.
    pub cache_pretrained: bool,
    /// Reference BSQ step count for α rescaling. The paper runs 350 epochs
    /// × ~390 steps (batch 128, 50k images); the group-Lasso shrinkage a
    /// plane accumulates is ≈ α·regw·lr per step, so the total shrinkage
    /// budget of an abbreviated schedule matches the paper's when α is
    /// multiplied by `alpha_ref_steps / actual_steps` (linear — calibrated
    /// on resnet20; EXPERIMENTS.md §Scaling). The paper's α labels then
    /// stay on the same trade-off axis for the paper's model sizes. Note
    /// the regime is model-size dependent (time-to-zero ∝ plane norm ∝
    /// √params): the 4k-param tinynet needs α ~50× smaller, which its
    /// tests/examples use explicitly. 0 disables rescaling.
    pub alpha_ref_steps: f64,
    /// End-of-epoch crash-safe snapshots (None = no snapshotting).
    pub snapshot: Option<SnapshotCfg>,
    /// Resume from the newest usable snapshot generation instead of
    /// starting fresh. Requires `snapshot`; errors if none is usable.
    pub resume: bool,
    /// Force pause-the-world re-quantization instead of overlapping the
    /// rebuild with the epoch-end eval window (CLI `--sync-requant`, env
    /// `BSQ_SYNC_REQUANT`). Purely a scheduling knob: both modes produce
    /// bitwise-identical trajectories (DESIGN.md §16), so it is excluded
    /// from the snapshot config fingerprint — a run killed in one mode
    /// resumes cleanly in the other.
    pub sync_requant: bool,
    /// Batches the async prefetcher assembles ahead of training (CLI
    /// `--prefetch-depth`, env `BSQ_PREFETCH_DEPTH`; 0 = synchronous
    /// in-thread assembly). Trajectory-invariant like `sync_requant`, and
    /// likewise outside the config fingerprint.
    pub prefetch_depth: usize,
}

impl BsqConfig {
    /// Testbed-scaled defaults per model (abbreviated schedules; the paper's
    /// full schedules are preserved in *shape* via StepDecay fractions —
    /// see EXPERIMENTS.md for the mapping).
    pub fn for_model(model: &str) -> BsqConfig {
        let (pre, bsq, ft, rq, train, test) = match model {
            "tinynet" => (3, 6, 3, 2, 512, 256),
            "resnet20" => (6, 8, 4, 2, 1024, 512),
            "resnet50_sim" => (2, 3, 2, 1, 256, 128),
            "inception_sim" => (4, 6, 3, 2, 1024, 512),
            _ => (3, 6, 3, 2, 512, 256),
        };
        BsqConfig {
            model: model.to_string(),
            alpha: 5e-3,
            act_bits: 4,
            act_first_last: 8,
            init_bits: if model.ends_with("_sim") { 6 } else { 8 },
            init_8bit_prefix: match model {
                "resnet50_sim" => 1,
                "inception_sim" => 3, // the twin's stem (paper: first 5 convs)
                _ => 0,
            },
            pretrain_epochs: pre,
            bsq_epochs: bsq,
            finetune_epochs: ft,
            requant_interval: rq,
            reweigh: Reweigh::MemoryAware,
            weight_decay: 1e-4,
            seed: 0,
            train_size: train,
            test_size: test,
            eval_batches: 8,
            cache_pretrained: true,
            alpha_ref_steps: 136_500.0, // 350 epochs × 390 steps (paper App. A)
            snapshot: None,
            resume: false,
            sync_requant: env_truthy("BSQ_SYNC_REQUANT"),
            prefetch_depth: std::env::var("BSQ_PREFETCH_DEPTH")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(2),
        }
    }

    pub fn act_mode(&self) -> ActMode {
        ActMode::for_bits(self.act_bits)
    }

    fn init_bits_vec(&self, layers: usize) -> Vec<usize> {
        (0..layers)
            .map(|i| if i < self.init_8bit_prefix { 8 } else { self.init_bits })
            .collect()
    }
}

/// `1`, `true`, `yes`… arm the knob; unset, empty, or `0` leave it off.
fn env_truthy(name: &str) -> bool {
    std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

#[derive(Debug, Clone)]
pub struct BsqOutcome {
    pub scheme: QuantScheme,
    pub acc_before_ft: f32,
    pub acc_after_ft: f32,
    pub bits_per_param: f64,
    pub compression: f64,
    pub history: History,
}

impl BsqOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits_per_param", Json::num(self.bits_per_param)),
            ("compression", Json::num(self.compression)),
            ("acc_before_ft", Json::num(self.acc_before_ft as f64)),
            ("acc_after_ft", Json::num(self.acc_after_ft as f64)),
            (
                "scheme",
                Json::Arr(
                    self.scheme
                        .layers
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("name", Json::str(l.name.clone())),
                                ("params", Json::num(l.params as f64)),
                                ("bits", Json::num(l.bits as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("history", self.history.to_json()),
        ])
    }
}

pub fn scheme_from_state(session: &Session, state: &ModelState) -> Result<QuantScheme> {
    let bits = state.bits_by_layer(&session.man)?;
    Ok(QuantScheme::new(
        session
            .man
            .qlayers
            .iter()
            .zip(bits)
            .map(|(q, b)| LayerPrec { name: q.name.clone(), params: q.params, bits: b })
            .collect(),
    ))
}

fn ckpt_dir() -> PathBuf {
    crate::runtime::artifacts_root().parent().map(|p| p.to_path_buf()).unwrap_or_default()
        .join("results/ckpt")
}

/// Phase 1 — float pretraining (cached by model/seed/epochs/corpus size).
///
/// `start` resumes from a snapshot: `(completed epochs, state)` — the
/// loader replays the completed epochs' RNG stream so the remaining ones
/// are bit-identical to an uninterrupted run.
pub fn pretrain(
    session: &Session,
    cfg: &BsqConfig,
    history: &mut History,
    mut snap: Option<&mut Snapshotter>,
    start: Option<(usize, ModelState)>,
) -> Result<ModelState> {
    let path = ckpt_dir().join(format!(
        "{}_s{}_e{}_n{}_fp.ckpt",
        cfg.model, cfg.seed, cfg.pretrain_epochs, cfg.train_size
    ));
    if start.is_none() && cfg.cache_pretrained && path.exists() {
        match checkpoint::load(&path) {
            Ok(state) => {
                log::info!("pretrain: reusing cached checkpoint {}", path.display());
                return Ok(state);
            }
            Err(e) => {
                log::warn!("pretrain cache {} unusable ({e:#}); retraining", path.display());
            }
        }
    }

    // Pretraining always runs the ReLU6 graph with float activations.
    let exe = session.artifact("fp_train_relu6")?;
    let eval = session.artifact("fp_eval_relu6")?;
    let (start_epoch, mut state) = match start {
        Some((done, state)) => (done, state),
        None => (0, ModelState::init_fp(&session.man, cfg.seed)),
    };
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs)?;

    // Pretrain with float activations (clip only): actlv = 0.
    let actlv = vec![0.0f32; session.man.act_sites.len()];
    let sched = StepDecay::pretrain();
    let mut loader = train_source(
        &session.corpus.train,
        session.man.batch,
        Default::default(),
        cfg.seed ^ 0xA,
        cfg.prefetch_depth,
    );
    for _ in 0..start_epoch {
        loader.skip_epoch();
    }
    for epoch in start_epoch..cfg.pretrain_epochs {
        let t0 = Instant::now();
        let lr = sched.lr(epoch, cfg.pretrain_epochs);
        let inputs = RunInputs::default()
            .hyper("lr", lr)
            .hyper("wd", cfg.weight_decay)
            .vec("actlv", actlv.clone());
        let m = train_epoch(&exe, &mut loader, &mut state, &inputs)?;
        let (_, eacc) = session.evaluate(
            &eval,
            &mut state,
            &RunInputs::default().vec("actlv", actlv.clone()),
            cfg.eval_batches,
        )?;
        history.push(EpochRecord {
            phase: "pretrain".into(),
            epoch,
            lr,
            loss: m.loss,
            ce: m.ce,
            acc: m.acc,
            bgl: 0.0,
            eval_acc: Some(eacc),
            bits_per_param: 32.0,
            compression: 1.0,
            seconds: t0.elapsed().as_secs_f64(),
        });
        if let Some(sn) = snap.as_deref_mut() {
            sn.take(cfg, "pretrain", epoch, &state, history, None, None)?;
        }
    }
    if cfg.cache_pretrained {
        let meta = Json::obj(vec![
            ("model", Json::str(cfg.model.clone())),
            ("phase", Json::str("pretrain")),
            ("epochs", Json::num(cfg.pretrain_epochs as f64)),
            ("seed", Json::num(cfg.seed as f64)),
        ]);
        checkpoint::save(&state, &path, &meta).context("caching pretrained model")?;
    }
    Ok(state)
}

/// Phases 2–4 — bit conversion, BSQ training with periodic re-quantization,
/// final adjustment. Returns the trained bit-state and the final scheme.
///
/// `start_epoch > 0` resumes a snapshot taken after that many BSQ epochs:
/// the state is already in bit representation (conversion and PACT setup
/// are skipped), and the scheme/regularizer weights are recomputed from it
/// — pure functions of the state, and snapshots land between requants, so
/// the recomputation reproduces the live values exactly.
pub fn bsq_train(
    session: &Session,
    cfg: &BsqConfig,
    mut state: ModelState,
    history: &mut History,
    mut snap: Option<&mut Snapshotter>,
    start_epoch: usize,
) -> Result<(ModelState, QuantScheme)> {
    let suffix = cfg.act_mode().suffix();
    let exe = session.artifact(&format!("bsq_train_{suffix}"))?;
    let eval = session.artifact(&format!("q_eval_{suffix}"))?;

    if start_epoch == 0 {
        state.to_bit_representation_per_layer(
            &session.man,
            &cfg.init_bits_vec(session.man.qlayers.len()),
        )?;
        if cfg.act_mode() == ActMode::Pact {
            state.add_pact(&session.man);
        }
    }
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs)?;

    let actlv = session.act_levels(cfg.act_bits, cfg.act_first_last);
    let mut scheme = scheme_from_state(session, &state)?;
    let mut regw = reg_weights(&scheme, cfg.reweigh);
    let sched = StepDecay::bsq();
    let mut loader = train_source(
        &session.corpus.train,
        session.man.batch,
        Default::default(),
        cfg.seed ^ 0xB,
        cfg.prefetch_depth,
    );
    for _ in 0..start_epoch {
        loader.skip_epoch();
    }
    let mut requant_bufs = RequantBuffers::new();

    // α rescaling for abbreviated schedules (see BsqConfig::alpha_ref_steps).
    let actual_steps = (cfg.bsq_epochs * loader.batches_per_epoch()).max(1) as f64;
    let alpha_eff = if cfg.alpha_ref_steps > 0.0 {
        (cfg.alpha as f64 * (cfg.alpha_ref_steps / actual_steps)) as f32
    } else {
        cfg.alpha
    };
    log::info!("bsq: α = {} (effective {alpha_eff:.4} over {actual_steps} steps)", cfg.alpha);

    for epoch in start_epoch..cfg.bsq_epochs {
        let t0 = Instant::now();
        let lr = sched.lr(epoch, cfg.bsq_epochs);
        let inputs = RunInputs::default()
            .hyper("lr", lr)
            .hyper("wd", cfg.weight_decay)
            .hyper("alpha", alpha_eff)
            .vec("regw", regw.clone())
            .vec("actlv", actlv.clone());
        let m = train_epoch(&exe, &mut loader, &mut state, &inputs)?;

        // Periodic re-quantization + precision adjustment (paper §3.3). The
        // rebuild is double-buffered and overlapped against the epoch-end
        // eval window (DESIGN.md §16): the eval reads the *pre-requant*
        // planes while workers rebuild into spares, and the rebuilt reps
        // install at the next batch boundary — identically in both modes,
        // so `--sync-requant` reproduces the overlapped trajectory bitwise.
        let is_last = epoch + 1 == cfg.bsq_epochs;
        let do_requant =
            (cfg.requant_interval > 0 && (epoch + 1) % cfg.requant_interval == 0) || is_last;
        let eval_inputs = RunInputs::default().vec("actlv", actlv.clone());
        let eacc = if do_requant {
            let ((_, eacc), _reports) = requantize_overlapped(
                session,
                &mut state,
                &mut requant_bufs,
                cfg.sync_requant,
                |st| session.evaluate(&eval, st, &eval_inputs, cfg.eval_batches),
            )?;
            scheme = scheme_from_state(session, &state)?;
            regw = reg_weights(&scheme, cfg.reweigh);
            log::info!(
                "requant @ epoch {epoch} ({}): {:.2} bits/param ({:.2}x) bits {:?}",
                if cfg.sync_requant { "sync" } else { "overlapped" },
                scheme.bits_per_param(),
                scheme.compression(),
                scheme.bits_vec()
            );
            eacc
        } else {
            session.evaluate(&eval, &mut state, &eval_inputs, cfg.eval_batches)?.1
        };
        history.push(EpochRecord {
            phase: "bsq".into(),
            epoch,
            lr,
            loss: m.loss,
            ce: m.ce,
            acc: m.acc,
            bgl: m.bgl,
            eval_acc: Some(eacc),
            bits_per_param: scheme.bits_per_param(),
            compression: scheme.compression(),
            seconds: t0.elapsed().as_secs_f64(),
        });
        if let Some(sn) = snap.as_deref_mut() {
            sn.take(cfg, "bsq", epoch, &state, history, Some(&scheme), None)?;
        }
    }
    Ok((state, scheme))
}

/// Re-quantize every layer; masks/scales/planes updated in place. The
/// one-shot pause-the-world variant — the training loop itself goes
/// through `requantize_overlapped` (DESIGN.md §16), which produces the
/// identical state; this stays for callers with no window to overlap
/// (experiment drivers, benches).
///
/// The layer planes are *moved* out of the state (no per-layer clone),
/// adjusted in parallel across `std::thread::scope` workers — layers are
/// independent and real models carry 20–50 of them, so the pause shrinks
/// toward the slowest single layer — then reinstalled.
///
/// Momentum buffers of the repacked planes are zeroed: LSB trims shift the
/// meaning of every plane slot, so carrying the old momentum would apply
/// stale updates to the wrong bits (the paper resumes training on the
/// "newly adjusted" W_p/W_n — a fresh optimizer state for those tensors).
pub fn requantize_all(session: &Session, state: &mut ModelState) -> Result<()> {
    let mut reps: Vec<(String, crate::quant::BitRep)> =
        Vec::with_capacity(session.man.qlayers.len());
    for q in &session.man.qlayers {
        match state.take_bitrep(&q.name) {
            Ok(rep) => reps.push((q.name.clone(), rep)),
            Err(e) => {
                // Put back what was already taken — a missing layer must not
                // leave the state with other layers' planes dropped.
                for (name, rep) in reps {
                    state.install_bitrep(&name, rep);
                }
                return Err(e);
            }
        }
    }

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(reps.len())
        .max(1);
    let chunk = reps.len().div_ceil(workers);
    if chunk > 0 {
        std::thread::scope(|s| {
            for part in reps.chunks_mut(chunk) {
                s.spawn(move || {
                    for (_, rep) in part.iter_mut() {
                        requantize(rep);
                    }
                });
            }
        });
    }

    for (name, rep) in reps {
        state.install_bitrep(&name, rep);
        state.zero_plane_momenta(&name);
    }
    Ok(())
}

/// Phase 5 — DoReFa finetuning at the frozen scheme (paper §3.3). Returns
/// the final full-test accuracy.
///
/// `start_epoch > 0` resumes a snapshot: the state already carries float
/// master weights and live momenta, so the bit→fp conversion and momentum
/// reset are skipped, and the running best is recovered from `history`.
/// `acc_before_ft` rides along in snapshot metadata because it is not
/// recoverable from the finetuned (fp) state.
pub fn finetune(
    session: &Session,
    cfg: &BsqConfig,
    state: &mut ModelState,
    scheme: &QuantScheme,
    history: &mut History,
    mut snap: Option<&mut Snapshotter>,
    start_epoch: usize,
    acc_before_ft: f32,
) -> Result<f32> {
    let suffix = cfg.act_mode().suffix();
    let exe = session.artifact(&format!("dorefa_train_{suffix}"))?;
    let eval = session.artifact(&format!("dorefa_eval_{suffix}"))?;

    if start_epoch == 0 {
        // Materialize float master weights from the bit representation.
        state.bit_to_fp_weights(&session.man)?;
        state.reset_momenta();
    }
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs)?;

    let actlv = session.act_levels(cfg.act_bits, cfg.act_first_last);
    let wlv = scheme.levels_vec();
    let sched = StepDecay::finetune();
    let mut loader = train_source(
        &session.corpus.train,
        session.man.batch,
        Default::default(),
        cfg.seed ^ 0xC,
        cfg.prefetch_depth,
    );
    for _ in 0..start_epoch {
        loader.skip_epoch();
    }
    let mut best =
        if start_epoch > 0 { history.best_eval("finetune").unwrap_or(0.0) } else { 0.0 };
    for epoch in start_epoch..cfg.finetune_epochs {
        let t0 = Instant::now();
        let lr = sched.lr(epoch, cfg.finetune_epochs);
        let inputs = RunInputs::default()
            .hyper("lr", lr)
            .hyper("wd", cfg.weight_decay)
            .vec("wlv", wlv.clone())
            .vec("actlv", actlv.clone());
        let m = train_epoch(&exe, &mut loader, state, &inputs)?;
        let (_, eacc) = session.evaluate(
            &eval,
            state,
            &RunInputs::default().vec("wlv", wlv.clone()).vec("actlv", actlv.clone()),
            cfg.eval_batches,
        )?;
        best = best.max(eacc);
        history.push(EpochRecord {
            phase: "finetune".into(),
            epoch,
            lr,
            loss: m.loss,
            ce: m.ce,
            acc: m.acc,
            bgl: 0.0,
            eval_acc: Some(eacc),
            bits_per_param: scheme.bits_per_param(),
            compression: scheme.compression(),
            seconds: t0.elapsed().as_secs_f64(),
        });
        if let Some(sn) = snap.as_deref_mut() {
            sn.take(cfg, "finetune", epoch, state, history, Some(scheme), Some(acc_before_ft))?;
        }
    }
    // Final full-test evaluation.
    let (_, final_acc) = session.evaluate(
        &eval,
        state,
        &RunInputs::default().vec("wlv", wlv).vec("actlv", actlv),
        usize::MAX,
    )?;
    Ok(final_acc.max(best))
}

/// Where to re-enter the pipeline, derived from a resume point. The
/// boundary cases collapse naturally: a completed pretrain enters BSQ at
/// epoch 0; a completed BSQ phase enters `bsq_train` with an empty epoch
/// range (conversion skipped, scheme recomputed) and falls through to the
/// pre-finetune evaluation; a completed finetune replays only the final
/// full-test evaluation.
enum Entry {
    Pretrain { start: Option<(usize, ModelState)> },
    Bsq { start: usize, state: ModelState },
    Finetune { start: usize, state: ModelState, scheme: QuantScheme, acc_before: f32 },
}

fn entry_for(rp: Option<ResumePoint>, cfg: &BsqConfig) -> Result<Entry> {
    let Some(rp) = rp else {
        return Ok(Entry::Pretrain { start: None });
    };
    let done = rp.epoch + 1;
    Ok(match rp.phase.as_str() {
        "pretrain" if done < cfg.pretrain_epochs => {
            Entry::Pretrain { start: Some((done, rp.state)) }
        }
        "pretrain" => Entry::Bsq { start: 0, state: rp.state },
        "bsq" => Entry::Bsq { start: done.min(cfg.bsq_epochs), state: rp.state },
        "finetune" => Entry::Finetune {
            start: done.min(cfg.finetune_epochs),
            state: rp.state,
            scheme: rp.scheme.ok_or_else(|| anyhow!("finetune snapshot missing scheme"))?,
            acc_before: rp
                .acc_before_ft
                .ok_or_else(|| anyhow!("finetune snapshot missing acc_before_ft"))?,
        },
        other => bail!("snapshot carries unknown phase {other:?}"),
    })
}

/// The full pipeline. This is what `bsq-repro bsq` and every experiment
/// harness call.
pub fn run_bsq(engine: &Engine, cfg: &BsqConfig) -> Result<BsqOutcome> {
    if cfg.act_mode() == ActMode::Pact && cfg.model != "resnet20" {
        bail!("PACT artifacts are lowered for resnet20 only (act_bits {} < 4)", cfg.act_bits);
    }
    let session = Session::open(engine, &cfg.model, cfg.train_size, cfg.test_size, cfg.seed)?;
    log::info!(
        "train steps fan across {} data-parallel shard(s); results are \
         shard-count invariant",
        session.shards()
    );
    let mut snap: Option<Snapshotter> =
        cfg.snapshot.as_ref().map(|s| Snapshotter::open_for(s, engine, cfg));
    let mut history = History::default();

    let rp: Option<ResumePoint> = if cfg.resume {
        let scfg = cfg
            .snapshot
            .as_ref()
            .ok_or_else(|| anyhow!("resume requested without a snapshot dir"))?;
        let rp = snapshot::latest(scfg, cfg)?.ok_or_else(|| {
            anyhow!("resume requested but no usable snapshot in {}", scfg.dir.display())
        })?;
        log::info!(
            "resuming from snapshot generation {} ({} epoch {} complete)",
            rp.gen,
            rp.phase,
            rp.epoch
        );
        history = rp.history.clone();
        Some(rp)
    } else {
        None
    };

    let (mut state, scheme, acc_before, ft_start) = match entry_for(rp, cfg)? {
        Entry::Finetune { start, state, scheme, acc_before } => (state, scheme, acc_before, start),
        entry => {
            let (state, bsq_start) = match entry {
                Entry::Pretrain { start } => {
                    (pretrain(&session, cfg, &mut history, snap.as_mut(), start)?, 0)
                }
                Entry::Bsq { start, state } => (state, start),
                Entry::Finetune { .. } => unreachable!("handled above"),
            };
            let (mut state, scheme) =
                bsq_train(&session, cfg, state, &mut history, snap.as_mut(), bsq_start)?;

            // Accuracy before finetuning, on the full test set.
            let suffix = cfg.act_mode().suffix();
            let eval = session.artifact(&format!("q_eval_{suffix}"))?;
            let actlv = session.act_levels(cfg.act_bits, cfg.act_first_last);
            let (_, acc_before) = session.evaluate(
                &eval,
                &mut state,
                &RunInputs::default().vec("actlv", actlv),
                usize::MAX,
            )?;
            (state, scheme, acc_before, 0)
        }
    };

    let acc_after = finetune(
        &session,
        cfg,
        &mut state,
        &scheme,
        &mut history,
        snap.as_mut(),
        ft_start,
        acc_before,
    )?;

    Ok(BsqOutcome {
        bits_per_param: scheme.bits_per_param(),
        compression: scheme.compression(),
        acc_before_ft: acc_before,
        acc_after_ft: acc_after,
        scheme,
        history,
    })
}
