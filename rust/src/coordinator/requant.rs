//! Overlapped re-quantization: the §3.3 pause off the critical path
//! (DESIGN.md §16).
//!
//! The paper's periodic re-quantization was a stop-the-world pause at the
//! epoch boundary: every layer's planes were moved out of the state,
//! rebuilt, and reinstalled while nothing else ran. This module makes the
//! rebuild concurrent with the epoch-end **evaluation window** — the one
//! stretch of coordinator work that *reads* the planes but never writes
//! them — while keeping the trajectory bitwise identical to the
//! synchronous fallback (`BSQ_SYNC_REQUANT=1` / `--sync-requant`).
//!
//! Protocol (both modes run the identical logical sequence):
//!
//! 1. **Rebuild into spares.** Each layer owns a persistent double buffer
//!    ([`RequantBuffers`]). Synchronous mode rebuilds inline on the main
//!    thread via [`requantize_into`] (reads the live codes, writes the
//!    spare). Overlap mode memcpys the live planes into the spare at the
//!    boundary, then background workers run [`requantize`] on the spares
//!    *concurrently with the window*.
//! 2. **The window** runs on the old pre-requant planes in the state (the
//!    epoch-end eval in `bsq_train`). It never writes planes, so overlap
//!    and sync see byte-identical state here.
//! 3. **Install at the next batch boundary.** After the window (and worker
//!    join), the rebuilt spares are swapped into the state all-or-nothing
//!    and the old planes become the next round's spares; the repacked
//!    plane momenta are zeroed at install (not at hand-off — the old
//!    planes keep training meaning until the swap).
//!
//! Bit-identity across modes is structural: the two rebuild paths are
//! differentially tested equal (`quant::adjust`), every state mutation
//! happens in the same order at the same point, and scheme/regularizer
//! takeover happens after install in both. Fault hooks
//! [`faults::REQUANT_WORKER`] (per worker chunk, fired in both modes so
//! one schedule means the same occurrence everywhere) and
//! [`faults::REQUANT_INSTALL`] (once, before the install loop) extend
//! chaos coverage to the overlap; a worker panic or install fault
//! surfaces as a clean `Err` *before* any plane is installed or any
//! snapshot taken, so resume replays from the previous boundary.

use std::panic::{catch_unwind, AssertUnwindSafe};

use anyhow::{bail, Result};

use crate::coordinator::trainer::Session;
use crate::faults;
use crate::model::ModelState;
use crate::quant::{requantize, requantize_into, AdjustReport, BitRep};

/// One layer's double buffer: the spare plane set the rebuild writes while
/// the live planes stay in the state, plus the report of the last rebuild.
struct LayerSpare {
    name: String,
    rep: BitRep,
    report: AdjustReport,
}

const ZERO_REPORT: AdjustReport =
    AdjustReport { bits_before: 0, bits_after: 0, msb_trimmed: 0, lsb_trimmed: 0 };

/// Persistent per-layer spare buffers for the double-buffered requant.
/// Allocated once per phase (shapes are static: `NB × layer elems`); after
/// every install the displaced live planes become the next spares, so the
/// steady state allocates nothing.
#[derive(Default)]
pub struct RequantBuffers {
    spares: Vec<LayerSpare>,
}

impl RequantBuffers {
    pub fn new() -> RequantBuffers {
        RequantBuffers { spares: Vec::new() }
    }

    /// Allocate the spares on first use (clones of the live reps — the
    /// contents are fully overwritten by every rebuild, only the shapes
    /// matter).
    fn ensure(&mut self, session: &Session, state: &ModelState) -> Result<()> {
        if self.spares.len() == session.man.qlayers.len() {
            return Ok(());
        }
        self.spares.clear();
        for q in &session.man.qlayers {
            self.spares.push(LayerSpare {
                name: q.name.clone(),
                rep: state.bitrep(&q.name)?,
                report: ZERO_REPORT,
            });
        }
        Ok(())
    }
}

/// Worker-chunk layout shared by both modes: `available_parallelism`
/// workers, layers split into contiguous chunks, one
/// [`faults::REQUANT_WORKER`] occurrence per chunk per boundary.
fn chunk_size(layers: usize) -> usize {
    let workers =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(layers).max(1);
    layers.div_ceil(workers).max(1)
}

/// Re-quantize every layer with the rebuild overlapped against `window`
/// (`sync = true` forces the pause-the-world ordering: rebuild first, then
/// window — same mutations, same stream, bitwise-identical trajectory).
///
/// `window` receives the state with the **old** planes still installed and
/// runs to completion before anything is swapped; the rebuilt reps are
/// installed after it returns, and the per-layer plane momenta are zeroed
/// at that install. Returns the window's value and the per-layer
/// [`AdjustReport`]s in manifest layer order.
pub fn requantize_overlapped<T>(
    session: &Session,
    state: &mut ModelState,
    bufs: &mut RequantBuffers,
    sync: bool,
    window: impl FnOnce(&mut ModelState) -> Result<T>,
) -> Result<(T, Vec<AdjustReport>)> {
    bufs.ensure(session, state)?;
    let chunk = chunk_size(bufs.spares.len());

    let win = if sync {
        // Pause-the-world: rebuild inline (reading live codes straight into
        // the spares — no plane copy), then run the window.
        let rebuilt = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            for (ci, part) in bufs.spares.chunks_mut(chunk).enumerate() {
                faults::fire(faults::REQUANT_WORKER, ci as u64);
                for sp in part.iter_mut() {
                    let rep = state.take_bitrep(&sp.name)?;
                    sp.report = requantize_into(&rep, &mut sp.rep);
                    state.install_bitrep(&sp.name, rep);
                }
            }
            Ok(())
        }));
        match rebuilt {
            Ok(r) => r?,
            Err(p) => {
                bail!("re-quantization worker faulted: {}", faults::panic_message(p))
            }
        }
        window(state)?
    } else {
        // Overlap: hand copies of the live planes to background workers and
        // run the window concurrently on the untouched originals.
        for sp in &mut bufs.spares {
            let rep = state.take_bitrep(&sp.name)?;
            sp.rep.wp.data_mut().copy_from_slice(rep.wp.data());
            sp.rep.wn.data_mut().copy_from_slice(rep.wn.data());
            sp.rep.mask.data_mut().copy_from_slice(rep.mask.data());
            sp.rep.scale = rep.scale;
            state.install_bitrep(&sp.name, rep);
        }
        let res = catch_unwind(AssertUnwindSafe(|| {
            std::thread::scope(|scope| {
                for (ci, part) in bufs.spares.chunks_mut(chunk).enumerate() {
                    scope.spawn(move || {
                        faults::fire(faults::REQUANT_WORKER, ci as u64);
                        for sp in part.iter_mut() {
                            sp.report = requantize(&mut sp.rep);
                        }
                    });
                }
                // runs on the calling thread while the workers rebuild;
                // a worker panic propagates when the scope joins, *after*
                // the window — never tearing the window mid-flight.
                window(state)
            })
        }));
        match res {
            Ok(r) => r?,
            Err(p) => {
                bail!("re-quantization worker faulted: {}", faults::panic_message(p))
            }
        }
    };

    // Install barrier: the next batch boundary. All-or-nothing — a fault
    // here leaves every live plane untouched (asserted by chaos).
    if let Err(p) = catch_unwind(|| faults::fire(faults::REQUANT_INSTALL, 0)) {
        bail!("re-quantization install faulted: {}", faults::panic_message(p));
    }
    let mut reports = Vec::with_capacity(bufs.spares.len());
    for sp in &mut bufs.spares {
        let old = state.take_bitrep(&sp.name)?;
        let rebuilt = std::mem::replace(&mut sp.rep, old);
        state.install_bitrep(&sp.name, rebuilt);
        // Zero at install, not hand-off: trims re-split the codes into
        // different plane slots, so the old per-plane momentum would push
        // the wrong bits (see requantize_all).
        state.zero_plane_momenta(&sp.name);
        reports.push(sp.report);
    }
    Ok((win, reports))
}
