//! Training telemetry: per-epoch records, JSON export, result files.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub phase: String,
    pub epoch: usize,
    pub lr: f32,
    pub loss: f32,
    pub ce: f32,
    pub acc: f32,
    pub bgl: f32,
    pub eval_acc: Option<f32>,
    pub bits_per_param: f64,
    pub compression: f64,
    pub seconds: f64,
}

#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<EpochRecord>,
}

impl History {
    pub fn push(&mut self, r: EpochRecord) {
        log::info!(
            "[{}] epoch {:>3} lr {:.4} loss {:.4} acc {:.3}{} bgl {:.2} {:.2} b/p ({:.2}x) {:.1}s",
            r.phase,
            r.epoch,
            r.lr,
            r.loss,
            r.acc,
            r.eval_acc.map(|a| format!(" eval {a:.3}")).unwrap_or_default(),
            r.bgl,
            r.bits_per_param,
            r.compression,
            r.seconds
        );
        self.records.push(r);
    }

    pub fn last_of(&self, phase: &str) -> Option<&EpochRecord> {
        self.records.iter().rev().find(|r| r.phase == phase)
    }

    pub fn best_eval(&self, phase: &str) -> Option<f32> {
        self.records
            .iter()
            .filter(|r| r.phase == phase)
            .filter_map(|r| r.eval_acc)
            .fold(None, |m, a| Some(m.map_or(a, |m: f32| m.max(a))))
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("phase", Json::str(r.phase.clone())),
                        ("epoch", Json::num(r.epoch as f64)),
                        ("lr", Json::num(r.lr as f64)),
                        ("loss", Json::num(r.loss as f64)),
                        ("ce", Json::num(r.ce as f64)),
                        ("acc", Json::num(r.acc as f64)),
                        ("bgl", Json::num(r.bgl as f64)),
                        (
                            "eval_acc",
                            r.eval_acc.map(|a| Json::num(a as f64)).unwrap_or(Json::Null),
                        ),
                        ("bits_per_param", Json::num(r.bits_per_param)),
                        ("compression", Json::num(r.compression)),
                        ("seconds", Json::num(r.seconds)),
                    ])
                })
                .collect(),
        )
    }

    /// Inverse of [`to_json`](History::to_json), for resuming from snapshot
    /// metadata. f32 metrics roundtrip bit-exactly: the JSON writer prints
    /// shortest-roundtrip f64, and f32 → f64 → f32 is lossless.
    pub fn from_json(j: &Json) -> Result<History> {
        let records = j
            .as_arr()
            .context("history: expected an array")?
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let decode = || -> Result<EpochRecord> {
                    let f32_of = |k: &str| -> Result<f32> { Ok(r.req(k)?.as_f64()? as f32) };
                    Ok(EpochRecord {
                        phase: r.req("phase")?.as_str()?.to_string(),
                        epoch: r.req("epoch")?.as_usize()?,
                        lr: f32_of("lr")?,
                        loss: f32_of("loss")?,
                        ce: f32_of("ce")?,
                        acc: f32_of("acc")?,
                        bgl: f32_of("bgl")?,
                        eval_acc: match r.req("eval_acc")? {
                            Json::Null => None,
                            v => Some(v.as_f64()? as f32),
                        },
                        bits_per_param: r.req("bits_per_param")?.as_f64()?,
                        compression: r.req("compression")?.as_f64()?,
                        seconds: r.req("seconds")?.as_f64()?,
                    })
                };
                decode().with_context(|| format!("history record {i}"))
            })
            .collect::<Result<Vec<EpochRecord>>>()?;
        Ok(History { records })
    }
}

/// Write an experiment record under `results/` (pretty JSON, atomic-ish).
pub fn write_result(path: &Path, value: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, value.to_string_pretty())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(phase: &str, epoch: usize, eval: Option<f32>) -> EpochRecord {
        EpochRecord {
            phase: phase.into(),
            epoch,
            lr: 0.1,
            loss: 1.0,
            ce: 0.9,
            acc: 0.5,
            bgl: 2.0,
            eval_acc: eval,
            bits_per_param: 8.0,
            compression: 4.0,
            seconds: 1.0,
        }
    }

    #[test]
    fn history_queries() {
        let mut h = History::default();
        h.push(rec("bsq", 0, Some(0.4)));
        h.push(rec("bsq", 1, Some(0.6)));
        h.push(rec("ft", 0, Some(0.55)));
        assert_eq!(h.last_of("bsq").unwrap().epoch, 1);
        assert_eq!(h.best_eval("bsq"), Some(0.6));
        assert_eq!(h.best_eval("nope"), None);
        let j = h.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn history_json_roundtrips_bit_exactly() {
        let mut h = History::default();
        // awkward values on purpose: subnormal-ish, repeating-fraction floats
        let mut r = rec("bsq", 7, Some(0.1f32 + 0.2f32));
        r.loss = 1.0f32 / 3.0;
        r.bgl = f32::MIN_POSITIVE;
        r.bits_per_param = 1.0 / 7.0;
        h.push(r);
        h.push(rec("finetune", 0, None));
        let text = h.to_json().to_string_pretty();
        let back = History::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.records.len(), 2);
        for (a, b) in back.records.iter().zip(&h.records) {
            assert_eq!(a.phase, b.phase);
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.lr.to_bits(), b.lr.to_bits());
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.ce.to_bits(), b.ce.to_bits());
            assert_eq!(a.acc.to_bits(), b.acc.to_bits());
            assert_eq!(a.bgl.to_bits(), b.bgl.to_bits());
            assert_eq!(a.eval_acc.map(f32::to_bits), b.eval_acc.map(f32::to_bits));
            assert_eq!(a.bits_per_param.to_bits(), b.bits_per_param.to_bits());
            assert_eq!(a.compression.to_bits(), b.compression.to_bits());
        }
    }

    #[test]
    fn result_files_roundtrip() {
        let dir = std::env::temp_dir().join(format!("bsq_res_{}", std::process::id()));
        let p = dir.join("t.json");
        write_result(&p, &Json::obj(vec![("x", Json::num(1.0))])).unwrap();
        let back = crate::util::json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(back.req("x").unwrap().as_f64().unwrap(), 1.0);
        std::fs::remove_dir_all(dir).ok();
    }
}
