//! Epoch-granular crash-safe snapshots and resume (DESIGN.md §12).
//!
//! At the end of every training epoch the coordinator persists the full
//! [`ModelState`] (bit-exact f32 binary, CRC-protected — `model::checkpoint`
//! v2) plus a JSON meta block: phase, completed-epoch index, the full
//! metric [`History`], the current [`QuantScheme`], the pre-finetune
//! accuracy once known, and a config fingerprint. Generations live in a
//! [`GenStore`] (`gen-NNNNNN.ckpt`), pruned to the newest `keep`.
//!
//! Resume invariant: a run killed at any point and resumed from
//! [`latest`] replays to a **bit-identical** trajectory versus the
//! uninterrupted run. This holds because every input to the remaining
//! epochs is reconstructed exactly: weights/momenta are bit-exact from the
//! checkpoint, the loader's shuffle/augmentation RNG is replayed through
//! the completed epochs (`Loader::skip_epoch` runs the identical state
//! transition), history metrics roundtrip through shortest-print JSON
//! losslessly, and schemes/regularizer weights are pure functions of the
//! snapshotted state. `tests/chaos.rs` machine-checks this end to end.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::bsq::BsqConfig;
use crate::coordinator::metrics::History;
use crate::model::checkpoint::GenStore;
use crate::model::ModelState;
use crate::quant::{LayerPrec, QuantScheme};
use crate::runtime::Engine;
use crate::serve::registry::ServableModel;
use crate::store::{DeployPin, ModelStore};
use crate::util::json::Json;

/// Where and how much to snapshot (CLI: `--snapshot-dir`, `--snapshot-keep`).
#[derive(Debug, Clone)]
pub struct SnapshotCfg {
    pub dir: PathBuf,
    /// Generations retained on disk. More than one is what makes a torn
    /// final write survivable (fallback), at one ModelState each.
    pub keep: usize,
    /// Root of a content-addressed model store to publish each committed
    /// generation into (CLI: `--publish-store`). `None` = snapshots only.
    /// Publication is additive: the `GenStore` retention/fallback story is
    /// untouched, the store just also receives every servable generation.
    pub publish: Option<PathBuf>,
}

impl SnapshotCfg {
    pub fn new(dir: impl Into<PathBuf>) -> SnapshotCfg {
        SnapshotCfg { dir: dir.into(), keep: 3, publish: None }
    }
}

/// Publishes committed checkpoints into a [`ModelStore`] and repins the
/// model's deploy: ingest the bytes (content-keyed, idempotent), load the
/// servable once to fingerprint its precision map and compiled plan, pin
/// the (weights, precision, plan) triple. The serving side picks the new
/// pin up via `Registry::load_pinned` + `SwapHandle::swap`.
pub struct StorePublisher<'e> {
    engine: &'e Engine,
    store_root: PathBuf,
    model: String,
    act_bits: usize,
    act_first_last: usize,
}

impl<'e> StorePublisher<'e> {
    pub fn new(
        engine: &'e Engine,
        store_root: impl Into<PathBuf>,
        model: impl Into<String>,
        act_bits: usize,
        act_first_last: usize,
    ) -> StorePublisher<'e> {
        StorePublisher {
            engine,
            store_root: store_root.into(),
            model: model.into(),
            act_bits,
            act_first_last,
        }
    }

    /// Publish one committed snapshot generation; returns the store digest
    /// it now lives under. Errors if the checkpoint is not servable (e.g. a
    /// float-weights pretrain epoch) — callers that publish every epoch
    /// treat that case as "skip", not "fail".
    pub fn publish(&self, ckpt: &Path, generation: u64) -> Result<String> {
        self.publish_as(ckpt, &format!("gen-{generation:06}"))
    }

    /// [`StorePublisher::publish`] with an explicit provenance string
    /// (the CLI's `store add` stamps the pin `"cli"`).
    pub fn publish_as(&self, ckpt: &Path, source: &str) -> Result<String> {
        let mut store = ModelStore::open(&self.store_root)?;
        let digest = store.put_checkpoint(ckpt)?;
        let sv = ServableModel::load_with_digest(
            self.engine,
            &self.model,
            ckpt,
            digest.clone(),
            self.act_bits,
            self.act_first_last,
        )?;
        store.pin_deploy(DeployPin {
            model: self.model.clone(),
            weights_hash: digest.clone(),
            precision_fp: sv.precision_fingerprint(),
            plan_fp: sv.plan_fingerprint(),
            act_bits: self.act_bits,
            act_first_last: self.act_first_last,
            source: source.to_string(),
        })?;
        Ok(digest)
    }
}

/// Writes one snapshot generation per completed epoch (and, when a
/// publisher is attached, pushes each servable generation into the store).
pub struct Snapshotter<'e> {
    store: GenStore,
    next_gen: u64,
    publisher: Option<StorePublisher<'e>>,
}

impl<'e> Snapshotter<'e> {
    pub fn open(cfg: &SnapshotCfg) -> Snapshotter<'static> {
        let store = GenStore::new(&cfg.dir, cfg.keep);
        let next_gen = store.generations().last().map(|g| g + 1).unwrap_or(0);
        Snapshotter { store, next_gen, publisher: None }
    }

    /// [`Snapshotter::open`] with store publication wired to the run's
    /// model and activation config when `cfg.publish` is set.
    pub fn open_for(cfg: &SnapshotCfg, engine: &'e Engine, run: &BsqConfig) -> Snapshotter<'e> {
        let snap = Self::open(cfg);
        let publisher = cfg.publish.as_ref().map(|root| {
            StorePublisher::new(engine, root, &run.model, run.act_bits, run.act_first_last)
        });
        Snapshotter { store: snap.store, next_gen: snap.next_gen, publisher }
    }

    /// Persist the end-of-epoch snapshot: `epoch` is the index of the epoch
    /// that just *completed* within `phase` (its record is already in
    /// `history`).
    pub fn take(
        &mut self,
        cfg: &BsqConfig,
        phase: &str,
        epoch: usize,
        state: &ModelState,
        history: &History,
        scheme: Option<&QuantScheme>,
        acc_before_ft: Option<f32>,
    ) -> Result<()> {
        let meta = Json::obj(vec![
            ("snapshot_version", Json::num(1.0)),
            ("gen", Json::num(self.next_gen as f64)),
            ("phase", Json::str(phase)),
            ("epoch", Json::num(epoch as f64)),
            ("acc_before_ft", acc_before_ft.map(|a| Json::num(a as f64)).unwrap_or(Json::Null)),
            ("scheme", scheme.map(scheme_to_json).unwrap_or(Json::Null)),
            ("history", history.to_json()),
            ("config", config_fingerprint(cfg)),
        ]);
        let gen = self.next_gen;
        self.store
            .save_generation(gen, state, &meta)
            .with_context(|| format!("snapshotting {phase} epoch {epoch}"))?;
        self.next_gen += 1;
        if let Some(publisher) = &self.publisher {
            // Lenient by design: publication must never fail training.
            // Pretrain-phase float checkpoints are not servable — skip them
            // quietly; anything else is worth a warning but not an abort.
            match publisher.publish(&self.store.path(gen), gen) {
                Ok(digest) => {
                    log::info!("published gen {gen} to store as {}", &digest[..16]);
                }
                Err(e) if format!("{e:#}").contains("bit-representation") => {
                    log::debug!("gen {gen} not servable yet (float weights); not published");
                }
                Err(e) => log::warn!("store publication of gen {gen} failed: {e:#}"),
            }
        }
        Ok(())
    }
}

/// A decoded resume point: everything `run_bsq` needs to continue the
/// pipeline as if it had never stopped.
pub struct ResumePoint {
    pub gen: u64,
    pub phase: String,
    /// Index of the last *completed* epoch within `phase`.
    pub epoch: usize,
    pub state: ModelState,
    pub history: History,
    pub scheme: Option<QuantScheme>,
    pub acc_before_ft: Option<f32>,
}

/// Newest usable snapshot generation, validated against the resuming run's
/// config fingerprint (resuming under different hyperparameters would
/// silently fork the trajectory — that must be a hard error).
pub fn latest(cfg: &SnapshotCfg, run: &BsqConfig) -> Result<Option<ResumePoint>> {
    let store = GenStore::new(&cfg.dir, cfg.keep);
    let Some((gen, state, meta)) = store.latest_good() else {
        return Ok(None);
    };
    let decode = || -> Result<ResumePoint> {
        let stored = meta.req("config")?;
        let ours = config_fingerprint(run);
        if *stored != ours {
            bail!(
                "config fingerprint mismatch: snapshot was taken by a different run\n  \
                 snapshot: {}\n  this run: {}",
                stored.to_string_compact(),
                ours.to_string_compact()
            );
        }
        Ok(ResumePoint {
            gen,
            phase: meta.req("phase")?.as_str()?.to_string(),
            epoch: meta.req("epoch")?.as_usize()?,
            history: History::from_json(meta.req("history")?)?,
            scheme: match meta.req("scheme")? {
                Json::Null => None,
                j => Some(scheme_from_json(j)?),
            },
            acc_before_ft: match meta.req("acc_before_ft")? {
                Json::Null => None,
                j => Some(j.as_f64()? as f32),
            },
            state,
        })
    };
    decode().map(Some).with_context(|| format!("resuming from snapshot generation {gen}"))
}

fn scheme_to_json(s: &QuantScheme) -> Json {
    Json::Arr(
        s.layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::str(l.name.clone())),
                    ("params", Json::num(l.params as f64)),
                    ("bits", Json::num(l.bits as f64)),
                ])
            })
            .collect(),
    )
}

fn scheme_from_json(j: &Json) -> Result<QuantScheme> {
    let layers = j
        .as_arr()
        .context("scheme: expected an array")?
        .iter()
        .map(|l| {
            Ok(LayerPrec {
                name: l.req("name")?.as_str()?.to_string(),
                params: l.req("params")?.as_usize()?,
                bits: l.req("bits")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<LayerPrec>>>()?;
    Ok(QuantScheme::new(layers))
}

/// Every config field that shapes the training trajectory. Compared for
/// exact equality on resume (f32 → f64 is lossless, and the JSON layer
/// never touches the values, so equality is bitwise in effect).
fn config_fingerprint(cfg: &BsqConfig) -> Json {
    Json::obj(vec![
        ("model", Json::str(cfg.model.clone())),
        ("alpha", Json::num(cfg.alpha as f64)),
        ("act_bits", Json::num(cfg.act_bits as f64)),
        ("act_first_last", Json::num(cfg.act_first_last as f64)),
        ("init_bits", Json::num(cfg.init_bits as f64)),
        ("init_8bit_prefix", Json::num(cfg.init_8bit_prefix as f64)),
        ("pretrain_epochs", Json::num(cfg.pretrain_epochs as f64)),
        ("bsq_epochs", Json::num(cfg.bsq_epochs as f64)),
        ("finetune_epochs", Json::num(cfg.finetune_epochs as f64)),
        ("requant_interval", Json::num(cfg.requant_interval as f64)),
        ("reweigh", Json::str(format!("{:?}", cfg.reweigh))),
        ("weight_decay", Json::num(cfg.weight_decay as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("train_size", Json::num(cfg.train_size as f64)),
        ("test_size", Json::num(cfg.test_size as f64)),
        ("eval_batches", Json::num(cfg.eval_batches as f64)),
        ("alpha_ref_steps", Json::num(cfg.alpha_ref_steps)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::EpochRecord;
    use crate::tensor::Tensor;
    use crate::util::Pcg32;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bsq_snap_{tag}_{}", std::process::id()))
    }

    fn tiny_state(seed: u64) -> ModelState {
        let mut rng = Pcg32::seeded(seed);
        let mut s = ModelState::new();
        s.insert("w:c1".into(), Tensor::randn(&[2, 3], 0.5, &mut rng));
        s
    }

    fn tiny_history() -> History {
        let mut h = History::default();
        h.push(EpochRecord {
            phase: "pretrain".into(),
            epoch: 0,
            lr: 0.1,
            loss: 1.25,
            ce: 1.25,
            acc: 0.5,
            bgl: 0.0,
            eval_acc: Some(0.1f32 + 0.2f32),
            bits_per_param: 32.0,
            compression: 1.0,
            seconds: 0.5,
        });
        h
    }

    #[test]
    fn snapshot_roundtrips_through_latest() {
        let cfg = BsqConfig::for_model("tinynet");
        let dir = scratch("rt");
        let scfg = SnapshotCfg::new(&dir);
        let mut snap = Snapshotter::open(&scfg);
        let scheme = QuantScheme::new(vec![LayerPrec { name: "c1".into(), params: 6, bits: 5 }]);
        snap.take(&cfg, "bsq", 1, &tiny_state(3), &tiny_history(), Some(&scheme), None).unwrap();
        snap.take(&cfg, "bsq", 2, &tiny_state(4), &tiny_history(), Some(&scheme), Some(0.75))
            .unwrap();

        let rp = latest(&scfg, &cfg).unwrap().unwrap();
        assert_eq!(rp.gen, 1);
        assert_eq!(rp.phase, "bsq");
        assert_eq!(rp.epoch, 2);
        assert_eq!(rp.scheme.as_ref().unwrap(), &scheme);
        assert_eq!(rp.acc_before_ft.map(f32::to_bits), Some(0.75f32.to_bits()));
        assert_eq!(rp.state.get("w:c1").unwrap(), tiny_state(4).get("w:c1").unwrap());
        assert_eq!(rp.history.records[0].eval_acc.map(f32::to_bits), Some((0.1f32 + 0.2f32).to_bits()));

        // a fresh Snapshotter continues the generation sequence
        let snap2 = Snapshotter::open(&scfg);
        assert_eq!(snap2.next_gen, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn resume_under_a_different_config_is_a_hard_error() {
        let cfg = BsqConfig::for_model("tinynet");
        let dir = scratch("fp");
        let scfg = SnapshotCfg::new(&dir);
        let mut snap = Snapshotter::open(&scfg);
        snap.take(&cfg, "pretrain", 0, &tiny_state(0), &tiny_history(), None, None).unwrap();

        let mut other = cfg.clone();
        other.alpha *= 2.0;
        let err = latest(&scfg, &other).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint mismatch"), "{err:#}");

        // matching config still resumes; no snapshots at all is Ok(None)
        assert!(latest(&scfg, &cfg).unwrap().is_some());
        let empty = SnapshotCfg::new(scratch("fp_empty"));
        assert!(latest(&empty, &cfg).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
