//! Content digest for the model store: `util::crc32`'s streaming shape,
//! widened from a 32-bit error check to a 256-bit content address.
//!
//! CRC32 is the right tool for detecting *accidental* corruption inside a
//! checkpoint, but at 32 bits it cannot key a store — two different
//! checkpoints colliding would silently serve the wrong weights, the exact
//! bug class this subsystem exists to kill. `Digest256` keeps the same
//! dependency-free, table-only construction discipline (the build is
//! offline) and widens the state to four 64-bit lanes mixed with a
//! splitmix64-style avalanche, Merkle–Damgård-padded with the message
//! length so no two byte strings share a padding image. Not cryptographic
//! — the threat model is accidental collision and bit-rot, matching the
//! rest of the repo's integrity story — but at 256 bits of well-diffused
//! state an accidental collision between checkpoints is beyond-astronomical.
//!
//! Streaming like [`crate::util::crc32::Crc32`]: `update` any number of
//! times, `finalize` without consuming (so a caller can checkpoint a
//! running hash), identical output for identical byte streams regardless
//! of chunking (`streaming_matches_one_shot`).

use std::io::Read;
use std::path::Path;

use anyhow::{Context, Result};

/// Golden-ratio seed, the splitmix64 increment constant.
const SEED: u64 = 0x9E37_79B9_7F4A_7C15;
/// splitmix64 finalizer multipliers.
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// splitmix64's output avalanche: every input bit flips ~half the output.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z ^= z >> 30;
    z = z.wrapping_mul(MIX1);
    z ^= z >> 27;
    z = z.wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// Streaming 256-bit content digest (see module docs).
#[derive(Debug, Clone)]
pub struct Digest256 {
    lanes: [u64; 4],
    /// Partial block awaiting 32 bytes.
    buf: [u8; 32],
    buf_len: usize,
    /// Total message bytes absorbed (folded into the final block).
    total: u64,
}

impl Digest256 {
    pub fn new() -> Digest256 {
        // Distinct per-lane seeds through the same avalanche that mixes
        // blocks, so no lane pair starts in a related state.
        let lanes = [mix64(SEED), mix64(SEED.wrapping_mul(3)), mix64(SEED.wrapping_mul(5)), mix64(SEED.wrapping_mul(7))];
        Digest256 { lanes, buf: [0u8; 32], buf_len: 0, total: 0 }
    }

    /// Absorb one full 32-byte block: xor the four words in, then two
    /// cross-lane mixing rounds so every message bit reaches every lane
    /// before the next block lands.
    fn absorb(lanes: &mut [u64; 4], block: &[u8]) {
        debug_assert_eq!(block.len(), 32);
        for (i, lane) in lanes.iter_mut().enumerate() {
            let w = u64::from_le_bytes(block[i * 8..(i + 1) * 8].try_into().unwrap());
            *lane ^= w;
        }
        for _ in 0..2 {
            for i in 0..4 {
                let neighbor = lanes[(i + 1) & 3].rotate_left(23);
                lanes[i] = mix64(lanes[i].wrapping_add(neighbor).wrapping_add(SEED));
            }
        }
    }

    pub fn update(&mut self, mut bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (32 - self.buf_len).min(bytes.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&bytes[..take]);
            self.buf_len += take;
            bytes = &bytes[take..];
            if self.buf_len == 32 {
                let buf = self.buf;
                Self::absorb(&mut self.lanes, &buf);
                self.buf_len = 0;
            }
        }
        let mut chunks = bytes.chunks_exact(32);
        for block in &mut chunks {
            Self::absorb(&mut self.lanes, block);
        }
        let rest = chunks.remainder();
        self.buf[..rest.len()].copy_from_slice(rest);
        self.buf_len = rest.len();
    }

    /// The 256-bit digest of everything absorbed so far. Non-consuming:
    /// padding and length-folding run on a copy of the state.
    pub fn finalize(&self) -> [u8; 32] {
        let mut lanes = self.lanes;
        // Merkle–Damgård tail: 0x80 marker, zero pad, then a length block.
        // The marker keeps "abc" and "abc\0" distinct; the length block
        // keeps any two same-padded prefixes distinct.
        let mut tail = [0u8; 32];
        tail[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
        tail[self.buf_len] = 0x80;
        Self::absorb(&mut lanes, &tail);
        let mut len_block = [0u8; 32];
        len_block[..8].copy_from_slice(&self.total.to_le_bytes());
        len_block[8..16].copy_from_slice(&(!self.total).to_le_bytes());
        Self::absorb(&mut lanes, &len_block);
        // One extra blank round flushes the last block through the
        // cross-lane diffusion before the state is read out.
        Self::absorb(&mut lanes, &[0u8; 32]);
        let mut out = [0u8; 32];
        for (i, lane) in lanes.iter().enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&lane.to_le_bytes());
        }
        out
    }

    /// Hex form — the store's object key and the manifest's pin value.
    pub fn hex(&self) -> String {
        to_hex(&self.finalize())
    }
}

impl Default for Digest256 {
    fn default() -> Digest256 {
        Digest256::new()
    }
}

fn to_hex(bytes: &[u8; 32]) -> String {
    let mut s = String::with_capacity(64);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// One-shot convenience, mirroring `crc32::crc32`.
pub fn digest_hex(bytes: &[u8]) -> String {
    let mut d = Digest256::new();
    d.update(bytes);
    d.hex()
}

/// Streaming digest of a file's bytes — how checkpoints get their store
/// key without ever holding the whole file in memory.
pub fn digest_file(path: &Path) -> Result<String> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {} for content hashing", path.display()))?;
    let mut d = Digest256::new();
    let mut buf = [0u8; 64 * 1024];
    loop {
        let n = f.read(&mut buf).with_context(|| format!("hashing {}", path.display()))?;
        if n == 0 {
            break;
        }
        d.update(&buf[..n]);
    }
    Ok(d.hex())
}

/// Is `s` a plausible digest key (64 lowercase hex chars)? Guards manifest
/// entries against hand-edited garbage before the filesystem lookup.
pub fn looks_like_digest(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_length_distinguishing() {
        assert_eq!(digest_hex(b"abc"), digest_hex(b"abc"));
        assert_ne!(digest_hex(b""), digest_hex(b"\0"));
        assert_ne!(digest_hex(b"abc"), digest_hex(b"abc\0"));
        // padding image of a 31-byte message must not collide with the
        // 32-byte message that equals it plus the 0x80 marker
        let mut a = [0u8; 31];
        a[0] = 7;
        let mut b = [0u8; 32];
        b[0] = 7;
        b[31] = 0x80;
        assert_ne!(digest_hex(&a), digest_hex(&b));
        let h = digest_hex(b"abc");
        assert_eq!(h.len(), 64);
        assert!(looks_like_digest(&h));
        assert!(!looks_like_digest("abc"));
        assert!(!looks_like_digest(&h.to_uppercase()));
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..257u16).map(|i| (i % 251) as u8).collect();
        let want = digest_hex(&data);
        for split in [0, 1, 31, 32, 33, 64, 100, 256, 257] {
            let mut d = Digest256::new();
            d.update(&data[..split]);
            d.update(&data[split..]);
            assert_eq!(d.hex(), want, "split at {split}");
            // finalize is non-consuming and repeatable
            assert_eq!(d.hex(), want);
        }
        // byte-at-a-time
        let mut d = Digest256::new();
        for b in &data {
            d.update(std::slice::from_ref(b));
        }
        assert_eq!(d.hex(), want);
    }

    #[test]
    fn every_single_bit_flip_changes_the_digest() {
        // The store's core promise: same bytes → same key, one flipped bit
        // anywhere → a different key. Machine-check every bit of a buffer
        // spanning multiple blocks plus a ragged tail.
        let mut data: Vec<u8> = (0..97u8).collect();
        let base = digest_hex(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                data[i] ^= 1 << bit;
                assert_ne!(digest_hex(&data), base, "flip byte {i} bit {bit} went undetected");
                data[i] ^= 1 << bit;
            }
        }
    }

    #[test]
    fn digest_file_matches_in_memory() {
        let path = std::env::temp_dir().join(format!("bsq_digest_{}", std::process::id()));
        let data: Vec<u8> = (0..100_000u32).map(|i| (i * 31 % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        assert_eq!(digest_file(&path).unwrap(), digest_hex(&data));
        std::fs::remove_file(path).ok();
    }
}
