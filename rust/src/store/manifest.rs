//! Deploy manifest: the store's lockfile.
//!
//! A deploy is reproducible only if it pins *everything* that determines
//! the served numerics: the exact weight bytes, the precision map those
//! bytes decode to, and the compiled plan they execute under. The
//! manifest records one pin per model name as a
//! (weights-hash, precision-fingerprint, plan-fingerprint) triple plus
//! the activation config — the package-lockfile idiom, minus serde (the
//! build is offline; `util::json` is the only JSON layer in the crate).
//!
//! Resolution is strict: a model with no pin, a pin whose hash is not a
//! digest, or a pin whose object is missing from the store is a hard
//! error, never a silent fallback to "whatever file is at the old path" —
//! that fallback is precisely the stale-serving bug this subsystem fixes.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::digest::{looks_like_digest, Digest256};
use crate::ir::plan::CompiledPlan;
use crate::model::checkpoint;
use crate::util::json::{self, Json};

pub const MANIFEST_VERSION: usize = 1;

/// One pinned deploy: everything needed to reproduce a serving config.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployPin {
    pub model: String,
    /// Content digest of the checkpoint bytes — the store object key.
    pub weights_hash: String,
    /// Fingerprint over the per-layer effective-precision map.
    pub precision_fp: String,
    /// Fingerprint over the compiled infer plan (schedule + arena).
    pub plan_fp: String,
    pub act_bits: usize,
    pub act_first_last: usize,
    /// Provenance label (source path or `gen-NNNNNN`), informational only.
    pub source: String,
}

impl DeployPin {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("weights_hash", Json::str(&self.weights_hash)),
            ("precision_fp", Json::str(&self.precision_fp)),
            ("plan_fp", Json::str(&self.plan_fp)),
            ("act_bits", Json::num(self.act_bits as f64)),
            ("act_first_last", Json::num(self.act_first_last as f64)),
            ("source", Json::str(&self.source)),
        ])
    }

    fn from_json(v: &Json) -> Result<DeployPin> {
        let pin = DeployPin {
            model: v.req("model")?.as_str()?.to_string(),
            weights_hash: v.req("weights_hash")?.as_str()?.to_string(),
            precision_fp: v.req("precision_fp")?.as_str()?.to_string(),
            plan_fp: v.req("plan_fp")?.as_str()?.to_string(),
            act_bits: v.req("act_bits")?.as_usize()?,
            act_first_last: v.req("act_first_last")?.as_usize()?,
            source: v.req("source")?.as_str()?.to_string(),
        };
        if !looks_like_digest(&pin.weights_hash) {
            bail!(
                "manifest pin for {:?} has malformed weights_hash {:?} (want 64 lowercase hex)",
                pin.model,
                pin.weights_hash
            );
        }
        Ok(pin)
    }
}

/// The manifest: one pin per model name, insertion-ordered, plus the pin
/// history `store gc` prunes against: a monotone deploy counter and the
/// last deploy each object hash was pinned at. Both fields are optional on
/// load (absent → 0 / empty) so manifests written before gc existed still
/// parse — the version number stays at [`MANIFEST_VERSION`] because old
/// readers simply ignore the extra keys.
#[derive(Debug, Default)]
pub struct Manifest {
    pins: Vec<DeployPin>,
    deploy_seq: usize,
    /// `(weights_hash, deploy_seq at last pin)` — upserted on every pin,
    /// never pruned here (gc consults it; pruning history would forget the
    /// very recency data gc needs).
    history: Vec<(String, usize)>,
}

impl Manifest {
    pub fn new() -> Manifest {
        Manifest::default()
    }

    /// Parse from disk; a missing file is an empty manifest (fresh store),
    /// a malformed file is a hard error (never guess at deploy state).
    pub fn load(path: &Path) -> Result<Manifest> {
        if !path.exists() {
            return Ok(Manifest::new());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing manifest {}", path.display()))?;
        let version = v.req("version")?.as_usize()?;
        if version != MANIFEST_VERSION {
            bail!("manifest {} is version {version}, this build reads {MANIFEST_VERSION}", path.display());
        }
        let mut pins = Vec::new();
        for entry in v.req("pins")?.as_arr()? {
            let pin = DeployPin::from_json(entry)?;
            if pins.iter().any(|p: &DeployPin| p.model == pin.model) {
                bail!("manifest {} pins {:?} twice", path.display(), pin.model);
            }
            pins.push(pin);
        }
        // Lenient: manifests from before `store gc` lack these keys.
        let deploy_seq = match v.get("deploy_seq") {
            Some(n) => n.as_usize()?,
            None => 0,
        };
        let mut history = Vec::new();
        if let Some(arr) = v.get("history") {
            for entry in arr.as_arr()? {
                let hash = entry.req("hash")?.as_str()?.to_string();
                if !looks_like_digest(&hash) {
                    bail!("manifest {} history has malformed hash {hash:?}", path.display());
                }
                history.push((hash, entry.req("seq")?.as_usize()?));
            }
        }
        Ok(Manifest { pins, deploy_seq, history })
    }

    /// Atomic write via the checkpoint tmp+fsync+rename path, so a crash
    /// mid-save leaves the previous manifest intact, never a torn one.
    pub fn save(&self, path: &Path) -> Result<()> {
        let doc = Json::obj(vec![
            ("version", Json::num(MANIFEST_VERSION as f64)),
            ("pins", Json::Arr(self.pins.iter().map(DeployPin::to_json).collect())),
            ("deploy_seq", Json::num(self.deploy_seq as f64)),
            (
                "history",
                Json::Arr(
                    self.history
                        .iter()
                        .map(|(hash, seq)| {
                            Json::obj(vec![
                                ("hash", Json::str(hash)),
                                ("seq", Json::num(*seq as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        checkpoint::commit_bytes(path, doc.to_string_pretty().as_bytes())
            .with_context(|| format!("writing manifest {}", path.display()))
    }

    /// Upsert the pin for `pin.model`. Returns the replaced pin, if any.
    /// Every pin bumps the deploy counter and stamps the pinned hash with
    /// it, so `store gc` can tell "pinned three deploys ago" from "never
    /// pinned at all".
    pub fn pin(&mut self, pin: DeployPin) -> Result<Option<DeployPin>> {
        if !looks_like_digest(&pin.weights_hash) {
            bail!("refusing to pin {:?}: malformed weights_hash {:?}", pin.model, pin.weights_hash);
        }
        self.deploy_seq += 1;
        let seq = self.deploy_seq;
        match self.history.iter_mut().find(|(h, _)| *h == pin.weights_hash) {
            Some(slot) => slot.1 = seq,
            None => self.history.push((pin.weights_hash.clone(), seq)),
        }
        match self.pins.iter_mut().find(|p| p.model == pin.model) {
            Some(slot) => Ok(Some(std::mem::replace(slot, pin))),
            None => {
                self.pins.push(pin);
                Ok(None)
            }
        }
    }

    /// Hard-error resolution: no pin for the model name is a deploy bug.
    pub fn resolve(&self, model: &str) -> Result<&DeployPin> {
        self.pins.iter().find(|p| p.model == model).ok_or_else(|| {
            let known: Vec<&str> = self.pins.iter().map(|p| p.model.as_str()).collect();
            anyhow::anyhow!("no manifest pin for model {model:?} (pinned: {known:?})")
        })
    }

    pub fn pins(&self) -> &[DeployPin] {
        &self.pins
    }

    /// Number of deploys (pins) this manifest has ever recorded.
    pub fn deploy_seq(&self) -> usize {
        self.deploy_seq
    }

    /// Hashes `store gc --keep-deploys N` must not delete: everything a
    /// model currently serves, plus anything pinned within the last `keep`
    /// deploys (seq in `(deploy_seq - keep, deploy_seq]`). Objects the
    /// manifest has never pinned don't appear — they are garbage at any
    /// `keep`.
    pub fn live_hashes(&self, keep: usize) -> std::collections::BTreeSet<String> {
        let mut live: std::collections::BTreeSet<String> =
            self.pins.iter().map(|p| p.weights_hash.clone()).collect();
        for (hash, seq) in &self.history {
            if seq + keep > self.deploy_seq {
                live.insert(hash.clone());
            }
        }
        live
    }
}

/// Short (64-bit) fingerprint over a list of labelled parts. Each part is
/// absorbed length-prefixed so `["ab","c"]` and `["a","bc"]` differ.
pub fn fingerprint_parts<I, S>(parts: I) -> String
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let mut d = Digest256::new();
    for part in parts {
        let b = part.as_ref().as_bytes();
        d.update(&(b.len() as u64).to_le_bytes());
        d.update(b);
    }
    d.hex()[..16].to_string()
}

/// Fingerprint of a compiled plan: everything that shapes execution
/// order and memory, none of the weight data (that's `weights_hash`).
/// Two checkpoints of the same architecture share a plan fingerprint;
/// a schedule, fusion, or arena-layout change breaks it — exactly the
/// granularity a "same plan?" deploy check wants.
pub fn plan_fingerprint(plan: &CompiledPlan) -> String {
    let mut parts = vec![
        format!("model={}", plan.graph.model),
        format!("mode={:?}", plan.mode),
        format!("nodes={}", plan.schedule_len()),
        format!("arena={}", plan.arena_elems),
        format!("naive={}", plan.naive_elems),
        format!("fused={}", plan.fused),
    ];
    for (kind, count) in plan.graph.kind_counts() {
        parts.push(format!("kind:{kind}={count}"));
    }
    fingerprint_parts(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pin(model: &str, hash_seed: u8) -> DeployPin {
        DeployPin {
            model: model.to_string(),
            weights_hash: super::super::digest::digest_hex(&[hash_seed]),
            precision_fp: fingerprint_parts(["conv1=4", "fc=2"]),
            plan_fp: fingerprint_parts(["model=t", "nodes=5"]),
            act_bits: 4,
            act_first_last: 8,
            source: "gen-000042".to_string(),
        }
    }

    #[test]
    fn pin_resolve_roundtrip_through_disk() {
        let dir = std::env::temp_dir().join(format!("bsq_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        let mut m = Manifest::new();
        assert!(m.pin(pin("tinynet", 1)).unwrap().is_none());
        assert!(m.pin(pin("convnet", 2)).unwrap().is_none());
        // repin replaces, not duplicates
        let replaced = m.pin(pin("tinynet", 3)).unwrap().unwrap();
        assert_eq!(replaced.weights_hash, super::super::digest::digest_hex(&[1]));
        m.save(&path).unwrap();

        let back = Manifest::load(&path).unwrap();
        assert_eq!(back.pins().len(), 2);
        assert_eq!(back.resolve("tinynet").unwrap(), m.resolve("tinynet").unwrap());
        assert_eq!(
            back.resolve("tinynet").unwrap().weights_hash,
            super::super::digest::digest_hex(&[3])
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_of_unpinned_model_is_a_hard_error() {
        let m = Manifest::new();
        let err = m.resolve("ghost").unwrap_err().to_string();
        assert!(err.contains("no manifest pin"), "got: {err}");
    }

    #[test]
    fn malformed_hash_is_rejected_on_pin_and_on_load() {
        let mut m = Manifest::new();
        let mut bad = pin("tinynet", 1);
        bad.weights_hash = "deadbeef".to_string();
        assert!(m.pin(bad).is_err());

        let dir = std::env::temp_dir().join(format!("bsq_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        std::fs::write(
            &path,
            r#"{"version": 1, "pins": [{"model": "t", "weights_hash": "nope",
                "precision_fp": "x", "plan_fp": "y", "act_bits": 4,
                "act_first_last": 8, "source": "s"}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_empty_wrong_version_is_error() {
        let dir = std::env::temp_dir().join(format!("bsq_manifest_ver_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir.join("absent.json")).unwrap().pins().is_empty());
        let path = dir.join("manifest.json");
        std::fs::write(&path, r#"{"version": 99, "pins": []}"#).unwrap();
        assert!(Manifest::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_manifest_without_history_keys_still_loads() {
        let dir = std::env::temp_dir().join(format!("bsq_manifest_legacy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        let hash = super::super::digest::digest_hex(&[7]);
        std::fs::write(
            &path,
            format!(
                r#"{{"version": 1, "pins": [{{"model": "t", "weights_hash": "{hash}",
                    "precision_fp": "x", "plan_fp": "y", "act_bits": 4,
                    "act_first_last": 8, "source": "s"}}]}}"#
            ),
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.deploy_seq(), 0);
        // the current pin is live even with no history at all
        assert_eq!(m.live_hashes(0).into_iter().collect::<Vec<_>>(), vec![hash]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pin_history_tracks_recency_through_disk() {
        let dir = std::env::temp_dir().join(format!("bsq_manifest_hist_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        let mut m = Manifest::new();
        m.pin(pin("tinynet", 1)).unwrap(); // seq 1
        m.pin(pin("tinynet", 2)).unwrap(); // seq 2 — hash 1 now unpinned
        m.pin(pin("tinynet", 3)).unwrap(); // seq 3 — hash 2 now unpinned
        m.save(&path).unwrap();

        let back = Manifest::load(&path).unwrap();
        assert_eq!(back.deploy_seq(), 3);
        let h = |b: u8| super::super::digest::digest_hex(&[b]);
        // keep 0: only the live pin survives
        assert_eq!(back.live_hashes(0), [h(3)].into_iter().collect());
        // keep 2: hashes pinned at seq > 1 survive
        assert_eq!(back.live_hashes(2), [h(2), h(3)].into_iter().collect());
        // keep well past the horizon: everything ever pinned survives
        assert_eq!(back.live_hashes(10), [h(1), h(2), h(3)].into_iter().collect());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprints_are_stable_and_boundary_sensitive() {
        let a = fingerprint_parts(["ab", "c"]);
        assert_eq!(a, fingerprint_parts(["ab", "c"]));
        assert_ne!(a, fingerprint_parts(["a", "bc"]));
        assert_eq!(a.len(), 16);
    }
}
