//! Content-addressed model store (DESIGN.md §14).
//!
//! The serving stack used to identify a model by *where its checkpoint
//! lives* — and BSQ training rewrites checkpoints in place (`GenStore`
//! retention, snapshot/resume), so "same path" stopped meaning "same
//! weights" the moment training kept running. The store makes identity
//! content-based, the package-manager way:
//!
//! ```text
//! <root>/
//!   objects/<digest>.ckpt        # immutable, named by their own bytes
//!   objects/<digest>.meta.json   # checkpoint meta sidecar, same key
//!   manifest.json                # lockfile: model → pinned deploy triple
//! ```
//!
//! Objects are immutable by construction: the filename *is* the digest of
//! the content, so an object can never go stale — a new checkpoint is a
//! new object under a new key. The manifest ([`manifest::Manifest`]) then
//! pins each model name to an exact (weights-hash, precision-fingerprint,
//! plan-fingerprint) triple, which is the unit of deploy: flip the pin,
//! and the serve layer hot-swaps to the new object at a batch boundary.
//! [`lru::ByteLru`] bounds how many cold `BoundPlan`s stay resident, and
//! [`ModelStore::gc`] reclaims objects that are neither pinned now nor
//! were pinned within the last N deploys (the manifest keeps a pin
//! history for exactly this).

pub mod digest;
pub mod lru;
pub mod manifest;

pub use digest::{digest_file, digest_hex, Digest256};
pub use lru::ByteLru;
pub use manifest::{plan_fingerprint, DeployPin, Manifest};

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::checkpoint;

/// What a [`ModelStore::gc`] pass did (or, dry-run, would do).
#[derive(Debug, Default)]
pub struct GcReport {
    /// Digests removed (or listed, under `dry_run`), in sorted order.
    pub deleted: Vec<String>,
    /// Objects that survived (pinned now, or pinned recently enough).
    pub kept: usize,
    /// Bytes of object + sidecar files freed (would-free under `dry_run`).
    pub bytes_freed: u64,
    pub dry_run: bool,
}

/// On-disk content-addressed store plus its manifest.
pub struct ModelStore {
    root: PathBuf,
    manifest: Manifest,
}

impl ModelStore {
    /// Open (creating if needed) the store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<ModelStore> {
        let root = root.into();
        std::fs::create_dir_all(root.join("objects"))
            .with_context(|| format!("creating store at {}", root.display()))?;
        let manifest = Manifest::load(&root.join("manifest.json"))?;
        Ok(ModelStore { root, manifest })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    /// Path an object with this digest lives at (whether or not present).
    pub fn object_path(&self, digest: &str) -> PathBuf {
        self.root.join("objects").join(format!("{digest}.ckpt"))
    }

    /// Ingest a checkpoint file: hash its bytes, commit them (and the
    /// `.meta.json` sidecar, if present) under `objects/<digest>`, and
    /// return the digest. Idempotent — re-adding identical bytes lands on
    /// the existing object and is a no-op copy.
    pub fn put_checkpoint(&self, src: &Path) -> Result<String> {
        let key = digest_file(src)?;
        let dst = self.object_path(&key);
        if !dst.exists() {
            let bytes =
                std::fs::read(src).with_context(|| format!("reading {}", src.display()))?;
            checkpoint::commit_bytes(&dst, &bytes)
                .with_context(|| format!("storing object {key}"))?;
        }
        let meta_src = src.with_extension("meta.json");
        let meta_dst = dst.with_extension("meta.json");
        if meta_src.exists() && !meta_dst.exists() {
            let bytes = std::fs::read(&meta_src)
                .with_context(|| format!("reading {}", meta_src.display()))?;
            checkpoint::commit_bytes(&meta_dst, &bytes)
                .with_context(|| format!("storing meta for object {key}"))?;
        }
        Ok(key)
    }

    /// Pin a deploy and persist the manifest in one step. The object must
    /// already be in the store — pinning a hash the store can't serve
    /// would turn into a load-time error at the worst possible moment.
    pub fn pin_deploy(&mut self, pin: DeployPin) -> Result<Option<DeployPin>> {
        let obj = self.object_path(&pin.weights_hash);
        if !obj.exists() {
            bail!(
                "refusing to pin {:?} → {}: object not in store (put_checkpoint first)",
                pin.model,
                pin.weights_hash
            );
        }
        let replaced = self.manifest.pin(pin)?;
        self.manifest.save(&self.manifest_path())?;
        Ok(replaced)
    }

    /// Resolve a model name to its pinned deploy and the object's path.
    /// Missing pin and missing object are both hard errors — the store
    /// never falls back to "whatever file is newest".
    pub fn resolve(&self, model: &str) -> Result<(DeployPin, PathBuf)> {
        let pin = self.manifest.resolve(model)?.clone();
        let path = self.object_path(&pin.weights_hash);
        if !path.exists() {
            bail!(
                "manifest pins {model:?} → {} but the object is missing from {}",
                pin.weights_hash,
                self.root.display()
            );
        }
        Ok((pin, path))
    }

    /// Garbage-collect unreferenced objects: delete (or, with `dry_run`,
    /// merely list) every object that is neither currently pinned nor was
    /// pinned within the last `keep_deploys` deploys. Objects the manifest
    /// has never pinned are unreferenced at any `keep_deploys`. Deletion
    /// removes both the `.ckpt` object and its `.meta.json` sidecar; the
    /// manifest itself is never touched, so a gc can never un-deploy
    /// anything.
    pub fn gc(&self, keep_deploys: usize, dry_run: bool) -> Result<GcReport> {
        let live = self.manifest.live_hashes(keep_deploys);
        let mut report = GcReport { dry_run, ..GcReport::default() };
        for key in self.objects() {
            if live.contains(&key) {
                report.kept += 1;
                continue;
            }
            let obj = self.object_path(&key);
            let meta = obj.with_extension("meta.json");
            for path in [&obj, &meta] {
                if let Ok(md) = std::fs::metadata(path) {
                    report.bytes_freed += md.len();
                    if !dry_run {
                        std::fs::remove_file(path)
                            .with_context(|| format!("deleting {}", path.display()))?;
                    }
                }
            }
            report.deleted.push(key);
        }
        Ok(report)
    }

    /// Digests of all objects present, sorted (diagnostics / `store list`).
    pub fn objects(&self) -> Vec<String> {
        let Ok(entries) = std::fs::read_dir(self.root.join("objects")) else {
            return Vec::new();
        };
        let mut keys: Vec<String> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                let key = name.strip_suffix(".ckpt")?;
                digest::looks_like_digest(key).then(|| key.to_string())
            })
            .collect();
        keys.sort();
        keys
    }
}
