//! Byte-budgeted LRU for cold servables.
//!
//! The registry's cache used to grow without bound: every distinct
//! checkpoint ever loaded kept its `BoundPlan` (weights re-packed into
//! bit-planes, arena plan, schedule) resident forever. Under a store
//! that continuously publishes new generations of evolving models, that
//! is a slow memory leak in the serving fleet. `ByteLru` caps residency
//! by bytes, not entry count — a tinynet servable and a deep convnet
//! servable are nowhere near the same size — and evicts strictly
//! least-recently-used first.
//!
//! Values are `Arc`s: eviction drops the cache's reference, and the
//! backing memory is freed when in-flight requests holding the same Arc
//! drain. A servable mid-batch is never deallocated under a worker.

use std::collections::HashMap;
use std::sync::Arc;

/// An LRU keyed by string (content digest) holding `Arc<V>` values with
/// a caller-reported byte weight per entry.
pub struct ByteLru<V> {
    /// Entries in recency order: index 0 = least recently used.
    order: Vec<String>,
    map: HashMap<String, (Arc<V>, usize)>,
    budget_bytes: usize,
    resident_bytes: usize,
    evictions: u64,
}

impl<V> ByteLru<V> {
    /// `budget_bytes == 0` disables eviction (unbounded cache) — the
    /// pre-store behaviour, kept as the default so existing serve paths
    /// are unchanged unless a budget is asked for.
    pub fn new(budget_bytes: usize) -> ByteLru<V> {
        ByteLru {
            order: Vec::new(),
            map: HashMap::new(),
            budget_bytes,
            resident_bytes: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// Keys from least to most recently used (for diagnostics/tests).
    pub fn keys_lru_first(&self) -> Vec<String> {
        self.order.clone()
    }

    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    /// Look up and mark as most-recently-used.
    pub fn get(&mut self, key: &str) -> Option<Arc<V>> {
        let hit = self.map.get(key).map(|(v, _)| Arc::clone(v))?;
        self.touch(key);
        Some(hit)
    }

    /// Insert (or refresh) an entry, then evict LRU entries until the
    /// budget holds again. The entry just inserted is never evicted even
    /// if it alone exceeds the budget — the caller is about to use it,
    /// so evicting it would only thrash.
    pub fn insert(&mut self, key: &str, value: Arc<V>, bytes: usize) {
        if let Some((_, old_bytes)) = self.map.remove(key) {
            self.resident_bytes -= old_bytes;
            if let Some(pos) = self.order.iter().position(|k| k == key) {
                self.order.remove(pos);
            }
        }
        self.map.insert(key.to_string(), (value, bytes));
        self.order.push(key.to_string());
        self.resident_bytes += bytes;
        if self.budget_bytes > 0 {
            while self.resident_bytes > self.budget_bytes && self.order.len() > 1 {
                let victim = self.order.remove(0);
                if let Some((_, b)) = self.map.remove(&victim) {
                    self.resident_bytes -= b;
                    self.evictions += 1;
                }
            }
        }
    }

    /// Drop one entry by key (used when a pin is retired explicitly).
    /// Not counted as an eviction — evictions are budget-driven only.
    pub fn remove(&mut self, key: &str) -> bool {
        match self.map.remove(key) {
            Some((_, b)) => {
                self.resident_bytes -= b;
                if let Some(pos) = self.order.iter().position(|k| k == key) {
                    self.order.remove(pos);
                }
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lru(budget: usize) -> ByteLru<u32> {
        ByteLru::new(budget)
    }

    #[test]
    fn evicts_least_recently_used_first() {
        let mut c = lru(300);
        c.insert("a", Arc::new(1), 100);
        c.insert("b", Arc::new(2), 100);
        c.insert("c", Arc::new(3), 100);
        // Touch `a` so `b` becomes the LRU victim.
        assert!(c.get("a").is_some());
        c.insert("d", Arc::new(4), 100);
        assert!(!c.contains("b"), "LRU entry should have been evicted");
        assert!(c.contains("a") && c.contains("c") && c.contains("d"));
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.resident_bytes(), 300);
    }

    #[test]
    fn byte_budget_is_enforced_not_entry_count() {
        let mut c = lru(250);
        c.insert("small1", Arc::new(1), 50);
        c.insert("small2", Arc::new(2), 50);
        c.insert("big", Arc::new(3), 200);
        // 300 > 250: evict small1 (LRU), now 250 ≤ 250.
        assert!(!c.contains("small1"));
        assert!(c.contains("small2") && c.contains("big"));
        assert_eq!(c.resident_bytes(), 250);
    }

    #[test]
    fn oversized_entry_survives_alone() {
        let mut c = lru(100);
        c.insert("a", Arc::new(1), 60);
        c.insert("huge", Arc::new(2), 500);
        // `a` is evicted, but `huge` stays even though it busts the budget.
        assert!(!c.contains("a"));
        assert!(c.contains("huge"));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_budget_means_unbounded() {
        let mut c = lru(0);
        for i in 0..64 {
            c.insert(&format!("k{i}"), Arc::new(i), 1 << 20);
        }
        assert_eq!(c.len(), 64);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn reinsert_updates_bytes_without_duplicating() {
        let mut c = lru(0);
        c.insert("a", Arc::new(1), 100);
        c.insert("a", Arc::new(2), 40);
        assert_eq!(c.len(), 1);
        assert_eq!(c.resident_bytes(), 40);
        assert_eq!(*c.get("a").unwrap(), 2);
        assert_eq!(c.keys_lru_first(), vec!["a".to_string()]);
    }

    #[test]
    fn remove_is_not_an_eviction() {
        let mut c = lru(1000);
        c.insert("a", Arc::new(1), 100);
        assert!(c.remove("a"));
        assert!(!c.remove("a"));
        assert_eq!(c.evictions(), 0);
        assert_eq!(c.resident_bytes(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn evicted_arc_stays_alive_while_held() {
        let mut c = lru(100);
        c.insert("a", Arc::new(7), 80);
        let held = c.get("a").unwrap();
        c.insert("b", Arc::new(8), 80);
        assert!(!c.contains("a"));
        // The in-flight reference still resolves — eviction never frees
        // memory under a request.
        assert_eq!(*held, 7);
    }
}
