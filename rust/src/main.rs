//! bsq-repro — leader binary for the BSQ (ICLR 2021) reproduction.
//!
//! Subcommands:
//!   bsq         run the full BSQ pipeline on one model/α
//!   dorefa      DoReFa QAT from scratch at a uniform precision
//!   hawq        Hessian-importance analysis of a pretrained model
//!   eval        evaluate a checkpoint
//!   experiment  regenerate a paper table/figure (table1…table7, fig2…fig9, all)
//!   info        list models/artifacts and their shapes plus the compiled
//!               layer-graph summary (node kinds, fusion, arena-vs-naive
//!               activation bytes); with --checkpoint, the serving
//!               registry's per-layer effective-precision map
//!   serve-bench closed-loop batched-serving sweep → BENCH_serve.json;
//!               with --swap, each cell hot-swaps to a second checkpoint
//!               mid-run and records the swap telemetry
//!   ingress-bench
//!               boot the HTTP ingress (DESIGN.md §15) and drive it with
//!               an open-loop Poisson load sweep past saturation: reports
//!               the latency/throughput knee, shed rates, and per-tenant
//!               quota behaviour, merged into BENCH_serve.json
//!   store       content-addressed model store: `add` ingests a checkpoint
//!               (keyed by its own bytes) and pins the deploy, `list`
//!               shows objects + pins, `resolve` prints a model's pin,
//!               `gc` deletes objects not pinned within --keep-deploys
//!               deploys (--dry-run to preview)
//!   bench-diff  compare two BENCH_*.json records, exit non-zero on a
//!               regression past --tolerance-pct (CI's bench gate)
//!
//! Training commands take `--shards N` (0 = auto: available parallelism) —
//! the native train step fans each minibatch across N data-parallel shards
//! with bit-identical results at any N (DESIGN.md §10).
//!
//! Robustness knobs (DESIGN.md §12): `bsq --snapshot-dir D [--snapshot-keep
//! N]` writes a crash-safe snapshot after every epoch and `--resume`
//! continues from the newest good one with a bit-identical trajectory;
//! `--faults "point[#key]@nth:kind[=arg];..."` arms deterministic fault
//! injection on `bsq` and `serve-bench` for chaos drills.
//!
//! Overlap knobs (DESIGN.md §16): re-quantization rebuilds concurrently
//! with the epoch-end eval and batches prefetch on a background thread by
//! default; `bsq --sync-requant` (or BSQ_SYNC_REQUANT=1) forces the
//! pause-the-world ordering and `--prefetch-depth 0` the synchronous
//! loader — both are bitwise trajectory-invariant.
//!
//! Examples:
//!   bsq-repro bsq --model resnet20 --alpha 5e-3 --act-bits 4 --shards 4
//!   bsq-repro bsq --model tinynet --snapshot-dir results/snap \
//!       --publish-store results/store
//!   bsq-repro experiment table1 --alphas 3e-3,5e-3,2e-2
//!   bsq-repro experiment all --epochs-scale 0.5
//!   bsq-repro hawq --model resnet20
//!   bsq-repro serve-bench --model tinynet --batches 1,8,32 --workers 1,4
//!   bsq-repro serve-bench --model tinynet --swap
//!   bsq-repro ingress-bench --model tinynet --load-factors 0.5,1.0,1.5 \
//!       --quota-rps 50 --conns 16
//!   bsq-repro store add --root results/store --model tinynet \
//!       --checkpoint results/ckpt/serve.ckpt
//!   bsq-repro store resolve --root results/store --model tinynet
//!   bsq-repro info --model tinynet --checkpoint results/ckpt/serve.ckpt
//!   bsq-repro bench-diff ci/baselines/BENCH_gemm.smoke.json \
//!       rust/BENCH_gemm.smoke.json --tolerance-pct 25

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use bsq::baselines::{self, QatConfig};
use bsq::coordinator::{run_bsq, write_result, BsqConfig, Session};
use bsq::experiments::{self, ExpOpts};
use bsq::model::ModelState;
use bsq::quant::{QuantScheme, Reweigh};
use bsq::runtime::Engine;
use bsq::serve;
use bsq::util::cli::Args;

fn main() {
    bsq::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: bsq-repro <bsq|dorefa|hawq|eval|experiment|info|serve-bench|ingress-bench|store|\
         bench-diff> [flags]\n\
         every subcommand and flag is documented in rust/CLI.md"
    );
    std::process::exit(2);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    let cmd = match args.take_positional(0) {
        Some(c) => c,
        None => usage(),
    };
    match cmd.as_str() {
        "bsq" => cmd_bsq(args),
        "dorefa" => cmd_dorefa(args),
        "hawq" => cmd_hawq(args),
        "eval" => cmd_eval(args),
        "experiment" => cmd_experiment(args),
        "info" => cmd_info(args),
        "serve-bench" => cmd_serve_bench(args),
        "ingress-bench" => cmd_ingress_bench(args),
        "store" => cmd_store(args),
        "bench-diff" => cmd_bench_diff(args),
        _ => usage(),
    }
}

/// Engine for a training command: CPU backend with the `--shards` knob
/// applied (0 = auto: available parallelism; results are shard-count
/// invariant, so this only trades threads for wall clock).
fn training_engine(args: &mut Args) -> Result<Engine> {
    let shards: usize = args.get_or("shards", 0)?;
    Ok(Engine::cpu()?.with_shards(shards))
}

fn bsq_cfg_from_args(args: &mut Args) -> Result<BsqConfig> {
    let model = args.str_or("model", "resnet20")?;
    let mut cfg = BsqConfig::for_model(&model);
    cfg.alpha = args.get_or("alpha", cfg.alpha)?;
    cfg.act_bits = args.get_or("act-bits", cfg.act_bits)?;
    cfg.act_first_last = args.get_or("act-first-last", cfg.act_first_last)?;
    cfg.init_bits = args.get_or("init-bits", cfg.init_bits)?;
    cfg.pretrain_epochs = args.get_or("pretrain-epochs", cfg.pretrain_epochs)?;
    cfg.bsq_epochs = args.get_or("bsq-epochs", cfg.bsq_epochs)?;
    cfg.finetune_epochs = args.get_or("finetune-epochs", cfg.finetune_epochs)?;
    cfg.requant_interval = args.get_or("requant-interval", cfg.requant_interval)?;
    cfg.weight_decay = args.get_or("weight-decay", cfg.weight_decay)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.train_size = args.get_or("train-size", cfg.train_size)?;
    cfg.test_size = args.get_or("test-size", cfg.test_size)?;
    cfg.eval_batches = args.get_or("eval-batches", cfg.eval_batches)?;
    cfg.alpha_ref_steps = args.get_or("alpha-ref-steps", cfg.alpha_ref_steps)?;
    if args.flag("no-reweigh") {
        cfg.reweigh = Reweigh::None;
    }
    if args.flag("no-cache") {
        cfg.cache_pretrained = false;
    }
    let keep: usize = args.get_or("snapshot-keep", 3)?;
    let publish = args.opt_str("publish-store")?;
    if let Some(dir) = args.opt_str("snapshot-dir")? {
        let mut scfg = bsq::coordinator::SnapshotCfg::new(dir);
        scfg.keep = keep.max(1);
        scfg.publish = publish.map(PathBuf::from);
        cfg.snapshot = Some(scfg);
    } else if publish.is_some() {
        bail!("--publish-store needs --snapshot-dir (publication rides the epoch snapshots)");
    }
    cfg.resume = args.flag("resume");
    if cfg.resume && cfg.snapshot.is_none() {
        bail!("--resume needs --snapshot-dir (where should the snapshots come from?)");
    }
    // Overlap knobs (DESIGN.md §16): both are trajectory-invariant, so
    // they sit outside the config fingerprint and can differ across a
    // kill/resume pair.
    if args.flag("sync-requant") {
        cfg.sync_requant = true;
    }
    cfg.prefetch_depth = args.get_or("prefetch-depth", cfg.prefetch_depth)?;
    Ok(cfg)
}

/// Arm the global fault-injection plane from `--faults <schedule>`
/// (grammar: `point[#key]@nth:kind[=arg];...` — see `bsq::faults`).
fn install_faults(args: &mut Args) -> Result<()> {
    if let Some(spec) = args.opt_str("faults")? {
        let schedule = bsq::faults::Schedule::parse(&spec)?;
        log::warn!("fault injection armed: {schedule}");
        bsq::faults::install_global(schedule);
    }
    Ok(())
}

fn cmd_bsq(mut args: Args) -> Result<()> {
    let cfg = bsq_cfg_from_args(&mut args)?;
    let out = args.str_or("out", "results/bsq_run.json")?;
    install_faults(&mut args)?;
    let engine = training_engine(&mut args)?;
    args.finish()?;
    let outcome = run_bsq(&engine, &cfg)?;
    println!("{}", outcome.scheme);
    println!(
        "acc before finetune {:.2}%  after {:.2}%  ({:.2} bits/param, {:.2}x)",
        100.0 * outcome.acc_before_ft,
        100.0 * outcome.acc_after_ft,
        outcome.bits_per_param,
        outcome.compression
    );
    write_result(std::path::Path::new(&out), &outcome.to_json())?;
    println!("record written to {out}");
    Ok(())
}

fn cmd_dorefa(mut args: Args) -> Result<()> {
    let model = args.str_or("model", "resnet20")?;
    let bits: usize = args.get_or("bits", 3)?;
    let act_bits: usize = args.get_or("act-bits", 4)?;
    let epochs: usize = args.get_or("epochs", 12)?;
    let train_size: usize = args.get_or("train-size", 1024)?;
    let test_size: usize = args.get_or("test-size", 512)?;
    let seed: u64 = args.get_or("seed", 0)?;
    let engine = training_engine(&mut args)?;
    args.finish()?;

    let session = Session::open(&engine, &model, train_size, test_size, seed)?;
    let names: Vec<(String, usize)> =
        session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
    let scheme = QuantScheme::uniform(&names, bits);
    let out = baselines::dorefa::train_from_scratch(
        &session,
        &scheme,
        &QatConfig::from_scratch(epochs, act_bits, seed),
    )?;
    println!(
        "DoReFa {model} w{bits}a{act_bits}: final acc {:.2}% (best {:.2}%), comp {:.2}x",
        100.0 * out.final_acc,
        100.0 * out.best_acc,
        scheme.compression()
    );
    Ok(())
}

fn cmd_hawq(mut args: Args) -> Result<()> {
    let model = args.str_or("model", "resnet20")?;
    let ckpt = args.opt_str("checkpoint")?;
    let train_size: usize = args.get_or("train-size", 512)?;
    let iters: usize = args.get_or("power-iters", 6)?;
    args.finish()?;

    let engine = Engine::cpu()?;
    let session = Session::open(&engine, &model, train_size, 128, 0)?;
    let state = match ckpt {
        Some(p) => bsq::model::checkpoint::load(std::path::Path::new(&p))?,
        None => {
            log::warn!("no --checkpoint given; analyzing a freshly initialized model");
            ModelState::init_fp(&session.man, 0)
        }
    };
    let report = baselines::hawq::analyze(
        &session,
        &state,
        &baselines::HawqConfig { power_iters: iters, ..Default::default() },
    )?;
    println!("{:<12} {:>12} {:>14}", "layer", "λ_max", "S = λ/n");
    for (i, q) in session.man.qlayers.iter().enumerate() {
        println!("{:<12} {:>12.4e} {:>14.4e}", q.name, report.eigenvalues[i], report.importance[i]);
    }
    println!("ranking (most → least important): {:?}", report.ranking);
    Ok(())
}

fn cmd_eval(mut args: Args) -> Result<()> {
    let model = args.str_or("model", "resnet20")?;
    let ckpt = args.opt_str("checkpoint")?.context("--checkpoint required")?;
    let act_bits: usize = args.get_or("act-bits", 4)?;
    let test_size: usize = args.get_or("test-size", 512)?;
    args.finish()?;

    let engine = Engine::cpu()?;
    let session = Session::open(&engine, &model, 64, test_size, 0)?;
    let mut state = bsq::model::checkpoint::load(std::path::Path::new(&ckpt))?;
    let bit_mode = state.contains(&format!("wp:{}", session.man.qlayers[0].name));
    let exe = session.artifact(if bit_mode { "q_eval_relu6" } else { "fp_eval_relu6" })?;
    let actlv = session.act_levels(act_bits, 8);
    let (loss, acc) = session.evaluate(
        &exe,
        &mut state,
        &bsq::runtime::RunInputs::default().vec("actlv", actlv),
        usize::MAX,
    )?;
    let kind = if bit_mode { "bit-rep" } else { "fp" };
    println!("{model} ({kind}): loss {loss:.4} acc {:.2}%", 100.0 * acc);
    Ok(())
}

fn cmd_experiment(mut args: Args) -> Result<()> {
    let id = args.take_positional(1).unwrap_or_else(|| "all".to_string());
    let mut opts = ExpOpts::default();
    opts.epochs_scale = args.get_or("epochs-scale", 1.0f32)?;
    opts.data_scale = args.get_or("data-scale", 1.0f32)?;
    opts.alphas = args.list("alphas")?;
    if let Some(seeds) = args.list::<u64>("seeds")? {
        opts.seeds = seeds;
    }
    if let Some(out) = args.opt_str("out-dir")? {
        opts.out_dir = out.into();
    }
    let engine = training_engine(&mut args)?;
    args.finish()?;
    experiments::run(&engine, &id, &opts)
}

fn cmd_bench_diff(mut args: Args) -> Result<()> {
    let baseline = args
        .take_positional(1)
        .context("usage: bsq-repro bench-diff <baseline.json> <current.json>")?;
    let current = args
        .take_positional(2)
        .context("usage: bsq-repro bench-diff <baseline.json> <current.json>")?;
    let tolerance: f64 = args.get_or("tolerance-pct", 25.0)?;
    args.finish()?;
    let report = bsq::util::benchdiff::compare_files(
        std::path::Path::new(&baseline),
        std::path::Path::new(&current),
        tolerance,
    )?;
    print!("{}", report.table());
    if report.failed() {
        bail!(
            "bench gate failed: {} regression(s) past +{tolerance}% and {} missing metric(s) \
             against {baseline}",
            report.regressions(),
            report.missing.len()
        );
    }
    println!("bench gate passed ({} metrics within +{tolerance}%)", report.rows.len());
    Ok(())
}

/// Per-layer effective-precision table of a loaded servable — the
/// registry-backed half of `info` and the header of `serve-bench`.
fn print_precision_map(sv: &serve::ServableModel) {
    println!(
        "{} @ {}  (serving registry)",
        sv.model_name,
        sv.checkpoint.display()
    );
    println!(
        "{:<12} {:>6} {:>9} {:>8} {:>10} {:>9} {:>10} {:>12}",
        "layer", "kind", "params", "nominal", "effective", "occupied", "set-bits", "bits/weight"
    );
    for l in &sv.layers {
        println!(
            "{:<12} {:>6} {:>9} {:>8} {:>10} {:>9} {:>10} {:>12.3}",
            l.name,
            l.kind,
            l.params,
            l.nominal_bits,
            l.effective_bits,
            l.occupied_planes,
            l.nnz_bits,
            l.bits_per_weight()
        );
    }
    println!(
        "total: {} set weight bits/sample, {:.2} mean effective bits/param",
        sv.weight_bits(),
        sv.mean_effective_bits()
    );
    println!("kernel backend: {}", sv.kernel_backend());
    let p = sv.plan();
    println!(
        "serve plan: {} nodes ({} fused conv-bn-act, {} dead layers elided), arena {} f32/sample \
         vs naive {} f32/sample",
        p.schedule_len(),
        p.fused,
        sv.elided_layers(),
        p.arena_elems,
        p.naive_elems
    );
}

fn cmd_serve_bench(mut args: Args) -> Result<()> {
    let model = args.str_or("model", "tinynet")?;
    let ckpt = args.opt_str("checkpoint")?;
    let batches = args.list::<usize>("batches")?.unwrap_or_else(|| vec![1, 8, 32]);
    let workers = args.list::<usize>("workers")?.unwrap_or_else(|| vec![1, 4]);
    let requests: usize = args.get_or("requests", 256)?;
    let max_wait_ms: f64 = args.get_or("max-wait-ms", 2.0)?;
    let act_bits: usize = args.get_or("act-bits", 4)?;
    let bits: usize = args.get_or("bits", 8)?; // synthesis precision
    let seed: u64 = args.get_or("seed", 0)?;
    let out = args.opt_str("out")?;
    let swap = args.flag("swap");
    install_faults(&mut args)?;
    args.finish()?;
    if batches.is_empty() || workers.is_empty() || requests == 0 {
        bail!("need non-empty --batches/--workers and --requests > 0");
    }

    let engine = Engine::cpu()?;
    let ckpt_path = match ckpt {
        Some(p) => PathBuf::from(p),
        None => {
            let p = PathBuf::from(format!("results/ckpt/serve_{model}_b{bits}_s{seed}.ckpt"));
            if !p.exists() {
                println!(
                    "no --checkpoint given; synthesizing a quantized {model} checkpoint at {}",
                    p.display()
                );
                serve::synthesize_quantized_checkpoint(&engine, &model, bits, seed, &p)?;
            }
            p
        }
    };
    let registry = serve::Registry::new(&engine);
    let servable = registry.load(&model, &ckpt_path, act_bits, 8)?;
    print_precision_map(&servable);

    println!("== serve-bench: closed-loop sweep ({requests} requests per cell) ==");
    let max_wait = Duration::from_secs_f64(max_wait_ms / 1e3);
    let cells = if swap {
        // Hot-swap mode: synthesize a second checkpoint (same geometry,
        // different weights) and install it mid-run in every cell.
        let next_path =
            PathBuf::from(format!("results/ckpt/serve_{model}_b{bits}_s{}_next.ckpt", seed));
        if !next_path.exists() {
            serve::synthesize_quantized_checkpoint(&engine, &model, bits, seed + 1, &next_path)?;
        }
        let next = registry.load(&model, &next_path, act_bits, 8)?;
        println!(
            "swap mode: each cell hot-swaps to {} ({}…) at a batch boundary",
            next_path.display(),
            &next.weights_digest[..16]
        );
        serve::sweep_swapped(&servable, &next, &batches, &workers, requests, max_wait, seed)?
    } else {
        serve::sweep(&servable, &batches, &workers, requests, max_wait, seed)?
    };
    for cell in &cells {
        println!(
            "batch {:>3} × {} workers: {}",
            cell.max_batch,
            cell.workers,
            cell.summary.report()
        );
    }

    let json = serve::sweep_json(&servable, &cells);
    let path = match out {
        Some(p) => {
            let p = PathBuf::from(p);
            std::fs::write(&p, json.to_string_pretty() + "\n")?;
            p
        }
        None => serve::write_bench_json(&json)?,
    };
    println!("wrote {}", path.display());
    Ok(())
}

/// `ingress-bench` — boot the HTTP ingress on a loopback port and sweep an
/// open-loop Poisson load across it (DESIGN.md §15): calibrate capacity
/// closed-loop, then offer `--load-factors` multiples of it and record
/// coordinated-omission-corrected latency, shed rates, and the saturation
/// knee into the `BENCH_serve.json` record (merging with a prior
/// `serve-bench` run when one exists, so both sweeps gate together).
fn cmd_ingress_bench(mut args: Args) -> Result<()> {
    let model = args.str_or("model", "tinynet")?;
    let ckpt = args.opt_str("checkpoint")?;
    let bits: usize = args.get_or("bits", 8)?; // synthesis precision
    let act_bits: usize = args.get_or("act-bits", 4)?;
    let seed: u64 = args.get_or("seed", 0)?;
    let workers: usize = args.get_or("workers", 4)?;
    let max_batch: usize = args.get_or("max-batch", 8)?;
    let max_wait_ms: f64 = args.get_or("max-wait-ms", 2.0)?;
    let requests: usize = args.get_or("requests", 512)?;
    let calib_requests: usize = args.get_or("calib-requests", 256)?;
    let conns: usize = args.get_or("conns", 16)?;
    let factors = args
        .list::<f64>("load-factors")?
        .unwrap_or_else(|| vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5]);
    let tenants: usize = args.get_or("tenants", 4)?;
    let high_frac: f64 = args.get_or("high-frac", 0.1)?;
    let quota_rps: Option<f64> = args.opt("quota-rps")?;
    let quota_burst: f64 = args.get_or("quota-burst", 32.0)?;
    let reserve_frac: f64 = args.get_or("reserve-frac", 0.25)?;
    let retry_after_ms: u64 = args.get_or("retry-after-ms", 250)?;
    let out = args.opt_str("out")?;
    install_faults(&mut args)?;
    args.finish()?;
    if factors.is_empty() || requests == 0 || calib_requests == 0 || conns == 0 {
        bail!("need non-empty --load-factors and --requests/--calib-requests/--conns > 0");
    }
    if factors.iter().any(|&f| !(f.is_finite() && f > 0.0)) {
        bail!("--load-factors must be positive");
    }

    let engine = Engine::cpu()?;
    let ckpt_path = match ckpt {
        Some(p) => PathBuf::from(p),
        None => {
            let p = PathBuf::from(format!("results/ckpt/serve_{model}_b{bits}_s{seed}.ckpt"));
            if !p.exists() {
                println!(
                    "no --checkpoint given; synthesizing a quantized {model} checkpoint at {}",
                    p.display()
                );
                serve::synthesize_quantized_checkpoint(&engine, &model, bits, seed, &p)?;
            }
            p
        }
    };
    // Load once up front for the precision map and the load generator's
    // sample geometry; the ingress registry re-loads by content digest.
    let registry = serve::Registry::new(&engine);
    let servable = registry.load(&model, &ckpt_path, act_bits, 8)?;
    print_precision_map(&servable);

    let routes = vec![serve::RouteSpec {
        model: model.clone(),
        source: serve::RouteSource::Checkpoint(ckpt_path),
        act_bits,
        act_first_last: 8,
    }];
    let pool_cfg = serve::PoolConfig::new(
        workers.max(1),
        serve::BatchPolicy {
            max_batch: max_batch.max(1),
            max_wait: Duration::from_secs_f64(max_wait_ms.max(0.0) / 1e3),
        },
    );
    let ingress_cfg = serve::IngressConfig {
        // Headroom over the client pool so load-gen reconnects never trip
        // the connection bound.
        max_conns: conns * 2 + 8,
        admission: serve::ingress::admission::AdmissionCfg {
            reserve_frac,
            quota: quota_rps.map(|r| serve::ingress::admission::QuotaCfg {
                rate_per_sec: r,
                burst: quota_burst,
            }),
            retry_after: Duration::from_millis(retry_after_ms),
            ..Default::default()
        },
        ..Default::default()
    };
    let lg = serve::ingress::loadgen::LoadGenCfg {
        model: model.clone(),
        sample_elems: servable.sample_elems(),
        conns,
        tenants: tenants.max(1),
        high_frac: high_frac.clamp(0.0, 1.0),
        seed,
    };

    println!(
        "== ingress-bench: open-loop sweep ({requests} requests per point, {} workers × batch {}) ==",
        pool_cfg.workers, pool_cfg.policy.max_batch
    );
    let (report, sweep) = serve::run_ingress(&engine, &routes, &pool_cfg, &ingress_cfg, |h| {
        let addr = h.addr();
        println!("ingress listening on {addr}");
        let calibrated = serve::ingress::loadgen::calibrate(addr, &lg, calib_requests)?;
        println!("calibrated capacity ≈ {calibrated:.0} req/s ({calib_requests} closed-loop requests)");
        let mut points = Vec::new();
        for &f in &factors {
            let label = format!("{f:.2}x");
            let p = serve::ingress::loadgen::run_point(addr, &lg, &label, calibrated * f, requests)?;
            println!(
                "offered {:>8.1} rps ({label}): achieved {:>8.1} rps, ok {}/{}, shed {}+{}, \
                 err {}, mean {:.0}µs p99 {:.0}µs{}",
                p.offered_rps,
                p.achieved_rps,
                p.ok,
                p.requests,
                p.shed_queue,
                p.shed_quota,
                p.errors,
                p.mean_us,
                p.p99_us,
                if p.kept_up() { "" } else { "  [over knee]" }
            );
            points.push(p);
        }
        anyhow::Ok((calibrated, points))
    })?;
    let (calibrated, points) = sweep?;
    let knee = serve::ingress::loadgen::find_knee(&points);
    match knee {
        Some(k) => println!(
            "knee: {} offered {:.1} rps → achieved {:.1} rps",
            points[k].label, points[k].offered_rps, points[k].achieved_rps
        ),
        None => println!("knee: none — every offered point overloaded the server"),
    }
    println!(
        "ingress totals: {} conns ({} rejected), {} served, {} shed-queue, {} shed-quota, \
         {} rejected, {} failed",
        report.conns,
        report.conns_rejected,
        report.served,
        report.shed_queue,
        report.shed_quota,
        report.rejected,
        report.failed
    );

    let path = match out {
        Some(p) => PathBuf::from(p),
        None => std::env::var_os("BSQ_BENCH_OUT")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("BENCH_serve.json")),
    };
    let existing = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| bsq::util::json::parse(&s).ok());
    let json = serve::ingress::loadgen::merge_bench_json(
        existing,
        &model,
        servable.weight_bits(),
        calibrated,
        &points,
        knee,
        &report,
    );
    std::fs::write(&path, json.to_string_pretty() + "\n")?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `store <add|list|resolve|gc>` — operate on a content-addressed model
/// store (DESIGN.md §14). `add` ingests a checkpoint under its content
/// digest and pins the model's deploy to it; `list` shows objects and
/// pins; `resolve` prints what a model name currently serves; `gc`
/// deletes objects that are neither pinned nor were pinned within the
/// last `--keep-deploys` deploys (`--dry-run` lists without deleting).
fn cmd_store(mut args: Args) -> Result<()> {
    let op = args
        .take_positional(1)
        .context("usage: bsq-repro store <add|list|resolve|gc> --root DIR [flags]")?;
    let root = args.str_or("root", "results/store")?;
    match op.as_str() {
        "add" => {
            let ckpt = args.opt_str("checkpoint")?.context("store add needs --checkpoint")?;
            let model = args.opt_str("model")?.context("store add needs --model")?;
            let act_bits: usize = args.get_or("act-bits", 4)?;
            let act_first_last: usize = args.get_or("act-first-last", 8)?;
            args.finish()?;
            let engine = Engine::cpu()?;
            let publisher =
                bsq::coordinator::StorePublisher::new(&engine, &root, &model, act_bits, act_first_last);
            let digest = publisher.publish_as(std::path::Path::new(&ckpt), "cli")?;
            println!("{model} pinned to {digest}");
            println!("object: {}", bsq::store::ModelStore::open(&root)?.object_path(&digest).display());
        }
        "list" => {
            args.finish()?;
            let store = bsq::store::ModelStore::open(&root)?;
            let objects = store.objects();
            println!("{} object(s) at {}", objects.len(), store.root().display());
            for key in &objects {
                println!("  {key}");
            }
            let pins = store.manifest().pins();
            println!("{} pin(s):", pins.len());
            for p in pins {
                println!(
                    "  {:<14} → {}  (precision {}, plan {}, a{}f{}, from {})",
                    p.model,
                    &p.weights_hash[..16],
                    p.precision_fp,
                    p.plan_fp,
                    p.act_bits,
                    p.act_first_last,
                    p.source
                );
            }
        }
        "resolve" => {
            let model = args.opt_str("model")?.context("store resolve needs --model")?;
            args.finish()?;
            let store = bsq::store::ModelStore::open(&root)?;
            let (pin, path) = store.resolve(&model)?;
            println!("{model} → {}", pin.weights_hash);
            println!("  object:       {}", path.display());
            println!("  precision_fp: {}", pin.precision_fp);
            println!("  plan_fp:      {}", pin.plan_fp);
            println!("  activations:  a{} first/last {}", pin.act_bits, pin.act_first_last);
            println!("  source:       {}", pin.source);
        }
        "gc" => {
            let keep: usize = args.get_or("keep-deploys", 3)?;
            let dry_run = args.flag("dry-run");
            args.finish()?;
            let store = bsq::store::ModelStore::open(&root)?;
            let report = store.gc(keep, dry_run)?;
            let verb = if dry_run { "would delete" } else { "deleted" };
            println!(
                "{verb} {} object(s), kept {}, {} bytes freed (keep-deploys {keep})",
                report.deleted.len(),
                report.kept,
                report.bytes_freed
            );
            for key in &report.deleted {
                println!("  {key}");
            }
        }
        other => bail!("unknown store op {other:?} (want add, list, resolve, or gc)"),
    }
    Ok(())
}

fn cmd_info(mut args: Args) -> Result<()> {
    let ckpt = args.opt_str("checkpoint")?;
    let model_flag = args.opt_str("model")?;
    let act_bits: usize = args.get_or("act-bits", 4)?;
    args.finish()?;
    let engine = Engine::cpu()?;
    if let Some(ckpt) = ckpt {
        let model = model_flag.as_deref().unwrap_or("tinynet");
        let registry = serve::Registry::new(&engine);
        let sv = registry.load(model, std::path::Path::new(&ckpt), act_bits, 8)?;
        print_precision_map(&sv);
        return Ok(());
    }
    // Without --checkpoint, --model narrows the listing to one model.
    let manifests: Vec<bsq::runtime::Manifest> = if engine.is_native() {
        println!("backend: native (PJRT stub; manifests synthesized from the model zoo)");
        match &model_flag {
            Some(m) => vec![engine.manifest(m)?],
            None => bsq::runtime::native::models::model_names()
                .into_iter()
                .map(|m| engine.manifest(m))
                .collect::<Result<_>>()?,
        }
    } else {
        let root = bsq::runtime::artifacts_root();
        if !root.exists() {
            bail!("no artifacts at {} — run `make artifacts`", root.display());
        }
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&root)? {
            let dir = entry?.path();
            let keep = model_flag
                .as_deref()
                .map_or(true, |m| dir.file_name().map_or(false, |n| n == m));
            if keep && dir.join("manifest.json").exists() {
                out.push(bsq::runtime::Manifest::load(&dir)?);
            }
        }
        if let (Some(m), true) = (model_flag.as_deref(), out.is_empty()) {
            bail!("no artifacts for model {m:?} under {}", root.display());
        }
        out
    };
    for man in &manifests {
        println!(
            "{:<14} batch {:>3}  {:>2} layers  {:>9} params  {} artifacts",
            man.model,
            man.batch,
            man.qlayers.len(),
            man.total_params(),
            man.artifacts.len()
        );
        for (name, a) in &man.artifacts {
            println!("    {:<22} {:>3} in / {:>3} out", name, a.inputs.len(), a.outputs.len());
        }
        if engine.is_native() {
            print_graph_summary(&engine, man)?;
        }
    }
    Ok(())
}

fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    }
}

/// Compiled-graph summary of one native model: node count per op kind,
/// schedule length, and the memory planner's arena-vs-naive savings at the
/// model's manifest batch size.
fn print_graph_summary(engine: &Engine, man: &bsq::runtime::Manifest) -> Result<()> {
    let plans = engine.native_plans(&man.model)?;
    let counts = plans
        .train
        .graph
        .kind_counts()
        .into_iter()
        .map(|(k, c)| format!("{k} {c}"))
        .collect::<Vec<_>>()
        .join(", ");
    println!("    graph: {} nodes ({counts})", plans.train.schedule_len());
    let p = &plans.infer;
    println!(
        "    eval plan: schedule {} steps ({} fused conv-bn-act), arena {} vs naive {} \
         ({:.1}x reuse) + scratch {}  [batch {}]",
        p.schedule_len(),
        p.fused,
        fmt_bytes(p.arena_bytes(man.batch)),
        fmt_bytes(p.naive_bytes(man.batch)),
        p.naive_elems as f64 / p.arena_elems.max(1) as f64,
        fmt_bytes(p.scratch_bytes(man.batch)),
        man.batch
    );
    Ok(())
}
