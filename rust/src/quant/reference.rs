//! Reference scalar implementations of the §3.3 hot path.
//!
//! These are the original plane-strided, per-element loops that the packed
//! engine (`quant::packed`) replaced. They are kept verbatim as the ground
//! truth for differential testing (`tests/packed_diff.rs` asserts the packed
//! path produces bit-identical codes, masks, scales and reconstructed
//! weights) and as the baseline the §Perf pass in EXPERIMENTS.md measures
//! speedups against. Do not optimize this module — its value is that it is
//! the obviously-correct transcription of paper Eq. 2 / §3.3.

use anyhow::{bail, Result};

use crate::quant::adjust::AdjustReport;
use crate::quant::bitplane::{packed_mask, BitRep, NB};
use crate::tensor::Tensor;

/// Scalar Eq. 2: float weights → bit representation (plane-strided writes).
pub fn to_bitplanes(w: &Tensor, n: usize) -> Result<BitRep> {
    if n == 0 || n > NB {
        bail!("initial precision must be in 1..={NB}, got {n}");
    }
    let elems = w.len();
    let scale = w.max_abs().max(1e-12);
    let levels = ((1u64 << n) - 1) as f32;

    let mut wp = vec![0.0f32; NB * elems];
    let mut wn = vec![0.0f32; NB * elems];
    for (e, &v) in w.data().iter().enumerate() {
        let code = ((v.abs() / scale) * levels).round() as u64; // ≤ 2^n − 1
        let planes = if v >= 0.0 { &mut wp } else { &mut wn };
        for b in 0..n {
            if (code >> b) & 1 == 1 {
                planes[b * elems + e] = 1.0;
            }
        }
    }

    let mut pshape = vec![NB];
    pshape.extend_from_slice(w.shape());
    Ok(BitRep {
        wp: Tensor::new(pshape.clone(), wp)?,
        wn: Tensor::new(pshape, wn)?,
        mask: packed_mask(n),
        scale,
    })
}

/// Scalar reconstruction: per-element f64 accumulation over all NB planes.
pub fn from_bitplanes(rep: &BitRep) -> Tensor {
    let n = rep.bits();
    let elems = rep.wp.len() / NB;
    let wshape = rep.wp.shape()[1..].to_vec();
    if n == 0 {
        return Tensor::zeros(&wshape);
    }
    let delta = rep.delta() as f32;
    let mut out = vec![0.0f32; elems];
    let wp = rep.wp.data();
    let wn = rep.wn.data();
    let mask = rep.mask.data();
    for (e, slot) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for b in 0..NB {
            if mask[b] != 0.0 {
                acc += ((wp[b * elems + e] - wn[b * elems + e]) as f64) * (1u64 << b) as f64;
            }
        }
        *slot = (acc.round() as f32) * delta;
    }
    Tensor::new(wshape, out).unwrap()
}

/// Scalar signed integer codes (strided walk, f64 inner accumulator).
pub fn integer_codes(rep: &BitRep) -> Vec<i64> {
    let elems = rep.wp.len() / NB;
    let wp = rep.wp.data();
    let wn = rep.wn.data();
    let mask = rep.mask.data();
    let cap = (1i64 << NB) - 1;
    let mut codes = vec![0i64; elems];
    for (e, slot) in codes.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for b in 0..NB {
            if mask[b] != 0.0 {
                acc += ((wp[b * elems + e] - wn[b * elems + e]) as f64) * (1u64 << b) as f64;
            }
        }
        *slot = (acc.round() as i64).clamp(-cap, cap);
    }
    codes
}

/// Scalar plane re-split of signed codes (freshly allocated plane tensors).
pub fn planes_from_codes(codes: &[i64], wshape: &[usize], n: usize) -> (Tensor, Tensor) {
    let elems = codes.len();
    let mut wp = vec![0.0f32; NB * elems];
    let mut wn = vec![0.0f32; NB * elems];
    for (e, &v) in codes.iter().enumerate() {
        let mag = v.unsigned_abs();
        let planes = if v >= 0 { &mut wp } else { &mut wn };
        for b in 0..n.min(NB) {
            if (mag >> b) & 1 == 1 {
                planes[b * elems + e] = 1.0;
            }
        }
    }
    let mut pshape = vec![NB];
    pshape.extend_from_slice(wshape);
    (Tensor::new(pshape.clone(), wp).unwrap(), Tensor::new(pshape, wn).unwrap())
}

/// Scalar §3.3 re-quantization + precision adjustment (allocates fresh
/// planes via `planes_from_codes`; per-element max/trailing-zero scans).
pub fn requantize(rep: &mut BitRep) -> AdjustReport {
    let n = rep.bits();
    let wshape = rep.wp.shape()[1..].to_vec();
    if n == 0 {
        return AdjustReport { bits_before: 0, bits_after: 0, msb_trimmed: 0, lsb_trimmed: 0 };
    }

    let mut codes = integer_codes(rep);
    let mut delta = rep.delta();

    let max_mag = codes.iter().map(|v| v.unsigned_abs()).max().unwrap_or(0);
    if max_mag == 0 {
        rep.mask = packed_mask(0);
        let (wp, wn) = planes_from_codes(&codes, &wshape, 0);
        rep.wp = wp;
        rep.wn = wn;
        return AdjustReport { bits_before: n, bits_after: 0, msb_trimmed: n, lsb_trimmed: 0 };
    }

    let hi = 63 - max_mag.leading_zeros() as usize;
    let lsb = codes
        .iter()
        .filter(|&&v| v != 0)
        .map(|v| v.trailing_zeros() as usize)
        .min()
        .unwrap_or(0)
        .min(hi);

    if lsb > 0 {
        for v in &mut codes {
            *v >>= lsb;
        }
        delta *= (1u64 << lsb) as f64;
    }

    let n_after = hi - lsb + 1;
    debug_assert!(n_after <= NB);

    let (wp, wn) = planes_from_codes(&codes, &wshape, n_after);
    rep.wp = wp;
    rep.wn = wn;
    rep.mask = packed_mask(n_after);
    rep.scale = (delta * ((1u64 << n_after) - 1) as f64) as f32;

    AdjustReport {
        bits_before: n,
        bits_after: n_after,
        msb_trimmed: (n + 1).saturating_sub(n_after + lsb),
        lsb_trimmed: lsb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_roundtrip_sanity() {
        let w = Tensor::new(vec![3], vec![0.5, -0.25, 1.0]).unwrap();
        let rep = to_bitplanes(&w, 8).unwrap();
        let back = from_bitplanes(&rep);
        for (a, b) in w.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= 0.5 * rep.delta() as f32 + 1e-7);
        }
    }

    #[test]
    fn reference_requantize_trims() {
        let codes = vec![4i64, -8, 12];
        let (wp, wn) = planes_from_codes(&codes, &[3], 8);
        let mut rep = BitRep { wp, wn, mask: packed_mask(8), scale: 1.0 };
        let r = requantize(&mut rep);
        assert_eq!(r.lsb_trimmed, 2); // all codes divisible by 4
        assert_eq!(r.bits_after, 2); // 12>>2 = 3 → two bits
    }
}
