//! Mixed-precision quantization schemes: the object BSQ *produces*.
//!
//! A `QuantScheme` is the per-layer precision assignment plus parameter
//! counts; it computes the paper's reporting metrics (`#Bits per Para`,
//! `Comp (×)` vs the fp32 baseline) and formats the per-layer tables of
//! the paper's Figures 2–3/5–9 and Tables 6–7.

use std::fmt;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPrec {
    pub name: String,
    pub params: usize,
    pub bits: usize,
}

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct QuantScheme {
    pub layers: Vec<LayerPrec>,
}

impl QuantScheme {
    pub fn new(layers: Vec<LayerPrec>) -> QuantScheme {
        QuantScheme { layers }
    }

    pub fn uniform(names_params: &[(String, usize)], bits: usize) -> QuantScheme {
        QuantScheme {
            layers: names_params
                .iter()
                .map(|(name, params)| LayerPrec { name: name.clone(), params: *params, bits })
                .collect(),
        }
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_bits(&self) -> usize {
        self.layers.iter().map(|l| l.params * l.bits).sum()
    }

    /// Paper's "#Bits per Para": Σ pₗ·nₗ / Σ pₗ.
    pub fn bits_per_param(&self) -> f64 {
        let p = self.total_params();
        if p == 0 {
            return 0.0;
        }
        self.total_bits() as f64 / p as f64
    }

    /// Paper's "Comp (×)" vs the 32-bit float model: 32·Σpₗ / Σ pₗ·nₗ.
    pub fn compression(&self) -> f64 {
        let bits = self.total_bits();
        if bits == 0 {
            return f64::INFINITY;
        }
        32.0 * self.total_params() as f64 / bits as f64
    }

    pub fn bits_of(&self, name: &str) -> Result<usize> {
        match self.layers.iter().find(|l| l.name == name) {
            Some(l) => Ok(l.bits),
            None => bail!("layer {name:?} not in scheme"),
        }
    }

    /// Precision vector in layer order (the `wlv` companion is 2^n − 1).
    pub fn bits_vec(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.bits).collect()
    }

    /// Per-layer level counts 2^n − 1 as f32 (the `wlv` artifact input).
    pub fn levels_vec(&self) -> Vec<f32> {
        self.layers.iter().map(|l| ((1u64 << l.bits) - 1) as f32).collect()
    }

    /// Average-precision ranking: layers sorted by descending bits, used for
    /// the HAWQ consistency comparison (paper App. B.3 / Fig. 7).
    pub fn ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.layers.len()).collect();
        idx.sort_by(|&a, &b| {
            self.layers[b].bits.cmp(&self.layers[a].bits).then(a.cmp(&b))
        });
        idx
    }
}

impl fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{:<14} {:>10} {:>6}", "layer", "params", "bits")?;
        for l in &self.layers {
            writeln!(f, "{:<14} {:>10} {:>6}", l.name, l.params, l.bits)?;
        }
        write!(
            f,
            "total {:.2} bits/param, {:.2}x compression",
            self.bits_per_param(),
            self.compression()
        )
    }
}

/// Spearman rank correlation between two precision orderings — quantifies
/// the paper's Fig. 7 claim that BSQ's precision ranking tracks HAWQ's
/// Hessian-importance ranking.
///
/// Degenerate rankings (every value tied, so a rank ordering carries no
/// information — e.g. a uniform scheme, or HAWQ importances collapsing)
/// have zero rank variance; the correlation is defined as 0.0 there rather
/// than the 0/0 = NaN the raw formula produces. NaN *entries* are ranked
/// via the IEEE total order instead of panicking mid-sort.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ra = fractional_ranks(a);
    let rb = fractional_ranks(b);
    let mean = (n as f64 + 1.0) / 2.0;
    let (mut num, mut da, mut db) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let xa = ra[i] - mean;
        let xb = rb[i] - mean;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0; // constant ranking: no order to correlate with
    }
    num / (da * db).sqrt()
}

fn fractional_ranks(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    let mut idx: Vec<usize> = (0..n).collect();
    // total_cmp: NaN-carrying inputs get a deterministic rank instead of a
    // partial_cmp().unwrap() panic (NaNs sort above +inf and tie together)
    idx.sort_by(|&a, &b| v[a].total_cmp(&v[b]));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && v[idx[j + 1]].total_cmp(&v[idx[i]]).is_eq() {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scheme(bits: &[usize]) -> QuantScheme {
        QuantScheme::new(
            bits.iter()
                .enumerate()
                .map(|(i, &b)| LayerPrec { name: format!("l{i}"), params: 100 * (i + 1), bits: b })
                .collect(),
        )
    }

    #[test]
    fn uniform_8bit_is_4x_compression() {
        let s = scheme(&[8, 8, 8]);
        assert_eq!(s.bits_per_param(), 8.0);
        assert_eq!(s.compression(), 4.0);
    }

    #[test]
    fn mixed_precision_weights_by_params() {
        // 100 params @ 2 bits + 200 params @ 8 bits = 1800 bits / 300 params
        let s = scheme(&[2, 8]);
        assert!((s.bits_per_param() - 6.0).abs() < 1e-12);
        assert!((s.compression() - 32.0 * 300.0 / 1800.0).abs() < 1e-12);
    }

    #[test]
    fn zero_bit_layers_count_as_free() {
        let s = scheme(&[0, 4]);
        assert_eq!(s.total_bits(), 800);
        let dead = scheme(&[0, 0]);
        assert!(dead.compression().is_infinite());
    }

    #[test]
    fn levels_vec_matches_bits() {
        let s = scheme(&[0, 1, 3, 8]);
        assert_eq!(s.levels_vec(), vec![0.0, 1.0, 7.0, 255.0]);
    }

    #[test]
    fn ranking_sorts_by_bits_desc() {
        let s = scheme(&[3, 8, 5]);
        assert_eq!(s.ranking(), vec![1, 2, 0]);
    }

    #[test]
    fn spearman_perfect_and_inverse() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_constant_ranking_is_defined() {
        // regression: a constant ranking has zero rank variance; the raw
        // formula divides 0 by 0 — the result must be the defined 0.0
        let flat = [3.0, 3.0, 3.0, 3.0];
        let rising = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(spearman(&flat, &rising), 0.0);
        assert_eq!(spearman(&rising, &flat), 0.0);
        assert_eq!(spearman(&flat, &flat), 0.0);
        assert!(!spearman(&flat, &rising).is_nan());
        // NaN entries rank deterministically instead of panicking
        let with_nan = [1.0, f64::NAN, 2.0, f64::NAN];
        assert!(spearman(&with_nan, &rising).is_finite());
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn display_formats() {
        let s = scheme(&[4, 2]);
        let out = format!("{s}");
        assert!(out.contains("compression"));
        assert!(out.contains("l0"));
    }
}
