//! The BSQ quantization substrate: bit planes, precision adjustment,
//! scheme accounting and regularizer reweighing (paper §3, Eqs. 2–6).
//!
//! The §3.3 hot path (conversion, code extraction, re-quantization) runs on
//! the packed codes engine in [`packed`]; the original scalar loops are
//! retained verbatim in [`reference`] as the differential-testing ground
//! truth and perf baseline.

pub mod adjust;
pub mod bitplane;
pub mod packed;
pub mod reference;
pub mod regweight;
pub mod scheme;

pub use adjust::{requantize, requantize_into, AdjustReport};
pub use bitplane::{from_bitplanes, packed_mask, to_bitplanes, BitRep, NB};
pub use packed::{PackedCodes, PlaneBits};
pub use regweight::{reg_weights, Reweigh};
pub use scheme::{spearman, LayerPrec, QuantScheme};
