//! The BSQ quantization substrate: bit planes, precision adjustment,
//! scheme accounting and regularizer reweighing (paper §3, Eqs. 2–6).

pub mod adjust;
pub mod bitplane;
pub mod regweight;
pub mod scheme;

pub use adjust::{requantize, AdjustReport};
pub use bitplane::{from_bitplanes, packed_mask, to_bitplanes, BitRep, NB};
pub use regweight::{reg_weights, Reweigh};
pub use scheme::{spearman, LayerPrec, QuantScheme};
