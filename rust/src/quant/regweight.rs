//! Memory-consumption-aware regularizer reweighing (paper Eq. 5).
//!
//! The BSQ objective penalizes each layer's bit-level group Lasso with
//! `c_l = #Para(W^l) * #Bit(W^l) / #Para(W^{1:L})` so layers holding more
//! memory feel a stronger pull. The coordinator
//! recomputes this vector after every precision adjustment (the #Bit term
//! changes) and feeds it to the `bsq_train` artifact as the `regw` input.
//! The ablation of paper §4.1 / Figs. 2, 5, 6 switches to the unweighted
//! variant (`c_l = 1`).

use crate::quant::scheme::QuantScheme;

/// Reweighing policy for the B_GL regularizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reweigh {
    /// Paper Eq. 5: c_l = pₗ·nₗ / Σp.
    MemoryAware,
    /// Ablation baseline: c_l = 1 for every layer.
    None,
}

/// Compute the per-layer regularizer weights for the current scheme.
pub fn reg_weights(scheme: &QuantScheme, policy: Reweigh) -> Vec<f32> {
    match policy {
        Reweigh::None => vec![1.0; scheme.layers.len()],
        Reweigh::MemoryAware => {
            let total = scheme.total_params().max(1) as f64;
            scheme
                .layers
                .iter()
                .map(|l| ((l.params * l.bits) as f64 / total) as f32)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scheme::LayerPrec;

    fn scheme() -> QuantScheme {
        QuantScheme::new(vec![
            LayerPrec { name: "a".into(), params: 100, bits: 8 },
            LayerPrec { name: "b".into(), params: 300, bits: 4 },
            LayerPrec { name: "c".into(), params: 600, bits: 0 },
        ])
    }

    #[test]
    fn memory_aware_matches_eq5() {
        let w = reg_weights(&scheme(), Reweigh::MemoryAware);
        let total = 1000.0;
        assert_eq!(w, vec![800.0 / total, 1200.0 / total, 0.0]);
    }

    #[test]
    fn none_is_all_ones() {
        assert_eq!(reg_weights(&scheme(), Reweigh::None), vec![1.0; 3]);
    }

    #[test]
    fn bigger_layers_get_more_pressure() {
        // equal bits → weight proportional to parameter count
        let s = QuantScheme::new(vec![
            LayerPrec { name: "small".into(), params: 10, bits: 8 },
            LayerPrec { name: "large".into(), params: 1000, bits: 8 },
        ]);
        let w = reg_weights(&s, Reweigh::MemoryAware);
        assert!(w[1] > 50.0 * w[0]);
    }

    #[test]
    fn dead_layer_feels_no_pressure() {
        let w = reg_weights(&scheme(), Reweigh::MemoryAware);
        assert_eq!(w[2], 0.0);
    }
}
