//! Packed codes engine — the word-level representation behind the §3.3
//! re-quantization hot path (DESIGN.md §2).
//!
//! The training-time interface stays `BitRep` (f32 planes in [0, 2] are
//! trained variables), but everything the coordinator computes *about* a
//! layer between epochs factors through two compact views:
//!
//! * [`PackedCodes`] — the signed integer codes V_e as a flat `Vec<i16>`
//!   (|V| ≤ 2^NB − 1 = 511, so i16 holds NB = 9 magnitude bits + sign with
//!   headroom): 2 bytes/weight vs the 72 bytes/weight of the 2×NB f32
//!   plane slots.
//! * [`PlaneBits`] — a sign-split bitset view: one `u64` word per 64
//!   weights per plane (1 bit/weight — 64× smaller than an f32 plane row),
//!   supporting word-level reductions: popcount for per-plane occupancy,
//!   OR-reduction for all-zero-plane detection, and bulk plane-row shifts
//!   for LSB trimming.
//!
//! Exactness contract: every routine here reproduces the retained scalar
//! path (`quant::reference`) bit for bit. The only floating-point work is
//! the code rounding in [`accumulate_codes`], which performs the *same*
//! f64 operations in the *same* per-element order (ascending plane index)
//! as the reference — f64 addition is deterministic, so the rounded codes
//! are identical, not merely close. `tests/packed_diff.rs` enforces this
//! over randomized continuous-plane states.

use crate::quant::bitplane::{packed_mask, BitRep, NB};
use crate::tensor::Tensor;

/// Plane capacity: |code| ≤ 2^NB − 1.
pub const CODE_CAP: i16 = ((1i32 << NB) - 1) as i16;

/// Per-layer signed integer codes plus the scheme scalars — the compact
/// re-quantization currency (2 bytes/weight).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    /// Signed codes V_e, |V_e| ≤ [`CODE_CAP`].
    pub codes: Vec<i16>,
    /// Weight-tensor shape (without the leading plane axis).
    pub wshape: Vec<usize>,
    /// Active precision n (number of live planes).
    pub bits: usize,
    /// Dynamic-range scale s.
    pub scale: f32,
}

impl PackedCodes {
    pub fn elems(&self) -> usize {
        self.codes.len()
    }

    /// The LSB step δ = s / (2^n − 1); 0 for a dead (n = 0) layer.
    pub fn delta(&self) -> f64 {
        if self.bits == 0 {
            0.0
        } else {
            self.scale as f64 / ((1u64 << self.bits) - 1) as f64
        }
    }

    /// The sign-split bitset view of the codes.
    pub fn plane_bits(&self) -> PlaneBits {
        PlaneBits::from_codes(&self.codes)
    }

    /// Effective precision: highest occupied plane + 1 (word-level
    /// OR-reduction over the bitsets), 0 for an all-zero layer. After a
    /// §3.3 adjustment this equals `bits`; mid-training it can run below
    /// (unused MSBs not yet trimmed) or one above (the n+1 growth).
    pub fn effective_bits(&self) -> usize {
        let occ = self.plane_bits().occupancy();
        if occ == 0 {
            0
        } else {
            32 - occ.leading_zeros() as usize
        }
    }

    /// Represented float weights W = δ·V. Matches `from_bitplanes` bitwise
    /// whenever the codes were within the ±[`CODE_CAP`] clamp.
    pub fn dequantize(&self) -> Tensor {
        let delta = self.delta() as f32;
        let data = self.codes.iter().map(|&c| c as f32 * delta).collect();
        Tensor::new(self.wshape.clone(), data).unwrap()
    }

    /// Expand back to an exact binary `BitRep` (the `pack()` inverse).
    ///
    /// Requires the codes to fit in `bits` planes — true for any freshly
    /// converted or re-quantized layer. A mid-training continuous `BitRep`
    /// can round to codes one bit wider (the §3.3 n+1 growth); run
    /// `requantize` first to renormalize.
    pub fn unpack(&self) -> BitRep {
        debug_assert!(self
            .codes
            .iter()
            .all(|c| (c.unsigned_abs() >> self.bits.min(15)) == 0 || self.bits >= NB));
        let elems = self.codes.len();
        let bits = self.plane_bits();
        let mut wp = vec![0.0f32; NB * elems];
        let mut wn = vec![0.0f32; NB * elems];
        bits.expand_into(&mut wp, &mut wn);
        let mut pshape = vec![NB];
        pshape.extend_from_slice(&self.wshape);
        BitRep {
            wp: Tensor::new(pshape.clone(), wp).unwrap(),
            wn: Tensor::new(pshape, wn).unwrap(),
            mask: packed_mask(self.bits),
            scale: self.scale,
        }
    }
}

/// Sign-split plane bitsets: `words` u64s per plane, NB planes, bit `e % 64`
/// of word `e / 64` in plane row b set iff bit b of |V_e| is set (in `pos`
/// for V_e > 0, `neg` for V_e < 0).
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneBits {
    pos: Vec<u64>,
    neg: Vec<u64>,
    /// Words per plane row.
    words: usize,
    /// Weights covered (bits past `elems` in the last word stay zero).
    elems: usize,
}

impl PlaneBits {
    /// Single element-major pass over narrow (already clamped) codes.
    pub fn from_codes(codes: &[i16]) -> PlaneBits {
        Self::build(codes.iter().map(|&c| c as i64), codes.len(), NB)
    }

    /// Wide codes with an explicit plane cap (bits ≥ `max_planes` of each
    /// magnitude are dropped — the `planes_from_codes` contract).
    pub fn from_wide_codes(codes: &[i64], max_planes: usize) -> PlaneBits {
        Self::build(codes.iter().copied(), codes.len(), max_planes.min(NB))
    }

    fn build<I: Iterator<Item = i64>>(codes: I, elems: usize, max_planes: usize) -> PlaneBits {
        let words = (elems + 63) / 64;
        let mut pos = vec![0u64; NB * words];
        let mut neg = vec![0u64; NB * words];
        for (e, v) in codes.enumerate() {
            if v == 0 {
                continue;
            }
            let (planes, mut mag) =
                if v > 0 { (&mut pos, v as u64) } else { (&mut neg, v.unsigned_abs()) };
            let word = e >> 6;
            let bit = 1u64 << (e & 63);
            while mag != 0 {
                let b = mag.trailing_zeros() as usize;
                if b >= max_planes {
                    break; // bits only ascend from here
                }
                planes[b * words + word] |= bit;
                mag &= mag - 1;
            }
        }
        PlaneBits { pos, neg, words, elems }
    }

    pub fn elems(&self) -> usize {
        self.elems
    }

    fn row(planes: &[u64], b: usize, words: usize) -> &[u64] {
        &planes[b * words..(b + 1) * words]
    }

    /// Per-plane set-bit counts `(positive, negative)` — word-level popcount.
    pub fn popcount(&self, plane: usize) -> (u64, u64) {
        let count = |row: &[u64]| row.iter().map(|w| w.count_ones() as u64).sum();
        (
            count(Self::row(&self.pos, plane, self.words)),
            count(Self::row(&self.neg, plane, self.words)),
        )
    }

    /// Total occupancy (pos + neg) per plane, planes 0..NB.
    pub fn plane_popcounts(&self) -> Vec<u64> {
        (0..NB)
            .map(|b| {
                let (p, n) = self.popcount(b);
                p + n
            })
            .collect()
    }

    /// Occupancy bitmask: bit b set iff plane b holds any weight bit —
    /// word-level OR-reduction (all-zero-plane detection). The §3.3 trims
    /// fall out directly: MSB trim from the leading zeros, LSB trim from
    /// the trailing zeros of this mask.
    pub fn occupancy(&self) -> u32 {
        let mut occ = 0u32;
        for b in 0..NB {
            let or = Self::row(&self.pos, b, self.words).iter().fold(0u64, |a, &w| a | w)
                | Self::row(&self.neg, b, self.words).iter().fold(0u64, |a, &w| a | w);
            if or != 0 {
                occ |= 1 << b;
            }
        }
        occ
    }

    /// Bulk LSB trim: drop the bottom `k` planes (plane b+k becomes plane
    /// b — the bitset image of `code >> k`), zero-filling the vacated top
    /// rows. Word-level `copy_within`, no per-element work.
    pub fn drop_low_planes(&mut self, k: usize) {
        let k = k.min(NB);
        if k == 0 {
            return;
        }
        let w = self.words;
        for planes in [&mut self.pos, &mut self.neg] {
            planes.copy_within(k * w.., 0);
            planes[(NB - k) * w..].fill(0);
        }
    }

    /// Expand to exact binary f32 planes in place (zero-copy with respect
    /// to the destination `BitRep` plane buffers: every `[NB * elems]` slot
    /// is overwritten, so no prior clearing or reallocation is needed).
    pub fn expand_into(&self, wp: &mut [f32], wn: &mut [f32]) {
        assert_eq!(wp.len(), NB * self.elems, "wp buffer mismatch");
        assert_eq!(wn.len(), NB * self.elems, "wn buffer mismatch");
        expand_plane_rows(&self.pos, self.words, self.elems, wp);
        expand_plane_rows(&self.neg, self.words, self.elems, wn);
    }
}

fn expand_plane_rows(bits: &[u64], words: usize, elems: usize, out: &mut [f32]) {
    for b in 0..NB {
        let row = &bits[b * words..(b + 1) * words];
        let out_row = &mut out[b * elems..(b + 1) * elems];
        for (wi, &w) in row.iter().enumerate() {
            let base = wi * 64;
            let chunk = &mut out_row[base..(base + 64).min(elems)];
            if w == 0 {
                chunk.fill(0.0); // bit-sparse planes are the common case
            } else {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    *slot = ((w >> j) & 1) as f32;
                }
            }
        }
    }
}

/// Fused element-major code accumulation over only the *active* planes.
///
/// Streams each active plane row (contiguous) into a shared f64 accumulator,
/// replacing the reference path's per-element strided walk and serial f64
/// dependency chain with plane-row passes the compiler can vectorize. Per
/// element, the additions happen in the same ascending-plane order with the
/// same operand values as `reference::integer_codes`, so the result is
/// bit-identical.
pub fn accumulate_codes(rep: &BitRep) -> Vec<f64> {
    let elems = rep.wp.len() / NB;
    let mut acc = vec![0.0f64; elems];
    for (b, &m) in rep.mask.data().iter().enumerate().take(NB) {
        if m == 0.0 {
            continue;
        }
        let weight = (1u64 << b) as f64;
        let p = rep.wp.row(b, elems);
        let n = rep.wn.row(b, elems);
        for ((a, &pv), &nv) in acc.iter_mut().zip(p).zip(n) {
            *a += (pv - nv) as f64 * weight;
        }
    }
    acc
}

/// Rounded, capacity-clamped i16 codes — the packed `integer_codes`.
pub fn codes_i16(rep: &BitRep) -> Vec<i16> {
    let cap = CODE_CAP as i64;
    accumulate_codes(rep).iter().map(|a| (a.round() as i64).clamp(-cap, cap) as i16).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitplane::to_bitplanes;

    fn codes_fixture() -> Vec<i16> {
        vec![5, -3, 0, 8, -511, 511, 64, -64, 1]
    }

    #[test]
    fn bitset_roundtrips_codes() {
        let codes = codes_fixture();
        let bits = PlaneBits::from_codes(&codes);
        let mut wp = vec![0.0f32; NB * codes.len()];
        let mut wn = vec![0.0f32; NB * codes.len()];
        bits.expand_into(&mut wp, &mut wn);
        for (e, &c) in codes.iter().enumerate() {
            let mut acc = 0i32;
            for b in 0..NB {
                acc += ((wp[b * codes.len() + e] - wn[b * codes.len() + e]) as i32) << b;
            }
            assert_eq!(acc, c as i32, "element {e}");
        }
    }

    #[test]
    fn occupancy_and_popcounts() {
        // codes {4, -4}: only plane 2 occupied, one bit in each sign half
        let bits = PlaneBits::from_codes(&[4, -4]);
        assert_eq!(bits.occupancy(), 0b100);
        assert_eq!(bits.popcount(2), (1, 1));
        assert_eq!(bits.popcount(0), (0, 0));
        let pc = bits.plane_popcounts();
        assert_eq!(pc[2], 2);
        assert_eq!(pc.iter().sum::<u64>(), 2);
    }

    #[test]
    fn drop_low_planes_is_right_shift() {
        let codes = vec![12i16, -8, 6];
        let mut bits = PlaneBits::from_codes(&codes);
        bits.drop_low_planes(1);
        let shifted: Vec<i16> = codes.iter().map(|&c| c >> 1).collect();
        assert_eq!(bits, PlaneBits::from_codes(&shifted));
        // dropping everything leaves an empty bitset
        bits.drop_low_planes(NB);
        assert_eq!(bits.occupancy(), 0);
    }

    #[test]
    fn word_boundary_elems() {
        // 64, 65 and 130 elements exercise full/partial trailing words
        for elems in [64usize, 65, 130] {
            let codes: Vec<i16> = (0..elems).map(|e| ((e % 13) as i16) - 6).collect();
            let bits = PlaneBits::from_codes(&codes);
            let mut wp = vec![9.0f32; NB * elems];
            let mut wn = vec![9.0f32; NB * elems];
            bits.expand_into(&mut wp, &mut wn);
            for (e, &c) in codes.iter().enumerate() {
                let mut acc = 0i32;
                for b in 0..NB {
                    acc += ((wp[b * elems + e] - wn[b * elems + e]) as i32) << b;
                }
                assert_eq!(acc, c as i32);
            }
        }
    }

    #[test]
    fn pack_unpack_bridge() {
        let w = Tensor::new(vec![4], vec![0.5, -0.25, 0.75, -1.0]).unwrap();
        let rep = to_bitplanes(&w, 6).unwrap();
        let packed = rep.pack();
        assert_eq!(packed.bits, 6);
        assert_eq!(packed.wshape, vec![4]);
        let back = packed.unpack();
        assert_eq!(back.wp, rep.wp);
        assert_eq!(back.wn, rep.wn);
        assert_eq!(back.mask, rep.mask);
        assert_eq!(back.scale.to_bits(), rep.scale.to_bits());
    }

    #[test]
    fn dequantize_matches_reconstruction() {
        let w = Tensor::new(vec![5], vec![0.1, -0.6, 0.33, 0.0, -0.05]).unwrap();
        let rep = to_bitplanes(&w, 8).unwrap();
        let packed = rep.pack();
        let deq = packed.dequantize();
        let rec = crate::quant::from_bitplanes(&rep);
        assert_eq!(deq.shape(), rec.shape());
        for (a, b) in deq.data().iter().zip(rec.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn effective_bits_tracks_occupancy() {
        let mk = |codes: Vec<i16>, bits: usize| PackedCodes {
            wshape: vec![codes.len()],
            codes,
            bits,
            scale: 1.0,
        };
        assert_eq!(mk(vec![0, 0, 0], 5).effective_bits(), 0);
        assert_eq!(mk(vec![1, -1], 5).effective_bits(), 1);
        // 12 = 0b1100 → highest plane 3 → 4 effective bits despite bits = 8
        assert_eq!(mk(vec![12, -2], 8).effective_bits(), 4);
        // the n+1 growth: a code past 2^bits − 1 reads one plane wider
        assert_eq!(mk(vec![9], 3).effective_bits(), 4);
    }

    #[test]
    fn wide_codes_respect_plane_cap() {
        // bit 3 of |−9| = 0b1001 is above a 3-plane cap and must be dropped
        let bits = PlaneBits::from_wide_codes(&[9, -9], 3);
        assert_eq!(bits.occupancy(), 0b001);
        assert_eq!(bits.popcount(0), (1, 1));
        assert_eq!(bits.popcount(3), (0, 0));
    }
}
