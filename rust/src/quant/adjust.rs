//! Re-quantization + dynamic precision adjustment (paper §3.3, Eq. 6).
//!
//! Periodically during BSQ training the coordinator:
//!   1. rounds the floating planes to signed integer codes
//!      V = Round[Σ_b (W_p^(b) − W_n^(b)) 2^b]  (re-quantization),
//!   2. trims all-zero MSBs (top-down until the first used bit),
//!   3. trims all-zero LSBs (each removal right-shifts every code and
//!      doubles the LSB step — the paper's s-doubling),
//!   4. updates the scale to s' = δ'·(2^{n'} − 1) (equivalently the paper's
//!      s' = s·(2^{n'}−1)/(2^n−1) composed with the LSB doublings),
//!   5. re-splits the shifted codes into fresh binary W_p / W_n planes.
//!
//! Implementation: the packed engine (`quant::packed`). The codes live as
//! i16, both trims come from word-level OR-reductions over the sign-split
//! plane bitsets (a plane is trimmable iff its OR is zero), the LSB shift
//! is a bulk plane-row drop, and the binary planes are rebuilt *in place*
//! inside the existing `BitRep` buffers — no `planes_from_codes`
//! reallocation. The scalar original lives in `quant::reference`.
//!
//! Invariant (verified by property tests): with δ = s/(2^n − 1), the
//! represented weight W = δ·V is unchanged (paper Eq. 6) — the integer
//! codes V transform *exactly* (pure shifts), and the only rounding is the
//! final f64→f32 store of the updated scale (≤ 1 ulp per adjustment).

use crate::quant::bitplane::{packed_mask, BitRep, NB};
use crate::quant::packed::{codes_i16, PlaneBits};

/// Outcome of one re-quantization + precision adjustment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdjustReport {
    pub bits_before: usize,
    pub bits_after: usize,
    pub msb_trimmed: usize,
    pub lsb_trimmed: usize,
}

/// Re-quantize one layer in place and adjust its precision.
///
/// Mirrors §3.3 exactly, with one engineering cap: codes exceeding the fixed
/// plane capacity (|V| > 2^NB − 1, possible only when every plane saturates
/// at its 2.0 clamp) are clamped by the code rounding — growth beyond NB
/// bits would need a dynamic shape, which the AOT artifacts rule out
/// (DESIGN.md §2). In practice the regularizer drives precision *down*.
pub fn requantize(rep: &mut BitRep) -> AdjustReport {
    let n = rep.bits();
    if n == 0 {
        // Dead layer: nothing to represent; stays dead.
        return AdjustReport { bits_before: 0, bits_after: 0, msb_trimmed: 0, lsb_trimmed: 0 };
    }

    let codes = codes_i16(rep); // bit-identical to reference::integer_codes
    let mut delta = rep.delta(); // s / (2^n − 1), exact in f64

    let mut bits = PlaneBits::from_codes(&codes);
    // Word-level OR-reduction: bit b of `occ` ⇔ plane b is non-empty. The
    // float planes live in [0, 2], so V can reach 2·(2^n − 1) < 2^{n+1}:
    // precision may *grow* to n + 1 (the paper's "between 0 and (n+1)-bit").
    let occ = bits.occupancy();
    if occ == 0 {
        // Every weight collapsed to zero: the layer is pruned away entirely
        // (the paper observes this under large α; shortcuts carry the signal).
        rep.mask = packed_mask(0);
        rep.wp.data_mut().fill(0.0);
        rep.wn.data_mut().fill(0.0);
        // Scale is meaningless for a dead layer; keep it for bookkeeping.
        return AdjustReport { bits_before: n, bits_after: 0, msb_trimmed: n, lsb_trimmed: 0 };
    }

    let hi = 31 - occ.leading_zeros() as usize; // highest occupied plane
    // LSB trim: common trailing zero planes ≡ trailing zeros of the
    // occupancy mask; keep at least one bit.
    let lsb = (occ.trailing_zeros() as usize).min(hi);

    if lsb > 0 {
        bits.drop_low_planes(lsb); // bulk right-shift of every code
        delta *= (1u64 << lsb) as f64; // each removed LSB doubles the step
    }

    let n_after = hi - lsb + 1; // bits needed for the shifted magnitudes
    debug_assert!(n_after <= NB);

    // Re-split into exact binary planes inside the existing buffers.
    bits.expand_into(rep.wp.data_mut(), rep.wn.data_mut());
    rep.mask = packed_mask(n_after);
    rep.scale = (delta * ((1u64 << n_after) - 1) as f64) as f32;

    AdjustReport {
        bits_before: n,
        bits_after: n_after,
        msb_trimmed: (n + 1).saturating_sub(n_after + lsb),
        lsb_trimmed: lsb,
    }
}

/// [`requantize`] into a double buffer: read `src`'s codes, write the
/// rebuilt planes / mask / scale into `dst`, leave `src` untouched.
///
/// This is the overlapped-requant worker primitive (DESIGN.md §16): the
/// training thread keeps reading the live `src` planes while a background
/// worker rebuilds into the spare, and the coordinator installs the spare
/// at the next batch boundary. Bitwise identical to cloning `src` and
/// running [`requantize`] on the clone (asserted by a differential test),
/// but reads only the i16 codes off `src` instead of copying 2·NB float
/// planes first. `dst` must be shape-compatible — a spare created as a
/// clone of the layer.
pub fn requantize_into(src: &BitRep, dst: &mut BitRep) -> AdjustReport {
    assert_eq!(src.wp.shape(), dst.wp.shape(), "requantize_into: spare wp shape mismatch");
    assert_eq!(src.wn.shape(), dst.wn.shape(), "requantize_into: spare wn shape mismatch");
    let n = src.bits();
    if n == 0 {
        // Dead layer: the spare must mirror it exactly (it gets installed).
        dst.wp.data_mut().copy_from_slice(src.wp.data());
        dst.wn.data_mut().copy_from_slice(src.wn.data());
        dst.mask = src.mask.clone();
        dst.scale = src.scale;
        return AdjustReport { bits_before: 0, bits_after: 0, msb_trimmed: 0, lsb_trimmed: 0 };
    }

    let codes = codes_i16(src);
    let mut delta = src.delta();
    let mut bits = PlaneBits::from_codes(&codes);
    let occ = bits.occupancy();
    if occ == 0 {
        dst.mask = packed_mask(0);
        dst.wp.data_mut().fill(0.0);
        dst.wn.data_mut().fill(0.0);
        dst.scale = src.scale; // meaningless for a dead layer; kept as in requantize
        return AdjustReport { bits_before: n, bits_after: 0, msb_trimmed: n, lsb_trimmed: 0 };
    }

    let hi = 31 - occ.leading_zeros() as usize;
    let lsb = (occ.trailing_zeros() as usize).min(hi);
    if lsb > 0 {
        bits.drop_low_planes(lsb);
        delta *= (1u64 << lsb) as f64;
    }
    let n_after = hi - lsb + 1;
    debug_assert!(n_after <= NB);

    bits.expand_into(dst.wp.data_mut(), dst.wn.data_mut());
    dst.mask = packed_mask(n_after);
    dst.scale = (delta * ((1u64 << n_after) - 1) as f64) as f32;

    AdjustReport {
        bits_before: n,
        bits_after: n_after,
        msb_trimmed: (n + 1).saturating_sub(n_after + lsb),
        lsb_trimmed: lsb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitplane::{from_bitplanes, integer_codes, planes_from_codes, to_bitplanes};
    use crate::tensor::Tensor;
    use crate::util::Pcg32;

    fn rep_from_codes(codes: &[i64], n: usize, scale: f32) -> BitRep {
        let (wp, wn) = planes_from_codes(codes, &[codes.len()], n);
        BitRep { wp, wn, mask: packed_mask(n), scale }
    }

    #[test]
    fn msb_trim_when_top_bits_unused() {
        // 8-bit layer whose codes all fit in 5 bits → n' = 5
        let rep0 = rep_from_codes(&[17, -9, 31, 2], 8, 1.0);
        let w_before = from_bitplanes(&rep0);
        let mut rep = rep0;
        let r = requantize(&mut rep);
        assert_eq!(r.bits_after, 5);
        assert_eq!(r.lsb_trimmed, 0);
        let w_after = from_bitplanes(&rep);
        assert_eq!(w_before.data(), w_after.data()); // Eq. 6, exact
        // s' = s·(2^5−1)/(2^8−1)
        assert!((rep.scale - 31.0 / 255.0).abs() < 1e-7);
    }

    #[test]
    fn lsb_trim_doubles_step() {
        // all codes even → one LSB removed, codes halved, δ doubled
        let rep0 = rep_from_codes(&[2, -4, 6, 128], 8, 2.0);
        let w_before = from_bitplanes(&rep0);
        let mut rep = rep0;
        let r = requantize(&mut rep);
        assert_eq!(r.lsb_trimmed, 1);
        assert_eq!(r.bits_after, 7); // 128>>1 = 64 → bits 0..6
        assert_eq!(from_bitplanes(&rep).data(), w_before.data());
    }

    #[test]
    fn precision_can_grow_by_one() {
        // float planes up to 2.0 can push codes past 2^n − 1
        let w = Tensor::new(vec![2], vec![0.9, 0.54]).unwrap(); // codes 15, 9
        let mut rep = to_bitplanes(&w, 4).unwrap();
        // inflate every active plane of element 0 to 1.9 → code 28; element 1
        // stays 9 (odd), so no LSB trim masks the growth
        for b in 0..4 {
            rep.wp.data_mut()[b * 2] = 1.9;
        }
        let r = requantize(&mut rep);
        assert_eq!(r.bits_after, 5); // 28 needs 5 bits
        assert_eq!(r.lsb_trimmed, 0);
    }

    #[test]
    fn common_trailing_zeros_trigger_lsb_trim_even_on_growth() {
        // codes {28, 8} share two trailing zeros → 28>>2 = 7 fits 3 bits
        let rep0 = rep_from_codes(&[28, 8], 5, 1.0);
        let w_before = from_bitplanes(&rep0);
        let mut rep = rep0;
        let r = requantize(&mut rep);
        assert_eq!(r.bits_after, 3);
        assert_eq!(r.lsb_trimmed, 2);
        assert_eq!(from_bitplanes(&rep).data(), w_before.data());
    }

    #[test]
    fn all_zero_layer_dies() {
        let rep0 = rep_from_codes(&[0, 0, 0], 6, 1.0);
        let mut rep = rep0;
        let r = requantize(&mut rep);
        assert_eq!(r.bits_after, 0);
        assert_eq!(rep.bits(), 0);
        assert!(from_bitplanes(&rep).data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dead_layer_stays_dead() {
        let mut rep = rep_from_codes(&[0, 0], 0, 1.0);
        rep.mask = packed_mask(0);
        let r = requantize(&mut rep);
        assert_eq!(r.bits_before, 0);
        assert_eq!(r.bits_after, 0);
    }

    #[test]
    fn single_bit_survives_lsb_trim() {
        // code 8 = 0b1000: three LSB trims, one bit left, δ scaled by 8
        let rep0 = rep_from_codes(&[8, -8], 4, 1.0);
        let w_before = from_bitplanes(&rep0);
        let mut rep = rep0;
        let r = requantize(&mut rep);
        assert_eq!(r.bits_after, 1);
        assert_eq!(r.lsb_trimmed, 3);
        assert_eq!(from_bitplanes(&rep).data(), w_before.data());
    }

    /// Property test (hand-rolled; proptest unavailable offline): the
    /// represented weight is exactly preserved across re-quantization for
    /// random continuous planes, masks and scales.
    #[test]
    fn prop_requantize_preserves_represented_weight() {
        let mut rng = Pcg32::seeded(42);
        for case in 0..300 {
            let n = 1 + (case % 8);
            let elems = 1 + rng.below(40) as usize;
            let w = Tensor::randn(&[elems], 0.5, &mut rng);
            let mut rep = to_bitplanes(&w, n).unwrap();
            // perturb planes into continuous values like mid-training state
            for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
                *v = (*v + rng.range(-0.45, 0.45)).clamp(0.0, 2.0);
            }
            rep.scale = rng.range(0.05, 3.0);
            // the pre-adjustment representation rounds the continuous planes
            let codes_before = integer_codes(&rep);
            let delta_before = rep.delta();
            let r = requantize(&mut rep);
            let codes_after = integer_codes(&rep);
            let delta_after = rep.delta();
            for (a, b) in codes_before.iter().zip(&codes_after) {
                let va = delta_before * *a as f64;
                let vb = delta_after * *b as f64;
                // codes shift exactly; the f32 scale store rounds ≤ 1 ulp
                let tol = 1e-6 * va.abs().max(1e-6);
                assert!(
                    (va - vb).abs() <= tol,
                    "case {case}: {va} vs {vb} (n {} → {})",
                    r.bits_before,
                    r.bits_after
                );
            }
            // masks stay bottom-packed
            let m = rep.mask.data();
            let n_after = rep.bits();
            assert!(m.iter().take(n_after).all(|&x| x == 1.0));
            assert!(m.iter().skip(n_after).all(|&x| x == 0.0));
            // planes come back exactly binary
            assert!(rep.wp.data().iter().all(|&v| v == 0.0 || v == 1.0));
            assert!(rep.wn.data().iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    /// Differential: `requantize_into` a spare ≡ `requantize` in place, for
    /// random continuous mid-training reps — and the source is untouched.
    #[test]
    fn prop_requantize_into_matches_in_place() {
        let mut rng = Pcg32::seeded(1312);
        for case in 0..200 {
            let n = case % 9; // include the dead-layer n == 0 path
            let elems = 1 + rng.below(40) as usize;
            let w = Tensor::randn(&[elems], 0.5, &mut rng);
            let mut src = to_bitplanes(&w, n.max(1)).unwrap();
            if n == 0 {
                src.mask = packed_mask(0);
            }
            for v in src.wp.data_mut().iter_mut().chain(src.wn.data_mut()) {
                *v = (*v + rng.range(-0.45, 0.45)).clamp(0.0, 2.0);
            }
            src.scale = rng.range(0.05, 3.0);

            let src_snapshot = src.clone();
            let mut in_place = src.clone();
            let r_in_place = requantize(&mut in_place);
            let mut spare = src.clone(); // shape-compatible double buffer
            let r_into = requantize_into(&src, &mut spare);

            assert_eq!(r_into, r_in_place, "case {case}: reports differ");
            assert_eq!(spare.wp, in_place.wp, "case {case}: wp differs");
            assert_eq!(spare.wn, in_place.wn, "case {case}: wn differs");
            assert_eq!(spare.mask, in_place.mask, "case {case}: mask differs");
            assert_eq!(
                spare.scale.to_bits(),
                in_place.scale.to_bits(),
                "case {case}: scale differs"
            );
            // the source is never written
            assert_eq!(src.wp, src_snapshot.wp);
            assert_eq!(src.wn, src_snapshot.wn);
            assert_eq!(src.mask, src_snapshot.mask);
            assert_eq!(src.scale.to_bits(), src_snapshot.scale.to_bits());
        }
    }

    /// Idempotence: adjusting twice changes nothing the second time.
    #[test]
    fn prop_requantize_idempotent() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..100 {
            let elems = 1 + rng.below(20) as usize;
            let w = Tensor::randn(&[elems], 1.0, &mut rng);
            let mut rep = to_bitplanes(&w, 8).unwrap();
            requantize(&mut rep);
            let wp = rep.wp.clone();
            let mask = rep.mask.clone();
            let scale = rep.scale;
            let r2 = requantize(&mut rep);
            assert_eq!(r2.bits_before, r2.bits_after);
            assert_eq!(rep.wp, wp);
            assert_eq!(rep.mask, mask);
            assert!((rep.scale - scale).abs() < 1e-9);
        }
    }
}
