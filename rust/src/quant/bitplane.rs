//! Bit-plane packing: paper Eq. 2 — floating weights ⇄ bit representation.
//!
//! `to_bitplanes` runs once at the start of BSQ training (and the repack half
//! runs at every re-quantization): it extracts the dynamic range s = max|W|,
//! quantizes |W|/s onto 2^n − 1 uniform steps, and splits the signed integer
//! codes into positive/negative binary planes W_p^(b), W_n^(b) stored as f32
//! (the planes are *trained* as continuous values in [0, 2]).
//!
//! All plane tensors carry a fixed NB = 9 planes (8-bit initial precision +
//! one overflow plane) with a bottom-packed activity mask — the static-shape
//! scheme of DESIGN.md §2.
//!
//! The conversions here run on the packed codes engine (`quant::packed`):
//! a single element-major pass emits i16 codes plus the sign-split plane
//! bitsets, and reconstruction/re-quantization stream contiguous plane rows
//! instead of the historical `b*elems + e` strided walks. The original
//! scalar loops survive as `quant::reference` for differential testing.

use anyhow::{bail, Result};

use crate::quant::packed::{self, PackedCodes, PlaneBits};
use crate::tensor::Tensor;

/// Fixed plane count; must match `python/compile/quantize.py::NB`.
pub const NB: usize = 9;

/// The bit representation of one layer.
#[derive(Debug, Clone)]
pub struct BitRep {
    /// Positive planes, shape `[NB, *wshape]`.
    pub wp: Tensor,
    /// Negative planes, shape `[NB, *wshape]`.
    pub wn: Tensor,
    /// Active-plane mask `[NB]`, bottom-packed (`[1]*n + [0]*(NB-n)`).
    pub mask: Tensor,
    /// Dynamic-range scale s (scalar).
    pub scale: f32,
}

impl BitRep {
    /// Effective precision n = number of active planes.
    pub fn bits(&self) -> usize {
        self.mask.data().iter().filter(|&&m| m != 0.0).count()
    }

    /// The LSB step δ = s / (2^n − 1); 0 for a dead (n = 0) layer.
    pub fn delta(&self) -> f64 {
        let n = self.bits();
        if n == 0 {
            0.0
        } else {
            self.scale as f64 / ((1u64 << n) - 1) as f64
        }
    }

    /// Round the (possibly continuous) planes down to packed integer codes
    /// — the cheap bridge onto the word-level engine (2 bytes/weight).
    pub fn pack(&self) -> PackedCodes {
        PackedCodes {
            codes: packed::codes_i16(self),
            wshape: self.wp.shape()[1..].to_vec(),
            bits: self.bits(),
            scale: self.scale,
        }
    }
}

/// Bottom-packed mask for n active planes.
pub fn packed_mask(n: usize) -> Tensor {
    let mut m = vec![0.0f32; NB];
    for slot in m.iter_mut().take(n.min(NB)) {
        *slot = 1.0;
    }
    Tensor::new(vec![NB], m).unwrap()
}

/// Convert a float weight tensor to its n-bit representation (paper Eq. 2).
///
/// Planes come out exactly binary (0.0 / 1.0). The represented value is
/// `sign ⊙ s·Round[|W|/s·(2^n−1)]/(2^n−1)`, i.e. the weight the quantized
/// forward pass will see at step 0 of BSQ training. One element-major pass
/// emits the codes; the binary planes are expanded from the plane bitsets.
pub fn to_bitplanes(w: &Tensor, n: usize) -> Result<BitRep> {
    if n == 0 || n > NB {
        bail!("initial precision must be in 1..={NB}, got {n}");
    }
    let elems = w.len();
    let scale = w.max_abs().max(1e-12);
    let levels = ((1u64 << n) - 1) as f32;

    let codes: Vec<i16> = w
        .data()
        .iter()
        .map(|&v| {
            let mag = ((v.abs() / scale) * levels).round() as i16; // ≤ 2^n − 1
            if v >= 0.0 {
                mag
            } else {
                -mag
            }
        })
        .collect();
    let bits = PlaneBits::from_codes(&codes);
    let mut wp = vec![0.0f32; NB * elems];
    let mut wn = vec![0.0f32; NB * elems];
    bits.expand_into(&mut wp, &mut wn);

    let mut pshape = vec![NB];
    pshape.extend_from_slice(w.shape());
    Ok(BitRep {
        wp: Tensor::new(pshape.clone(), wp)?,
        wn: Tensor::new(pshape, wn)?,
        mask: packed_mask(n),
        scale,
    })
}

/// Reconstruct the represented float weight from a bit representation
/// (the exact value the device-side STE forward computes: rounds first).
pub fn from_bitplanes(rep: &BitRep) -> Tensor {
    let n = rep.bits();
    let wshape = rep.wp.shape()[1..].to_vec();
    if n == 0 {
        return Tensor::zeros(&wshape);
    }
    let delta = rep.delta() as f32;
    let out: Vec<f32> =
        packed::accumulate_codes(rep).iter().map(|a| (a.round() as f32) * delta).collect();
    Tensor::new(wshape, out).unwrap()
}

/// The signed integer codes V_e = Round[Σ_b mask_b (wp−wn) 2^b], clamped to
/// the plane capacity ±(2^NB − 1). This is the re-quantization of §3.3.
pub fn integer_codes(rep: &BitRep) -> Vec<i64> {
    let cap = (1i64 << NB) - 1;
    packed::accumulate_codes(rep).iter().map(|a| (a.round() as i64).clamp(-cap, cap)).collect()
}

/// Rebuild exact binary planes from signed integer codes (post-adjustment
/// re-split of §3.3: positives to W_p, magnitudes of negatives to W_n).
pub fn planes_from_codes(codes: &[i64], wshape: &[usize], n: usize) -> (Tensor, Tensor) {
    let elems = codes.len();
    let bits = PlaneBits::from_wide_codes(codes, n);
    let mut wp = vec![0.0f32; NB * elems];
    let mut wn = vec![0.0f32; NB * elems];
    bits.expand_into(&mut wp, &mut wn);
    let mut pshape = vec![NB];
    pshape.extend_from_slice(wshape);
    (Tensor::new(pshape.clone(), wp).unwrap(), Tensor::new(pshape, wn).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip_exact_for_quantized_values() {
        let mut rng = Pcg32::seeded(0);
        for n in 1..=8 {
            let levels = ((1u64 << n) - 1) as f32;
            let s = 0.7f32;
            // weights already on the n-bit grid → conversion must be exact
            let data: Vec<f32> = (0..64)
                .map(|_| {
                    let code = rng.below(levels as u32 + 1) as f32;
                    let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
                    sign * s * code / levels
                })
                .collect();
            let mut w = Tensor::new(vec![64], data.clone()).unwrap();
            // ensure max|w| = s so the scale matches
            w.data_mut()[0] = s;
            let rep = to_bitplanes(&w, n).unwrap();
            assert_eq!(rep.bits(), n);
            let back = from_bitplanes(&rep);
            for (a, b) in w.data().iter().zip(back.data()) {
                assert!((a - b).abs() < 1e-6 * s, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        let mut rng = Pcg32::seeded(1);
        let w = Tensor::randn(&[3, 3, 4, 4], 0.1, &mut rng);
        let rep = to_bitplanes(&w, 8).unwrap();
        let back = from_bitplanes(&rep);
        let delta = rep.delta() as f32;
        for (a, b) in w.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= 0.5 * delta + 1e-7);
        }
    }

    #[test]
    fn planes_are_binary_and_signed_split() {
        let w = Tensor::new(vec![2], vec![0.5, -1.0]).unwrap();
        let rep = to_bitplanes(&w, 4).unwrap();
        for &v in rep.wp.data().iter().chain(rep.wn.data()) {
            assert!(v == 0.0 || v == 1.0);
        }
        // element 0 positive → wn all zero at e=0; element 1 negative → wp zero
        for b in 0..NB {
            assert_eq!(rep.wn.data()[b * 2], 0.0);
            assert_eq!(rep.wp.data()[b * 2 + 1], 0.0);
        }
    }

    #[test]
    fn integer_codes_round_float_planes() {
        // planes hold continuous values; codes must round the weighted sum
        let mut rep = to_bitplanes(&Tensor::new(vec![1], vec![0.3]).unwrap(), 3).unwrap();
        rep.wp.data_mut().fill(0.0);
        rep.wp.data_mut()[0] = 0.6; // bit0 → 0.6·1
        rep.wp.data_mut()[1] = 0.8; // bit1 → 0.8·2
        // sum = 2.2 → rounds to 2
        assert_eq!(integer_codes(&rep), vec![2]);
    }

    #[test]
    fn codes_clamp_to_capacity() {
        let mut rep = to_bitplanes(&Tensor::new(vec![1], vec![0.3]).unwrap(), 8).unwrap();
        rep.wp.data_mut().fill(2.0);
        rep.wn.data_mut().fill(0.0);
        rep.mask = packed_mask(NB);
        // Σ 2·2^b over 9 planes = 1022 > 511 → clamp
        assert_eq!(integer_codes(&rep), vec![(1 << NB) - 1]);
    }

    #[test]
    fn packed_mask_is_bottom_packed() {
        let m = packed_mask(3);
        assert_eq!(m.data(), &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(packed_mask(0).data().iter().sum::<f32>(), 0.0);
    }

    #[test]
    fn zero_bits_layer_reconstructs_zero() {
        let w = Tensor::new(vec![4], vec![0.1, -0.2, 0.3, 0.0]).unwrap();
        let mut rep = to_bitplanes(&w, 4).unwrap();
        rep.mask = packed_mask(0);
        let back = from_bitplanes(&rep);
        assert!(back.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gapped_masks_skip_inactive_planes() {
        // a non-bottom-packed mask (never produced, but the reference path
        // honors it — the packed path must match) weighs only planes 0 and 2
        let mut rep = to_bitplanes(&Tensor::new(vec![1], vec![0.5]).unwrap(), 3).unwrap();
        rep.wp.data_mut().fill(0.0);
        rep.wp.data_mut()[0] = 1.0; // plane 0
        rep.wp.data_mut()[1] = 1.0; // plane 1 (masked off below)
        rep.wp.data_mut()[2] = 1.0; // plane 2
        let mut m = vec![0.0f32; NB];
        m[0] = 1.0;
        m[2] = 1.0;
        rep.mask = Tensor::new(vec![NB], m).unwrap();
        assert_eq!(integer_codes(&rep), vec![5]); // 1 + 4, plane 1 skipped
    }
}
