//! Training-time augmentation — the paper's CIFAR pipeline (App. A.1):
//! random crop with 4-pixel padding, random horizontal flip, per-channel
//! normalization. Operates on single NHWC images in place of a batch slot.

use crate::util::Pcg32;

#[derive(Debug, Clone, Copy)]
pub struct AugmentCfg {
    pub pad: usize,
    pub hflip: bool,
    pub enabled: bool,
}

impl Default for AugmentCfg {
    fn default() -> Self {
        AugmentCfg { pad: 4, hflip: true, enabled: true }
    }
}

impl AugmentCfg {
    pub fn off() -> Self {
        AugmentCfg { pad: 0, hflip: false, enabled: false }
    }
}

/// Per-channel statistics for normalization, computed once on the train split.
#[derive(Debug, Clone)]
pub struct ChannelStats {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl ChannelStats {
    pub fn compute(images: &[f32], channels: usize) -> ChannelStats {
        let mut mean = vec![0.0f64; channels];
        let mut count = vec![0usize; channels];
        for (i, &v) in images.iter().enumerate() {
            mean[i % channels] += v as f64;
            count[i % channels] += 1;
        }
        for (m, &c) in mean.iter_mut().zip(&count) {
            *m /= c.max(1) as f64;
        }
        let mut var = vec![0.0f64; channels];
        for (i, &v) in images.iter().enumerate() {
            let d = v as f64 - mean[i % channels];
            var[i % channels] += d * d;
        }
        ChannelStats {
            mean: mean.iter().map(|&m| m as f32).collect(),
            std: var
                .iter()
                .zip(&count)
                .map(|(&v, &c)| ((v / c.max(1) as f64).sqrt().max(1e-6)) as f32)
                .collect(),
        }
    }

    pub fn normalize(&self, px: &mut [f32]) {
        let c = self.mean.len();
        for (i, v) in px.iter_mut().enumerate() {
            *v = (*v - self.mean[i % c]) / self.std[i % c];
        }
    }
}

/// Copy `src` (one HWC image) into `dst`, applying pad-crop + flip + norm.
///
/// Padding is zero-fill (post-normalization zeros ≈ channel mean), matching
/// the standard CIFAR `RandomCrop(32, padding=4)` recipe.
pub fn augment_into(
    src: &[f32],
    dst: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    cfg: &AugmentCfg,
    stats: &ChannelStats,
    rng: &mut Pcg32,
) {
    debug_assert_eq!(src.len(), h * w * c);
    debug_assert_eq!(dst.len(), h * w * c);
    let (dy, dx, flip) = if cfg.enabled {
        (
            rng.below(2 * cfg.pad as u32 + 1) as isize - cfg.pad as isize,
            rng.below(2 * cfg.pad as u32 + 1) as isize - cfg.pad as isize,
            cfg.hflip && rng.bool(0.5),
        )
    } else {
        (0, 0, false)
    };
    for y in 0..h {
        let sy = y as isize + dy;
        for x in 0..w {
            let sx0 = x as isize + dx;
            let sx = if flip { w as isize - 1 - sx0 } else { sx0 };
            let di = (y * w + x) * c;
            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                let si = (sy as usize * w + sx as usize) * c;
                dst[di..di + c].copy_from_slice(&src[si..si + c]);
            } else {
                dst[di..di + c].fill(0.0);
            }
        }
    }
    stats.normalize(dst);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ident_stats(c: usize) -> ChannelStats {
        ChannelStats { mean: vec![0.0; c], std: vec![1.0; c] }
    }

    #[test]
    fn disabled_augment_is_identity_with_norm() {
        let src: Vec<f32> = (0..4 * 4 * 3).map(|i| i as f32).collect();
        let mut dst = vec![0.0; src.len()];
        let mut rng = Pcg32::seeded(0);
        augment_into(&src, &mut dst, 4, 4, 3, &AugmentCfg::off(), &ident_stats(3), &mut rng);
        assert_eq!(src, dst);
    }

    #[test]
    fn normalization_applies() {
        let src = vec![2.0f32; 2 * 2 * 1];
        let mut dst = vec![0.0; 4];
        let stats = ChannelStats { mean: vec![2.0], std: vec![4.0] };
        let mut rng = Pcg32::seeded(0);
        augment_into(&src, &mut dst, 2, 2, 1, &AugmentCfg::off(), &stats, &mut rng);
        assert!(dst.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn crop_shifts_content() {
        // image with a single hot pixel; over many draws the hot pixel must
        // land on different positions (or fall off) — i.e. crops vary.
        let mut src = vec![0.0f32; 8 * 8];
        src[3 * 8 + 3] = 1.0;
        let mut rng = Pcg32::seeded(7);
        let cfg = AugmentCfg { pad: 2, hflip: false, enabled: true };
        let mut positions = std::collections::BTreeSet::new();
        for _ in 0..50 {
            let mut dst = vec![0.0f32; 64];
            augment_into(&src, &mut dst, 8, 8, 1, &cfg, &ident_stats(1), &mut rng);
            positions.insert(dst.iter().position(|&v| v == 1.0));
        }
        assert!(positions.len() > 5, "crops did not vary: {positions:?}");
    }

    #[test]
    fn flip_mirrors_row() {
        let src: Vec<f32> = (0..4).map(|i| i as f32).collect(); // 1×4×1
        let cfg = AugmentCfg { pad: 0, hflip: true, enabled: true };
        let mut rng = Pcg32::seeded(1);
        let mut saw_flipped = false;
        for _ in 0..20 {
            let mut dst = vec![0.0f32; 4];
            augment_into(&src, &mut dst, 1, 4, 1, &cfg, &ident_stats(1), &mut rng);
            if dst == [3.0, 2.0, 1.0, 0.0] {
                saw_flipped = true;
            }
        }
        assert!(saw_flipped);
    }

    #[test]
    fn channel_stats_compute() {
        // 2 pixels × 2 channels: ch0 = {1, 3}, ch1 = {2, 4}
        let img = [1.0, 2.0, 3.0, 4.0];
        let s = ChannelStats::compute(&img, 2);
        assert_eq!(s.mean, vec![2.0, 3.0]);
        assert!((s.std[0] - 1.0).abs() < 1e-6);
    }
}
