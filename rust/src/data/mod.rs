//! Data pipeline substrate: synthetic corpora, augmentation, batch loading.
//!
//! Stands in for the paper's CIFAR-10 / ImageNet inputs (DESIGN.md §4) with
//! deterministic, learnable synthetic corpora that exercise the identical
//! pipeline: generation → shuffle → pad-crop/flip augmentation → per-channel
//! normalization → fixed-size NHWC batches. `prefetch` moves the
//! augment/assemble stage onto a background thread behind a bounded channel
//! (bit-identical to the synchronous `Loader` — DESIGN.md §16).

pub mod augment;
pub mod loader;
pub mod prefetch;
pub mod synthetic;

pub use augment::{AugmentCfg, ChannelStats};
pub use loader::{Batch, Loader};
pub use prefetch::{train_source, BatchSource, Prefetcher, TrainSource};
pub use synthetic::{Corpus, CorpusSpec, Split};
