//! Data pipeline substrate: synthetic corpora, augmentation, batch loading.
//!
//! Stands in for the paper's CIFAR-10 / ImageNet inputs (DESIGN.md §4) with
//! deterministic, learnable synthetic corpora that exercise the identical
//! pipeline: generation → shuffle → pad-crop/flip augmentation → per-channel
//! normalization → fixed-size NHWC batches.

pub mod augment;
pub mod loader;
pub mod synthetic;

pub use augment::{AugmentCfg, ChannelStats};
pub use loader::{Batch, Loader};
pub use synthetic::{Corpus, CorpusSpec, Split};
