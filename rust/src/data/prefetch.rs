//! Async batch prefetch: augmentation + batch assembly off the training
//! thread (DESIGN.md §16).
//!
//! A [`Prefetcher`] owns a background producer thread running an ordinary
//! [`Loader`] over an `Arc<Split>` and ships finished [`Batch`]es through a
//! **bounded** channel, so assembly runs 1–`depth` batches ahead of the
//! consumer and memory stays bounded by backpressure. Bit-identity with the
//! synchronous loader is structural, not probabilistic: the producer applies
//! the *same* state transitions (`next_epoch` / `fill_next` / `skip_epoch`)
//! to the same `Pcg32` stream in the same order the training loop would —
//! commands are processed strictly in submission order by a single thread —
//! so the delivered batch stream, and therefore `--resume` replay via
//! [`BatchSource::skip_epoch`], is bitwise identical to `Loader`'s.
//!
//! The consumer contract is epoch-structured (what `train_epoch` does):
//! call [`BatchSource::next_epoch`], then [`BatchSource::next_batch`]
//! exactly [`BatchSource::batches_per_epoch`] times. Undrained batches from
//! an abandoned epoch are discarded on the next epoch/skip call.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::augment::AugmentCfg;
use crate::data::loader::{Batch, Loader};
use crate::data::synthetic::Split;

/// The epoch-structured face of a batch stream: everything the training
/// loop needs, implemented by both the synchronous [`Loader`] and the
/// threaded [`Prefetcher`] so `train_epoch` is generic over the two.
pub trait BatchSource {
    /// Advance to the next epoch (train mode reshuffles).
    fn next_epoch(&mut self);
    /// The next device-ready minibatch of the current epoch.
    fn next_batch(&mut self) -> Batch;
    /// Full batches per epoch (ragged tail wraps; see `Loader`).
    fn batches_per_epoch(&self) -> usize;
    /// Replay one full epoch's RNG state transitions without yielding
    /// batches — the `--resume` fast-forward path.
    fn skip_epoch(&mut self);
}

impl BatchSource for Loader<'_> {
    fn next_epoch(&mut self) {
        Loader::next_epoch(self);
    }

    fn next_batch(&mut self) -> Batch {
        Loader::next_batch(self)
    }

    fn batches_per_epoch(&self) -> usize {
        Loader::batches_per_epoch(self)
    }

    fn skip_epoch(&mut self) {
        Loader::skip_epoch(self);
    }
}

enum Cmd {
    NextEpoch,
    SkipEpoch,
    Stop,
}

/// Bounded-channel async prefetcher: a producer thread owns the loader and
/// runs `depth` batches ahead; the consumer blocks only when assembly is
/// genuinely slower than training.
pub struct Prefetcher {
    cmd: Sender<Cmd>,
    data: Receiver<Batch>,
    batches_per_epoch: usize,
    /// Batches of the current epoch produced-or-pending but not yet
    /// delivered to the consumer.
    outstanding: usize,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the producer over its own `Loader::new(split, batch, cfg, seed)`.
    /// `depth` is the data-channel bound (clamped to ≥ 1; use the
    /// synchronous `Loader` directly for depth 0 — see [`train_source`]).
    pub fn new(split: Arc<Split>, batch: usize, cfg: AugmentCfg, seed: u64, depth: usize) -> Self {
        let batches_per_epoch = split.n.div_ceil(batch);
        let (cmd, cmd_rx) = std::sync::mpsc::channel::<Cmd>();
        let (data_tx, data): (SyncSender<Batch>, Receiver<Batch>) = sync_channel(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("bsq-prefetch".into())
            .spawn(move || {
                let mut loader = Loader::new(&split, batch, cfg, seed);
                let per_epoch = loader.batches_per_epoch();
                loop {
                    match cmd_rx.recv() {
                        Ok(Cmd::NextEpoch) => {
                            loader.next_epoch();
                            for _ in 0..per_epoch {
                                // a hung-up consumer is a normal shutdown
                                if data_tx.send(loader.next_batch()).is_err() {
                                    return;
                                }
                            }
                        }
                        Ok(Cmd::SkipEpoch) => loader.skip_epoch(),
                        Ok(Cmd::Stop) | Err(_) => return,
                    }
                }
            })
            .expect("spawning prefetch producer thread");
        Prefetcher { cmd, data, batches_per_epoch, outstanding: 0, handle: Some(handle) }
    }

    /// Discard batches of an epoch the consumer abandoned mid-stream, so
    /// the producer can reach the next command.
    fn drain_outstanding(&mut self) {
        while self.outstanding > 0 {
            if self.data.recv().is_err() {
                break; // producer died; surfaced on the next next_batch/join
            }
            self.outstanding -= 1;
        }
        self.outstanding = 0;
    }
}

impl BatchSource for Prefetcher {
    fn next_epoch(&mut self) {
        self.drain_outstanding();
        if self.cmd.send(Cmd::NextEpoch).is_ok() {
            self.outstanding = self.batches_per_epoch;
        }
    }

    fn next_batch(&mut self) -> Batch {
        assert!(
            self.outstanding > 0,
            "prefetcher: next_batch with no epoch outstanding (call next_epoch first)"
        );
        let b = self.data.recv().expect("prefetch producer thread died");
        self.outstanding -= 1;
        b
    }

    fn batches_per_epoch(&self) -> usize {
        self.batches_per_epoch
    }

    fn skip_epoch(&mut self) {
        self.drain_outstanding();
        let _ = self.cmd.send(Cmd::SkipEpoch);
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let _ = self.cmd.send(Cmd::Stop);
        // Unblock a producer parked on the full bounded channel: drain until
        // it observes Stop (or the consumer hang-up) and drops its sender.
        while self.data.recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            if h.join().is_err() && !std::thread::panicking() {
                panic!("prefetch producer thread panicked");
            }
        }
    }
}

/// A training-phase batch stream: synchronous in-thread assembly, or the
/// threaded prefetcher, chosen by `depth` (0 = synchronous). Both deliver
/// bit-identical batches; the coordinator picks via `--prefetch-depth`.
pub enum TrainSource<'a> {
    Sync(Loader<'a>),
    Prefetch(Prefetcher),
}

/// Build the batch source for one training phase. `depth == 0` keeps
/// everything on the calling thread (the `BSQ_SYNC_REQUANT=1`-style
/// fallback for the data pipeline); `depth >= 1` runs assembly that many
/// batches ahead on a background thread.
pub fn train_source(
    split: &Arc<Split>,
    batch: usize,
    cfg: AugmentCfg,
    seed: u64,
    depth: usize,
) -> TrainSource<'_> {
    if depth == 0 {
        TrainSource::Sync(Loader::new(split, batch, cfg, seed))
    } else {
        TrainSource::Prefetch(Prefetcher::new(Arc::clone(split), batch, cfg, seed, depth))
    }
}

impl BatchSource for TrainSource<'_> {
    fn next_epoch(&mut self) {
        match self {
            TrainSource::Sync(l) => BatchSource::next_epoch(l),
            TrainSource::Prefetch(p) => p.next_epoch(),
        }
    }

    fn next_batch(&mut self) -> Batch {
        match self {
            TrainSource::Sync(l) => BatchSource::next_batch(l),
            TrainSource::Prefetch(p) => p.next_batch(),
        }
    }

    fn batches_per_epoch(&self) -> usize {
        match self {
            TrainSource::Sync(l) => BatchSource::batches_per_epoch(l),
            TrainSource::Prefetch(p) => p.batches_per_epoch(),
        }
    }

    fn skip_epoch(&mut self) {
        match self {
            TrainSource::Sync(l) => BatchSource::skip_epoch(l),
            TrainSource::Prefetch(p) => p.skip_epoch(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Corpus, CorpusSpec};

    fn corpus() -> Corpus {
        Corpus::generate(CorpusSpec::tiny().with_sizes(64, 32))
    }

    fn collect_epochs(src: &mut impl BatchSource, epochs: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        for _ in 0..epochs {
            src.next_epoch();
            for _ in 0..src.batches_per_epoch() {
                out.push(src.next_batch());
            }
        }
        out
    }

    fn assert_streams_equal(a: &[Batch], b: &[Batch]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.x.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.x.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "pixel mismatch at batch {i}"
            );
            assert_eq!(x.y.data(), y.y.data(), "label mismatch at batch {i}");
        }
    }

    /// Satellite: prefetch-vs-synchronous differential sweep over batch
    /// sizes and augment configs — every delivered batch bitwise equal.
    #[test]
    fn prefetcher_matches_sync_loader_across_configs() {
        let c = corpus();
        let configs = [
            AugmentCfg::default(),
            AugmentCfg::off(),
            AugmentCfg { pad: 2, hflip: false, enabled: true },
        ];
        for &batch in &[8usize, 16, 48] {
            for cfg in configs {
                for depth in [1usize, 2, 4] {
                    let mut sync = Loader::new(&c.train, batch, cfg, 11);
                    let mut pre = Prefetcher::new(Arc::clone(&c.train), batch, cfg, 11, depth);
                    assert_eq!(
                        BatchSource::batches_per_epoch(&sync),
                        pre.batches_per_epoch()
                    );
                    let a = collect_epochs(&mut sync, 3);
                    let b = collect_epochs(&mut pre, 3);
                    assert_streams_equal(&a, &b);
                }
            }
        }
    }

    /// Satellite: skip_epoch-then-train ≡ consumed-epoch-then-train with
    /// the prefetcher enabled — the `--resume` replay invariant holds
    /// through the producer thread.
    #[test]
    fn prefetcher_skip_epoch_matches_consumed_epoch() {
        let c = corpus();
        let mut skipped = Prefetcher::new(Arc::clone(&c.train), 16, AugmentCfg::default(), 9, 2);
        let mut walked = Prefetcher::new(Arc::clone(&c.train), 16, AugmentCfg::default(), 9, 2);
        for _ in 0..2 {
            skipped.skip_epoch();
            collect_epochs(&mut walked, 1);
        }
        let a = collect_epochs(&mut skipped, 1);
        let b = collect_epochs(&mut walked, 1);
        assert_streams_equal(&a, &b);
    }

    /// A prefetcher replaying skipped epochs matches the *synchronous*
    /// loader that consumed them — cross-implementation resume identity.
    #[test]
    fn prefetcher_resume_matches_sync_consumed_run() {
        let c = corpus();
        let mut sync = Loader::new(&c.train, 16, AugmentCfg::default(), 21);
        collect_epochs(&mut sync, 2);
        let mut pre = Prefetcher::new(Arc::clone(&c.train), 16, AugmentCfg::default(), 21, 2);
        pre.skip_epoch();
        pre.skip_epoch();
        let a = collect_epochs(&mut sync, 2);
        let b = collect_epochs(&mut pre, 2);
        assert_streams_equal(&a, &b);
    }

    /// Abandoning an epoch mid-stream must not wedge or desync: the next
    /// next_epoch discards undelivered batches and both sides stay aligned.
    #[test]
    fn abandoned_epoch_is_discarded_cleanly() {
        let c = corpus();
        let mut sync = Loader::new(&c.train, 16, AugmentCfg::default(), 5);
        let mut pre = Prefetcher::new(Arc::clone(&c.train), 16, AugmentCfg::default(), 5, 1);
        // consume epoch 0 only partially on the prefetcher...
        BatchSource::next_epoch(&mut sync);
        pre.next_epoch();
        for _ in 0..BatchSource::batches_per_epoch(&sync) {
            BatchSource::next_batch(&mut sync);
        }
        pre.next_batch();
        // ...then both advance; epoch 1 must still be bitwise aligned.
        let a = collect_epochs(&mut sync, 1);
        let b = collect_epochs(&mut pre, 1);
        assert_streams_equal(&a, &b);
    }

    #[test]
    fn train_source_depth_selects_implementation() {
        let c = corpus();
        let mut s0 = train_source(&c.train, 16, AugmentCfg::default(), 7, 0);
        let mut s2 = train_source(&c.train, 16, AugmentCfg::default(), 7, 2);
        assert!(matches!(&s0, TrainSource::Sync(_)));
        assert!(matches!(&s2, TrainSource::Prefetch(_)));
        let a = collect_epochs(&mut s0, 2);
        let b = collect_epochs(&mut s2, 2);
        assert_streams_equal(&a, &b);
    }

    /// Dropping a prefetcher mid-epoch (producer parked on the bounded
    /// channel) must shut down cleanly, not deadlock.
    #[test]
    fn drop_mid_epoch_does_not_deadlock() {
        let c = corpus();
        let mut pre = Prefetcher::new(Arc::clone(&c.train), 8, AugmentCfg::default(), 3, 1);
        pre.next_epoch();
        pre.next_batch();
        drop(pre);
    }
}
