//! Synthetic image corpus — the CIFAR-10 / ImageNet stand-in (DESIGN.md §4).
//!
//! The build environment has no dataset downloads, so we synthesize a
//! deterministic, *learnable but non-trivial* classification corpus that
//! exercises the exact code path the paper's experiments exercise:
//! class-conditional structure (per-class Gabor-like oriented gratings +
//! blob prototypes), instance nuisances (random phase, position jitter,
//! contrast), and pixel noise. A linear model cannot saturate it, class
//! information is spatially distributed (so convolutions matter), and
//! difficulty is seed-stable.
//!
//! Profiles: `cifar()` (10 classes, 32×32), `imagenet_sim()` (100 classes,
//! 32×32), `tiny()` (10 classes, 16×16).

use std::sync::Arc;

use crate::tensor::{IntTensor, Tensor};
use crate::util::Pcg32;

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub name: &'static str,
    pub classes: usize,
    pub hw: (usize, usize),
    pub channels: usize,
    pub train_size: usize,
    pub test_size: usize,
    /// Pixel noise stddev; higher = harder corpus.
    pub noise: f32,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn cifar() -> CorpusSpec {
        CorpusSpec {
            name: "synthetic-cifar",
            classes: 10,
            hw: (32, 32),
            channels: 3,
            train_size: 4096,
            test_size: 1024,
            noise: 0.35,
            seed: 0xC1FA_0010,
        }
    }

    pub fn imagenet_sim() -> CorpusSpec {
        CorpusSpec {
            name: "synthetic-imagenet",
            classes: 100,
            hw: (32, 32),
            channels: 3,
            train_size: 8192,
            test_size: 2048,
            noise: 0.30,
            seed: 0x1A6E_0100,
        }
    }

    pub fn tiny() -> CorpusSpec {
        CorpusSpec {
            name: "synthetic-tiny",
            classes: 10,
            hw: (16, 16),
            channels: 3,
            train_size: 512,
            test_size: 256,
            noise: 0.25,
            seed: 0x71AE_0001,
        }
    }

    pub fn with_sizes(mut self, train: usize, test: usize) -> CorpusSpec {
        self.train_size = train;
        self.test_size = test;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> CorpusSpec {
        self.seed = seed;
        self
    }
}

/// Class prototype: a mixture of oriented gratings and Gaussian blobs with
/// class-specific parameters.
#[derive(Debug, Clone)]
struct Prototype {
    /// (frequency, orientation, channel weights) per grating.
    gratings: Vec<(f32, f32, [f32; 3])>,
    /// (cy, cx, sigma, channel weights) per blob.
    blobs: Vec<(f32, f32, f32, [f32; 3])>,
}

fn make_prototypes(spec: &CorpusSpec, rng: &mut Pcg32) -> Vec<Prototype> {
    (0..spec.classes)
        .map(|_| {
            let ng = 1 + rng.below(2) as usize;
            let nb = 1 + rng.below(2) as usize;
            Prototype {
                gratings: (0..ng)
                    .map(|_| {
                        (
                            rng.range(2.0, 6.0),
                            rng.range(0.0, std::f32::consts::PI),
                            [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)],
                        )
                    })
                    .collect(),
                blobs: (0..nb)
                    .map(|_| {
                        (
                            rng.range(0.2, 0.8),
                            rng.range(0.2, 0.8),
                            rng.range(0.08, 0.25),
                            [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)],
                        )
                    })
                    .collect(),
            }
        })
        .collect()
}

/// An in-memory split: images NHWC (already normalized), labels.
#[derive(Debug, Clone)]
pub struct Split {
    pub images: Tensor,
    pub labels: IntTensor,
    pub n: usize,
}

/// The full corpus. Splits are `Arc`-shared so a background prefetch
/// thread (`data::prefetch`) can hold a handle while the training thread
/// keeps borrowing through the `Corpus`; deref coercion keeps every
/// `&corpus.train` call site working unchanged.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub spec: CorpusSpec,
    pub train: Arc<Split>,
    pub test: Arc<Split>,
}

impl Corpus {
    /// Deterministically synthesize the corpus for `spec`.
    pub fn generate(spec: CorpusSpec) -> Corpus {
        let mut rng = Pcg32::new(spec.seed, 1);
        let protos = make_prototypes(&spec, &mut rng);
        let train = render_split(&spec, &protos, spec.train_size, Pcg32::new(spec.seed, 2));
        let test = render_split(&spec, &protos, spec.test_size, Pcg32::new(spec.seed, 3));
        Corpus { spec, train: Arc::new(train), test: Arc::new(test) }
    }
}

fn render_split(spec: &CorpusSpec, protos: &[Prototype], n: usize, mut rng: Pcg32) -> Split {
    let (h, w) = spec.hw;
    let c = spec.channels;
    let mut images = vec![0.0f32; n * h * w * c];
    let mut labels = vec![0i32; n];
    for i in 0..n {
        let label = (i % spec.classes) as u32; // balanced classes
        labels[i] = label as i32;
        let img = &mut images[i * h * w * c..(i + 1) * h * w * c];
        render_instance(spec, &protos[label as usize], img, &mut rng);
    }
    Split {
        images: Tensor::new(vec![n, h, w, c], images).unwrap(),
        labels: IntTensor::new(vec![n], labels).unwrap(),
        n,
    }
}

fn render_instance(spec: &CorpusSpec, proto: &Prototype, out: &mut [f32], rng: &mut Pcg32) {
    let (h, w) = spec.hw;
    let c = spec.channels;
    // instance nuisances
    let phase = rng.range(0.0, 2.0 * std::f32::consts::PI);
    let jit_y = rng.range(-0.12, 0.12);
    let jit_x = rng.range(-0.12, 0.12);
    let contrast = rng.range(0.7, 1.3);
    for y in 0..h {
        for x in 0..w {
            let fy = y as f32 / h as f32 - 0.5 + jit_y;
            let fx = x as f32 / w as f32 - 0.5 + jit_x;
            let mut px = [0.0f32; 3];
            for (freq, theta, cw) in &proto.gratings {
                let u = fx * theta.cos() + fy * theta.sin();
                let v = (2.0 * std::f32::consts::PI * freq * u + phase).sin();
                for ch in 0..c.min(3) {
                    px[ch] += v * cw[ch];
                }
            }
            for (cy, cx, sigma, cw) in &proto.blobs {
                let dy = fy + 0.5 - cy;
                let dx = fx + 0.5 - cx;
                let g = (-(dy * dy + dx * dx) / (2.0 * sigma * sigma)).exp();
                for ch in 0..c.min(3) {
                    px[ch] += g * cw[ch];
                }
            }
            for ch in 0..c {
                let noise = rng.normal() * spec.noise;
                out[(y * w + x) * c + ch] = px[ch.min(2)] * contrast + noise;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::generate(CorpusSpec::tiny());
        let b = Corpus::generate(CorpusSpec::tiny());
        assert_eq!(a.train.images.data(), b.train.images.data());
        assert_eq!(a.train.labels.data(), b.train.labels.data());
    }

    #[test]
    fn different_seeds_differ() {
        let a = Corpus::generate(CorpusSpec::tiny());
        let b = Corpus::generate(CorpusSpec::tiny().with_seed(99));
        assert_ne!(a.train.images.data(), b.train.images.data());
    }

    #[test]
    fn shapes_and_balance() {
        let spec = CorpusSpec::tiny().with_sizes(100, 50);
        let c = Corpus::generate(spec);
        assert_eq!(c.train.images.shape(), &[100, 16, 16, 3]);
        assert_eq!(c.test.images.shape(), &[50, 16, 16, 3]);
        let mut counts = [0; 10];
        for &l in c.train.labels.data() {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&n| n == 10));
    }

    #[test]
    fn classes_are_statistically_separable() {
        // mean image of class 0 differs from class 1 far beyond noise
        let c = Corpus::generate(CorpusSpec::tiny().with_sizes(400, 10));
        let hw3 = 16 * 16 * 3;
        let mut mean = vec![vec![0.0f64; hw3]; 2];
        let mut count = [0usize; 2];
        for i in 0..c.train.n {
            let l = c.train.labels.data()[i] as usize;
            if l < 2 {
                for (j, m) in mean[l].iter_mut().enumerate() {
                    *m += c.train.images.data()[i * hw3 + j] as f64;
                }
                count[l] += 1;
            }
        }
        let dist: f64 = (0..hw3)
            .map(|j| {
                let d = mean[0][j] / count[0] as f64 - mean[1][j] / count[1] as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn values_are_sane() {
        let c = Corpus::generate(CorpusSpec::tiny().with_sizes(20, 10));
        for &v in c.train.images.data() {
            assert!(v.is_finite() && v.abs() < 20.0);
        }
    }

    #[test]
    fn imagenet_profile_has_100_classes() {
        let spec = CorpusSpec::imagenet_sim().with_sizes(200, 100);
        let c = Corpus::generate(spec);
        let max = c.train.labels.data().iter().max().unwrap();
        assert_eq!(*max, 99);
    }
}
