//! Batch loader: epoch shuffling, augmentation, fixed-size batch assembly.
//!
//! Artifacts are compiled at a static batch size, so the loader always
//! yields full batches: the final ragged remainder of an epoch wraps around
//! into the shuffled head (standard drop-last-free behaviour at small
//! corpus sizes). Deterministic given (corpus seed, loader seed, epoch).

use crate::data::augment::{augment_into, AugmentCfg, ChannelStats};
use crate::data::synthetic::Split;
use crate::tensor::{IntTensor, Tensor};
use crate::util::Pcg32;

/// One device-ready minibatch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: IntTensor,
}

pub struct Loader<'a> {
    split: &'a Split,
    batch: usize,
    hw: (usize, usize),
    channels: usize,
    cfg: AugmentCfg,
    stats: ChannelStats,
    rng: Pcg32,
    order: Vec<usize>,
    cursor: usize,
    // reusable staging buffers (hot path: no per-batch allocation)
    xbuf: Vec<f32>,
    ybuf: Vec<i32>,
}

impl<'a> Loader<'a> {
    pub fn new(split: &'a Split, batch: usize, cfg: AugmentCfg, seed: u64) -> Loader<'a> {
        let shape = split.images.shape();
        let (h, w, c) = (shape[1], shape[2], shape[3]);
        let stats = ChannelStats::compute(split.images.data(), c);
        let mut rng = Pcg32::new(seed, 17);
        let mut order: Vec<usize> = (0..split.n).collect();
        rng.shuffle(&mut order);
        Loader {
            split,
            batch,
            hw: (h, w),
            channels: c,
            cfg,
            stats,
            rng,
            order,
            cursor: 0,
            xbuf: vec![0.0; batch * h * w * c],
            ybuf: vec![0; batch],
        }
    }

    /// Evaluation loader: no augmentation, sequential order.
    pub fn eval(split: &'a Split, batch: usize) -> Loader<'a> {
        let mut l = Loader::new(split, batch, AugmentCfg::off(), 0);
        l.order = (0..split.n).collect();
        l
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.split.n.div_ceil(self.batch)
    }

    /// Advance to the next epoch: reshuffle (train mode) and reset.
    pub fn next_epoch(&mut self) {
        if self.cfg.enabled {
            self.rng.shuffle(&mut self.order);
        }
        self.cursor = 0;
    }

    /// Fill the staging buffers with the next batch and advance the cursor
    /// and augmentation RNG — the full per-batch state transition, minus
    /// the tensor materialization. [`skip_epoch`](Loader::skip_epoch) runs
    /// exactly this, so a resumed loader's RNG stream lands bit-identically
    /// where the uninterrupted run's would.
    fn fill_next(&mut self) {
        let (h, w) = self.hw;
        let c = self.channels;
        let pix = h * w * c;
        let src = self.split.images.data();
        for slot in 0..self.batch {
            let idx = self.order[(self.cursor + slot) % self.order.len()];
            let img = &src[idx * pix..(idx + 1) * pix];
            augment_into(
                img,
                &mut self.xbuf[slot * pix..(slot + 1) * pix],
                h,
                w,
                c,
                &self.cfg,
                &self.stats,
                &mut self.rng,
            );
            self.ybuf[slot] = self.split.labels.data()[idx];
        }
        self.cursor = (self.cursor + self.batch) % self.order.len().max(1);
    }

    /// Assemble the next batch (wrapping at the epoch tail).
    pub fn next_batch(&mut self) -> Batch {
        self.fill_next();
        let (h, w) = self.hw;
        Batch {
            x: Tensor::new(vec![self.batch, h, w, self.channels], self.xbuf.clone()).unwrap(),
            y: IntTensor::new(vec![self.batch], self.ybuf.clone()).unwrap(),
        }
    }

    /// Consume one full epoch without yielding batches: the epoch advance
    /// plus every per-batch shuffle/augmentation RNG draw, for replaying a
    /// loader to its position at a snapshot boundary on resume.
    pub fn skip_epoch(&mut self) {
        self.next_epoch();
        for _ in 0..self.batches_per_epoch() {
            self.fill_next();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Corpus, CorpusSpec};

    fn corpus() -> Corpus {
        Corpus::generate(CorpusSpec::tiny().with_sizes(64, 32))
    }

    #[test]
    fn batch_shapes() {
        let c = corpus();
        let mut l = Loader::new(&c.train, 16, AugmentCfg::default(), 1);
        let b = l.next_batch();
        assert_eq!(b.x.shape(), &[16, 16, 16, 3]);
        assert_eq!(b.y.shape(), &[16]);
    }

    #[test]
    fn epoch_covers_every_example_once() {
        let c = corpus();
        let mut l = Loader::eval(&c.train, 16);
        let mut seen = std::collections::BTreeMap::new();
        for _ in 0..l.batches_per_epoch() {
            let b = l.next_batch();
            for &y in b.y.data() {
                *seen.entry(y).or_insert(0) += 1;
            }
        }
        // 64 examples / 16 per batch = 4 batches, each example exactly once
        assert_eq!(seen.values().sum::<i32>(), 64);
    }

    #[test]
    fn shuffling_changes_order_across_epochs() {
        let c = corpus();
        let mut l = Loader::new(&c.train, 64, AugmentCfg::default(), 5);
        let b1 = l.next_batch().y;
        l.next_epoch();
        let b2 = l.next_batch().y;
        assert_ne!(b1.data(), b2.data());
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus();
        let mut a = Loader::new(&c.train, 8, AugmentCfg::default(), 3);
        let mut b = Loader::new(&c.train, 8, AugmentCfg::default(), 3);
        for _ in 0..5 {
            let (x, y) = (a.next_batch(), b.next_batch());
            assert_eq!(x.x.data(), y.x.data());
            assert_eq!(x.y.data(), y.y.data());
        }
    }

    #[test]
    fn eval_loader_is_unaugmented_and_normalized() {
        let c = corpus();
        let mut l1 = Loader::eval(&c.test, 32);
        let mut l2 = Loader::eval(&c.test, 32);
        assert_eq!(l1.next_batch().x.data(), l2.next_batch().x.data());
        // normalized data should be roughly zero-mean
        let mut l = Loader::eval(&c.train, 64);
        let b = l.next_batch();
        let mean: f32 = b.x.data().iter().sum::<f32>() / b.x.len() as f32;
        assert!(mean.abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn skip_epoch_matches_a_consumed_epoch_bit_for_bit() {
        let c = corpus();
        let mut skipped = Loader::new(&c.train, 16, AugmentCfg::default(), 9);
        let mut walked = Loader::new(&c.train, 16, AugmentCfg::default(), 9);
        for _ in 0..2 {
            skipped.skip_epoch();
            walked.next_epoch();
            for _ in 0..walked.batches_per_epoch() {
                walked.next_batch();
            }
        }
        // after identical epoch replays, both streams continue identically
        walked.next_epoch();
        skipped.next_epoch();
        for _ in 0..3 {
            let (a, b) = (skipped.next_batch(), walked.next_batch());
            assert_eq!(a.x.data(), b.x.data());
            assert_eq!(a.y.data(), b.y.data());
        }
    }

    #[test]
    fn wraps_final_ragged_batch() {
        let c = corpus(); // train 64
        let mut l = Loader::eval(&c.train, 48);
        assert_eq!(l.batches_per_epoch(), 2);
        l.next_batch();
        let b = l.next_batch(); // 16 real + 32 wrapped
        assert_eq!(b.y.data().len(), 48);
    }
}
