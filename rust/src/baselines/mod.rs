//! Comparator implementations: DoReFa / PACT QAT, LSQ, HAWQ ranking.
//!
//! These are the baselines the BSQ pipeline itself depends on (DoReFa is
//! the paper's finetuning substrate; PACT its low-bit activation function)
//! plus the Hessian-aware HAWQ ranking used in Tables 2–3 and Fig. 7.
//! Comparators we cannot rebuild faithfully offline (DNAS, HAQ, RVQ, DC,
//! Integer) are reported as paper-cited reference rows by the experiment
//! harnesses (DESIGN.md §4).

pub mod dorefa;
pub mod hawq;
pub mod lsq;

pub use dorefa::{train_from_scratch, QatConfig, QatOutcome};
pub use hawq::{analyze, assign_scheme, HawqConfig, HawqReport};
