//! HAWQ baseline (Dong et al., 2019): Hessian-aware importance ranking.
//!
//! HAWQ scores layer i by S_i = λ_i / n_i where λ_i is the top eigenvalue
//! of the loss Hessian restricted to that layer's weights; higher-scored
//! layers get more bits. The paper compares BSQ's discovered precision
//! ranking against this ranking (App. B.3 / Fig. 7) and against HAWQ's
//! manually assigned schemes (Tables 2–3).
//!
//! We compute λ_i by *block power iteration* on the AOT `hvp` artifact:
//! the probe vector v is zero outside layer i, Hv comes back from the
//! device, and the Rayleigh quotient converges to the top eigenvalue of
//! the layer-diagonal Hessian block (averaged over a few minibatches).

use anyhow::Result;

use crate::coordinator::trainer::Session;
use crate::data::Loader;
use crate::model::ModelState;
use crate::quant::{LayerPrec, QuantScheme};
use crate::runtime::RunInputs;
use crate::tensor::Tensor;
use crate::util::Pcg32;

#[derive(Debug, Clone)]
pub struct HawqConfig {
    pub power_iters: usize,
    pub batches: usize,
    pub seed: u64,
}

impl Default for HawqConfig {
    fn default() -> Self {
        HawqConfig { power_iters: 6, batches: 2, seed: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct HawqReport {
    /// Per-layer top Hessian eigenvalue λ_i.
    pub eigenvalues: Vec<f64>,
    /// Per-layer importance S_i = λ_i / n_i.
    pub importance: Vec<f64>,
    /// Layer indices sorted by descending importance.
    pub ranking: Vec<usize>,
}

/// Estimate per-layer top Hessian eigenvalues of the pretrained fp model.
pub fn analyze(session: &Session, state: &ModelState, cfg: &HawqConfig) -> Result<HawqReport> {
    let exe = session.artifact("hvp")?;
    let man = &session.man;
    let mut rng = Pcg32::new(cfg.seed, 0x4A39);

    // fixed analysis batches (HAWQ uses a data subsample)
    let mut loader = Loader::eval(&session.corpus.train, man.batch);
    let batches: Vec<_> = (0..cfg.batches.max(1)).map(|_| loader.next_batch()).collect();

    let mut eigenvalues = Vec::with_capacity(man.qlayers.len());
    let mut state = state.clone();
    for q in &man.qlayers {
        let probe_key = format!("v:{}", q.name);
        let hv_key = format!("hv:{}", q.name);
        // random unit start
        let mut v = Tensor::randn(&q.shape, 1.0, &mut rng);
        let norm = v.norm2().max(1e-12);
        v.scale_inplace(1.0 / norm);

        let mut lambda = 0.0f64;
        for _ in 0..cfg.power_iters {
            // Hv averaged over the analysis batches
            let mut hv_acc = Tensor::zeros(&q.shape);
            for b in batches.iter() {
                let mut inputs = RunInputs::default();
                inputs.probes.insert(probe_key.clone(), v.clone());
                let out = exe.run(&mut state, Some(b), &inputs)?;
                let hv = &out.probes[&hv_key];
                for (a, &h) in hv_acc.data_mut().iter_mut().zip(hv.data()) {
                    *a += h / batches.len() as f32;
                }
            }
            lambda = (v.dot(&hv_acc) as f64).abs(); // Rayleigh quotient (‖v‖=1)
            let n = hv_acc.norm2();
            if n < 1e-12 {
                lambda = 0.0;
                break;
            }
            hv_acc.scale_inplace(1.0 / n);
            v = hv_acc;
        }
        eigenvalues.push(lambda);
    }

    let importance: Vec<f64> = eigenvalues
        .iter()
        .zip(&man.qlayers)
        .map(|(&l, q)| l / q.params.max(1) as f64)
        .collect();
    let mut ranking: Vec<usize> = (0..importance.len()).collect();
    ranking.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
    Ok(HawqReport { eigenvalues, importance, ranking })
}

/// HAWQ's manual step: assign precisions by importance rank to hit a target
/// average bit budget. Layers are split into rank tiers mapped onto a
/// descending bit ladder around `target_bits` (HAWQ itself picks these by
/// hand; this is the deterministic policy we use for the comparison rows).
pub fn assign_scheme(
    session: &Session,
    report: &HawqReport,
    target_bits: f64,
    ladder: &[usize],
) -> QuantScheme {
    let man = &session.man;
    let n = man.qlayers.len();
    // search the tier split that gets closest to the target average
    let mut best: Option<(f64, Vec<usize>)> = None;
    // tiers: top k1 layers → ladder[0], next k2 → ladder[1], … remainder last
    let tiers = ladder.len();
    let mut counts = vec![n / tiers; tiers];
    counts[tiers - 1] += n % tiers;
    // local search: move boundaries to approach the target
    for shift in -(n as isize)..=(n as isize) {
        let mut c = counts.clone();
        let delta = shift.unsigned_abs().min(c[0] + c[tiers - 1]);
        if shift >= 0 {
            let d = delta.min(c[tiers - 1].saturating_sub(1));
            c[0] += d;
            c[tiers - 1] -= d;
        } else {
            let d = delta.min(c[0].saturating_sub(1));
            c[0] -= d;
            c[tiers - 1] += d;
        }
        let bits = bits_by_rank(report, &c, ladder, n);
        let scheme = scheme_with_bits(man, &bits);
        let err = (scheme.bits_per_param() - target_bits).abs();
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, bits));
        }
    }
    scheme_with_bits(man, &best.unwrap().1)
}

fn bits_by_rank(report: &HawqReport, counts: &[usize], ladder: &[usize], n: usize) -> Vec<usize> {
    let mut bits = vec![*ladder.last().unwrap(); n];
    let mut pos = 0usize;
    for (tier, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            if pos < n {
                bits[report.ranking[pos]] = ladder[tier];
                pos += 1;
            }
        }
    }
    bits
}

fn scheme_with_bits(man: &crate::runtime::Manifest, bits: &[usize]) -> QuantScheme {
    QuantScheme::new(
        man.qlayers
            .iter()
            .zip(bits)
            .map(|(q, &b)| LayerPrec { name: q.name.clone(), params: q.params, bits: b })
            .collect(),
    )
}
