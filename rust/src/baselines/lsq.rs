//! Learned-step-size quantization baseline (LSQ, Esser et al. 2019) —
//! the stand-in for the paper's learned-quantizer comparators (LQ-Nets /
//! LSQ rows of Tables 2–3). Uniform precision, per-layer trainable step.

use std::time::Instant;

use anyhow::Result;

use crate::baselines::dorefa::{QatConfig, QatOutcome};
use crate::coordinator::metrics::EpochRecord;
use crate::coordinator::trainer::{train_epoch, Session};
use crate::data::Loader;
use crate::model::{momentum_slots, ModelState};
use crate::quant::QuantScheme;
use crate::runtime::RunInputs;

/// Train from scratch with LSQ at a uniform `bits` precision.
///
/// LSQ codes are symmetric in [−(2^{b−1}−1), 2^{b−1}−1] per level count;
/// we pass `levels = 2^{bits−1} − 1` to match the signed quantizer the
/// artifact implements.
pub fn train_from_scratch(
    session: &Session,
    scheme: &QuantScheme,
    cfg: &QatConfig,
) -> Result<QatOutcome> {
    let exe = session.artifact("lsq_train_relu6")?;
    let eval = session.artifact("lsq_eval_relu6")?;

    let mut state = ModelState::init_fp(&session.man, cfg.seed);
    state.add_lsq_steps(&session.man)?;
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs)?;

    // signed levels: 2^{b−1} − 1 (≥ 1)
    let wlv: Vec<f32> = scheme
        .layers
        .iter()
        .map(|l| (((1u64 << l.bits.max(1)) / 2).max(2) - 1) as f32)
        .collect();
    let actlv = session.act_levels(cfg.act_bits, cfg.act_first_last);
    let mut loader =
        Loader::new(&session.corpus.train, session.man.batch, Default::default(), cfg.seed ^ 0xE);
    let mut history = crate::coordinator::History::default();
    let mut best = 0.0f32;

    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let lr = cfg.schedule.lr(epoch, cfg.epochs);
        let inputs = RunInputs::default()
            .hyper("lr", lr)
            .hyper("wd", cfg.weight_decay)
            .vec("wlv", wlv.clone())
            .vec("actlv", actlv.clone());
        let m = train_epoch(&exe, &mut loader, &mut state, &inputs)?;
        let (_, eacc) = session.evaluate(
            &eval,
            &mut state,
            &RunInputs::default().vec("wlv", wlv.clone()).vec("actlv", actlv.clone()),
            cfg.eval_batches,
        )?;
        best = best.max(eacc);
        history.push(EpochRecord {
            phase: "lsq".into(),
            epoch,
            lr,
            loss: m.loss,
            ce: m.ce,
            acc: m.acc,
            bgl: 0.0,
            eval_acc: Some(eacc),
            bits_per_param: scheme.bits_per_param(),
            compression: scheme.compression(),
            seconds: t0.elapsed().as_secs_f64(),
        });
    }
    let (_, final_acc) = session.evaluate(
        &eval,
        &mut state,
        &RunInputs::default().vec("wlv", wlv).vec("actlv", actlv),
        usize::MAX,
    )?;
    Ok(QatOutcome { final_acc, best_acc: best.max(final_acc), history })
}
