//! DoReFa-Net baseline (Zhou et al., 2016): quantization-aware training at a
//! *fixed* per-layer scheme, from scratch.
//!
//! Serves three paper roles: the DoReFa rows of Table 2, the PACT rows
//! (same weight quantizer + trainable PACT activation clip — the `pact`
//! artifact variant), and Table 1's "train from scratch" comparison where
//! the scheme is the one BSQ discovered.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::{EpochRecord, History};
use crate::coordinator::schedule::StepDecay;
use crate::coordinator::trainer::{train_epoch, Session};
use crate::coordinator::ActMode;
use crate::data::Loader;
use crate::model::{momentum_slots, ModelState};
use crate::quant::QuantScheme;
use crate::runtime::RunInputs;

#[derive(Debug, Clone)]
pub struct QatConfig {
    pub epochs: usize,
    pub act_bits: usize,
    pub act_first_last: usize,
    pub weight_decay: f32,
    pub seed: u64,
    pub eval_batches: usize,
    /// Learning-rate schedule (paper: pretrain-shaped for from-scratch QAT).
    pub schedule: StepDecay,
}

impl QatConfig {
    pub fn from_scratch(epochs: usize, act_bits: usize, seed: u64) -> QatConfig {
        QatConfig {
            epochs,
            act_bits,
            act_first_last: 8,
            weight_decay: 1e-4,
            seed,
            eval_batches: 8,
            schedule: StepDecay::pretrain(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct QatOutcome {
    pub final_acc: f32,
    pub best_acc: f32,
    pub history: History,
}

/// Train a model from scratch with DoReFa STE at the given scheme.
pub fn train_from_scratch(
    session: &Session,
    scheme: &QuantScheme,
    cfg: &QatConfig,
) -> Result<QatOutcome> {
    let act_mode = ActMode::for_bits(cfg.act_bits);
    let exe = session.artifact(&format!("dorefa_train_{}", act_mode.suffix()))?;
    let eval = session.artifact(&format!("dorefa_eval_{}", act_mode.suffix()))?;

    let mut state = ModelState::init_fp(&session.man, cfg.seed);
    if act_mode == ActMode::Pact {
        state.add_pact(&session.man);
    }
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs)?;

    let wlv = scheme.levels_vec();
    let actlv = session.act_levels(cfg.act_bits, cfg.act_first_last);
    let mut loader =
        Loader::new(&session.corpus.train, session.man.batch, Default::default(), cfg.seed ^ 0xD);
    let mut history = History::default();
    let mut best = 0.0f32;

    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let lr = cfg.schedule.lr(epoch, cfg.epochs);
        let inputs = RunInputs::default()
            .hyper("lr", lr)
            .hyper("wd", cfg.weight_decay)
            .vec("wlv", wlv.clone())
            .vec("actlv", actlv.clone());
        let m = train_epoch(&exe, &mut loader, &mut state, &inputs)?;
        let (_, eacc) = session.evaluate(
            &eval,
            &mut state,
            &RunInputs::default().vec("wlv", wlv.clone()).vec("actlv", actlv.clone()),
            cfg.eval_batches,
        )?;
        best = best.max(eacc);
        history.push(EpochRecord {
            phase: "dorefa".into(),
            epoch,
            lr,
            loss: m.loss,
            ce: m.ce,
            acc: m.acc,
            bgl: 0.0,
            eval_acc: Some(eacc),
            bits_per_param: scheme.bits_per_param(),
            compression: scheme.compression(),
            seconds: t0.elapsed().as_secs_f64(),
        });
    }
    let (_, final_acc) = session.evaluate(
        &eval,
        &mut state,
        &RunInputs::default().vec("wlv", wlv).vec("actlv", actlv),
        usize::MAX,
    )?;
    Ok(QatOutcome { final_acc, best_acc: best.max(final_acc), history })
}
