//! `faults` — deterministic, schedule-driven fault injection (DESIGN.md §12).
//!
//! The chaos discipline mirrors the repo's bit-identity discipline: faults
//! are not random monkey-testing but *seeded schedules* — a fault fires at
//! the N-th occurrence of a named injection point, so a failing chaos run
//! reproduces from its schedule string alone. Injection points are threaded
//! through the shard trainer ([`SHARD_WORKER`], [`SHARD_BARRIER`]), the
//! checkpoint writer ([`CKPT_WRITE`], [`CKPT_COMMIT`]), the serving
//! pool ([`SERVE_BATCH`], [`SERVE_BATCHER`]), and the overlapped
//! re-quantization path ([`REQUANT_WORKER`], [`REQUANT_INSTALL`]).
//!
//! Cost model: the plane is a single relaxed atomic load when disarmed —
//! production paths pay one predictable branch. Arming happens either via
//! [`inject`] (tests: returns a guard that disarms on drop and serializes
//! concurrent injections process-wide) or [`install_global`] (the CLI's
//! `--faults`, armed for the life of the process).
//!
//! Occurrence counters are keyed by `(point, key)` — e.g. shard worker 0's
//! stream is counted independently of worker 1's — so "kill worker 0 at
//! its 7th step" means the same step at any thread interleaving.
//!
//! Schedule grammar (`;`-separated): `point[#key]@nth:kind[=arg]`
//!
//! ```text
//! shard.worker#0@7:panic; ckpt.commit@0:truncate=9; serve.batcher@1:delay=30
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

/// Start of a sharded train-step worker, keyed by shard index.
pub const SHARD_WORKER: &str = "shard.worker";
/// Inside [`AbortBarrier::wait`] while the barrier mutex is held — a panic
/// here poisons the mutex (the hazard the barrier must survive); a delay
/// here stalls the lockstep.
pub const SHARD_BARRIER: &str = "shard.barrier";
/// Checkpoint save entry: `ioerr` makes the write fail before any byte
/// lands (the previous file must stay intact).
pub const CKPT_WRITE: &str = "ckpt.write";
/// Checkpoint commit: `truncate`/`bitflip` corrupt the fully-written temp
/// file just before the atomic rename — simulating a torn write the
/// rename discipline cannot catch, which the CRCs must.
pub const CKPT_COMMIT: &str = "ckpt.commit";
/// Serve-pool batch dispatch (inside the worker's `catch_unwind`): `panic`
/// kills the worker mid-batch, exercising the resurrect-and-retry path.
pub const SERVE_BATCH: &str = "serve.batch";
/// Batcher thread after a batch is collected: `delay` slows the pipeline
/// so the bounded request queue backs up (load-shedding pressure).
pub const SERVE_BATCHER: &str = "serve.batcher";
/// Start of an overlapped re-quantization worker chunk (DESIGN.md §16),
/// keyed by chunk index: `panic` kills the rebuild mid-overlap (the run
/// dies before install, so resume replays from the last snapshot); `delay`
/// makes the rebuild slower than the overlap window, proving the install
/// barrier actually waits.
pub const REQUANT_WORKER: &str = "requant.worker";
/// Just before the rebuilt reps are installed into the model state at the
/// batch boundary: `panic` here proves the install is all-or-nothing —
/// state still holds the old planes and no snapshot has been taken.
pub const REQUANT_INSTALL: &str = "requant.install";

/// What happens when a scheduled fault fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the injection point (contained by the site's unwind
    /// boundary — every instrumented site has one).
    Panic,
    /// Sleep in place.
    Delay(Duration),
    /// The site reports an I/O error instead of doing its work.
    IoError,
    /// Chop this many bytes off the end of the file being committed.
    Truncate(u64),
    /// Flip one bit (`byte_offset % file_len`, lowest bit) of the file
    /// being committed.
    BitFlip(u64),
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Panic => write!(f, "panic"),
            FaultKind::Delay(d) => write!(f, "delay={}", d.as_millis()),
            FaultKind::IoError => write!(f, "ioerr"),
            FaultKind::Truncate(n) => write!(f, "truncate={n}"),
            FaultKind::BitFlip(n) => write!(f, "bitflip={n}"),
        }
    }
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind> {
        let (name, arg) = match s.split_once('=') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (s.trim(), None),
        };
        let num = |what: &str| -> Result<u64> {
            arg.ok_or_else(|| anyhow!("fault kind {name:?} needs =<{what}>"))?
                .parse()
                .map_err(|e| anyhow!("fault kind {name:?}: bad {what} {arg:?}: {e}"))
        };
        match name {
            "panic" => Ok(FaultKind::Panic),
            "delay" => Ok(FaultKind::Delay(Duration::from_millis(num("millis")?))),
            "ioerr" => Ok(FaultKind::IoError),
            "truncate" => Ok(FaultKind::Truncate(num("bytes")?)),
            "bitflip" => Ok(FaultKind::BitFlip(num("byte offset")?)),
            other => bail!("unknown fault kind {other:?}"),
        }
    }
}

/// One scheduled fault: fire `kind` at the `nth` occurrence of `point`
/// (0-based), optionally restricted to one occurrence-counter `key`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    pub point: String,
    /// `None` matches any key (each key still counts independently).
    pub key: Option<u64>,
    pub nth: u64,
    pub kind: FaultKind,
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.key {
            Some(k) => write!(f, "{}#{}@{}:{}", self.point, k, self.nth, self.kind),
            None => write!(f, "{}@{}:{}", self.point, self.nth, self.kind),
        }
    }
}

/// A parsed fault schedule. Empty schedules are legal and useful: arming
/// one turns on occurrence counting without firing anything (see
/// [`occurrences`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Schedule {
    pub specs: Vec<FaultSpec>,
}

impl Schedule {
    pub fn parse(text: &str) -> Result<Schedule> {
        let mut specs = Vec::new();
        for item in text.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (site, kind) = item
                .split_once(':')
                .ok_or_else(|| anyhow!("fault spec {item:?}: want point[#key]@nth:kind[=arg]"))?;
            let (place, nth) = site
                .split_once('@')
                .ok_or_else(|| anyhow!("fault spec {item:?}: missing @nth"))?;
            let (point, key) = match place.split_once('#') {
                Some((p, k)) => {
                    let k: u64 = k
                        .trim()
                        .parse()
                        .map_err(|e| anyhow!("fault spec {item:?}: bad key: {e}"))?;
                    (p.trim(), Some(k))
                }
                None => (place.trim(), None),
            };
            if point.is_empty() {
                bail!("fault spec {item:?}: empty point name");
            }
            let nth: u64 =
                nth.trim().parse().map_err(|e| anyhow!("fault spec {item:?}: bad nth: {e}"))?;
            specs.push(FaultSpec {
                point: point.to_string(),
                key,
                nth,
                kind: FaultKind::parse(kind)?,
            });
        }
        Ok(Schedule { specs })
    }
}

impl fmt::Display for Schedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, s) in self.specs.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

// -- the plane ----------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static PLANE: Mutex<Option<Plane>> = Mutex::new(None);
/// Serializes [`inject`] guards so concurrent tests in one binary cannot
/// interleave schedules through the process-global plane.
static SERIALIZE: Mutex<()> = Mutex::new(());

struct Plane {
    /// `(spec, fired)` — each spec fires at most once.
    specs: Vec<(FaultSpec, bool)>,
    counters: BTreeMap<(String, u64), u64>,
    log: Vec<String>,
}

fn lock_plane() -> MutexGuard<'static, Option<Plane>> {
    PLANE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Active injection session. Dropping disarms the plane and clears the
/// schedule; the embedded serialize guard keeps sessions exclusive.
pub struct Injection {
    _serial: MutexGuard<'static, ()>,
}

impl Injection {
    /// Human-readable lines for every fault fired so far this session.
    pub fn fired(&self) -> Vec<String> {
        lock_plane().as_ref().map(|p| p.log.clone()).unwrap_or_default()
    }
}

impl Drop for Injection {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::Release);
        *lock_plane() = None;
    }
}

/// Arm the plane with `schedule` for the lifetime of the returned guard.
pub fn inject(schedule: Schedule) -> Injection {
    let serial = SERIALIZE.lock().unwrap_or_else(|e| e.into_inner());
    *lock_plane() = Some(Plane {
        specs: schedule.specs.into_iter().map(|s| (s, false)).collect(),
        counters: BTreeMap::new(),
        log: Vec::new(),
    });
    ARMED.store(true, Ordering::Release);
    Injection { _serial: serial }
}

/// Arm the plane for the rest of the process — the CLI's `--faults` path.
pub fn install_global(schedule: Schedule) {
    *lock_plane() = Some(Plane {
        specs: schedule.specs.into_iter().map(|s| (s, false)).collect(),
        counters: BTreeMap::new(),
        log: Vec::new(),
    });
    ARMED.store(true, Ordering::Release);
}

/// Occurrence count of `(point, key)` since arming (0 when disarmed).
/// With an empty schedule armed this turns the plane into a pure counter —
/// how chaos tests calibrate `@nth` indices for timing-dependent points.
pub fn occurrences(point: &str, key: u64) -> u64 {
    lock_plane()
        .as_ref()
        .and_then(|p| p.counters.get(&(point.to_string(), key)).copied())
        .unwrap_or(0)
}

/// Core check: count this occurrence of `(point, key)` and return the
/// scheduled fault, if any. Call sites that need kind-specific handling
/// (the checkpoint writer) use this directly; panic/delay sites use
/// [`fire`]. A single relaxed-ish atomic load when disarmed.
#[inline]
pub fn take(point: &str, key: u64) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Acquire) {
        return None;
    }
    take_slow(point, key)
}

#[cold]
fn take_slow(point: &str, key: u64) -> Option<FaultKind> {
    let mut guard = lock_plane();
    let plane = guard.as_mut()?;
    let counter = plane.counters.entry((point.to_string(), key)).or_insert(0);
    let occ = *counter;
    *counter += 1;
    let (spec, fired) = plane.specs.iter_mut().find(|(s, fired)| {
        !fired && s.point == point && s.nth == occ && s.key.map_or(true, |k| k == key)
    })?;
    *fired = true;
    let kind = spec.kind;
    let line = format!("{point}#{key} occurrence {occ}: {kind}");
    log::warn!("fault injected: {line}");
    plane.log.push(line.clone());
    append_log_file(&line);
    Some(kind)
}

/// Fire panic/delay faults in place (the right helper for pure code
/// paths); other kinds are meaningless at such sites and are ignored.
#[inline]
pub fn fire(point: &str, key: u64) {
    match take(point, key) {
        Some(FaultKind::Panic) => panic!("injected fault: {point}#{key}"),
        Some(FaultKind::Delay(d)) => std::thread::sleep(d),
        _ => {}
    }
}

/// Append a fired-fault line to `$BSQ_FAULT_LOG` (CI uploads this file as
/// an artifact when a chaos job fails). Best-effort.
fn append_log_file(line: &str) {
    use std::io::Write as _;
    let Some(path) = std::env::var_os("BSQ_FAULT_LOG") else { return };
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(std::path::Path::new(&path))
    {
        let _ = writeln!(f, "{line}");
    }
}

/// Render a `catch_unwind` payload: the `&str`/`String` message when there
/// is one (injected faults and `panic!` both produce these).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parses_and_roundtrips() {
        let text = "shard.worker#0@7:panic; ckpt.commit@0:truncate=9; serve.batcher@1:delay=30";
        let s = Schedule::parse(text).unwrap();
        assert_eq!(s.specs.len(), 3);
        assert_eq!(
            s.specs[0],
            FaultSpec {
                point: "shard.worker".into(),
                key: Some(0),
                nth: 7,
                kind: FaultKind::Panic
            }
        );
        assert_eq!(s.specs[1].kind, FaultKind::Truncate(9));
        assert_eq!(s.specs[2].kind, FaultKind::Delay(Duration::from_millis(30)));
        assert_eq!(Schedule::parse(&s.to_string()).unwrap(), s);
        // empty schedules arm pure counting
        assert!(Schedule::parse("").unwrap().specs.is_empty());
    }

    #[test]
    fn schedule_rejects_malformed_specs() {
        for bad in
            ["shard.worker", "p@x:panic", "p@1:noexist", "p@1:delay", "#1@0:panic", "p#z@0:panic"]
        {
            assert!(Schedule::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn fires_at_the_scheduled_occurrence_only() {
        let g = inject(Schedule::parse("t.point@2:ioerr").unwrap());
        assert_eq!(take("t.point", 0), None); // occurrence 0
        assert_eq!(take("t.point", 0), None); // occurrence 1
        assert_eq!(take("t.point", 0), Some(FaultKind::IoError));
        assert_eq!(take("t.point", 0), None); // one-shot
        assert_eq!(occurrences("t.point", 0), 4);
        assert_eq!(g.fired().len(), 1);
        assert!(g.fired()[0].contains("occurrence 2"));
    }

    #[test]
    fn keys_count_independently_and_match_exactly() {
        let _g = inject(Schedule::parse("t.keyed#1@1:ioerr").unwrap());
        assert_eq!(take("t.keyed", 0), None);
        assert_eq!(take("t.keyed", 1), None); // key 1, occurrence 0
        assert_eq!(take("t.keyed", 0), None); // key 0 never matches
        assert_eq!(take("t.keyed", 1), Some(FaultKind::IoError));
        assert_eq!(occurrences("t.keyed", 0), 2);
        assert_eq!(occurrences("t.keyed", 1), 2);
    }

    #[test]
    fn disarmed_plane_is_inert_and_guard_drop_disarms() {
        assert_eq!(take("t.inert", 0), None);
        assert_eq!(occurrences("t.inert", 0), 0);
        {
            let _g = inject(Schedule::parse("t.inert@0:panic").unwrap());
            let caught = std::panic::catch_unwind(|| fire("t.inert", 0));
            assert!(caught.is_err(), "scheduled panic must fire");
        }
        // disarmed again: same call is a no-op
        fire("t.inert", 0);
        assert_eq!(occurrences("t.inert", 0), 0);
    }

    #[test]
    fn delay_fault_sleeps_in_place() {
        let _g = inject(Schedule::parse("t.slow@0:delay=20").unwrap());
        let t0 = std::time::Instant::now();
        fire("t.slow", 0);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn panic_messages_unwrap_common_payloads() {
        let p = std::panic::catch_unwind(|| panic!("plain str")).unwrap_err();
        assert_eq!(panic_message(p), "plain str");
        let p = std::panic::catch_unwind(|| panic!("formatted {}", 7)).unwrap_err();
        assert_eq!(panic_message(p), "formatted 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_message(p), "non-string panic payload");
    }
}
