//! Zero-downtime hot-swap: replace the servable a running pool executes,
//! at a batch boundary, without dropping or mixing a single request.
//!
//! The mechanism is deliberately small (DESIGN.md §14): a [`SwapHandle`]
//! holds `(Arc<ServableModel>, generation)` behind one short-critical-
//! section mutex. Workers snapshot the pair **once per batch** and run the
//! whole forward pass against that snapshot — so a swap landing mid-pass
//! cannot tear a batch across two weight sets *by construction*: the old
//! `Arc` stays alive until its last in-flight batch drops it, and every
//! response is stamped with the generation that computed it. The
//! swap-under-load test (`tests/swap_serve.rs`) asserts the resulting
//! contract: every served response's logits bitwise-match exactly one of
//! {old, new}, and everything after the swap settles matches new.
//!
//! Batch-boundary swapping also preserves the batched-vs-single
//! bit-identity story: per-sample results depend only on which servable
//! ran the batch (kernels accumulate per output element in an order
//! independent of the batch dimension), never on where the swap landed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::serve::registry::ServableModel;

/// Poison-tolerant lock, same discipline as the worker pool: the guarded
/// pair is replaced atomically and is valid at every step.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared handle to the servable a pool is executing. Cheap to snapshot
/// (one Arc clone under a mutex), swapped atomically by a publisher.
pub struct SwapHandle {
    /// Current servable and its generation stamp, replaced as one unit so
    /// a reader can never observe a new model under an old stamp.
    current: Mutex<(Arc<ServableModel>, u64)>,
    swaps: AtomicU64,
    /// Worst-case install latency (lock → replace → unlock), microseconds.
    swap_install_us_max: AtomicU64,
    /// Batches completed against this handle — lets a publisher wait for
    /// real traffic before and after swapping (the under-load test does).
    batches_served: AtomicU64,
}

/// Generation stamp of the first installed servable. Stamp 0 is reserved
/// for "not served through a swap handle" (fixed-model pools, timed-out
/// and shed responses).
pub const FIRST_GEN: u64 = 1;

impl SwapHandle {
    pub fn new(initial: Arc<ServableModel>) -> SwapHandle {
        SwapHandle {
            current: Mutex::new((initial, FIRST_GEN)),
            swaps: AtomicU64::new(0),
            swap_install_us_max: AtomicU64::new(0),
            batches_served: AtomicU64::new(0),
        }
    }

    /// The servable and generation a batch should run against. Workers
    /// call this once per batch, never per request.
    pub fn snapshot(&self) -> (Arc<ServableModel>, u64) {
        let cur = lock(&self.current);
        (Arc::clone(&cur.0), cur.1)
    }

    /// Install `next` as the served model. In-flight batches finish on
    /// the servable they snapshotted; every later batch runs `next`.
    /// Geometry must match — the pool sized its request pipeline off the
    /// initial model, so a swap cannot change the input/output contract.
    pub fn swap(&self, next: Arc<ServableModel>) -> Result<u64> {
        let t0 = Instant::now();
        let mut cur = lock(&self.current);
        if next.sample_elems() != cur.0.sample_elems()
            || next.num_classes() != cur.0.num_classes()
        {
            bail!(
                "refusing swap: {} [{} elems → {} classes] does not match served \
                 {} [{} elems → {} classes]",
                next.model_name,
                next.sample_elems(),
                next.num_classes(),
                cur.0.model_name,
                cur.0.sample_elems(),
                cur.0.num_classes()
            );
        }
        let gen = cur.1 + 1;
        *cur = (next, gen);
        drop(cur);
        let us = t0.elapsed().as_micros() as u64;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        self.swap_install_us_max.fetch_max(us, Ordering::Relaxed);
        Ok(gen)
    }

    /// Swaps installed over this handle's lifetime.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Worst-case install latency across those swaps, microseconds.
    pub fn swap_install_us_max(&self) -> u64 {
        self.swap_install_us_max.load(Ordering::Relaxed)
    }

    /// Batches completed against this handle so far.
    pub fn batches_served(&self) -> u64 {
        self.batches_served.load(Ordering::Relaxed)
    }

    pub(crate) fn note_batch(&self) {
        self.batches_served.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Engine;
    use crate::serve::registry::{synthesize_quantized_checkpoint, ServableModel};

    fn servable(engine: &Engine, model: &str, bits: usize, seed: u64) -> Arc<ServableModel> {
        let dir = std::env::temp_dir().join(format!("bsq_swap_{}", std::process::id()));
        let path = dir.join(format!("{model}_b{bits}_s{seed}.ckpt"));
        synthesize_quantized_checkpoint(engine, model, bits, seed, &path).unwrap();
        Arc::new(ServableModel::load(engine, model, &path, 4, 8).unwrap())
    }

    #[test]
    fn swap_advances_generation_and_snapshot() {
        let engine = Engine::native();
        let a = servable(&engine, "tinynet", 6, 10);
        let b = servable(&engine, "tinynet", 3, 11);
        let h = SwapHandle::new(Arc::clone(&a));

        let (s0, g0) = h.snapshot();
        assert!(Arc::ptr_eq(&s0, &a));
        assert_eq!(g0, FIRST_GEN);
        assert_eq!(h.swaps(), 0);

        let g1 = h.swap(Arc::clone(&b)).unwrap();
        assert_eq!(g1, FIRST_GEN + 1);
        let (s1, g) = h.snapshot();
        assert!(Arc::ptr_eq(&s1, &b));
        assert_eq!(g, g1);
        assert_eq!(h.swaps(), 1);
        // install latency was measured (may round to 0 µs on a fast box,
        // so only assert it's recorded monotonically, not a magnitude)
        let _ = h.swap_install_us_max();
        // the old servable survives while someone still holds it
        assert_eq!(s0.model_name, "tinynet");
    }

    #[test]
    fn swap_rejects_geometry_change() {
        let engine = Engine::native();
        let tiny = servable(&engine, "tinynet", 4, 12);
        let deep = servable(&engine, "resnet20", 4, 12);
        assert_ne!(tiny.sample_elems(), deep.sample_elems());
        let h = SwapHandle::new(tiny);
        let err = h.swap(deep).unwrap_err().to_string();
        assert!(err.contains("refusing swap"), "{err}");
        // the failed swap must not have advanced anything
        assert_eq!(h.swaps(), 0);
        assert_eq!(h.snapshot().1, FIRST_GEN);
    }
}
