//! `serve` — the batched quantized-inference serving layer (DESIGN.md §9).
//!
//! BSQ's payoff is a mixed-precision model whose inference cost shrinks
//! with bit-level sparsity; this subsystem turns that into an end-to-end
//! throughput story. A [`Registry`] loads quantized checkpoints into
//! immutable [`ServableModel`]s — each one the model's compiled layer
//! graph (`ir`, DESIGN.md §11) bound once against the checkpoint:
//! bit-plane weights prebuilt, conv→bn→act fused, dead layers elided,
//! activations living at planned arena offsets. A batcher coalesces
//! single-sample requests into fixed-deadline dynamic batches
//! ([`BatchPolicy`]), and a scoped worker pool runs them out of
//! thread-local arenas with zero steady-state heap allocations —
//! per-sample results are bit-identical to the engine's `q_eval_*`
//! artifacts and independent of batch composition. [`stats`] digests latency percentiles, throughput,
//! and the set-weight-bits-per-sample observable that makes the
//! sparsity-vs-speedup trade visible in production terms.
//!
//! Entry points: `bsq-repro serve-bench` (closed-loop sweep →
//! `BENCH_serve.json`), `bsq-repro ingress-bench` (open-loop Poisson sweep
//! over the HTTP front door, [`ingress`], DESIGN.md §15), `bsq-repro info
//! --checkpoint` (the registry's effective-precision map), and
//! `benches/serve.rs` (the CI smoke twin).

pub mod batcher;
pub mod ingress;
pub mod registry;
pub mod stats;
pub mod swap;
pub mod worker;

use std::io;
use std::path::PathBuf;

pub use batcher::{collect_batch, BatchPolicy};
pub use registry::{
    act_levels, synthesize_quantized_checkpoint, LayerPrecision, Registry, ServableModel,
};
pub use stats::{ServeStats, ServeSummary};
pub use swap::{SwapHandle, FIRST_GEN};
pub use ingress::{run_ingress, IngressConfig, IngressReport, RouteSource, RouteSpec};
pub use worker::{
    run_closed_loop, run_closed_loop_swapped, spawn_pool, sweep, sweep_swapped, synthetic_input,
    Admission, ModelSource, PoolClient, PoolConfig, PoolState, ServeRequest, ServeResponse,
    ServeStatus, Submit, SweepCell,
};

use crate::util::json::Json;

/// Assemble the `BENCH_serve.json` payload: the servable's precision map,
/// every sweep cell, per-worker-count speedups of the largest batch size
/// over the smallest (the batching win the acceptance gate tracks), swap
/// telemetry, and a `results` array (one `{name, mean_ns}` entry per
/// cell's mean latency) so `bench-diff` can gate this record like every
/// other `BENCH_*.json`.
pub fn sweep_json(servable: &ServableModel, cells: &[SweepCell]) -> Json {
    let mut speedups: Vec<(String, Json)> = Vec::new();
    let mut worker_counts: Vec<usize> = cells.iter().map(|c| c.workers).collect();
    worker_counts.sort_unstable();
    worker_counts.dedup();
    for &w in &worker_counts {
        let mut at_w: Vec<&SweepCell> = cells.iter().filter(|c| c.workers == w).collect();
        at_w.sort_by_key(|c| c.max_batch);
        if let (Some(lo), Some(hi)) = (at_w.first(), at_w.last()) {
            if lo.max_batch != hi.max_batch {
                speedups.push((
                    format!("workers{w}_batch{}_over_batch{}", hi.max_batch, lo.max_batch),
                    Json::num(
                        hi.summary.throughput_rps / lo.summary.throughput_rps.max(1e-9),
                    ),
                ));
            }
        }
    }
    // One bench-diff-compatible entry per cell: mean request latency as
    // mean_ns under a stable cell name.
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", Json::str(format!("serve_b{}_w{}", c.max_batch, c.workers))),
                ("mean_ns", Json::num(c.summary.mean_us * 1e3)),
            ])
        })
        .collect();
    let swaps: u64 = cells.iter().map(|c| c.summary.swaps).sum();
    let install_us_max = cells.iter().map(|c| c.summary.swap_install_us_max).max().unwrap_or(0);
    Json::obj(vec![
        ("target", Json::str("serve")),
        ("model", Json::str(servable.model_name.clone())),
        ("checkpoint", Json::str(servable.checkpoint.display().to_string())),
        ("weights_digest", Json::str(servable.weights_digest.clone())),
        ("weight_bits_per_sample", Json::num(servable.weight_bits() as f64)),
        ("mean_effective_bits", Json::num(servable.mean_effective_bits())),
        ("kernel_backend", Json::str(servable.kernel_backend())),
        (
            "layers",
            Json::Arr(servable.layers.iter().map(LayerPrecision::to_json).collect()),
        ),
        ("cells", Json::Arr(cells.iter().map(SweepCell::to_json).collect())),
        ("speedups", Json::Obj(speedups)),
        ("swaps", Json::num(swaps as f64)),
        ("swap_install_us_max", Json::num(install_us_max as f64)),
        ("results", Json::Arr(results)),
    ])
}

/// Write the serve bench record: `BENCH_serve.json` in the working
/// directory, or wherever `BSQ_BENCH_OUT` points (same contract as
/// `util::bench::JsonReport`).
pub fn write_bench_json(json: &Json) -> io::Result<PathBuf> {
    let path = std::env::var_os("BSQ_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_serve.json"));
    std::fs::write(&path, json.to_string_pretty() + "\n")?;
    Ok(path)
}
