//! Checkpoint registry: quantized checkpoints → immutable servable models.
//!
//! A [`ServableModel`] is the deployment image of one BSQ run: the
//! checkpoint's bit-representation state loaded once, every layer's
//! sign-split plane bitsets prebuilt into [`BitPlaneMatrix`] weights and
//! **bound into the model's compiled infer plan** (`ir::exec::bind`) —
//! fused conv→bn→act nodes, a static activation-memory layout, fully
//! trimmed layers elided — plus the per-layer effective-precision map
//! derived from the trimmed-plane bitsets. The weight build goes through
//! the *same* `native::step::bitplane_weight` code path as the engine's
//! `q_eval_*` artifacts, so a served checkpoint is bit-identical to an
//! engine eval of the same state — `tests/serve_e2e.rs` enforces this.
//! Forward passes run out of a thread-local arena with zero steady-state
//! heap allocations (`tests/serve_alloc.rs`).
//!
//! The [`Registry`] caches servables by **content digest** — the key is
//! `(model, hash-of-checkpoint-bytes, act config)`, never the path. A
//! checkpoint rewritten in place (exactly what `GenStore` retention and
//! snapshot/resume do mid-training) therefore hashes to a new key and
//! rebuilds, instead of silently serving stale weights forever — the
//! regression `tests/swap_serve.rs::overwritten_checkpoint_is_not_served_stale`
//! pins this. Cold misses are single-flighted (one build per key, however
//! many threads race to it), the cache mutex is poison-tolerant (one
//! panicked load cannot take down every later load), and residency is
//! bounded by a byte-budgeted LRU ([`crate::store::ByteLru`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::ir;
use crate::model::{checkpoint, ModelState};
use crate::runtime::native::step::{self, AMode};
use crate::runtime::native::tape::WeightRep;
use crate::runtime::Engine;
use crate::store::{self, ByteLru};
use crate::tensor::gemm::BitPlaneMatrix;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// One layer's precision as actually deployed, read off the plane bitsets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPrecision {
    pub name: String,
    pub kind: String,
    pub params: usize,
    /// Active planes per the checkpoint's bottom-packed mask.
    pub nominal_bits: usize,
    /// Width of the widest code actually present (0 for a dead layer).
    pub effective_bits: usize,
    /// Planes holding at least one set bit (empty ones are skipped free).
    pub occupied_planes: usize,
    /// Total set weight bits — the work one output position costs.
    pub nnz_bits: u64,
}

impl LayerPrecision {
    pub fn bits_per_weight(&self) -> f64 {
        self.nnz_bits as f64 / self.params.max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("kind", Json::str(self.kind.clone())),
            ("params", Json::num(self.params as f64)),
            ("nominal_bits", Json::num(self.nominal_bits as f64)),
            ("effective_bits", Json::num(self.effective_bits as f64)),
            ("occupied_planes", Json::num(self.occupied_planes as f64)),
            ("nnz_bits", Json::num(self.nnz_bits as f64)),
            ("bits_per_weight", Json::num(self.bits_per_weight())),
        ])
    }
}

/// An immutable, thread-shareable quantized model ready to serve: the
/// compiled infer plan bound once against the checkpoint's state, nothing
/// left to look up or allocate per request.
pub struct ServableModel {
    pub model_name: String,
    pub checkpoint: PathBuf,
    /// Content digest of the checkpoint bytes — this servable's identity
    /// in the registry cache and the model store.
    pub weights_digest: String,
    pub layers: Vec<LayerPrecision>,
    /// The compiled plan resolved against this checkpoint — prebuilt
    /// bit-plane weights, BN statistics, activation levels, elision flags.
    bound: ir::BoundPlan,
    input_hw: (usize, usize),
    in_ch: usize,
    num_classes: usize,
    /// Heap bytes the prebuilt bit-plane weights keep resident — what the
    /// registry's byte-budgeted LRU charges this servable for.
    resident_bytes: usize,
}

// Servables are shared by reference across the batcher/worker/client
// threads of the pool; fail the build loudly if a field ever breaks that.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ServableModel>();
};

impl ServableModel {
    /// Load a quantized checkpoint for `model_name` and prebuild its
    /// serving weights. `act_bits`/`act_first_last` pick the activation
    /// quantization levels (the paper pins first/last sites to 8).
    pub fn load(
        engine: &Engine,
        model_name: &str,
        ckpt: &Path,
        act_bits: usize,
        act_first_last: usize,
    ) -> Result<ServableModel> {
        let digest = store::digest_file(ckpt)?;
        Self::load_with_digest(engine, model_name, ckpt, digest, act_bits, act_first_last)
    }

    /// [`ServableModel::load`] with the content digest already computed —
    /// the registry hashes the file to form the cache key and must not pay
    /// for a second read of the same bytes on a miss.
    pub(crate) fn load_with_digest(
        engine: &Engine,
        model_name: &str,
        ckpt: &Path,
        weights_digest: String,
        act_bits: usize,
        act_first_last: usize,
    ) -> Result<ServableModel> {
        let man = engine.manifest(model_name)?;
        let model = engine.native_model(model_name)?;
        let state = checkpoint::load(ckpt)
            .with_context(|| format!("loading servable checkpoint {}", ckpt.display()))?;

        let first = &man.qlayers[0].name;
        if !state.contains(&format!("wp:{first}")) {
            bail!(
                "{} is not a bit-representation checkpoint (no wp:{first}); \
                 serving runs the quantized eval path only",
                ckpt.display()
            );
        }
        let am = if man.act_sites.iter().any(|s| state.contains(&format!("pact:{s}"))) {
            AMode::Pact
        } else {
            AMode::Relu6
        };
        // Validate the state against the engine's eval contract up front so
        // a malformed checkpoint fails at load time, not mid-request.
        let suffix = if am == AMode::Pact { "pact" } else { "relu6" };
        let spec = man.artifact(&format!("q_eval_{suffix}"))?;
        state.check_against(&spec.inputs)?;

        let mut weights: BTreeMap<String, Arc<BitPlaneMatrix>> = BTreeMap::new();
        let mut layers = Vec::with_capacity(man.qlayers.len());
        let mut resident_bytes = 0usize;
        for q in &man.qlayers {
            let bpm = step::bitplane_weight(&state, model.layer(&q.name)?)?;
            resident_bytes += bpm.resident_bytes();
            let mask = state.get(&format!("mask:{}", q.name))?;
            let nnz = bpm.nnz_bits();
            layers.push(LayerPrecision {
                name: q.name.clone(),
                kind: q.kind.clone(),
                params: q.params,
                nominal_bits: mask.data().iter().filter(|&&m| m != 0.0).count(),
                effective_bits: if nnz == 0 { 0 } else { bpm.bits() },
                occupied_planes: bpm.occupied_planes(),
                nnz_bits: nnz,
            });
            weights.insert(q.name.clone(), bpm);
        }

        // Bind the compiled infer plan against this checkpoint once: all
        // state lookups happen here, none per request.
        let plans = engine.native_plans(model_name)?;
        let reps: BTreeMap<String, WeightRep> = weights
            .into_iter()
            .map(|(k, v)| (k, WeightRep::Planes(v)))
            .collect();
        let actlv = act_levels(man.act_sites.len(), act_bits, act_first_last);
        let bound = ir::bind(&plans.infer, &model, &state, reps, &actlv, am)?;

        Ok(ServableModel {
            model_name: model_name.to_string(),
            checkpoint: ckpt.to_path_buf(),
            weights_digest,
            layers,
            bound,
            input_hw: man.input_hw,
            in_ch: man.in_ch,
            num_classes: man.num_classes,
            resident_bytes,
        })
    }

    /// Heap bytes the prebuilt bit-plane weights keep resident.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Fingerprint of the deployed per-layer precision map — one leg of
    /// the manifest's (weights, precision, plan) deploy pin.
    pub fn precision_fingerprint(&self) -> String {
        store::manifest::fingerprint_parts(self.layers.iter().map(|l| {
            format!(
                "{}:{}:n{}e{}o{}z{}",
                l.name, l.kind, l.nominal_bits, l.effective_bits, l.occupied_planes, l.nnz_bits
            )
        }))
    }

    /// Fingerprint of the bound compiled plan — the third leg of the pin.
    pub fn plan_fingerprint(&self) -> String {
        store::plan_fingerprint(self.plan())
    }

    pub fn input_hw(&self) -> (usize, usize) {
        self.input_hw
    }

    pub fn in_ch(&self) -> usize {
        self.in_ch
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Elements of one input sample (`h·w·c`).
    pub fn sample_elems(&self) -> usize {
        self.input_hw.0 * self.input_hw.1 * self.in_ch
    }

    /// Total set weight bits across layers — proportional to the bit-plane
    /// GEMM work one sample costs, the serving-side sparsity observable.
    pub fn weight_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.nnz_bits).sum()
    }

    /// Params-weighted mean effective precision (the scheme's bits/param).
    pub fn mean_effective_bits(&self) -> f64 {
        let params: usize = self.layers.iter().map(|l| l.params).sum();
        let weighted: f64 =
            self.layers.iter().map(|l| (l.effective_bits * l.params) as f64).sum();
        weighted / params.max(1) as f64
    }

    /// Layers whose plane bitsets are fully trimmed — their GEMMs are
    /// elided (zero-filled) by the planned executor.
    pub fn elided_layers(&self) -> usize {
        self.bound.elided_layers()
    }

    /// The GEMM backend this servable's kernels dispatch to ("avx2+fma"
    /// or "scalar") — surfaced in serve stats so a benchmark or incident
    /// record always states which kernel family produced it.
    pub fn kernel_backend(&self) -> &'static str {
        crate::tensor::gemm::active_backend().name()
    }

    /// The compiled plan this servable executes (arena layout, fusion).
    pub fn plan(&self) -> &ir::CompiledPlan {
        self.bound.plan()
    }

    /// Run one batch `[m, h, w, c]` to logits `[m, classes]` through the
    /// bound plan, inside this thread's persistent arena. Per-sample
    /// results are bit-identical regardless of batch composition (every
    /// kernel accumulates per output element in a fixed order independent
    /// of the batch dimension), which is what lets the batcher coalesce
    /// requests freely.
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        let s = x.shape();
        if s.len() != 4 || (s[1], s[2]) != self.input_hw || s[3] != self.in_ch {
            bail!(
                "input {s:?} does not match {} geometry [m, {}, {}, {}]",
                self.model_name,
                self.input_hw.0,
                self.input_hw.1,
                self.in_ch
            );
        }
        let m = s[0];
        ir::with_thread_arena(|arena| {
            let logits = self.bound.execute(x.data(), m, arena)?;
            Tensor::new(vec![m, self.num_classes], logits.to_vec())
        })
    }

    /// [`ServableModel::infer`] without the tensor marshalling: flattened
    /// samples in, logits appended to `out`. The forward pass itself runs
    /// allocation-free once the thread's arena is warm — the serving
    /// workers' hot path (`tests/serve_alloc.rs` asserts the zero-alloc
    /// steady state with a counting allocator).
    pub fn infer_into(&self, x: &[f32], m: usize, out: &mut Vec<f32>) -> Result<usize> {
        if x.len() != m * self.sample_elems() {
            bail!(
                "flat input carries {} elements, want {} ({m} samples × {})",
                x.len(),
                m * self.sample_elems(),
                self.sample_elems()
            );
        }
        ir::with_thread_arena(|arena| self.bound.execute_into(x, m, arena, out))?;
        Ok(self.num_classes)
    }
}

/// Per-site activation levels (2^a − 1), first/last pinned — the serving
/// twin of `Session::act_levels` (no corpus needed here).
pub fn act_levels(sites: usize, bits: usize, first_last: usize) -> Vec<f32> {
    let lv = |b: usize| if b == 0 { 0.0 } else { ((1u64 << b) - 1) as f32 };
    (0..sites)
        .map(|i| if i == 0 || i + 1 == sites { lv(first_last) } else { lv(bits) })
        .collect()
}

/// Acquire a registry lock even if a previous holder panicked. A panic
/// inside one load must not poison-propagate into every later load — the
/// guarded state (cache map, in-flight latches) stays structurally valid
/// at every await-free step, so the data is safe to keep using. Same
/// discipline as `runtime::native::shard`.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One in-progress cold-miss build. Losers of the claim race park on `cv`
/// until the winner publishes an outcome.
struct Inflight {
    state: Mutex<BuildState>,
    cv: Condvar,
}

enum BuildState {
    Building,
    /// `anyhow::Error` is not `Clone`, so waiters get the failure rendered.
    Done(Result<Arc<ServableModel>, String>),
}

/// Publishes a failure for the in-flight key if the builder panics or
/// errors out before reaching its success path — without this, every
/// waiter on the latch would park forever.
struct BuildGuard<'a, 'e> {
    registry: &'a Registry<'e>,
    key: &'a str,
    latch: &'a Inflight,
    done: bool,
}

impl Drop for BuildGuard<'_, '_> {
    fn drop(&mut self) {
        if !self.done {
            self.registry.finish(
                self.key,
                self.latch,
                Err("builder thread panicked mid-load".to_string()),
            );
        }
    }
}

/// Loads checkpoints into immutable [`ServableModel`]s, cached by
/// `(model, content digest, act config)` — see the module docs for why
/// the key is the hash of the bytes, never the path.
pub struct Registry<'e> {
    engine: &'e Engine,
    cache: Mutex<ByteLru<ServableModel>>,
    inflight: Mutex<BTreeMap<String, Arc<Inflight>>>,
    builds: AtomicU64,
}

impl<'e> Registry<'e> {
    /// Unbounded residency (the pre-store behaviour).
    pub fn new(engine: &'e Engine) -> Registry<'e> {
        Registry::with_budget(engine, 0)
    }

    /// Bound resident servables to `budget_bytes` of prebuilt bit-plane
    /// weights, evicting least-recently-served first (0 = unbounded).
    pub fn with_budget(engine: &'e Engine, budget_bytes: usize) -> Registry<'e> {
        Registry {
            engine,
            cache: Mutex::new(ByteLru::new(budget_bytes)),
            inflight: Mutex::new(BTreeMap::new()),
            builds: AtomicU64::new(0),
        }
    }

    /// Load (or return the cached) servable for a checkpoint. The cache
    /// key is `(model, content-digest, act config)`: overwriting the file
    /// at the same path yields a new digest and a fresh build, and the
    /// same bytes under any path share one servable. Concurrent misses on
    /// one key are single-flighted — exactly one thread builds, the rest
    /// park and share the result.
    pub fn load(
        &self,
        model: &str,
        ckpt: &Path,
        act_bits: usize,
        act_first_last: usize,
    ) -> Result<Arc<ServableModel>> {
        let digest = store::digest_file(ckpt)?;
        let key = format!("{model}@{digest}#a{act_bits}f{act_first_last}");
        if let Some(hit) = lock(&self.cache).get(&key) {
            return Ok(hit);
        }
        // Claim the build or join one already in flight.
        let (latch, is_builder) = {
            let mut inflight = lock(&self.inflight);
            match inflight.get(&key) {
                Some(l) => (Arc::clone(l), false),
                None => {
                    let l = Arc::new(Inflight {
                        state: Mutex::new(BuildState::Building),
                        cv: Condvar::new(),
                    });
                    inflight.insert(key.clone(), Arc::clone(&l));
                    (l, true)
                }
            }
        };
        if !is_builder {
            let mut st = lock(&latch.state);
            while matches!(*st, BuildState::Building) {
                st = latch.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            return match &*st {
                BuildState::Done(Ok(sv)) => Ok(Arc::clone(sv)),
                BuildState::Done(Err(msg)) => bail!("concurrent build of {key} failed: {msg}"),
                BuildState::Building => unreachable!("woken only after a Done is published"),
            };
        }
        // Builder path. A finished builder inserts into the cache *before*
        // retiring its latch, so this re-check closes the claim race: any
        // build that completed before our claim is visible here.
        if let Some(hit) = lock(&self.cache).get(&key) {
            self.finish(&key, &latch, Ok(Arc::clone(&hit)));
            return Ok(hit);
        }
        let mut guard = BuildGuard { registry: self, key: &key, latch: &latch, done: false };
        self.builds.fetch_add(1, Ordering::Relaxed);
        // Build outside all locks: checkpoint I/O and bitset packing are
        // the slow part and must not serialize unrelated loads.
        match ServableModel::load_with_digest(self.engine, model, ckpt, digest, act_bits, act_first_last)
        {
            Ok(sv) => {
                let sv = Arc::new(sv);
                lock(&self.cache).insert(&key, Arc::clone(&sv), sv.resident_bytes());
                guard.done = true;
                self.finish(&key, &latch, Ok(Arc::clone(&sv)));
                Ok(sv)
            }
            Err(e) => {
                guard.done = true;
                self.finish(&key, &latch, Err(format!("{e:#}")));
                Err(e)
            }
        }
    }

    /// Load the deploy a model-store manifest pins for `model`, verifying
    /// the loaded bytes still hash to the pinned digest (bit-rot check —
    /// store objects are named by their own content).
    pub fn load_pinned(
        &self,
        st: &store::ModelStore,
        model: &str,
    ) -> Result<Arc<ServableModel>> {
        let (pin, path) = st.resolve(model)?;
        let sv = self.load(model, &path, pin.act_bits, pin.act_first_last)?;
        if sv.weights_digest != pin.weights_hash {
            bail!(
                "store object for {model:?} no longer hashes to its pin \
                 (want {}, got {}) — object corrupted on disk",
                pin.weights_hash,
                sv.weights_digest
            );
        }
        Ok(sv)
    }

    /// Publish an outcome on a latch and retire it.
    fn finish(&self, key: &str, latch: &Inflight, outcome: Result<Arc<ServableModel>, String>) {
        *lock(&latch.state) = BuildState::Done(outcome);
        latch.cv.notify_all();
        lock(&self.inflight).remove(key);
    }

    /// Keys of everything currently resident, least-recently-served first.
    pub fn loaded(&self) -> Vec<String> {
        lock(&self.cache).keys_lru_first()
    }

    /// Cold-miss builds actually executed (single-flight merges races).
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Budget-driven evictions so far.
    pub fn evictions(&self) -> u64 {
        lock(&self.cache).evictions()
    }

    /// Bytes of prebuilt bit-plane weights currently resident.
    pub fn resident_bytes(&self) -> usize {
        lock(&self.cache).resident_bytes()
    }
}

/// Write a deterministic quantized checkpoint for `model`: He-initialized
/// weights converted to the bit representation at `bits` and §3.3-adjusted
/// per layer. Gives `serve-bench` and the serving tests a self-contained
/// checkpoint source when no trained run is at hand.
pub fn synthesize_quantized_checkpoint(
    engine: &Engine,
    model: &str,
    bits: usize,
    seed: u64,
    path: &Path,
) -> Result<()> {
    let man = engine.manifest(model)?;
    let mut state = ModelState::init_fp(&man, seed);
    state.to_bit_representation(&man, bits)?;
    for q in &man.qlayers {
        let mut rep = state.take_bitrep(&q.name)?;
        crate::quant::requantize(&mut rep);
        state.install_bitrep(&q.name, rep);
    }
    let meta = Json::obj(vec![
        ("model", Json::str(model)),
        ("phase", Json::str("synthetic-serve")),
        ("bits", Json::num(bits as f64)),
        ("seed", Json::num(seed as f64)),
    ]);
    checkpoint::save(&state, path, &meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_levels_pin_first_and_last() {
        assert_eq!(act_levels(4, 4, 8), vec![255.0, 15.0, 15.0, 255.0]);
        assert_eq!(act_levels(1, 4, 8), vec![255.0]);
        // bits 0 disables quantization mid-model
        assert_eq!(act_levels(3, 0, 8), vec![255.0, 0.0, 255.0]);
    }

    #[test]
    fn registry_caches_and_rejects_fp_checkpoints() {
        let engine = Engine::native();
        let dir = std::env::temp_dir().join(format!("bsq_registry_{}", std::process::id()));
        let path = dir.join("tiny_q.ckpt");
        synthesize_quantized_checkpoint(&engine, "tinynet", 6, 0, &path).unwrap();

        let reg = Registry::new(&engine);
        let a = reg.load("tinynet", &path, 4, 8).unwrap();
        let b = reg.load("tinynet", &path, 4, 8).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must hit the cache");
        assert_eq!(reg.loaded().len(), 1);
        // same checkpoint at a different activation precision is a
        // different servable, not a cache hit
        let c = reg.load("tinynet", &path, 8, 8).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.loaded().len(), 2);
        assert_eq!(a.layers.len(), 4);
        assert!(a.layers.iter().all(|l| l.nominal_bits >= 1 && l.nnz_bits > 0));
        assert!(a.weight_bits() > 0);

        // a float checkpoint must be refused with a clear error
        let man = engine.manifest("tinynet").unwrap();
        let fp = ModelState::init_fp(&man, 0);
        let fp_path = dir.join("tiny_fp.ckpt");
        checkpoint::save(&fp, &fp_path, &Json::obj(vec![])).unwrap();
        let err = reg.load("tinynet", &fp_path, 4, 8).unwrap_err().to_string();
        assert!(err.contains("bit-representation"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    /// One panicked thread holding the cache mutex must not condemn every
    /// later load to a poison panic — the regression for the old
    /// `self.cache.lock().unwrap()` sites.
    #[test]
    fn poisoned_cache_still_serves() {
        let engine = Engine::native();
        let dir = std::env::temp_dir().join(format!("bsq_registry_p_{}", std::process::id()));
        let path = dir.join("tiny_q.ckpt");
        synthesize_quantized_checkpoint(&engine, "tinynet", 5, 2, &path).unwrap();

        let reg = Registry::new(&engine);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = reg.cache.lock().unwrap();
            panic!("poison the registry cache on purpose");
        }));
        assert!(reg.cache.lock().is_err(), "cache mutex must actually be poisoned");

        let a = reg.load("tinynet", &path, 4, 8).expect("poisoned cache must still serve");
        let b = reg.load("tinynet", &path, 4, 8).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "and still cache");
        assert_eq!(reg.builds(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Identical bytes under two different paths are one servable — the
    /// flip side of content keying (the stale-overwrite side lives in
    /// tests/swap_serve.rs).
    #[test]
    fn identical_bytes_share_one_servable_across_paths() {
        let engine = Engine::native();
        let dir = std::env::temp_dir().join(format!("bsq_registry_d_{}", std::process::id()));
        let path_a = dir.join("a.ckpt");
        synthesize_quantized_checkpoint(&engine, "tinynet", 6, 3, &path_a).unwrap();
        let path_b = dir.join("b.ckpt");
        std::fs::copy(&path_a, &path_b).unwrap();

        let reg = Registry::new(&engine);
        let a = reg.load("tinynet", &path_a, 4, 8).unwrap();
        let b = reg.load("tinynet", &path_b, 4, 8).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same content must be one cache entry");
        assert_eq!(reg.loaded().len(), 1);
        assert_eq!(reg.builds(), 1);
        assert_eq!(a.weights_digest, b.weights_digest);
        assert!(a.resident_bytes() > 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn servable_infer_checks_geometry() {
        let engine = Engine::native();
        let dir = std::env::temp_dir().join(format!("bsq_registry_g_{}", std::process::id()));
        let path = dir.join("tiny_q.ckpt");
        synthesize_quantized_checkpoint(&engine, "tinynet", 4, 1, &path).unwrap();
        let sv = ServableModel::load(&engine, "tinynet", &path, 4, 8).unwrap();
        assert_eq!(sv.input_hw(), (16, 16));
        assert_eq!(sv.sample_elems(), 16 * 16 * 3);
        let logits = sv.infer(Tensor::zeros(&[2, 16, 16, 3])).unwrap();
        assert_eq!(logits.shape(), &[2, 10]);
        assert!(sv.infer(Tensor::zeros(&[2, 8, 8, 3])).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
