//! Serving telemetry: latency percentiles, throughput, batch occupancy,
//! and the bits-processed-per-sample observable that ties serving speed to
//! BSQ's bit-level sparsity (fewer set weight bits → less bit-plane GEMM
//! work → higher throughput at fixed hardware).

use std::time::Duration;

use crate::util::bench::{fmt_dur, percentile};
use crate::util::json::Json;

/// Raw per-run serving measurements.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requested: usize,
    pub completed: usize,
    /// Wall time of the whole closed-loop run (clients + pool).
    pub wall: Duration,
    /// Per-request queue-to-response latencies, ascending.
    pub latencies: Vec<Duration>,
    /// Size of every batch the workers executed, in dispatch order.
    pub batch_sizes: Vec<usize>,
    /// Σ set weight bits across layers: per-sample work ∝ this number.
    pub weight_bits_per_sample: u64,
    /// Forward-pass panics caught and recovered by the worker supervisor.
    pub worker_panics: usize,
    /// Requests answered `TimedOut` (deadline expired before dispatch).
    pub timed_out: usize,
    /// Requests answered `Shed` at admission (queue full).
    pub shed: usize,
    /// Hot-swaps installed while this run was serving (0 = fixed model).
    pub swaps: u64,
    /// Worst-case swap install latency (lock→replace→unlock), microseconds.
    pub swap_install_us_max: u64,
}

impl ServeStats {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        requested: usize,
        mut latencies: Vec<Duration>,
        batch_sizes: Vec<usize>,
        wall: Duration,
        weight_bits_per_sample: u64,
        worker_panics: usize,
        timed_out: usize,
        shed: usize,
    ) -> ServeStats {
        latencies.sort();
        ServeStats {
            requested,
            completed: latencies.len(),
            wall,
            latencies,
            batch_sizes,
            weight_bits_per_sample,
            worker_panics,
            timed_out,
            shed,
            swaps: 0,
            swap_install_us_max: 0,
        }
    }

    /// Attach hot-swap telemetry (swapped-pool runs only).
    pub fn with_swaps(mut self, swaps: u64, swap_install_us_max: u64) -> ServeStats {
        self.swaps = swaps;
        self.swap_install_us_max = swap_install_us_max;
        self
    }

    pub fn summary(&self) -> ServeSummary {
        let us = |d: Option<Duration>| d.map(|d| d.as_nanos() as f64 / 1e3).unwrap_or(0.0);
        let mean = if self.latencies.is_empty() {
            0.0
        } else {
            self.latencies.iter().map(|d| d.as_nanos() as f64 / 1e3).sum::<f64>()
                / self.latencies.len() as f64
        };
        let mean_batch = if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        };
        ServeSummary {
            requested: self.requested,
            completed: self.completed,
            throughput_rps: self.completed as f64 / self.wall.as_secs_f64().max(1e-9),
            p50_us: us(percentile(&self.latencies, 0.5)),
            p99_us: us(percentile(&self.latencies, 0.99)),
            mean_us: mean,
            max_us: us(self.latencies.last().copied()),
            batches: self.batch_sizes.len(),
            mean_batch,
            max_batch_observed: self.batch_sizes.iter().copied().max().unwrap_or(0),
            weight_bits_per_sample: self.weight_bits_per_sample,
            worker_panics: self.worker_panics,
            timed_out: self.timed_out,
            shed: self.shed,
            swaps: self.swaps,
            swap_install_us_max: self.swap_install_us_max,
        }
    }
}

/// One serving configuration's digested numbers — what `BENCH_serve.json`
/// records per sweep cell.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSummary {
    pub requested: usize,
    pub completed: usize,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
    pub batches: usize,
    pub mean_batch: f64,
    pub max_batch_observed: usize,
    pub weight_bits_per_sample: u64,
    pub worker_panics: usize,
    pub timed_out: usize,
    pub shed: usize,
    pub swaps: u64,
    pub swap_install_us_max: u64,
}

impl ServeSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requested", Json::num(self.requested as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_us", Json::num(self.p50_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("mean_us", Json::num(self.mean_us)),
            ("max_us", Json::num(self.max_us)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("max_batch_observed", Json::num(self.max_batch_observed as f64)),
            ("weight_bits_per_sample", Json::num(self.weight_bits_per_sample as f64)),
            ("worker_panics", Json::num(self.worker_panics as f64)),
            ("timed_out", Json::num(self.timed_out as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("swaps", Json::num(self.swaps as f64)),
            ("swap_install_us_max", Json::num(self.swap_install_us_max as f64)),
        ])
    }

    /// One human line, criterion-report style.
    pub fn report(&self) -> String {
        let d = |us: f64| fmt_dur(Duration::from_nanos((us * 1e3) as u64));
        format!(
            "{:>9.1} req/s  p50 {:>9} p99 {:>9}  mean batch {:>5.1}  {} bits/sample  ({}/{})",
            self.throughput_rps,
            d(self.p50_us),
            d(self.p99_us),
            self.mean_batch,
            self.weight_bits_per_sample,
            self.completed,
            self.requested,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_digests_latencies_and_batches() {
        let lats: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = ServeStats::new(100, lats, vec![4, 4, 2], Duration::from_secs(2), 1234, 1, 2, 3)
            .with_swaps(2, 57);
        assert_eq!(s.completed, 100);
        let sum = s.summary();
        assert_eq!(sum.throughput_rps, 50.0);
        assert_eq!(sum.p50_us, 50_000.0);
        assert_eq!(sum.p99_us, 99_000.0);
        assert_eq!(sum.max_us, 100_000.0);
        assert_eq!(sum.batches, 3);
        assert!((sum.mean_batch - 10.0 / 3.0).abs() < 1e-9);
        assert_eq!(sum.max_batch_observed, 4);
        assert_eq!(sum.weight_bits_per_sample, 1234);
        assert_eq!((sum.worker_panics, sum.timed_out, sum.shed), (1, 2, 3));
        let j = sum.to_json();
        assert_eq!(j.req("completed").unwrap().as_usize().unwrap(), 100);
        assert_eq!(j.req("worker_panics").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("timed_out").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("shed").unwrap().as_usize().unwrap(), 3);
        assert_eq!((sum.swaps, sum.swap_install_us_max), (2, 57));
        assert_eq!(j.req("swaps").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("swap_install_us_max").unwrap().as_usize().unwrap(), 57);
        assert!(sum.report().contains("req/s"));
    }

    #[test]
    fn empty_run_is_well_defined() {
        let s = ServeStats::new(0, vec![], vec![], Duration::from_millis(1), 0, 0, 0, 0);
        let sum = s.summary();
        assert_eq!(sum.completed, 0);
        assert_eq!(sum.p50_us, 0.0);
        assert_eq!(sum.mean_batch, 0.0);
    }

    #[test]
    fn unsorted_latencies_are_sorted_on_ingest() {
        let s = ServeStats::new(
            3,
            vec![Duration::from_millis(30), Duration::from_millis(10), Duration::from_millis(20)],
            vec![3],
            Duration::from_secs(1),
            0,
            0,
            0,
            0,
        );
        assert!(s.latencies.windows(2).all(|w| w[0] <= w[1]));
    }
}
