//! Dynamic batching: coalesce single-sample requests into fixed-deadline
//! batches.
//!
//! Policy semantics (DESIGN.md §9): a batch opens when the first request
//! arrives and closes when either `max_batch` requests have been collected
//! or `max_wait` has elapsed since the first arrival — the deadline is
//! *fixed* at batch-open time, so a trickle of late arrivals cannot starve
//! the requests already waiting. Already-queued requests are drained
//! without waiting (`try_recv` before any timed block), so a backlogged
//! queue produces full batches with zero added latency.
//!
//! The collector is generic over the item type so the policy logic is
//! testable without the worker pool around it.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Batch-closing policy: size cap + fixed deadline from first arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch: max_batch.max(1), max_wait }
    }
}

/// Collect the next batch from `rx` under `policy`.
///
/// Blocks until the first item arrives (this is the idle state of the
/// batcher thread — no spinning), then fills the batch per the policy.
/// Returns `None` only when every sender is gone and the queue is empty —
/// the pool's shutdown signal.
pub fn collect_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    while batch.len() < policy.max_batch {
        match rx.try_recv() {
            Ok(item) => batch.push(item),
            Err(TryRecvError::Disconnected) => break,
            Err(TryRecvError::Empty) => {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(item) => batch.push(item),
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
    }
    // Chaos hook: a delay here models a stalled batcher with requests
    // already aged in the queue (what the timeout/shed tests exercise).
    crate::faults::fire(crate::faults::SERVE_BATCHER, 0);
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy::new(max_batch, Duration::from_millis(wait_ms))
    }

    #[test]
    fn drains_backlog_up_to_cap_without_waiting() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let t0 = Instant::now();
        let b = collect_batch(&rx, &policy(4, 5_000)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        // a backlogged queue must never pay the deadline
        assert!(t0.elapsed() < Duration::from_millis(500));
        // the remainder stays queued for the next batch
        let b2 = collect_batch(&rx, &policy(16, 0)).unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn deadline_closes_a_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let b = collect_batch(&rx, &policy(8, 30)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn zero_wait_still_takes_whatever_is_ready() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let b = collect_batch(&rx, &policy(8, 0)).unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    #[test]
    fn disconnect_flushes_then_signals_shutdown() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        // the queued item still comes out as a final batch...
        assert_eq!(collect_batch(&rx, &policy(8, 1_000)), Some(vec![7]));
        // ...and only then does the collector report shutdown
        assert_eq!(collect_batch::<i32>(&rx, &policy(8, 1_000)), None);
    }

    #[test]
    fn senders_can_feed_mid_collection() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let feeder = std::thread::spawn(move || {
            for i in 1..4 {
                std::thread::sleep(Duration::from_millis(5));
                tx.send(i).unwrap();
            }
        });
        let b = collect_batch(&rx, &policy(4, 2_000)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]); // closed by the size cap, not the deadline
        feeder.join().unwrap();
    }

    #[test]
    fn policy_clamps_zero_batch() {
        assert_eq!(policy(0, 1).max_batch, 1);
    }
}
