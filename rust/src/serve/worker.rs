//! The serving pool: batcher thread + scoped worker threads over an
//! immutable [`ServableModel`] — either fixed for the pool's lifetime or
//! read through a hot-swappable [`SwapHandle`] ([`ModelSource`]), swapped
//! at batch boundaries with zero dropped or mixed requests — plus the
//! closed-loop load harness behind `bsq-repro serve-bench` and
//! `benches/serve.rs`.
//!
//! The pool core is reusable: [`spawn_pool`] wires the batcher + workers
//! onto a caller-owned [`std::thread::scope`] and hands back a cloneable
//! [`PoolClient`]; the closed-loop harness below and the open-loop HTTP
//! ingress ([`crate::serve::ingress`], DESIGN.md §15) are both thin layers
//! over that one worker loop.
//!
//! Topology (DESIGN.md §9):
//!
//! ```text
//!  clients ──bounded mpsc──► batcher ──mpsc──► workers ──reply──► clients
//!            (backpressure)   (deadline          (bit-plane GEMM,
//!                              coalescing)        shared model)
//! ```
//!
//! Everything runs inside one `std::thread::scope`, so the pool borrows the
//! model and engine instead of cloning them, and shutdown is structural:
//! clients finishing drops the request senders, the batcher flushes its
//! final batch and drops the batch sender, the workers drain and exit —
//! no stop flags, no leaked threads.
//!
//! Supervision (DESIGN.md §12): a batch whose forward pass panics is
//! caught in the worker, and its in-flight jobs are re-enqueued on a retry
//! queue **exactly once** — no request is dropped, none is answered twice.
//! A second panic of the same batch is a pool failure. Requests can carry a
//! deadline (`PoolConfig::request_timeout`): a request already expired when
//! its batch is dispatched gets a [`ServeStatus::TimedOut`] response
//! instead of riding the forward pass. Under [`Admission::Shed`], a full
//! request queue answers immediately with [`ServeStatus::Shed`] and a
//! retry-after hint instead of blocking the client.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::faults;
use crate::serve::batcher::{collect_batch, BatchPolicy};
use crate::serve::registry::ServableModel;
use crate::serve::stats::{ServeStats, ServeSummary};
use crate::serve::swap::SwapHandle;
use crate::util::Pcg32;

/// Request-queue depth in batches: senders block (backpressure) once this
/// many batches' worth of requests are already waiting.
const QUEUE_BATCHES: usize = 4;

/// What a client does when the bounded request queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Block until the queue drains (closed-loop benching default).
    Block,
    /// Answer the request locally with [`ServeStatus::Shed`] carrying this
    /// retry-after hint — bounded-queue load shedding.
    Shed { retry_after: Duration },
}

/// Pool shape: worker count, the batcher's coalescing policy, and the
/// robustness knobs (per-request deadline, admission policy).
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// A request older than this at batch-dispatch time is answered
    /// [`ServeStatus::TimedOut`] instead of riding the forward pass.
    pub request_timeout: Option<Duration>,
    pub admission: Admission,
}

impl PoolConfig {
    /// Benching defaults: block on a full queue, no deadline.
    pub fn new(workers: usize, policy: BatchPolicy) -> PoolConfig {
        PoolConfig { workers, policy, request_timeout: None, admission: Admission::Block }
    }
}

/// How a request was resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeStatus {
    /// Served: `argmax`/`logits` are live model output.
    Ok,
    /// Deadline expired before its batch dispatched; payload fields empty.
    TimedOut,
    /// Rejected at admission: queue full; retry after the embedded hint.
    Shed { retry_after: Duration },
}

/// One enqueued inference request.
pub struct ServeRequest {
    pub client: usize,
    pub index: usize,
    /// Flattened `[h, w, c]` sample.
    pub x: Vec<f32>,
    pub enqueued: Instant,
    reply: Sender<ServeResponse>,
}

impl ServeRequest {
    /// Build a request stamped `enqueued: now`. The private reply sender
    /// guarantees the pool answers it exactly once, with the terminal
    /// [`ServeResponse`] arriving on the paired receiver.
    pub fn new(
        client: usize,
        index: usize,
        x: Vec<f32>,
        reply: Sender<ServeResponse>,
    ) -> ServeRequest {
        ServeRequest { client, index, x, enqueued: Instant::now(), reply }
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub client: usize,
    pub index: usize,
    pub status: ServeStatus,
    pub argmax: usize,
    pub logits: Vec<f32>,
    /// Queue-to-response latency.
    pub latency: Duration,
    /// Size of the batch this request rode in (0 if it never rode one).
    pub batch_size: usize,
    /// Generation of the servable that computed this response. 0 when no
    /// servable was involved (fixed-model pools, timed-out and shed
    /// requests); swappable pools stamp [`crate::serve::swap::FIRST_GEN`]
    /// and up — the swap-under-load test keys its old-vs-new audit off
    /// this field.
    pub model_gen: u64,
}

/// Where a pool reads its model: a fixed borrowed servable (the classic
/// single-checkpoint path), or a [`SwapHandle`] a publisher may hot-swap
/// while the pool is serving.
#[derive(Clone, Copy)]
pub enum ModelSource<'a> {
    Fixed(&'a ServableModel),
    Swappable(&'a SwapHandle),
}

/// A per-batch model snapshot. Swappable pools hold an `Arc` so the
/// servable stays alive for the whole forward pass even if a swap (or an
/// LRU eviction in the registry) drops every other reference mid-batch.
enum ModelRef<'a> {
    Fixed(&'a ServableModel),
    Owned(Arc<ServableModel>),
}

impl std::ops::Deref for ModelRef<'_> {
    type Target = ServableModel;
    fn deref(&self) -> &ServableModel {
        match self {
            ModelRef::Fixed(m) => m,
            ModelRef::Owned(a) => a,
        }
    }
}

impl<'a> ModelSource<'a> {
    /// The model a batch should run against, with its generation stamp.
    /// Called once per batch — the entire pass runs on this snapshot, so a
    /// swap can only take effect at a batch boundary (never a torn mix).
    fn snapshot(&self) -> (ModelRef<'a>, u64) {
        match self {
            ModelSource::Fixed(m) => (ModelRef::Fixed(m), 0),
            ModelSource::Swappable(h) => {
                let (m, gen) = h.snapshot();
                (ModelRef::Owned(m), gen)
            }
        }
    }

    fn note_batch(&self) {
        if let ModelSource::Swappable(h) = self {
            h.note_batch();
        }
    }

    fn sample_elems(&self) -> usize {
        match self {
            ModelSource::Fixed(m) => m.sample_elems(),
            // geometry is swap-invariant (SwapHandle::swap enforces it),
            // so reading it off the current snapshot is stable for the run
            ModelSource::Swappable(h) => h.snapshot().0.sample_elems(),
        }
    }
}

/// One batch in flight between batcher and workers. `retried` enforces the
/// exactly-once re-enqueue: a batch that panics once goes back on the retry
/// queue; a batch that panics twice fails the pool.
struct BatchJob {
    jobs: Vec<ServeRequest>,
    retried: bool,
}

/// Poison-tolerant lock: a panicking batch is caught inside the worker, but
/// an injected panic elsewhere must not cascade into `PoisonError` unwraps.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Deterministic synthetic sample for client `c`, request `i` — public so
/// tests can regenerate a request's input and check the served logits
/// against a direct single-sample inference.
pub fn synthetic_input(seed: u64, client: usize, index: usize, elems: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(
        seed ^ ((client as u64) << 40) ^ ((index as u64) << 8),
        0x5e2e,
    );
    (0..elems).map(|_| rng.normal()).collect()
}

/// Shared mutable state of one pool. The caller allocates it *before*
/// opening the thread scope so the scoped pool threads and any number of
/// [`PoolClient`] handles can both borrow it; after the scope closes it
/// holds the pool's telemetry (batch log, panic count, first failure).
#[derive(Default)]
pub struct PoolState {
    /// Panicked batches land here for their one retry. A plain shared
    /// deque (not another sender on the batch channel): workers holding a
    /// sender clone would keep the batch channel alive and break the
    /// disconnect-based structural shutdown.
    retry: Mutex<VecDeque<BatchJob>>,
    batch_log: Mutex<Vec<usize>>,
    failure: Mutex<Option<String>>,
    worker_panics: AtomicUsize,
    /// Requests currently sitting in the bounded queue — incremented at
    /// submit, decremented when the batcher collects a batch. This is the
    /// admission layer's occupancy signal (DESIGN.md §15); it is racy by
    /// at most a batch's worth of requests and only ever over-counts, so
    /// reading it can shed slightly early but never admits into a queue
    /// the `try_send` backstop would reject.
    depth: AtomicUsize,
}

impl PoolState {
    pub fn new() -> PoolState {
        PoolState::default()
    }

    /// First recorded pool failure, if any.
    pub fn failure(&self) -> Option<String> {
        lock(&self.failure).clone()
    }

    /// Record a pool-level failure unless one is already recorded.
    pub fn fail(&self, msg: String) {
        let mut slot = lock(&self.failure);
        if slot.is_none() {
            *slot = Some(msg);
        }
    }

    pub fn worker_panics(&self) -> usize {
        self.worker_panics.load(Ordering::Relaxed)
    }

    /// Drain the per-batch size log (call after the pool's scope closed).
    pub fn take_batch_log(&self) -> Vec<usize> {
        std::mem::take(&mut *lock(&self.batch_log))
    }

    /// Current request-queue occupancy (conservative; see `depth` field).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

/// Outcome of a non-blocking [`PoolClient::try_submit`].
pub enum Submit {
    /// Queued; the reply channel will answer exactly once.
    Sent,
    /// Bounded queue full — request handed back so the caller can shed it.
    Full(ServeRequest),
    /// The pool is gone (scope tearing down); request handed back.
    Closed(ServeRequest),
}

/// Submit-side handle to a pool spawned with [`spawn_pool`]. Clone one per
/// submitting thread; the pool shuts down structurally when the last clone
/// drops — the batcher sees the request channel disconnect, flushes its
/// final batch, and the workers drain and exit. No stop flags.
pub struct PoolClient<'a> {
    tx: SyncSender<ServeRequest>,
    state: &'a PoolState,
    capacity: usize,
}

impl Clone for PoolClient<'_> {
    fn clone(&self) -> Self {
        PoolClient { tx: self.tx.clone(), state: self.state, capacity: self.capacity }
    }
}

impl<'a> PoolClient<'a> {
    /// Bounded request-queue capacity (`max_batch × QUEUE_BATCHES`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current queue occupancy (conservative; see [`PoolState`]).
    pub fn depth(&self) -> usize {
        self.state.depth()
    }

    /// The pool state this handle submits into.
    pub fn state(&self) -> &'a PoolState {
        self.state
    }

    /// Blocking submit — waits for queue room (the closed-loop client
    /// discipline). `false` means the pool is gone; the request (and its
    /// reply sender) was dropped.
    pub fn send_blocking(&self, req: ServeRequest) -> bool {
        self.state.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.send(req) {
            Ok(()) => true,
            Err(_) => {
                self.state.depth.fetch_sub(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Non-blocking submit — the admission-controlled ingress path.
    pub fn try_submit(&self, req: ServeRequest) -> Submit {
        self.state.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(req) {
            Ok(()) => Submit::Sent,
            Err(TrySendError::Full(req)) => {
                self.state.depth.fetch_sub(1, Ordering::Relaxed);
                Submit::Full(req)
            }
            Err(TrySendError::Disconnected(req)) => {
                self.state.depth.fetch_sub(1, Ordering::Relaxed);
                Submit::Closed(req)
            }
        }
    }
}

/// Spawn a pool's batcher + worker threads onto `s` and return the submit
/// handle. `state` must be allocated *outside* the scope so the handle and
/// the scoped threads can both borrow it. Lifecycle is structural: the
/// pool runs until every [`PoolClient`] clone is dropped, then drains and
/// exits; closing the scope joins the threads.
pub fn spawn_pool<'scope, 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    source: ModelSource<'env>,
    cfg: &PoolConfig,
    state: &'env PoolState,
) -> PoolClient<'env> {
    let workers = cfg.workers.max(1);
    let policy = cfg.policy;
    let request_timeout = cfg.request_timeout;
    let capacity = policy.max_batch.max(1) * QUEUE_BATCHES;
    // Each worker gets its share of the cores for intra-op GEMM fan-out
    // (the shard trainer's budget rule). A saturated pool (workers ≥
    // cores) runs at cap 1, where forward passes are also allocation-free
    // (tests/serve_alloc.rs); an undersubscribed pool keeps the idle
    // cores working inside the kernels instead.
    let gemm_cap = crate::tensor::gemm::worker_budget(workers);

    let (req_tx, req_rx) = sync_channel::<ServeRequest>(capacity);
    let (batch_tx, batch_rx) = channel::<Vec<ServeRequest>>();
    // Workers share the batch receiver behind a mutex (the lock is held
    // across the blocking recv, which only serializes *waiting* — exactly
    // one worker can pop the next batch either way). Arc'd because
    // spawn_pool returns before the scope closes, so the receiver cannot
    // live on this stack frame.
    let batch_rx = Arc::new(Mutex::new(batch_rx));

    // Batcher: owns the request receiver; exits when every submit handle
    // is gone and the queue is drained.
    s.spawn(move || {
        while let Some(batch) = collect_batch(&req_rx, &policy) {
            state.depth.fetch_sub(batch.len(), Ordering::Relaxed);
            if batch_tx.send(batch).is_err() {
                break; // every worker died; nobody left to serve
            }
        }
    });

    for _ in 0..workers {
        let batch_rx = Arc::clone(&batch_rx);
        s.spawn(move || worker_loop(source, state, &batch_rx, request_timeout, gemm_cap));
    }

    PoolClient { tx: req_tx, state, capacity }
}

/// One worker thread: pop batches (retried batches take priority over
/// fresh ones, and the batcher-gone shutdown path re-checks the retry
/// queue so a batch whose panic raced the disconnect is never orphaned),
/// partition out expired riders at dispatch, snapshot the model once per
/// batch, and run the panic-supervised forward pass.
///
/// On a compute error the worker records the first failure and keeps
/// *draining* batches without executing them: dropping a job drops its
/// reply sender, which unblocks its submitter with an error, which stops
/// that submitter from sending more — the structural shutdown then unwinds
/// as usual. Breaking out instead would leave queued batches holding reply
/// senders forever (the batch receiver lives in the sibling workers, so
/// the batcher's send never fails) and the submitters would hang.
fn worker_loop(
    source: ModelSource<'_>,
    state: &PoolState,
    batch_rx: &Mutex<Receiver<Vec<ServeRequest>>>,
    request_timeout: Option<Duration>,
    gemm_cap: usize,
) {
    crate::tensor::gemm::set_thread_parallelism_cap(gemm_cap);
    loop {
        let job = match lock(&state.retry).pop_front() {
            Some(job) => job,
            None => match lock(batch_rx).recv() {
                Ok(jobs) => BatchJob { jobs, retried: false },
                // Batcher gone: drain a retry that raced the disconnect,
                // else shut down.
                Err(_) => match lock(&state.retry).pop_front() {
                    Some(job) => job,
                    None => break,
                },
            },
        };
        if state.failure().is_some() {
            continue; // failed pool: drain and drop to unblock submitters
        }
        let BatchJob { jobs, retried } = job;
        // Deadline check at dispatch: expired riders get a TimedOut
        // answer instead of the forward pass.
        let (live, expired): (Vec<_>, Vec<_>) = match request_timeout {
            Some(t) => jobs.into_iter().partition(|j| j.enqueued.elapsed() < t),
            None => (jobs, Vec::new()),
        };
        for j in expired {
            resolve_empty(j, ServeStatus::TimedOut);
        }
        if live.is_empty() {
            continue;
        }
        // One snapshot per batch: the entire pass (and its retry, if it
        // panics) runs against whatever servable is current at *this*
        // boundary. A concurrent swap changes the next batch, never this
        // one.
        let (model, model_gen) = source.snapshot();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            faults::fire(faults::SERVE_BATCH, 0);
            compute_rows(&model, &live)
        }));
        match outcome {
            Ok(Ok(rows)) => {
                source.note_batch();
                lock(&state.batch_log).push(live.len());
                send_rows(live, rows, model_gen);
            }
            Ok(Err(e)) => state.fail(format!("{e:#}")),
            Err(payload) => {
                state.worker_panics.fetch_add(1, Ordering::Relaxed);
                let msg = faults::panic_message(payload);
                if retried {
                    // Second panic of the same batch: the input is
                    // poison, not bad luck. Fail the pool.
                    state.fail(format!("batch panicked twice: {msg}"));
                } else {
                    log::warn!(
                        "serve worker panicked ({msg}); re-enqueueing \
                         {}-request batch once",
                        live.len()
                    );
                    lock(&state.retry).push_back(BatchJob { jobs: live, retried: true });
                }
            }
        }
    }
}

/// Run one batch's forward pass and return a logits row per job, sending
/// nothing. The compute/send split is what makes panic recovery safe: a
/// panic can only happen in here, *before* any response exists, so
/// re-enqueueing the jobs can never duplicate an answer. The pass runs
/// through the servable's bound plan in this thread's arena
/// (`ServableModel::infer_into`) — no tensor marshalling, and zero heap
/// allocations inside the pass once the arena is warm.
fn compute_rows(model: &ServableModel, jobs: &[ServeRequest]) -> Result<Vec<Vec<f32>>> {
    let m = jobs.len();
    let pix = model.sample_elems();
    let mut xb = Vec::with_capacity(m * pix);
    for j in jobs {
        if j.x.len() != pix {
            bail!(
                "request {}/{} carries {} elements, model wants {pix}",
                j.client,
                j.index,
                j.x.len()
            );
        }
        xb.extend_from_slice(&j.x);
    }
    let mut data = Vec::with_capacity(m * model.num_classes());
    let classes = model.infer_into(&xb, m, &mut data)?;
    Ok((0..m).map(|ji| data[ji * classes..(ji + 1) * classes].to_vec()).collect())
}

/// Answer every rider of a computed batch. Infallible by construction —
/// runs only after `compute_rows` succeeded. `model_gen` is the stamp of
/// the snapshot that computed the rows.
fn send_rows(jobs: Vec<ServeRequest>, rows: Vec<Vec<f32>>, model_gen: u64) {
    let m = jobs.len();
    for (j, row) in jobs.into_iter().zip(rows) {
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let resp = ServeResponse {
            client: j.client,
            index: j.index,
            status: ServeStatus::Ok,
            argmax,
            logits: row,
            latency: j.enqueued.elapsed(),
            batch_size: m,
            model_gen,
        };
        let _ = j.reply.send(resp); // requester may have given up; not fatal
    }
}

/// Answer a request that never rode a batch (timeout / shed).
fn resolve_empty(j: ServeRequest, status: ServeStatus) {
    let resp = ServeResponse {
        client: j.client,
        index: j.index,
        status,
        argmax: 0,
        logits: Vec::new(),
        latency: j.enqueued.elapsed(),
        batch_size: 0,
        model_gen: 0,
    };
    let _ = j.reply.send(resp);
}

/// Drive `total` requests through a freshly spun-up pool from `clients`
/// closed-loop client threads (each sends its next request only after the
/// previous one answered — offered load matches capacity, the standard
/// serving-bench discipline). Returns the run's stats plus every response,
/// so callers can verify payloads; responses arrive in client-completion
/// order, keyed by `(client, index)`. Exactly one response per request:
/// `Ok`, `TimedOut`, or `Shed`.
pub fn run_closed_loop(
    model: &ServableModel,
    cfg: &PoolConfig,
    total: usize,
    clients: usize,
    seed: u64,
) -> Result<(ServeStats, Vec<ServeResponse>)> {
    run_closed_loop_on(ModelSource::Fixed(model), cfg, total, clients, seed)
}

/// [`run_closed_loop`] against a hot-swappable handle: a publisher thread
/// may call [`SwapHandle::swap`] while this runs, and the pool picks up
/// the new servable at the next batch boundary with zero dropped or
/// mixed-weights requests (`tests/swap_serve.rs` asserts the contract).
pub fn run_closed_loop_swapped(
    handle: &SwapHandle,
    cfg: &PoolConfig,
    total: usize,
    clients: usize,
    seed: u64,
) -> Result<(ServeStats, Vec<ServeResponse>)> {
    run_closed_loop_on(ModelSource::Swappable(handle), cfg, total, clients, seed)
}

fn run_closed_loop_on(
    source: ModelSource<'_>,
    cfg: &PoolConfig,
    total: usize,
    clients: usize,
    seed: u64,
) -> Result<(ServeStats, Vec<ServeResponse>)> {
    if total == 0 || clients == 0 {
        bail!("closed loop needs at least one request and one client");
    }
    // Same audit as the trainer's empty-shard fix: never spin up more
    // workers than there are requests — the surplus threads could only ever
    // idle on the batch queue until shutdown.
    let cfg = PoolConfig { workers: cfg.workers.max(1).min(total), ..*cfg };
    let admission = cfg.admission;
    let pix = source.sample_elems();
    let state = PoolState::new();

    let mut responses: Vec<ServeResponse> = Vec::with_capacity(total);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let pool = spawn_pool(s, source, &cfg, &state);

        // Closed-loop clients.
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let pool = pool.clone();
            handles.push(s.spawn(move || {
                let quota = total / clients + usize::from(c < total % clients);
                let mut done = Vec::with_capacity(quota);
                for i in 0..quota {
                    let (rtx, rrx) = channel();
                    let req =
                        ServeRequest::new(c, i, synthetic_input(seed, c, i, pix), rtx);
                    match admission {
                        Admission::Block => {
                            if !pool.send_blocking(req) {
                                break; // pool tore down under us
                            }
                        }
                        Admission::Shed { retry_after } => match pool.try_submit(req) {
                            Submit::Sent => {}
                            Submit::Full(req) => {
                                // Queue full: answer locally, skip the wait.
                                done.push(ServeResponse {
                                    client: c,
                                    index: i,
                                    status: ServeStatus::Shed { retry_after },
                                    argmax: 0,
                                    logits: Vec::new(),
                                    latency: req.enqueued.elapsed(),
                                    batch_size: 0,
                                    model_gen: 0,
                                });
                                continue;
                            }
                            Submit::Closed(_) => break,
                        },
                    }
                    match rrx.recv() {
                        Ok(resp) => done.push(resp),
                        Err(_) => break, // reply dropped: worker failed
                    }
                }
                done
            }));
        }
        drop(pool); // clients hold the only submit handles now
        for h in handles {
            // A panicking client is a harness bug, but it must surface as
            // a pool failure, not tear down the caller mid-scope.
            match h.join() {
                Ok(rs) => responses.extend(rs),
                Err(payload) => state.fail(format!(
                    "serve client thread panicked: {}",
                    faults::panic_message(payload)
                )),
            }
        }
    });
    let wall = t0.elapsed();

    if let Some(msg) = state.failure() {
        bail!("serve worker failed: {msg}");
    }
    if responses.len() != total {
        bail!("closed loop completed {}/{} requests", responses.len(), total);
    }
    let timed_out = responses.iter().filter(|r| r.status == ServeStatus::TimedOut).count();
    let shed = responses
        .iter()
        .filter(|r| matches!(r.status, ServeStatus::Shed { .. }))
        .count();
    // Latency percentiles digest served requests only; timeout/shed volumes
    // are reported as their own counters.
    let latencies = responses
        .iter()
        .filter(|r| r.status == ServeStatus::Ok)
        .map(|r| r.latency)
        .collect();
    // Swapped pools report the *current* (post-swap) servable's bits plus
    // the swap telemetry; fixed pools report their one model, zero swaps.
    let (weight_bits, swaps, install_us) = match source {
        ModelSource::Fixed(m) => (m.weight_bits(), 0, 0),
        ModelSource::Swappable(h) => {
            (h.snapshot().0.weight_bits(), h.swaps(), h.swap_install_us_max())
        }
    };
    let stats = ServeStats::new(
        total,
        latencies,
        state.take_batch_log(),
        wall,
        weight_bits,
        state.worker_panics(),
        timed_out,
        shed,
    )
    .with_swaps(swaps, install_us);
    Ok((stats, responses))
}

/// One cell of the serve-bench sweep grid.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub max_batch: usize,
    pub workers: usize,
    pub summary: ServeSummary,
}

impl SweepCell {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut kv = vec![
            ("max_batch".to_string(), Json::num(self.max_batch as f64)),
            ("workers".to_string(), Json::num(self.workers as f64)),
        ];
        if let Json::Obj(fields) = self.summary.to_json() {
            kv.extend(fields);
        }
        Json::Obj(kv)
    }
}

/// Closed-loop sweep over batch-size × worker-count cells (each cell a
/// fresh pool; clients = 2×max_batch keep the queue fed so the batcher can
/// actually fill batches).
pub fn sweep(
    model: &ServableModel,
    batches: &[usize],
    workers: &[usize],
    requests: usize,
    max_wait: Duration,
    seed: u64,
) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::with_capacity(batches.len() * workers.len());
    for &w in workers {
        for &b in batches {
            let cfg = PoolConfig::new(w, BatchPolicy::new(b, max_wait));
            let clients = (2 * b.max(1)).min(requests.max(1));
            let (stats, _) = run_closed_loop(model, &cfg, requests, clients, seed)?;
            cells.push(SweepCell { max_batch: b.max(1), workers: w, summary: stats.summary() });
        }
    }
    Ok(cells)
}

/// [`sweep`] through a [`SwapHandle`]: every cell starts serving `old`,
/// and once the pool has completed a couple of batches a publisher thread
/// installs `new` — so each cell's summary carries live hot-swap telemetry
/// (`swaps`, `swap_install_us_max`) measured under real traffic.
pub fn sweep_swapped(
    old: &Arc<ServableModel>,
    new: &Arc<ServableModel>,
    batches: &[usize],
    workers: &[usize],
    requests: usize,
    max_wait: Duration,
    seed: u64,
) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::with_capacity(batches.len() * workers.len());
    for &w in workers {
        for &b in batches {
            let cfg = PoolConfig::new(w, BatchPolicy::new(b, max_wait));
            let clients = (2 * b.max(1)).min(requests.max(1));
            let handle = SwapHandle::new(Arc::clone(old));
            let run = std::thread::scope(|s| {
                let publisher = s.spawn(|| {
                    // Wait for real traffic, but never past the run: short
                    // cells (tiny --requests) may finish in one batch, in
                    // which case the late swap is harmless telemetry.
                    let t0 = Instant::now();
                    while handle.batches_served() < 2
                        && t0.elapsed() < Duration::from_secs(2)
                    {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    handle.swap(Arc::clone(new))
                });
                let run = run_closed_loop_swapped(&handle, &cfg, requests, clients, seed);
                publisher.join().expect("publisher thread panicked").map(|_gen| ())?;
                run
            })?;
            let (stats, _) = run;
            cells.push(SweepCell { max_batch: b.max(1), workers: w, summary: stats.summary() });
        }
    }
    Ok(cells)
}
