//! The serving pool: batcher thread + scoped worker threads over one
//! immutable [`ServableModel`], plus the closed-loop load harness behind
//! `bsq-repro serve-bench` and `benches/serve.rs`.
//!
//! Topology (DESIGN.md §9):
//!
//! ```text
//!  clients ──bounded mpsc──► batcher ──mpsc──► workers ──reply──► clients
//!            (backpressure)   (deadline          (bit-plane GEMM,
//!                              coalescing)        shared model)
//! ```
//!
//! Everything runs inside one `std::thread::scope`, so the pool borrows the
//! model and engine instead of cloning them, and shutdown is structural:
//! clients finishing drops the request senders, the batcher flushes its
//! final batch and drops the batch sender, the workers drain and exit —
//! no stop flags, no leaked threads.

use std::sync::mpsc::{channel, sync_channel, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::serve::batcher::{collect_batch, BatchPolicy};
use crate::serve::registry::ServableModel;
use crate::serve::stats::{ServeStats, ServeSummary};
use crate::util::Pcg32;

/// Request-queue depth in batches: senders block (backpressure) once this
/// many batches' worth of requests are already waiting.
const QUEUE_BATCHES: usize = 4;

/// Pool shape: worker count + the batcher's coalescing policy.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
}

/// One enqueued inference request.
pub struct ServeRequest {
    pub client: usize,
    pub index: usize,
    /// Flattened `[h, w, c]` sample.
    pub x: Vec<f32>,
    pub enqueued: Instant,
    reply: Sender<ServeResponse>,
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub client: usize,
    pub index: usize,
    pub argmax: usize,
    pub logits: Vec<f32>,
    /// Queue-to-response latency.
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Deterministic synthetic sample for client `c`, request `i` — public so
/// tests can regenerate a request's input and check the served logits
/// against a direct single-sample inference.
pub fn synthetic_input(seed: u64, client: usize, index: usize, elems: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(
        seed ^ ((client as u64) << 40) ^ ((index as u64) << 8),
        0x5e2e,
    );
    (0..elems).map(|_| rng.normal()).collect()
}

/// Execute one batch on the shared model and answer every rider. The
/// forward pass runs through the servable's bound plan in this thread's
/// arena (`ServableModel::infer_into`) — no tensor marshalling, and zero
/// heap allocations inside the pass once the arena is warm.
fn process_batch(model: &ServableModel, jobs: Vec<ServeRequest>) -> Result<()> {
    let m = jobs.len();
    let pix = model.sample_elems();
    let mut xb = Vec::with_capacity(m * pix);
    for j in &jobs {
        if j.x.len() != pix {
            bail!(
                "request {}/{} carries {} elements, model wants {pix}",
                j.client,
                j.index,
                j.x.len()
            );
        }
        xb.extend_from_slice(&j.x);
    }
    let mut data = Vec::with_capacity(m * model.num_classes());
    let classes = model.infer_into(&xb, m, &mut data)?;
    for (ji, j) in jobs.into_iter().enumerate() {
        let row = data[ji * classes..(ji + 1) * classes].to_vec();
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let resp = ServeResponse {
            client: j.client,
            index: j.index,
            argmax,
            logits: row,
            latency: j.enqueued.elapsed(),
            batch_size: m,
        };
        let _ = j.reply.send(resp); // requester may have given up; not fatal
    }
    Ok(())
}

/// Drive `total` requests through a freshly spun-up pool from `clients`
/// closed-loop client threads (each sends its next request only after the
/// previous one answered — offered load matches capacity, the standard
/// serving-bench discipline). Returns the run's stats plus every response,
/// so callers can verify payloads; responses arrive in client-completion
/// order, keyed by `(client, index)`.
pub fn run_closed_loop(
    model: &ServableModel,
    cfg: &PoolConfig,
    total: usize,
    clients: usize,
    seed: u64,
) -> Result<(ServeStats, Vec<ServeResponse>)> {
    if total == 0 || clients == 0 {
        bail!("closed loop needs at least one request and one client");
    }
    // Same audit as the trainer's empty-shard fix: never spin up more
    // workers than there are requests — the surplus threads could only ever
    // idle on the batch queue until shutdown.
    let workers = cfg.workers.max(1).min(total);
    let policy = cfg.policy;
    let pix = model.sample_elems();
    // Each worker gets its share of the cores for intra-op GEMM fan-out
    // (the shard trainer's budget rule). A saturated pool (workers ≥
    // cores) runs at cap 1, where forward passes are also allocation-free
    // (tests/serve_alloc.rs); an undersubscribed pool keeps the idle
    // cores working inside the kernels instead.
    let gemm_cap = (crate::tensor::gemm::max_parallelism() / workers).max(1);

    let (req_tx, req_rx) = sync_channel::<ServeRequest>(policy.max_batch * QUEUE_BATCHES);
    let (batch_tx, batch_rx) = channel::<Vec<ServeRequest>>();
    let batch_rx = Mutex::new(batch_rx);
    let batch_log: Mutex<Vec<usize>> = Mutex::new(Vec::new());
    let failure: Mutex<Option<String>> = Mutex::new(None);

    let mut responses: Vec<ServeResponse> = Vec::with_capacity(total);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        // Batcher: owns the request receiver; exits when every client
        // sender is gone and the queue is drained.
        s.spawn(move || {
            while let Some(batch) = collect_batch(&req_rx, &policy) {
                if batch_tx.send(batch).is_err() {
                    break; // every worker died; nobody left to serve
                }
            }
        });

        // Workers: share the batch receiver behind a mutex (the lock is
        // held across the blocking recv, which only serializes *waiting* —
        // exactly one worker can pop the next batch either way).
        //
        // On a process_batch error the worker records the first failure and
        // keeps *draining* batches without executing them: dropping a job
        // drops its reply sender, which unblocks its client with an error,
        // which stops that client from sending more — the structural
        // shutdown then unwinds as usual. Breaking out instead would leave
        // queued batches holding reply senders forever (the batch receiver
        // lives in this frame, so the batcher's send never fails) and the
        // clients would hang.
        for _ in 0..workers {
            let batch_rx = &batch_rx;
            let batch_log = &batch_log;
            let failure = &failure;
            s.spawn(move || {
                crate::tensor::gemm::set_thread_parallelism_cap(gemm_cap);
                loop {
                    let got = batch_rx.lock().unwrap().recv();
                    let jobs = match got {
                        Ok(jobs) => jobs,
                        Err(_) => break, // batcher gone: shutdown
                    };
                    if failure.lock().unwrap().is_some() {
                        continue; // failed pool: drain and drop to unblock clients
                    }
                    batch_log.lock().unwrap().push(jobs.len());
                    if let Err(e) = process_batch(model, jobs) {
                        let mut slot = failure.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(format!("{e:#}"));
                        }
                    }
                }
            });
        }

        // Closed-loop clients.
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let tx = req_tx.clone();
            handles.push(s.spawn(move || {
                let quota = total / clients + usize::from(c < total % clients);
                let mut done = Vec::with_capacity(quota);
                for i in 0..quota {
                    let (rtx, rrx) = channel();
                    let req = ServeRequest {
                        client: c,
                        index: i,
                        x: synthetic_input(seed, c, i, pix),
                        enqueued: Instant::now(),
                        reply: rtx,
                    };
                    if tx.send(req).is_err() {
                        break; // pool tore down under us
                    }
                    match rrx.recv() {
                        Ok(resp) => done.push(resp),
                        Err(_) => break, // reply dropped: worker failed
                    }
                }
                done
            }));
        }
        drop(req_tx); // clients hold the only senders now
        for h in handles {
            responses.extend(h.join().expect("serve client thread panicked"));
        }
    });
    let wall = t0.elapsed();

    if let Some(msg) = failure.into_inner().unwrap() {
        bail!("serve worker failed: {msg}");
    }
    if responses.len() != total {
        bail!("closed loop completed {}/{} requests", responses.len(), total);
    }
    let latencies = responses.iter().map(|r| r.latency).collect();
    let stats = ServeStats::new(
        total,
        latencies,
        batch_log.into_inner().unwrap(),
        wall,
        model.weight_bits(),
    );
    Ok((stats, responses))
}

/// One cell of the serve-bench sweep grid.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub max_batch: usize,
    pub workers: usize,
    pub summary: ServeSummary,
}

impl SweepCell {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut kv = vec![
            ("max_batch".to_string(), Json::num(self.max_batch as f64)),
            ("workers".to_string(), Json::num(self.workers as f64)),
        ];
        if let Json::Obj(fields) = self.summary.to_json() {
            kv.extend(fields);
        }
        Json::Obj(kv)
    }
}

/// Closed-loop sweep over batch-size × worker-count cells (each cell a
/// fresh pool; clients = 2×max_batch keep the queue fed so the batcher can
/// actually fill batches).
pub fn sweep(
    model: &ServableModel,
    batches: &[usize],
    workers: &[usize],
    requests: usize,
    max_wait: Duration,
    seed: u64,
) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::with_capacity(batches.len() * workers.len());
    for &w in workers {
        for &b in batches {
            let cfg = PoolConfig { workers: w, policy: BatchPolicy::new(b, max_wait) };
            let clients = (2 * b.max(1)).min(requests.max(1));
            let (stats, _) = run_closed_loop(model, &cfg, requests, clients, seed)?;
            cells.push(SweepCell { max_batch: b.max(1), workers: w, summary: stats.summary() });
        }
    }
    Ok(cells)
}
