//! Open-loop HTTP/1.1 front door for the serving stack (DESIGN.md §15).
//!
//! Hand-rolled on `std::net` — no new dependencies — and deliberately
//! boring: one accept thread, one thread per connection (bounded by
//! `max_conns`), blocking I/O with read timeouts. Each connection parses
//! requests under strict [`http::Limits`], routes them to a per-model
//! serving pool loaded through the content-addressed [`Registry`], runs
//! them past the [`admission`] layer (per-tenant token-bucket quotas,
//! priority lanes over the bounded queue), and answers with logits or a
//! precise rejection (`400`/`404`/`413`/`429 + Retry-After`/`431`/`503`).
//!
//! ```text
//!  socket ──accept──► conn threads ──admission──► PoolClient ─► batcher
//!                        │   (quota → lane → try_send)            │
//!                        ◄────────── reply channel ◄── workers ◄──┘
//! ```
//!
//! Everything runs inside one `std::thread::scope` rooted in
//! [`run_ingress`]: the caller's closure drives traffic against a live
//! [`IngressHandle`], and when it returns the listener wakes, connection
//! threads drain, the per-route [`PoolClient`]s drop, and the pools shut
//! down structurally — the same no-stop-flag lifecycle as the closed-loop
//! harness (DESIGN.md §9).
//!
//! Endpoints:
//!
//! | method | path                        | purpose                         |
//! |--------|-----------------------------|---------------------------------|
//! | GET    | `/healthz`                  | liveness                        |
//! | GET    | `/v1/models`                | route table + queue occupancy   |
//! | POST   | `/v1/models/{model}/infer`  | one sample → logits             |
//!
//! Infer bodies are either raw little-endian `f32` octets (the zero-copy
//! path, `Content-Type: application/octet-stream`, the default) or JSON
//! `{"x": [...]}`. Responses are JSON by default; `Accept:
//! application/octet-stream` returns raw little-endian logits with the
//! metadata in `x-bsq-*` headers — the bit-identity tests compare those
//! bytes against a direct in-process forward pass.

pub mod admission;
pub mod http;
pub mod loadgen;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::runtime::Engine;
use crate::serve::registry::{Registry, ServableModel};
use crate::serve::worker::{
    spawn_pool, ModelSource, PoolClient, PoolConfig, PoolState, ServeRequest, ServeResponse,
    ServeStatus, Submit,
};
use crate::store::ModelStore;
use crate::util::json::{self, Json};

use admission::{AdmissionCfg, AdmissionCtl, Decision, Priority};
use http::{Limits, RecvError, Request, Response};

/// Where a route's checkpoint bytes come from.
#[derive(Debug, Clone)]
pub enum RouteSource {
    /// Load this checkpoint file (registry still keys it by content
    /// digest, so identical bytes under different paths share a servable).
    Checkpoint(PathBuf),
    /// Resolve the model's pinned deploy from the content-addressed store
    /// rooted here ([`Registry::load_pinned`] — digest re-verified).
    StorePin(PathBuf),
}

/// One served model: name on the URL, checkpoint source, and the
/// activation-quantization geometry baked into its servable.
#[derive(Debug, Clone)]
pub struct RouteSpec {
    pub model: String,
    pub source: RouteSource,
    pub act_bits: usize,
    pub act_first_last: usize,
}

/// Ingress shape: bind address, connection bound, parse limits, admission
/// policy. One serving pool per route is configured separately via
/// [`PoolConfig`].
#[derive(Debug, Clone)]
pub struct IngressConfig {
    /// Bind address; port 0 picks a free port (read it back off
    /// [`IngressHandle::addr`]).
    pub addr: String,
    /// Concurrent connection bound; connection `max_conns + 1` is answered
    /// `503 + Retry-After` and closed without a thread.
    pub max_conns: usize,
    pub limits: Limits,
    pub admission: AdmissionCfg,
}

impl Default for IngressConfig {
    fn default() -> IngressConfig {
        IngressConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 64,
            limits: Limits::default(),
            admission: AdmissionCfg::default(),
        }
    }
}

/// Live counters, shared across connection threads. Counted once per
/// request at its terminal status: exactly one of `served`, `shed_queue`,
/// `shed_quota`, `rejected`, `failed`.
#[derive(Default)]
pub struct IngressStats {
    pub conns: AtomicU64,
    pub conns_rejected: AtomicU64,
    pub served: AtomicU64,
    pub shed_queue: AtomicU64,
    pub shed_quota: AtomicU64,
    /// Client errors: malformed/oversized/unknown-route/bad-header (4xx
    /// other than 429).
    pub rejected: AtomicU64,
    /// Server-side failures (5xx).
    pub failed: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
}

/// Live view of a running ingress, passed to the [`run_ingress`] body.
pub struct IngressHandle<'a> {
    addr: SocketAddr,
    shutdown: &'a AtomicBool,
    stats: &'a IngressStats,
}

impl IngressHandle<'_> {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &IngressStats {
        self.stats
    }

    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Kick the accept loop out of its blocking accept. Best-effort:
        // if the wake connect fails the listener still sees the flag on
        // the next real connection.
        for _ in 0..3 {
            if TcpStream::connect(self.addr).is_ok() {
                break;
            }
        }
    }
}

/// Per-route slice of the final report.
#[derive(Debug, Clone)]
pub struct RouteReport {
    pub model: String,
    pub weights_digest: String,
    pub weight_bits: u64,
    pub batches: usize,
    pub mean_batch: f64,
    pub worker_panics: usize,
}

/// Terminal counters of one [`run_ingress`] lifetime.
#[derive(Debug, Clone)]
pub struct IngressReport {
    pub conns: u64,
    pub conns_rejected: u64,
    pub served: u64,
    pub shed_queue: u64,
    pub shed_quota: u64,
    pub rejected: u64,
    pub failed: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub routes: Vec<RouteReport>,
}

impl IngressReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("conns", Json::num(self.conns as f64)),
            ("conns_rejected", Json::num(self.conns_rejected as f64)),
            ("served", Json::num(self.served as f64)),
            ("shed_queue", Json::num(self.shed_queue as f64)),
            ("shed_quota", Json::num(self.shed_quota as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("bytes_in", Json::num(self.bytes_in as f64)),
            ("bytes_out", Json::num(self.bytes_out as f64)),
            (
                "routes",
                Json::Arr(
                    self.routes
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("model", Json::str(r.model.as_str())),
                                ("weights_digest", Json::str(r.weights_digest.as_str())),
                                ("weight_bits", Json::num(r.weight_bits as f64)),
                                ("batches", Json::num(r.batches as f64)),
                                ("mean_batch", Json::num(r.mean_batch)),
                                ("worker_panics", Json::num(r.worker_panics as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One model a connection thread can route to. Cloned per connection —
/// a few `Arc`/sender bumps, nothing heavy.
struct RouteTarget<'a> {
    name: String,
    servable: Arc<ServableModel>,
    client: PoolClient<'a>,
}

impl Clone for RouteTarget<'_> {
    fn clone(&self) -> Self {
        RouteTarget {
            name: self.name.clone(),
            servable: Arc::clone(&self.servable),
            client: self.client.clone(),
        }
    }
}

/// Boot an ingress over `routes`, run `body` against the live
/// [`IngressHandle`], then shut everything down structurally and return
/// the terminal report next to the body's return value. All request
/// traffic happens inside `body` (tests and the load generator connect as
/// ordinary TCP clients); returning from it is the shutdown signal.
pub fn run_ingress<R>(
    engine: &Engine,
    routes: &[RouteSpec],
    pool_cfg: &PoolConfig,
    cfg: &IngressConfig,
    body: impl FnOnce(&IngressHandle<'_>) -> R,
) -> Result<(IngressReport, R)> {
    if routes.is_empty() {
        bail!("ingress needs at least one route");
    }
    for (i, r) in routes.iter().enumerate() {
        if routes[..i].iter().any(|p| p.model == r.model) {
            bail!("duplicate route for model {:?}", r.model);
        }
    }
    // Boot fully before binding: a route that fails to load must fail
    // run_ingress, not answer 500s.
    let registry = Registry::new(engine);
    let mut servables: Vec<Arc<ServableModel>> = Vec::with_capacity(routes.len());
    for r in routes {
        let sv = match &r.source {
            RouteSource::Checkpoint(p) => registry
                .load(&r.model, p, r.act_bits, r.act_first_last)
                .with_context(|| format!("loading route {:?}", r.model))?,
            RouteSource::StorePin(root) => {
                let st = ModelStore::open(root.clone())?;
                registry
                    .load_pinned(&st, &r.model)
                    .with_context(|| format!("resolving pinned route {:?}", r.model))?
            }
        };
        servables.push(sv);
    }
    let states: Vec<PoolState> = routes.iter().map(|_| PoolState::new()).collect();
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding ingress to {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let shutdown = AtomicBool::new(false);
    let stats = IngressStats::default();
    let live_conns = AtomicUsize::new(0);
    let admission = AdmissionCtl::new(cfg.admission.clone());
    let mut accept_failed = false;

    let out = std::thread::scope(|s| {
        // Reference shadows: the accept/conn closures are `move` (they
        // must own their clones of the route table), so the shared state
        // has to enter them as copied references, not moved values.
        let shutdown = &shutdown;
        let stats = &stats;
        let live_conns = &live_conns;
        let admission = &admission;

        let mut targets: Vec<RouteTarget<'_>> = Vec::with_capacity(routes.len());
        for (i, r) in routes.iter().enumerate() {
            let client = spawn_pool(s, ModelSource::Fixed(&servables[i]), pool_cfg, &states[i]);
            targets.push(RouteTarget {
                name: r.model.clone(),
                servable: Arc::clone(&servables[i]),
                client,
            });
        }

        // Accept loop: owns the listener and the route table; spawns one
        // scoped thread per connection and joins them before returning, so
        // by the time it exits every submit handle is dropped and the
        // pools drain.
        let accept = s.spawn(move || {
            let mut conn_handles = Vec::new();
            let mut next_conn = 0u64;
            for inbound in listener.incoming() {
                if shutdown.load(Ordering::Acquire) {
                    break;
                }
                let stream = match inbound {
                    Ok(st) => st,
                    Err(_) => continue, // transient accept error
                };
                if live_conns.load(Ordering::Relaxed) >= cfg.max_conns {
                    stats.conns_rejected.fetch_add(1, Ordering::Relaxed);
                    let mut st = stream;
                    let _ = Response::error(503, "overloaded", "connection limit reached")
                        .header("retry-after", "1")
                        .write_to(&mut st, false);
                    continue;
                }
                live_conns.fetch_add(1, Ordering::Relaxed);
                stats.conns.fetch_add(1, Ordering::Relaxed);
                let conn_id = next_conn;
                next_conn += 1;
                let targets = targets.clone();
                conn_handles.push(s.spawn(move || {
                    handle_conn(stream, &targets, cfg, admission, stats, shutdown, conn_id);
                    live_conns.fetch_sub(1, Ordering::Relaxed);
                }));
                // Reap finished connections so the handle list stays
                // bounded by the live-connection cap (the scope would
                // join stragglers anyway).
                conn_handles.retain(|h| !h.is_finished());
            }
            drop(targets); // conn threads hold the remaining submit handles
            for h in conn_handles {
                let _ = h.join();
            }
        });

        let handle = IngressHandle { addr, shutdown, stats };
        let out = body(&handle);
        handle.request_shutdown();
        accept_failed = accept.join().is_err();
        out
    });

    if accept_failed {
        bail!("ingress accept thread panicked");
    }
    for (i, st) in states.iter().enumerate() {
        if let Some(msg) = st.failure() {
            bail!("ingress pool for {:?} failed: {msg}", routes[i].model);
        }
    }
    let routes_report = routes
        .iter()
        .zip(&states)
        .zip(&servables)
        .map(|((r, st), sv)| {
            let log = st.take_batch_log();
            let mean = if log.is_empty() {
                0.0
            } else {
                log.iter().sum::<usize>() as f64 / log.len() as f64
            };
            RouteReport {
                model: r.model.clone(),
                weights_digest: sv.weights_digest.clone(),
                weight_bits: sv.weight_bits(),
                batches: log.len(),
                mean_batch: mean,
                worker_panics: st.worker_panics(),
            }
        })
        .collect();
    let report = IngressReport {
        conns: stats.conns.load(Ordering::Relaxed),
        conns_rejected: stats.conns_rejected.load(Ordering::Relaxed),
        served: stats.served.load(Ordering::Relaxed),
        shed_queue: stats.shed_queue.load(Ordering::Relaxed),
        shed_quota: stats.shed_quota.load(Ordering::Relaxed),
        rejected: stats.rejected.load(Ordering::Relaxed),
        failed: stats.failed.load(Ordering::Relaxed),
        bytes_in: stats.bytes_in.load(Ordering::Relaxed),
        bytes_out: stats.bytes_out.load(Ordering::Relaxed),
        routes: routes_report,
    };
    Ok((report, out))
}

/// Count a response against exactly one terminal-status counter. The
/// queue-vs-quota split for 429s rides the `x-bsq-shed` header the shed
/// responses carry anyway (it doubles as the client-visible reason).
fn count_response(stats: &IngressStats, resp: &Response) {
    let counter = match resp.status {
        200 => &stats.served,
        429 if resp.header_value("x-bsq-shed") == Some("quota") => &stats.shed_quota,
        429 => &stats.shed_queue,
        400..=499 => &stats.rejected,
        _ => &stats.failed,
    };
    counter.fetch_add(1, Ordering::Relaxed);
}

/// One connection: keep-alive request loop under the parse limits. Framing
/// errors answer their mapped status and close (the stream position is
/// unreliable after a malformed message); idle timeouts just re-check the
/// shutdown flag.
fn handle_conn(
    stream: TcpStream,
    targets: &[RouteTarget<'_>],
    cfg: &IngressConfig,
    admission: &AdmissionCtl,
    stats: &IngressStats,
    shutdown: &AtomicBool,
    conn_id: u64,
) {
    let _ = stream.set_read_timeout(Some(cfg.limits.read_timeout));
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(st) => st,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(reader_stream);
    let mut writer = stream;
    let mut seq = 0usize;
    loop {
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        match http::read_request(&mut reader, &cfg.limits) {
            Ok(req) => {
                stats.bytes_in.fetch_add(req.wire_bytes as u64, Ordering::Relaxed);
                let keep = req.keep_alive;
                let resp = dispatch(&req, targets, admission, conn_id, seq);
                seq += 1;
                count_response(stats, &resp);
                match resp.write_to(&mut writer, keep) {
                    Ok(n) => stats.bytes_out.fetch_add(n as u64, Ordering::Relaxed),
                    Err(_) => return,
                };
                if !keep {
                    return;
                }
            }
            Err(RecvError::IdleTimeout) => continue,
            Err(RecvError::Closed) => return,
            Err(e) => {
                if let Some(status) = e.status() {
                    let mut resp = Response::error(status, "bad_request", &e.to_string());
                    if status == 405 {
                        resp = resp.header("allow", "GET, POST");
                    }
                    count_response(stats, &resp);
                    if let Ok(n) = resp.write_to(&mut writer, false) {
                        stats.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                    }
                }
                return;
            }
        }
    }
}

fn dispatch(
    req: &Request,
    targets: &[RouteTarget<'_>],
    admission: &AdmissionCtl,
    conn_id: u64,
    seq: usize,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            &Json::obj(vec![
                ("status", Json::str("ok")),
                ("models", Json::num(targets.len() as f64)),
            ]),
        ),
        ("GET", "/v1/models") => Response::json(200, &models_json(targets)),
        _ => {
            if let Some(name) =
                req.path.strip_prefix("/v1/models/").and_then(|r| r.strip_suffix("/infer"))
            {
                let Some(target) = targets.iter().find(|t| t.name == name) else {
                    return Response::error(404, "unknown_model", &format!("no route for {name:.64}"));
                };
                if req.method != "POST" {
                    return Response::error(405, "method_not_allowed", "infer is POST-only")
                        .header("allow", "POST");
                }
                return infer(req, target, admission, conn_id, seq);
            }
            Response::error(404, "not_found", &format!("no handler for {:.80}", req.path))
        }
    }
}

fn models_json(targets: &[RouteTarget<'_>]) -> Json {
    Json::Arr(
        targets
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("model", Json::str(t.name.as_str())),
                    ("weights_digest", Json::str(t.servable.weights_digest.as_str())),
                    ("weight_bits", Json::num(t.servable.weight_bits() as f64)),
                    ("mean_effective_bits", Json::num(t.servable.mean_effective_bits())),
                    ("sample_elems", Json::num(t.servable.sample_elems() as f64)),
                    ("num_classes", Json::num(t.servable.num_classes() as f64)),
                    ("kernel_backend", Json::str(t.servable.kernel_backend())),
                    ("queue_depth", Json::num(t.client.depth() as f64)),
                    ("queue_capacity", Json::num(t.client.capacity() as f64)),
                ])
            })
            .collect(),
    )
}

/// 429 with both a coarse integer `Retry-After` (RFC form, ceiled, ≥ 1s)
/// and the precise `x-bsq-retry-after-ms` hint; `x-bsq-shed` names the
/// shed reason (`queue` or `quota`).
fn shed_response(reason: &str, retry_after: Duration) -> Response {
    let ms = retry_after.as_millis() as u64;
    Response::error(429, "shed", &format!("{reason} full; retry after {ms}ms"))
        .header("retry-after", format!("{}", retry_after.as_secs_f64().ceil().max(1.0) as u64))
        .header("x-bsq-retry-after-ms", format!("{ms}"))
        .header("x-bsq-shed", reason)
}

/// Decode an infer body into a flattened sample of exactly `pix` floats.
fn decode_input(req: &Request, pix: usize) -> Result<Vec<f32>, Response> {
    let ct = req.header("content-type").unwrap_or("application/octet-stream");
    let x: Vec<f32> = if ct.starts_with("application/json") {
        let text = std::str::from_utf8(&req.body)
            .map_err(|_| Response::error(400, "bad_body", "json body is not utf-8"))?;
        let v = json::parse(text)
            .map_err(|e| Response::error(400, "bad_body", &format!("json parse: {e:#}")))?;
        let arr = v.get("x").unwrap_or(&v);
        let items = arr
            .as_arr()
            .map_err(|_| Response::error(400, "bad_body", "expected {\"x\": [...]} or [...]"))?;
        let mut x = Vec::with_capacity(items.len());
        for j in items {
            let f = j
                .as_f64()
                .map_err(|_| Response::error(400, "bad_body", "non-numeric sample element"))?;
            x.push(f as f32);
        }
        x
    } else {
        if req.body.len() % 4 != 0 {
            return Err(Response::error(400, "bad_body", "octet body length not a multiple of 4"));
        }
        req.body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    if x.len() != pix {
        return Err(Response::error(
            400,
            "bad_shape",
            &format!("model wants {pix} elements, body carries {}", x.len()),
        ));
    }
    Ok(x)
}

/// The infer path: validate → quota → priority lane → bounded-queue
/// submit → block on the reply channel. The admission order is fixed so an
/// overloaded server does constant work per rejection (DESIGN.md §15).
fn infer(
    req: &Request,
    target: &RouteTarget<'_>,
    admission: &AdmissionCtl,
    conn_id: u64,
    seq: usize,
) -> Response {
    let tenant = req.header("x-bsq-tenant").unwrap_or("anonymous");
    if !admission::valid_tenant(tenant) {
        return Response::error(400, "bad_tenant", "tenant must be ≤64 chars of [A-Za-z0-9._@-]");
    }
    let prio = match Priority::parse(req.header("x-bsq-priority")) {
        Ok(p) => p,
        Err(e) => return Response::error(400, "bad_priority", &e),
    };
    let x = match decode_input(req, target.servable.sample_elems()) {
        Ok(x) => x,
        Err(resp) => return resp,
    };
    if let Decision::Shed { retry_after } = admission.check_quota(tenant) {
        return shed_response("quota", retry_after);
    }
    if !admission.lane_open(target.client.depth(), target.client.capacity(), prio) {
        return shed_response("queue", admission.cfg().retry_after);
    }
    let (rtx, rrx) = channel::<ServeResponse>();
    match target.client.try_submit(ServeRequest::new(conn_id as usize, seq, x, rtx)) {
        Submit::Sent => {}
        Submit::Full(_) => return shed_response("queue", admission.cfg().retry_after),
        Submit::Closed(_) => {
            return Response::error(503, "shutting_down", "serving pool is gone")
        }
    }
    match rrx.recv() {
        Err(_) => Response::error(500, "pool_failure", "request dropped by a failed pool"),
        Ok(r) => match r.status {
            ServeStatus::Ok => ok_response(req, target, &r),
            ServeStatus::TimedOut => {
                Response::error(503, "deadline", "request expired before dispatch")
                    .header("retry-after", "1")
            }
            ServeStatus::Shed { retry_after } => shed_response("queue", retry_after),
        },
    }
}

fn ok_response(req: &Request, target: &RouteTarget<'_>, r: &ServeResponse) -> Response {
    let latency_us = r.latency.as_micros() as u64;
    let wants_octets = req
        .header("accept")
        .is_some_and(|a| a.contains("application/octet-stream"));
    if wants_octets {
        let mut body = Vec::with_capacity(r.logits.len() * 4);
        for &v in &r.logits {
            body.extend_from_slice(&v.to_le_bytes());
        }
        Response::octets(200, body)
            .header("x-bsq-argmax", format!("{}", r.argmax))
            .header("x-bsq-model-gen", format!("{}", r.model_gen))
            .header("x-bsq-batch-size", format!("{}", r.batch_size))
            .header("x-bsq-latency-us", format!("{latency_us}"))
    } else {
        Response::json(
            200,
            &Json::obj(vec![
                ("model", Json::str(target.name.as_str())),
                ("argmax", Json::num(r.argmax as f64)),
                // f32→f64 printing is shortest-round-trip exact, so the
                // JSON path loses no logit bits either.
                ("logits", Json::arr_num(r.logits.iter().map(|&v| v as f64))),
                ("model_gen", Json::num(r.model_gen as f64)),
                ("batch_size", Json::num(r.batch_size as f64)),
                ("latency_us", Json::num(latency_us as f64)),
            ]),
        )
    }
}
