//! Poisson open-loop load generator for the ingress (DESIGN.md §15).
//!
//! Closed-loop clients (the `serve-bench` harness) can never observe
//! saturation: they wait for each answer before sending the next request,
//! so offered load self-throttles to capacity. This generator is
//! *open-loop*: request arrival times are drawn up front from a Poisson
//! process at the offered rate, and each request's latency is measured
//! from its **scheduled** arrival — not from when a connection finally got
//! around to sending it — which is the standard coordinated-omission
//! correction. Past saturation the corrected latencies blow up and the
//! shed rate rises; the sweep records both and locates the knee.
//!
//! Methodology (`bsq-repro ingress-bench`):
//! 1. **Calibrate**: a short closed-loop HTTP burst estimates capacity in
//!    requests/s. Calibration tenants rotate through a wide pool so
//!    per-tenant quotas never distort the estimate.
//! 2. **Sweep**: for each factor `f` in the grid, offer `f × capacity`
//!    Poisson traffic and record achieved throughput, shed split
//!    (queue vs quota), and corrected latency percentiles.
//! 3. **Knee**: the highest offered point that kept up — achieved ≥ 90% of
//!    offered, total shed ≤ 1%, no transport errors. Its achieved rate is
//!    exported as `ingress_knee_interval` (`mean_ns = 1e9 / rps`) so a
//!    throughput regression fails the bench-diff gate like any latency
//!    regression would.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::serve::ingress::http::{self, Limits, RecvError, Response};
use crate::serve::ingress::IngressReport;
use crate::serve::worker::synthetic_input;
use crate::util::json::Json;
use crate::util::Pcg32;

/// Load-generator shape, fixed across a sweep.
#[derive(Debug, Clone)]
pub struct LoadGenCfg {
    /// Route to hit: `POST /v1/models/{model}/infer`.
    pub model: String,
    /// Flattened sample size the model expects (octet body = 4× this).
    pub sample_elems: usize,
    /// Persistent keep-alive connections (the client-side parallelism cap;
    /// keep it under the ingress `max_conns`).
    pub conns: usize,
    /// Sweep traffic rotates tenants `tenant-0..tenants`.
    pub tenants: usize,
    /// Fraction of requests tagged `x-bsq-priority: high`.
    pub high_frac: f64,
    pub seed: u64,
}

/// One offered-load point of the sweep.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Stable label for bench records, e.g. `0.50x`.
    pub label: String,
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub requests: usize,
    pub ok: usize,
    pub shed_queue: usize,
    pub shed_quota: usize,
    pub errors: usize,
    /// Coordinated-omission-corrected latencies over served requests, µs.
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub wall_s: f64,
}

impl LoadPoint {
    pub fn shed_rate(&self) -> f64 {
        (self.shed_queue + self.shed_quota) as f64 / (self.requests.max(1)) as f64
    }

    /// Did this point keep up with its offered load? (The knee predicate.)
    pub fn kept_up(&self) -> bool {
        self.achieved_rps >= 0.9 * self.offered_rps && self.shed_rate() <= 0.01 && self.errors == 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.as_str())),
            ("offered_rps", Json::num(self.offered_rps)),
            ("achieved_rps", Json::num(self.achieved_rps)),
            ("requests", Json::num(self.requests as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("shed_queue", Json::num(self.shed_queue as f64)),
            ("shed_quota", Json::num(self.shed_quota as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("mean_us", Json::num(self.mean_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("wall_s", Json::num(self.wall_s)),
            ("kept_up", Json::Bool(self.kept_up())),
        ])
    }
}

/// Client-side limits: same line caps as the server, but a long read
/// timeout — under deliberate overload a queued response can take a while,
/// and a client timeout would misreport server sheds as transport errors.
fn client_limits() -> Limits {
    Limits { read_timeout: Duration::from_secs(30), ..Limits::default() }
}

/// `[0,1)` with 53 random mantissa bits (`Pcg32::uniform` is f32-grained —
/// too coarse for exponential interarrival tails).
fn f64_uniform(rng: &mut Pcg32) -> f64 {
    (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Cumulative Poisson arrival offsets at `rate` requests/s: exponential
/// interarrival gaps `-ln(1-u)/rate`.
pub fn poisson_arrivals(rate: f64, n: usize, seed: u64) -> Vec<Duration> {
    let mut rng = Pcg32::new(seed, 0x10ad);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += -(1.0 - f64_uniform(&mut rng)).ln() / rate.max(1e-9);
            Duration::from_secs_f64(t)
        })
        .collect()
}

/// Sleep until `t`: coarse `thread::sleep` to within ~1 ms, spin the rest
/// (kernel sleep granularity would otherwise skew high offered rates low).
fn sleep_until(t: Instant) {
    loop {
        let now = Instant::now();
        if now >= t {
            return;
        }
        let rem = t - now;
        if rem > Duration::from_millis(2) {
            std::thread::sleep(rem - Duration::from_millis(1));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// One persistent keep-alive HTTP connection.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn connect(addr: SocketAddr) -> std::io::Result<Conn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(client_limits().read_timeout))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn { reader, writer: stream })
    }

    fn post_infer(
        &mut self,
        model: &str,
        tenant: &str,
        high: bool,
        body: &[u8],
    ) -> Result<Response, RecvError> {
        let mut head = format!(
            "POST /v1/models/{model}/infer HTTP/1.1\r\n\
             content-type: application/octet-stream\r\n\
             content-length: {}\r\n\
             x-bsq-tenant: {tenant}\r\n",
            body.len()
        );
        if high {
            head.push_str("x-bsq-priority: high\r\n");
        }
        head.push_str("\r\n");
        self.writer.write_all(head.as_bytes()).map_err(RecvError::Io)?;
        self.writer.write_all(body).map_err(RecvError::Io)?;
        self.writer.flush().map_err(RecvError::Io)?;
        http::read_response(&mut self.reader, &client_limits())
    }
}

fn sample_bytes(cfg: &LoadGenCfg, i: usize) -> Vec<u8> {
    let x = synthetic_input(cfg.seed, i % 64, i / 64, cfg.sample_elems);
    let mut body = Vec::with_capacity(x.len() * 4);
    for v in x {
        body.extend_from_slice(&v.to_le_bytes());
    }
    body
}

fn is_high(cfg: &LoadGenCfg, i: usize) -> bool {
    Pcg32::new(cfg.seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15), 7)
        .bool(cfg.high_frac as f32)
}

enum Outcome {
    Ok(f64), // corrected latency, µs
    ShedQueue,
    ShedQuota,
    Error,
}

fn classify(resp: &Response, latency_us: f64) -> Outcome {
    match resp.status {
        200 => Outcome::Ok(latency_us),
        429 if resp.header_value("x-bsq-shed") == Some("quota") => Outcome::ShedQuota,
        429 => Outcome::ShedQueue,
        _ => Outcome::Error,
    }
}

/// Fire one request as conn-thread body logic: send (reconnecting once on
/// a transport error), classify the response.
fn fire(conn: &mut Option<Conn>, addr: SocketAddr, model: &str, tenant: &str, high: bool, body: &[u8], start: Instant) -> Outcome {
    for attempt in 0..2 {
        if conn.is_none() {
            *conn = Conn::connect(addr).ok();
        }
        let Some(c) = conn.as_mut() else { return Outcome::Error };
        match c.post_infer(model, tenant, high, body) {
            Ok(resp) => {
                let latency_us = start.elapsed().as_secs_f64() * 1e6;
                // The server closes the conn after framing-error 4xxs.
                if resp.header_value("connection") == Some("close") {
                    *conn = None;
                }
                return classify(&resp, latency_us);
            }
            Err(_) => {
                *conn = None;
                if attempt == 1 {
                    return Outcome::Error;
                }
            }
        }
    }
    Outcome::Error
}

/// Closed-loop HTTP burst → capacity estimate (requests/s). Tenants rotate
/// through a 512-name calibration pool so token buckets never empty.
pub fn calibrate(addr: SocketAddr, cfg: &LoadGenCfg, requests: usize) -> Result<f64> {
    if requests == 0 || cfg.conns == 0 {
        bail!("calibration needs at least one request and one connection");
    }
    let next = AtomicUsize::new(0);
    let ok = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let next = &next;
        let ok = &ok;
        for _ in 0..cfg.conns {
            s.spawn(move || {
                let mut conn: Option<Conn> = None;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= requests {
                        break;
                    }
                    let body = sample_bytes(cfg, i);
                    let tenant = format!("calib-{}", i % 512);
                    let t = Instant::now();
                    if matches!(
                        fire(&mut conn, addr, &cfg.model, &tenant, false, &body, t),
                        Outcome::Ok(_)
                    ) {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-6);
    let served = ok.load(Ordering::Relaxed);
    if served == 0 {
        bail!("calibration served 0/{requests} requests — ingress unhealthy");
    }
    Ok(served as f64 / wall)
}

/// Run one offered-load point: `requests` Poisson arrivals at
/// `offered_rps`, pulled by `cfg.conns` connection threads.
pub fn run_point(
    addr: SocketAddr,
    cfg: &LoadGenCfg,
    label: &str,
    offered_rps: f64,
    requests: usize,
) -> Result<LoadPoint> {
    if requests == 0 || cfg.conns == 0 {
        bail!("load point needs at least one request and one connection");
    }
    let arrivals = poisson_arrivals(offered_rps, requests, cfg.seed ^ label.len() as u64);
    let next = AtomicUsize::new(0);
    // Small priming offset so the first arrivals aren't already late
    // while connection threads are still spinning up.
    let t0 = Instant::now() + Duration::from_millis(50);
    let mut outcomes: Vec<Outcome> = Vec::with_capacity(requests);
    std::thread::scope(|s| {
        let arrivals = &arrivals;
        let next = &next;
        let handles: Vec<_> = (0..cfg.conns)
            .map(|_| {
                s.spawn(move || {
                    let mut conn: Option<Conn> = None;
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= requests {
                            break;
                        }
                        let start = t0 + arrivals[i];
                        sleep_until(start);
                        let body = sample_bytes(cfg, i);
                        let tenant = format!("tenant-{}", i % cfg.tenants.max(1));
                        let high = is_high(cfg, i);
                        out.push(fire(&mut conn, addr, &cfg.model, &tenant, high, &body, start));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            if let Ok(part) = h.join() {
                outcomes.extend(part);
            }
        }
    });
    let wall = (t0.elapsed()).as_secs_f64().max(1e-6);

    let mut lat_us: Vec<f64> = Vec::new();
    let (mut shed_queue, mut shed_quota, mut errors) = (0usize, 0usize, 0usize);
    for o in &outcomes {
        match o {
            Outcome::Ok(us) => lat_us.push(*us),
            Outcome::ShedQueue => shed_queue += 1,
            Outcome::ShedQuota => shed_quota += 1,
            Outcome::Error => errors += 1,
        }
    }
    errors += requests - outcomes.len(); // panicked conn threads, if any
    lat_us.sort_by(|a, b| a.total_cmp(b));
    let ok = lat_us.len();
    let mean_us = if ok == 0 { 0.0 } else { lat_us.iter().sum::<f64>() / ok as f64 };
    let pct = |q: f64| -> f64 {
        if lat_us.is_empty() {
            0.0
        } else {
            lat_us[((lat_us.len() - 1) as f64 * q).round() as usize]
        }
    };
    Ok(LoadPoint {
        label: label.to_string(),
        offered_rps,
        achieved_rps: ok as f64 / wall,
        requests,
        ok,
        shed_queue,
        shed_quota,
        errors,
        mean_us,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        wall_s: wall,
    })
}

/// Index of the knee: the highest offered point that [`LoadPoint::kept_up`].
pub fn find_knee(points: &[LoadPoint]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, p) in points.iter().enumerate() {
        let better = match best {
            None => true,
            Some(b) => points[b].offered_rps <= p.offered_rps,
        };
        if p.kept_up() && better {
            best = Some(i);
        }
    }
    best
}

/// Fold an ingress sweep into a `BENCH_serve.json` record. If `existing`
/// is the closed-loop sweep's record, the ingress block and its gated
/// metrics are merged in (replacing any previous `ingress_*` entries, so
/// re-runs are idempotent); otherwise a minimal fresh record is built.
///
/// Gated metrics (`results` entries with `mean_ns`, compared by
/// `bench-diff`):
/// - `ingress_{label}` — mean corrected latency of every *kept-up* point
///   (overload points are informational only: their corrected latency is
///   dominated by run length, not server speed);
/// - `ingress_knee_interval` — `1e9 / knee_achieved_rps`, so a capacity
///   regression fails the gate as a slower "latency".
///
/// `speedups.ingress_knee_goodput` (achieved/offered at the knee, ≈ 1.0)
/// is floor-armable via `ci/baselines/` like the GEMM floors.
pub fn merge_bench_json(
    existing: Option<Json>,
    model: &str,
    weight_bits: u64,
    calibrated_rps: f64,
    points: &[LoadPoint],
    knee: Option<usize>,
    report: &IngressReport,
) -> Json {
    let mut fields: Vec<(String, Json)> = match existing {
        Some(Json::Obj(kv))
            if kv.iter().any(|(k, v)| k == "target" && v.as_str().ok() == Some("serve")) =>
        {
            kv
        }
        _ => vec![
            ("target".to_string(), Json::str("serve")),
            ("model".to_string(), Json::str(model)),
            ("weight_bits_per_sample".to_string(), Json::num(weight_bits as f64)),
        ],
    };

    // Fresh gated entries.
    let mut results: Vec<Json> = points
        .iter()
        .filter(|p| p.kept_up() && p.ok > 0 && p.mean_us > 0.0)
        .map(|p| {
            Json::obj(vec![
                ("name", Json::str(format!("ingress_{}", p.label))),
                ("mean_ns", Json::num(p.mean_us * 1e3)),
            ])
        })
        .collect();
    if let Some(k) = knee {
        let rps = points[k].achieved_rps;
        if rps > 0.0 {
            results.push(Json::obj(vec![
                ("name", Json::str("ingress_knee_interval")),
                ("mean_ns", Json::num(1e9 / rps)),
            ]));
        }
    }

    let ingress = Json::obj(vec![
        ("calibrated_rps", Json::num(calibrated_rps)),
        ("points", Json::Arr(points.iter().map(LoadPoint::to_json).collect())),
        (
            "knee",
            match knee {
                Some(k) => Json::obj(vec![
                    ("label", Json::str(points[k].label.as_str())),
                    ("offered_rps", Json::num(points[k].offered_rps)),
                    ("achieved_rps", Json::num(points[k].achieved_rps)),
                ]),
                None => Json::Null,
            },
        ),
        ("report", report.to_json()),
    ]);

    // Merge: drop stale ingress entries, splice fresh ones.
    let mut replaced_ingress = false;
    for (k, v) in fields.iter_mut() {
        match k.as_str() {
            "results" => {
                if let Json::Arr(entries) = v {
                    entries.retain(|e| match e.get("name").and_then(|n| n.as_str().ok()) {
                        Some(n) => !n.starts_with("ingress_"),
                        None => true,
                    });
                    entries.extend(std::mem::take(&mut results));
                }
            }
            "speedups" => {
                if let Json::Obj(kv) = v {
                    kv.retain(|(name, _)| !name.starts_with("ingress_"));
                    if let Some(k) = knee {
                        let goodput =
                            points[k].achieved_rps / points[k].offered_rps.max(1e-9);
                        kv.push(("ingress_knee_goodput".to_string(), Json::num(goodput)));
                    }
                }
            }
            "ingress" => {
                *v = ingress.clone();
                replaced_ingress = true;
            }
            _ => {}
        }
    }
    if !results.is_empty() {
        fields.push(("results".to_string(), Json::Arr(results)));
    }
    if !fields.iter().any(|(k, _)| k == "speedups") {
        let mut kv = Vec::new();
        if let Some(k) = knee {
            let goodput = points[k].achieved_rps / points[k].offered_rps.max(1e-9);
            kv.push(("ingress_knee_goodput".to_string(), Json::num(goodput)));
        }
        fields.push(("speedups".to_string(), Json::Obj(kv)));
    }
    if !replaced_ingress {
        fields.push(("ingress".to_string(), ingress));
    }
    Json::Obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_are_monotone_and_scale_with_rate() {
        let fast = poisson_arrivals(1000.0, 512, 7);
        let slow = poisson_arrivals(10.0, 512, 7);
        assert!(fast.windows(2).all(|w| w[0] <= w[1]));
        // Same seed: the slow schedule is exactly 100× the fast one in
        // expectation; allow broad slack for the draw.
        assert!(slow[511] > fast[511] * 50);
        let mean_gap = fast[511].as_secs_f64() / 512.0;
        assert!((mean_gap - 1e-3).abs() < 5e-4, "mean gap {mean_gap}");
    }

    fn point(label: &str, offered: f64, achieved: f64, shed: usize, errors: usize) -> LoadPoint {
        LoadPoint {
            label: label.to_string(),
            offered_rps: offered,
            achieved_rps: achieved,
            requests: 1000,
            ok: 1000 - shed - errors,
            shed_queue: shed,
            shed_quota: 0,
            errors,
            mean_us: 500.0,
            p50_us: 400.0,
            p99_us: 900.0,
            wall_s: 1.0,
        }
    }

    #[test]
    fn knee_is_highest_kept_up_point() {
        let pts = vec![
            point("0.25x", 250.0, 249.0, 0, 0),
            point("0.50x", 500.0, 497.0, 1, 0),
            point("1.00x", 1000.0, 980.0, 5, 0),
            point("2.00x", 2000.0, 1050.0, 700, 0),
        ];
        assert_eq!(find_knee(&pts), Some(2));
        assert_eq!(find_knee(&pts[3..]), None);
    }

    #[test]
    fn merge_into_existing_record_is_idempotent() {
        let base = Json::obj(vec![
            ("target", Json::str("serve")),
            ("model", Json::str("tinynet")),
            (
                "results",
                Json::Arr(vec![Json::obj(vec![
                    ("name", Json::str("serve_b8_w2")),
                    ("mean_ns", Json::num(1e6)),
                ])]),
            ),
            ("speedups", Json::Obj(vec![])),
        ]);
        let pts = vec![point("0.50x", 500.0, 499.0, 0, 0)];
        let report = IngressReport {
            conns: 1,
            conns_rejected: 0,
            served: 500,
            shed_queue: 0,
            shed_quota: 0,
            rejected: 0,
            failed: 0,
            bytes_in: 1,
            bytes_out: 1,
            routes: Vec::new(),
        };
        let once = merge_bench_json(Some(base), "tinynet", 1000, 1000.0, &pts, Some(0), &report);
        let twice =
            merge_bench_json(Some(once.clone()), "tinynet", 1000, 1000.0, &pts, Some(0), &report);
        assert_eq!(once, twice);
        let names: Vec<String> = once
            .get("results")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["serve_b8_w2", "ingress_0.50x", "ingress_knee_interval"]);
        assert!(once.get("ingress").is_some());
        assert!(once
            .get("speedups")
            .unwrap()
            .get("ingress_knee_goodput")
            .is_some());
    }
}
