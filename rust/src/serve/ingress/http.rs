//! Minimal HTTP/1.1 framing for the ingress, hand-rolled on `std::io`
//! (DESIGN.md §15). Server side: [`read_request`] parses one request off a
//! `BufRead` under strict [`Limits`] — every cap and malformation maps to
//! a precise [`RecvError`] so the connection handler can answer 400 / 408 /
//! 413 / 431 instead of hanging or buffering unboundedly. Client side:
//! [`read_response`] parses one response with the same capped reader, used
//! by the open-loop load generator and the integration tests.
//!
//! Scope is deliberately narrow: `Content-Length` bodies only (chunked
//! transfer coding is rejected with 400), no continuation lines, no
//! percent-decoding. Pipelining needs no special handling — requests are
//! framed sequentially off the same reader, so back-to-back requests in
//! one TCP segment are answered in order.

use std::fmt;
use std::io::{self, BufRead, Read, Write};
use std::time::Duration;

/// Parse limits. Every byte read off the socket is accounted against one
/// of these caps *before* it is buffered, so a hostile peer cannot make
/// the server allocate more than `max_line + max_body` per connection.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Cap on the request line and on each header line (bytes, excluding
    /// the CRLF). Overflow → 431.
    pub max_line: usize,
    /// Cap on the number of header lines. Overflow → 431.
    pub max_headers: usize,
    /// Cap on `Content-Length`. Overflow → 413, checked before the body
    /// is read.
    pub max_body: usize,
    /// Socket read timeout. A timeout *between* requests is an idle tick
    /// (the conn loop re-checks shutdown); a timeout *inside* a request is
    /// a stalled peer → 408.
    pub read_timeout: Duration,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_line: 8 * 1024,
            max_headers: 64,
            max_body: 1 << 20,
            read_timeout: Duration::from_millis(1000),
        }
    }
}

/// Why [`read_request`] / [`read_response`] did not produce a message.
#[derive(Debug)]
pub enum RecvError {
    /// Peer closed the connection cleanly between messages.
    Closed,
    /// Read timed out with no byte of a new message consumed — an idle
    /// keep-alive connection, not an error.
    IdleTimeout,
    /// Read timed out mid-message: the peer stalled. Maps to 408.
    Stalled,
    /// Syntactically invalid message. Maps to 400.
    Malformed(String),
    /// More than `max_headers` header lines. Maps to 431.
    TooManyHeaders,
    /// Request line or a header line over `max_line`. Maps to 431.
    LineTooLong,
    /// Declared `Content-Length` over `max_body`. Maps to 413.
    BodyTooLarge(usize),
    /// Method outside the supported set (GET/POST). Maps to 405.
    MethodNotAllowed(String),
    /// Transport error other than the mapped timeouts.
    Io(io::Error),
}

impl RecvError {
    /// The HTTP status this error maps to, when it maps to one at all.
    /// `Closed`/`IdleTimeout`/`Io` return `None`: there is nobody to
    /// answer, or the transport itself failed.
    pub fn status(&self) -> Option<u16> {
        match self {
            RecvError::Closed | RecvError::IdleTimeout | RecvError::Io(_) => None,
            RecvError::Stalled => Some(408),
            RecvError::Malformed(_) => Some(400),
            RecvError::TooManyHeaders | RecvError::LineTooLong => Some(431),
            RecvError::BodyTooLarge(_) => Some(413),
            RecvError::MethodNotAllowed(_) => Some(405),
        }
    }
}

impl fmt::Display for RecvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecvError::Closed => write!(f, "connection closed"),
            RecvError::IdleTimeout => write!(f, "idle timeout"),
            RecvError::Stalled => write!(f, "peer stalled mid-message"),
            RecvError::Malformed(m) => write!(f, "malformed message: {m}"),
            RecvError::TooManyHeaders => write!(f, "too many header lines"),
            RecvError::LineTooLong => write!(f, "header line too long"),
            RecvError::BodyTooLarge(n) => write!(f, "declared body of {n} bytes over cap"),
            RecvError::MethodNotAllowed(m) => write!(f, "method {m} not allowed"),
            RecvError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read one CRLF- (or bare-LF-) terminated line without ever buffering
/// more than `cap` bytes, built on `fill_buf`/`consume` so an attacker
/// streaming an endless line is cut off at the cap instead of growing the
/// buffer. `started` tracks whether any byte of the current *message* has
/// been consumed — it decides idle-vs-stalled on timeout and
/// closed-vs-truncated on EOF. Returns consumed byte count alongside the
/// line (for wire accounting).
fn read_line_capped<R: BufRead>(
    r: &mut R,
    cap: usize,
    started: &mut bool,
    line: &mut Vec<u8>,
) -> Result<usize, RecvError> {
    line.clear();
    let mut consumed = 0usize;
    loop {
        let buf = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                return Err(if *started { RecvError::Stalled } else { RecvError::IdleTimeout })
            }
            Err(e) => return Err(RecvError::Io(e)),
        };
        if buf.is_empty() {
            return Err(if *started {
                RecvError::Malformed("eof mid-message".into())
            } else {
                RecvError::Closed
            });
        }
        *started = true;
        match buf.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                if line.len() + nl > cap {
                    r.consume(nl + 1);
                    return Err(RecvError::LineTooLong);
                }
                line.extend_from_slice(&buf[..nl]);
                r.consume(nl + 1);
                consumed += nl + 1;
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(consumed);
            }
            None => {
                let take = buf.len();
                if line.len() + take > cap {
                    r.consume(take);
                    return Err(RecvError::LineTooLong);
                }
                line.extend_from_slice(buf);
                r.consume(take);
                consumed += take;
            }
        }
    }
}

/// Read exactly `n` body bytes, mapping timeout → `Stalled` and early EOF
/// → `Malformed`.
fn read_body<R: Read>(r: &mut R, n: usize) -> Result<Vec<u8>, RecvError> {
    let mut body = vec![0u8; n];
    let mut filled = 0usize;
    while filled < n {
        match r.read(&mut body[filled..]) {
            Ok(0) => return Err(RecvError::Malformed("eof inside body".into())),
            Ok(k) => filled += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return Err(RecvError::Stalled),
            Err(e) => return Err(RecvError::Io(e)),
        }
    }
    Ok(body)
}

fn valid_header_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
}

/// Parse `lines.count() <= max_headers` header lines off `r` into
/// lowercase-name pairs. Shared by request and response parsing.
fn read_headers<R: BufRead>(
    r: &mut R,
    limits: &Limits,
    started: &mut bool,
    wire: &mut usize,
) -> Result<Vec<(String, String)>, RecvError> {
    let mut headers = Vec::new();
    let mut line = Vec::new();
    loop {
        *wire += read_line_capped(r, limits.max_line, started, &mut line)?;
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= limits.max_headers {
            return Err(RecvError::TooManyHeaders);
        }
        if line[0] == b' ' || line[0] == b'\t' {
            // obs-fold continuation lines are long deprecated and a
            // smuggling vector; reject outright.
            return Err(RecvError::Malformed("folded header line".into()));
        }
        let text = String::from_utf8_lossy(&line);
        let Some((name, value)) = text.split_once(':') else {
            return Err(RecvError::Malformed(format!("header without colon: {text:.60}")));
        };
        if !valid_header_name(name) {
            return Err(RecvError::Malformed(format!("invalid header name: {name:.60}")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
}

/// `Content-Length` resolution: absent → 0, duplicates must agree,
/// anything non-numeric is malformed, over-cap is `BodyTooLarge` *before*
/// any body byte is read.
fn content_length(headers: &[(String, String)], limits: &Limits) -> Result<usize, RecvError> {
    let mut len: Option<usize> = None;
    for (name, value) in headers {
        if name == "transfer-encoding" {
            return Err(RecvError::Malformed("transfer-encoding not supported".into()));
        }
        if name == "content-length" {
            let v: usize = value
                .parse()
                .map_err(|_| RecvError::Malformed(format!("bad content-length: {value:.60}")))?;
            if let Some(prev) = len {
                if prev != v {
                    return Err(RecvError::Malformed("conflicting content-length".into()));
                }
            }
            len = Some(v);
        }
    }
    let n = len.unwrap_or(0);
    if n > limits.max_body {
        return Err(RecvError::BodyTooLarge(n));
    }
    Ok(n)
}

/// One parsed request. Header names are lowercased; `keep_alive` already
/// folds in the HTTP version default (1.1 on unless `Connection: close`,
/// 1.0 off unless `Connection: keep-alive`).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub keep_alive: bool,
    /// Total bytes this request consumed off the wire.
    pub wire_bytes: usize,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }
}

/// Parse one request off `r`. Blocking up to `limits.read_timeout` per
/// socket read; see [`RecvError`] for the status mapping of each failure.
pub fn read_request<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Request, RecvError> {
    let mut started = false;
    let mut wire = 0usize;
    let mut line = Vec::new();
    // Tolerate a little CRLF padding between pipelined requests
    // (RFC 9112 §2.2 robustness) but never an unbounded stream of it.
    for _ in 0..4 {
        wire += read_line_capped(r, limits.max_line, &mut started, &mut line)?;
        if !line.is_empty() {
            break;
        }
        started = false;
    }
    if line.is_empty() {
        return Err(RecvError::Malformed("blank request line".into()));
    }
    let text = String::from_utf8_lossy(&line).into_owned();
    let mut parts = text.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return Err(RecvError::Malformed(format!("bad request line: {text:.80}"))),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(RecvError::Malformed(format!("unsupported version: {version:.20}")));
    }
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(RecvError::Malformed(format!("bad method: {method:.20}")));
    }
    if method != "GET" && method != "POST" {
        return Err(RecvError::MethodNotAllowed(method.to_string()));
    }
    if !target.starts_with('/') {
        return Err(RecvError::Malformed(format!("bad request target: {target:.80}")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let headers = read_headers(r, limits, &mut started, &mut wire)?;
    let body_len = content_length(&headers, limits)?;
    let body = read_body(r, body_len)?;
    wire += body_len;

    let connection = headers
        .iter()
        .find(|(n, _)| n == "connection")
        .map(|(_, v)| v.to_ascii_lowercase())
        .unwrap_or_default();
    let keep_alive = if version == "HTTP/1.1" {
        !connection.split(',').any(|t| t.trim() == "close")
    } else {
        connection.split(',').any(|t| t.trim() == "keep-alive")
    };

    Ok(Request { method: method.to_string(), path, query, headers, body, keep_alive, wire_bytes: wire })
}

/// One response — produced by handlers on the server side, parsed back by
/// [`read_response`] on the client side (header names lowercased there).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    /// JSON body (`Content-Type: application/json`).
    pub fn json(status: u16, v: &crate::util::json::Json) -> Response {
        let mut r = Response::new(status);
        r.body = v.to_string_compact().into_bytes();
        r.headers.push(("content-type".into(), "application/json".into()));
        r
    }

    /// Raw bytes body (`Content-Type: application/octet-stream`).
    pub fn octets(status: u16, body: Vec<u8>) -> Response {
        let mut r = Response::new(status);
        r.body = body;
        r.headers.push(("content-type".into(), "application/octet-stream".into()));
        r
    }

    /// Machine-readable error body: `{"error": code, "message": msg}`.
    pub fn error(status: u16, code: &str, msg: &str) -> Response {
        use crate::util::json::Json;
        Response::json(
            status,
            &Json::Obj(vec![
                ("error".into(), Json::Str(code.into())),
                ("message".into(), Json::Str(msg.into())),
            ]),
        )
    }

    /// Builder-style extra header.
    pub fn header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.into()));
        self
    }

    pub fn header_value(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            413 => "Content Too Large",
            429 => "Too Many Requests",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Response",
        }
    }

    /// Serialize onto `w` (one flush). Returns bytes written. The server
    /// always states framing explicitly: `Content-Length` plus a
    /// `Connection` header matching what the conn loop will actually do.
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> io::Result<usize> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, Response::reason(self.status));
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str(&format!("content-length: {}\r\n", self.body.len()));
        head.push_str(if keep_alive { "connection: keep-alive\r\n" } else { "connection: close\r\n" });
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(head.len() + self.body.len())
    }
}

/// Client-side: parse one response off `r` (status line + headers +
/// `Content-Length` body) under the same caps. Used by the load generator
/// and tests; `IdleTimeout`/`Stalled` semantics mirror [`read_request`].
pub fn read_response<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Response, RecvError> {
    let mut started = false;
    let mut wire = 0usize;
    let mut line = Vec::new();
    read_line_capped(r, limits.max_line, &mut started, &mut line)?;
    let text = String::from_utf8_lossy(&line).into_owned();
    let mut parts = text.splitn(3, ' ');
    let status = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse::<u16>()
            .map_err(|_| RecvError::Malformed(format!("bad status line: {text:.80}")))?,
        _ => return Err(RecvError::Malformed(format!("bad status line: {text:.80}"))),
    };
    let headers = read_headers(r, limits, &mut started, &mut wire)?;
    let body_len = content_length(&headers, limits)?;
    let body = read_body(r, body_len)?;
    Ok(Response { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &[u8]) -> Result<Request, RecvError> {
        read_request(&mut Cursor::new(raw.to_vec()), &Limits::default())
    }

    #[test]
    fn parses_get_and_query() {
        let r = req(b"GET /v1/models?verbose=1 HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/models");
        assert_eq!(r.query.as_deref(), Some("verbose=1"));
        assert!(r.keep_alive);
        assert_eq!(r.header("host"), Some("x"));
    }

    #[test]
    fn parses_post_body_and_counts_wire_bytes() {
        let raw = b"POST /p HTTP/1.1\r\ncontent-length: 4\r\n\r\nabcd";
        let r = req(raw).unwrap();
        assert_eq!(r.body, b"abcd");
        assert_eq!(r.wire_bytes, raw.len());
    }

    #[test]
    fn connection_close_and_http10_defaults() {
        assert!(!req(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().keep_alive);
        assert!(!req(b"GET / HTTP/1.0\r\n\r\n").unwrap().keep_alive);
        assert!(req(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().keep_alive);
    }

    #[test]
    fn malformed_requests_map_to_400() {
        for raw in [
            b"GARBAGE\r\n\r\n".as_slice(),
            b"GET / HTTP/2.0\r\n\r\n".as_slice(),
            b"GET  / HTTP/1.1\r\n\r\n".as_slice(),
            b"GET noslash HTTP/1.1\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1\r\nno colon here\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1\r\n a: folded\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\ncontent-length: nan\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\ncontent-length: 2\r\ncontent-length: 3\r\n\r\nab".as_slice(),
            b"POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n".as_slice(),
        ] {
            assert_eq!(req(raw).unwrap_err().status(), Some(400), "{:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn caps_map_to_431_and_413() {
        let long = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "y".repeat(9000));
        assert_eq!(req(long.as_bytes()).unwrap_err().status(), Some(431));
        let many = format!("GET / HTTP/1.1\r\n{}\r\n", "a: b\r\n".repeat(100));
        assert_eq!(req(many.as_bytes()).unwrap_err().status(), Some(431));
        let big = format!("POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n", (1 << 20) + 1);
        assert_eq!(req(big.as_bytes()).unwrap_err().status(), Some(413));
    }

    #[test]
    fn unsupported_method_maps_to_405() {
        assert_eq!(req(b"PUT / HTTP/1.1\r\n\r\n").unwrap_err().status(), Some(405));
    }

    #[test]
    fn clean_close_and_truncation_are_distinct() {
        assert!(matches!(req(b"").unwrap_err(), RecvError::Closed));
        assert!(matches!(req(b"GET / HT").unwrap_err(), RecvError::Malformed(_)));
        assert!(matches!(
            req(b"POST / HTTP/1.1\r\ncontent-length: 9\r\n\r\nabc").unwrap_err(),
            RecvError::Malformed(_)
        ));
    }

    #[test]
    fn pipelined_requests_frame_sequentially() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\ncontent-length: 2\r\n\r\nhi\
                    GET /c HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.to_vec());
        let lim = Limits::default();
        assert_eq!(read_request(&mut cur, &lim).unwrap().path, "/a");
        let b = read_request(&mut cur, &lim).unwrap();
        assert_eq!(b.path, "/b");
        assert_eq!(b.body, b"hi");
        assert_eq!(read_request(&mut cur, &lim).unwrap().path, "/c");
        assert!(matches!(read_request(&mut cur, &lim).unwrap_err(), RecvError::Closed));
    }

    #[test]
    fn response_round_trips_through_writer_and_parser() {
        use crate::util::json::Json;
        let resp = Response::json(200, &Json::Obj(vec![("ok".into(), Json::Bool(true))]))
            .header("x-bsq-argmax", "3");
        let mut wire = Vec::new();
        let n = resp.write_to(&mut wire, true).unwrap();
        assert_eq!(n, wire.len());
        let back = read_response(&mut Cursor::new(wire), &Limits::default()).unwrap();
        assert_eq!(back.status, 200);
        assert_eq!(back.header_value("x-bsq-argmax"), Some("3"));
        assert_eq!(back.body, resp.body);
    }
}
