//! Admission control for the ingress (DESIGN.md §15): per-tenant
//! token-bucket quotas and priority lanes layered over the pool's bounded
//! request queue. Decisions are made *before* a request touches the queue,
//! in a fixed order — quota first (cheapest, per-tenant fairness), then
//! the lane check against queue occupancy, then the queue's own `try_send`
//! as the race-safe backstop — so an overloaded server does constant work
//! per rejected request.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Request priority, parsed from the `x-bsq-priority` header. Lanes are
/// *admission* lanes, not dispatch lanes: a high-priority request may use
/// the reserved queue headroom, but once admitted it rides the same FIFO
/// batcher as everyone else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    Normal,
    High,
}

impl Priority {
    /// Parse the header value; absent means `Normal`, anything outside
    /// {`normal`, `high`} is a client error (400, not a silent default).
    pub fn parse(header: Option<&str>) -> Result<Priority, String> {
        match header.map(|h| h.to_ascii_lowercase()) {
            None => Ok(Priority::Normal),
            Some(h) if h == "normal" => Ok(Priority::Normal),
            Some(h) if h == "high" => Ok(Priority::High),
            Some(h) => Err(format!("unknown priority: {h:.20}")),
        }
    }
}

/// Per-tenant token-bucket quota: sustained `rate_per_sec` with bursts up
/// to `burst` requests.
#[derive(Debug, Clone, Copy)]
pub struct QuotaCfg {
    pub rate_per_sec: f64,
    pub burst: f64,
}

/// Admission knobs.
#[derive(Debug, Clone)]
pub struct AdmissionCfg {
    /// Fraction of the queue capacity reserved for high-priority traffic:
    /// normal requests are shed once occupancy reaches
    /// `capacity − ceil(capacity × reserve_frac)`, high-priority ones only
    /// at full capacity. Clamped to `[0, 0.9]`.
    pub reserve_frac: f64,
    /// Per-tenant quota; `None` disables quota checks entirely.
    pub quota: Option<QuotaCfg>,
    /// `Retry-After` hint attached to queue-occupancy sheds (quota sheds
    /// compute their own hint from the bucket deficit).
    pub retry_after: Duration,
    /// Bound on the tenant-bucket table; beyond it the stalest bucket is
    /// evicted, so an attacker rotating tenant names cannot grow memory.
    pub max_tenants: usize,
}

impl Default for AdmissionCfg {
    fn default() -> AdmissionCfg {
        AdmissionCfg {
            reserve_frac: 0.25,
            quota: None,
            retry_after: Duration::from_millis(250),
            max_tenants: 1024,
        }
    }
}

/// Outcome of a quota check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Admit,
    /// Over quota; retry once the bucket has refilled one token.
    Shed { retry_after: Duration },
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Shared admission state: the config plus the per-tenant bucket table.
/// One instance per ingress, shared across connection threads.
pub struct AdmissionCtl {
    cfg: AdmissionCfg,
    buckets: Mutex<BTreeMap<String, Bucket>>,
}

impl AdmissionCtl {
    pub fn new(mut cfg: AdmissionCfg) -> AdmissionCtl {
        cfg.reserve_frac = cfg.reserve_frac.clamp(0.0, 0.9);
        if let Some(q) = &mut cfg.quota {
            q.rate_per_sec = q.rate_per_sec.max(1e-6);
            q.burst = q.burst.max(1.0);
        }
        cfg.max_tenants = cfg.max_tenants.max(1);
        AdmissionCtl { cfg, buckets: Mutex::new(BTreeMap::new()) }
    }

    pub fn cfg(&self) -> &AdmissionCfg {
        &self.cfg
    }

    /// May a request of `prio` enter a queue currently `depth` deep out of
    /// `capacity`? Normal traffic keeps `ceil(capacity × reserve_frac)`
    /// slots free for high-priority traffic (at least one normal slot
    /// always remains, so a misconfigured reserve cannot starve the lane
    /// entirely).
    pub fn lane_open(&self, depth: usize, capacity: usize, prio: Priority) -> bool {
        match prio {
            Priority::High => depth < capacity,
            Priority::Normal => {
                let reserve = (capacity as f64 * self.cfg.reserve_frac).ceil() as usize;
                depth < capacity.saturating_sub(reserve).max(1)
            }
        }
    }

    /// Token-bucket check for `tenant` at wall-clock `now` (injected so
    /// tests drive deterministic timelines). Admitting costs one token;
    /// an empty bucket sheds with a hint sized to the refill deficit.
    pub fn check_quota_at(&self, tenant: &str, now: Instant) -> Decision {
        let Some(q) = self.cfg.quota else { return Decision::Admit };
        let mut buckets = self.buckets.lock().unwrap_or_else(|e| e.into_inner());
        if !buckets.contains_key(tenant) && buckets.len() >= self.cfg.max_tenants {
            // Evict the stalest bucket — the tenant least recently seen.
            if let Some(oldest) = buckets
                .iter()
                .min_by_key(|(_, b)| b.last)
                .map(|(k, _)| k.clone())
            {
                buckets.remove(&oldest);
            }
        }
        let bucket = buckets
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: q.burst, last: now });
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * q.rate_per_sec).min(q.burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Decision::Admit
        } else {
            let secs = (1.0 - bucket.tokens) / q.rate_per_sec;
            Decision::Shed { retry_after: Duration::from_secs_f64(secs.min(3600.0)) }
        }
    }

    pub fn check_quota(&self, tenant: &str) -> Decision {
        self.check_quota_at(tenant, Instant::now())
    }
}

/// Tenant names ride a header; bound and sanitize them before they become
/// bucket-table keys.
pub fn valid_tenant(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.' | b'@'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(quota: Option<QuotaCfg>, reserve: f64) -> AdmissionCtl {
        AdmissionCtl::new(AdmissionCfg { reserve_frac: reserve, quota, ..Default::default() })
    }

    #[test]
    fn lane_reserves_headroom_for_high_priority() {
        let c = ctl(None, 0.25);
        // capacity 4, reserve ceil(1) = 1: normal admitted below depth 3,
        // high below depth 4.
        assert!(c.lane_open(2, 4, Priority::Normal));
        assert!(!c.lane_open(3, 4, Priority::Normal));
        assert!(c.lane_open(3, 4, Priority::High));
        assert!(!c.lane_open(4, 4, Priority::High));
    }

    #[test]
    fn lane_never_starves_normal_traffic() {
        let c = ctl(None, 0.9); // clamp cap; reserve would eat everything
        assert!(c.lane_open(0, 2, Priority::Normal));
    }

    #[test]
    fn bucket_burst_then_refill() {
        let c = ctl(Some(QuotaCfg { rate_per_sec: 2.0, burst: 2.0 }), 0.0);
        let t0 = Instant::now();
        assert_eq!(c.check_quota_at("a", t0), Decision::Admit);
        assert_eq!(c.check_quota_at("a", t0), Decision::Admit);
        let shed = c.check_quota_at("a", t0);
        match shed {
            Decision::Shed { retry_after } => {
                // Empty bucket at 2 tokens/s: one token is 500ms away.
                assert!(retry_after > Duration::from_millis(400));
                assert!(retry_after <= Duration::from_millis(500));
            }
            Decision::Admit => panic!("third burst request must shed"),
        }
        // Other tenants are unaffected.
        assert_eq!(c.check_quota_at("b", t0), Decision::Admit);
        // 600ms later one token has refilled.
        let t1 = t0 + Duration::from_millis(600);
        assert_eq!(c.check_quota_at("a", t1), Decision::Admit);
        assert!(matches!(c.check_quota_at("a", t1), Decision::Shed { .. }));
    }

    #[test]
    fn bucket_table_is_bounded() {
        let c = AdmissionCtl::new(AdmissionCfg {
            quota: Some(QuotaCfg { rate_per_sec: 1.0, burst: 1.0 }),
            max_tenants: 4,
            ..Default::default()
        });
        let t0 = Instant::now();
        for i in 0..32u64 {
            c.check_quota_at(&format!("tenant-{i}"), t0 + Duration::from_millis(i));
        }
        assert!(c.buckets.lock().unwrap().len() <= 4);
    }

    #[test]
    fn tenant_names_are_sanitized() {
        assert!(valid_tenant("team-a_01.svc@prod"));
        assert!(!valid_tenant(""));
        assert!(!valid_tenant(&"x".repeat(65)));
        assert!(!valid_tenant("bad tenant"));
        assert!(!valid_tenant("bad\r\nheader"));
    }

    #[test]
    fn priority_parses_strictly() {
        assert_eq!(Priority::parse(None), Ok(Priority::Normal));
        assert_eq!(Priority::parse(Some("HIGH")), Ok(Priority::High));
        assert_eq!(Priority::parse(Some("normal")), Ok(Priority::Normal));
        assert!(Priority::parse(Some("urgent")).is_err());
    }
}
