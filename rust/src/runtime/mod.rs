//! Runtime layer: engine (PJRT or native host backend) + artifact manifests.
//!
//! With the real `xla` bindings linked, the engine loads the HLO-text
//! artifacts produced by `python/compile/aot.py` (`make artifacts`),
//! compiles them once on the CPU PJRT client, and executes them from the
//! coordinator's hot path. With the offline stub, [`Engine::cpu`] falls
//! back to [`native`]: the same entry points executed on host kernels
//! (`tensor::gemm`), with manifests synthesized from the model zoo — the
//! full pipeline runs without Python or XLA.

pub mod engine;
pub mod manifest;
pub mod native;
pub mod xla_stub;

pub use engine::{artifacts_root, load_manifest, Engine, Executable, RunInputs, RunOutputs};
pub use manifest::{ArtifactSpec, IoItem, Manifest, Role};
