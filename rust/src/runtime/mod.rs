//! Runtime layer: PJRT client wrapper + artifact manifests.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`), compiles them once on the CPU PJRT client, and
//! executes them from the coordinator's hot path. Python never runs here.

pub mod engine;
pub mod manifest;
pub mod xla_stub;

pub use engine::{artifacts_root, load_manifest, Engine, Executable, RunInputs, RunOutputs};
pub use manifest::{ArtifactSpec, IoItem, Manifest, Role};
