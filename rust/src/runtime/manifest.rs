//! Manifest parsing: the Python↔Rust artifact contract.
//!
//! `python/compile/aot.py` writes one `manifest.json` per model describing
//! the architecture metadata (quantized layers, BN groups, activation
//! sites) and, per artifact, the flat ordered input/output specs. This
//! module parses it with the in-crate JSON parser and validates the
//! invariants the step loop depends on.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Role {
    X,
    Y,
    State,
    Hyper,
    Vec,
    Probe,
    Metric,
    ProbeOut,
}

impl Role {
    fn from_str(s: &str) -> Result<Role> {
        Ok(match s {
            "x" => Role::X,
            "y" => Role::Y,
            "state" => Role::State,
            "hyper" => Role::Hyper,
            "vec" => Role::Vec,
            "probe" => Role::Probe,
            "metric" => Role::Metric,
            "probe_out" => Role::ProbeOut,
            other => bail!("unknown role {other:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct IoItem {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
    pub role: Role,
}

impl IoItem {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoItem>,
    pub outputs: Vec<IoItem>,
}

#[derive(Debug, Clone)]
pub struct QLayerMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String, // "conv" | "dense"
    pub params: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub batch: usize,
    pub nb: usize,
    pub input_hw: (usize, usize),
    pub in_ch: usize,
    pub num_classes: usize,
    pub qlayers: Vec<QLayerMeta>,
    pub bn_names: Vec<String>,
    pub act_sites: Vec<String>,
    pub dense_bias: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let qlayers = v
            .req("qlayers")?
            .as_arr()?
            .iter()
            .map(|q| {
                Ok(QLayerMeta {
                    name: q.req("name")?.as_str()?.to_string(),
                    shape: q.req("shape")?.as_usize_vec()?,
                    kind: q.req("kind")?.as_str()?.to_string(),
                    params: q.req("params")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in v.req("artifacts")?.as_obj()? {
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(a.req("file")?.as_str()?),
                inputs: parse_items(a.req("inputs")?)?,
                outputs: parse_items(a.req("outputs")?)?,
            };
            validate_spec(&spec)?;
            artifacts.insert(name.clone(), spec);
        }

        let hw = v.req("input_hw")?.as_usize_vec()?;
        if hw.len() != 2 {
            bail!("input_hw must have 2 entries");
        }
        let m = Manifest {
            model: v.req("model")?.as_str()?.to_string(),
            batch: v.req("batch")?.as_usize()?,
            nb: v.req("nb")?.as_usize()?,
            input_hw: (hw[0], hw[1]),
            in_ch: v.req("in_ch")?.as_usize()?,
            num_classes: v.req("num_classes")?.as_usize()?,
            qlayers,
            bn_names: v.req("bn_names")?.as_str_vec()?,
            act_sites: v.req("act_sites")?.as_str_vec()?,
            dense_bias: v.req("dense_bias")?.as_str_vec()?,
            artifacts,
            dir: dir.to_path_buf(),
        };
        if m.qlayers.is_empty() {
            bail!("manifest has no quantized layers");
        }
        for q in &m.qlayers {
            let n: usize = q.shape.iter().product();
            if n != q.params {
                bail!("layer {}: shape {:?} ≠ params {}", q.name, q.shape, q.params);
            }
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("model {} has no artifact {name:?}", self.model))
    }

    pub fn total_params(&self) -> usize {
        self.qlayers.iter().map(|q| q.params).sum()
    }

    pub fn layer_names(&self) -> Vec<String> {
        self.qlayers.iter().map(|q| q.name.clone()).collect()
    }
}

fn parse_items(v: &Json) -> Result<Vec<IoItem>> {
    v.as_arr()?
        .iter()
        .map(|item| {
            Ok(IoItem {
                name: item.req("name")?.as_str()?.to_string(),
                shape: item.req("shape")?.as_usize_vec()?,
                dtype: item.req("dtype")?.as_str()?.to_string(),
                role: Role::from_str(item.req("role")?.as_str()?)?,
            })
        })
        .collect()
}

fn validate_spec(spec: &ArtifactSpec) -> Result<()> {
    let mut seen = std::collections::BTreeSet::new();
    for i in &spec.inputs {
        if !seen.insert(&i.name) {
            bail!("{}: duplicate input {}", spec.name, i.name);
        }
        if i.dtype != "f32" && i.dtype != "i32" {
            bail!("{}: unsupported dtype {}", spec.name, i.dtype);
        }
    }
    let in_shapes: BTreeMap<&str, &Vec<usize>> =
        spec.inputs.iter().map(|i| (i.name.as_str(), &i.shape)).collect();
    for o in &spec.outputs {
        if o.role == Role::State {
            match in_shapes.get(o.name.as_str()) {
                Some(s) if **s == o.shape => {}
                Some(s) => {
                    bail!("{}: output {} shape {:?} ≠ input {:?}", spec.name, o.name, o.shape, s)
                }
                None => bail!("{}: state output {} has no matching input", spec.name, o.name),
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_tinynet_manifest() {
        let dir = artifacts_dir().join("tinynet");
        if !dir.exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "tinynet");
        assert_eq!(m.nb, 9);
        assert_eq!(m.qlayers.len(), 4);
        assert!(m.artifacts.contains_key("bsq_train_relu6"));
        let a = m.artifact("bsq_train_relu6").unwrap();
        assert!(a.file.exists());
        // batch inputs come first by construction
        assert_eq!(a.inputs[0].name, "x");
        assert_eq!(a.inputs[0].role, Role::X);
        assert_eq!(a.inputs[1].dtype, "i32");
    }

    #[test]
    fn rejects_bad_role() {
        assert!(Role::from_str("bogus").is_err());
        assert!(Role::from_str("state").is_ok());
    }

    #[test]
    fn spec_validation_catches_shape_mismatch() {
        let spec = ArtifactSpec {
            name: "t".into(),
            file: "/tmp/x".into(),
            inputs: vec![IoItem {
                name: "a".into(),
                shape: vec![2],
                dtype: "f32".into(),
                role: Role::State,
            }],
            outputs: vec![IoItem {
                name: "a".into(),
                shape: vec![3],
                dtype: "f32".into(),
                role: Role::State,
            }],
        };
        assert!(validate_spec(&spec).is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
