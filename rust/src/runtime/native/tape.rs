//! Reverse-mode tape for the native backend.
//!
//! One forward pass records a topologically ordered node list; `backward`
//! walks it in reverse, producing input-space cotangents per node plus a
//! keyed map of parameter gradients (effective weights under `weff:<layer>`,
//! biases, BN affines, PACT clips). The op set is exactly what the model
//! zoo's forward graphs need — this is not a general autodiff system.
//!
//! Semantics mirror `python/compile` (the lowered JAX graphs) operation by
//! operation: SAME-padded NHWC conv via im2col + the `tensor::gemm` blocked
//! kernels, batch-norm with biased batch statistics, the fake-quant STE of
//! `kernels/actquant.py` (pass-through inside `(0, bound)`, above-bound mass
//! to the PACT clip), and the option-A shortcut / concat / pooling glue.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::tensor::gemm::{self, BitPlaneMatrix, ConvGeom};
use crate::tensor::Tensor;

pub const BN_MOMENTUM: f32 = 0.1;
pub const BN_EPS: f32 = 1e-5;

/// Handle to a tape node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize);

/// Cross-shard reduction hooks for data-parallel training
/// (`runtime::native::shard`, DESIGN.md §10).
///
/// The tape calls these at every point where the math couples samples
/// across the batch. Implementations must return values that depend only on
/// the *global* batch — per-sample partials combined in a canonical
/// fixed-order tree — never on how samples were partitioned into shards;
/// that contract is what makes sharded training bit-identical to the
/// single-shard path at any shard count.
pub trait ShardHook {
    /// Total sample count across all shards.
    fn global_samples(&self) -> usize;
    /// Global index of this shard's first sample.
    fn sample_base(&self) -> usize;
    /// Exchange one f64 vector per local sample (in shard order) against
    /// the other shards; returns the canonical fixed-order tree fold over
    /// all global samples. Errors if a peer shard aborted.
    fn exchange(&self, local: Vec<Vec<f64>>) -> Result<Vec<f64>>;
    /// Deposit one per-sample leaf-gradient partial under `key` for the
    /// given *global* sample index (reduced later in canonical order).
    fn deposit(&self, key: String, sample: usize, grad: Tensor);
}

/// Effective weight of a conv/dense layer for one forward pass.
pub enum WeightRep {
    /// Dense f32 (training paths; backward supported).
    Dense(Tensor),
    /// Sign-split plane bitsets (inference path; forward only, cost
    /// proportional to set weight bits). Behind `Arc` so a serving layer
    /// can prebuild the bitsets once and share them across every batch.
    Planes(Arc<BitPlaneMatrix>),
}

pub(crate) enum Op {
    Input,
    Conv { x: Var, layer: String, w: WeightRep, geom: ConvGeom },
    Dense { x: Var, layer: String, w: WeightRep, in_dim: usize, out_dim: usize },
    Bn { x: Var, name: String, gamma: Vec<f32>, mean: Vec<f32>, var: Vec<f32>, batch_stats: bool },
    ActQuant { x: Var, bound: f32, levels: f32, pact: Option<String> },
    Add { a: Var, b: Var },
    GlobalAvgPool { x: Var },
    Subsample { x: Var, stride: usize },
    PadChannels { x: Var, cin: usize },
    Concat { parts: Vec<(Var, usize)> },
    AvgPool3x3Edge { x: Var },
}

pub(crate) struct Node {
    pub op: Op,
    pub out: Tensor,
}

#[derive(Default)]
pub struct Tape {
    pub(crate) nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape { nodes: Vec::new() }
    }

    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].out
    }

    fn push(&mut self, op: Op, out: Tensor) -> Var {
        self.nodes.push(Node { op, out });
        Var(self.nodes.len() - 1)
    }

    pub fn input(&mut self, t: Tensor) -> Var {
        self.push(Op::Input, t)
    }

    /// SAME-padded NHWC convolution; `kshape` is the HWIO kernel shape.
    pub fn conv(
        &mut self,
        x: Var,
        layer: &str,
        w: WeightRep,
        kshape: &[usize],
        stride: usize,
    ) -> Result<Var> {
        if kshape.len() != 4 {
            bail!("conv {layer}: kernel shape {kshape:?} is not HWIO");
        }
        let (kh, kw, cin, cout) = (kshape[0], kshape[1], kshape[2], kshape[3]);
        let (geom, ydata) = {
            let xt = self.value(x);
            let s = xt.shape();
            if s.len() != 4 || s[3] != cin {
                bail!("conv {layer}: input {s:?} vs kernel {kshape:?}");
            }
            let geom = ConvGeom::same(s[0], s[1], s[2], cin, kh, kw, cout, stride);
            let patches = gemm::im2col(xt.data(), &geom);
            let rows = geom.rows();
            let k = geom.kdim();
            let ydata = match &w {
                WeightRep::Dense(wt) => gemm::matmul(&patches, wt.data(), rows, k, cout),
                WeightRep::Planes(bpm) => {
                    let yt = bpm.matmul_t(&gemm::transpose(&patches, rows, k), rows);
                    gemm::transpose(&yt, cout, rows)
                }
            };
            (geom, ydata)
        };
        let out = Tensor::new(vec![geom.n, geom.oh, geom.ow, geom.cout], ydata)?;
        Ok(self.push(Op::Conv { x, layer: layer.to_string(), w, geom }, out))
    }

    /// `x[N, in] · W[in, out] + b` (bias handled by the caller as a separate
    /// keyed parameter; pass it pre-added via `bias`).
    pub fn dense(&mut self, x: Var, layer: &str, w: WeightRep, bias: &[f32]) -> Result<Var> {
        let (n, in_dim) = {
            let s = self.value(x).shape();
            if s.len() != 2 {
                bail!("dense {layer}: input {s:?} is not [N, in]");
            }
            (s[0], s[1])
        };
        let out_dim = bias.len();
        let ydata = {
            let xd = self.value(x).data();
            let mut y = match &w {
                WeightRep::Dense(wt) => {
                    if wt.shape() != [in_dim, out_dim] {
                        bail!("dense {layer}: weight {:?} vs [{in_dim}, {out_dim}]", wt.shape());
                    }
                    gemm::matmul(xd, wt.data(), n, in_dim, out_dim)
                }
                WeightRep::Planes(bpm) => {
                    let yt = bpm.matmul_t(&gemm::transpose(xd, n, in_dim), n);
                    gemm::transpose(&yt, out_dim, n)
                }
            };
            for row in y.chunks_mut(out_dim) {
                for (v, &b) in row.iter_mut().zip(bias) {
                    *v += b;
                }
            }
            y
        };
        let out = Tensor::new(vec![n, out_dim], ydata)?;
        Ok(self.push(Op::Dense { x, layer: layer.to_string(), w, in_dim, out_dim }, out))
    }

    /// Normalize with the supplied statistics. `batch_stats` says the
    /// mean/var were computed from this very `x` (train mode) so backward
    /// must differentiate through them; false treats them as constants
    /// (eval / HVP running statistics).
    pub fn bn(
        &mut self,
        x: Var,
        name: &str,
        gamma: &[f32],
        beta: &[f32],
        mean: &[f32],
        var: &[f32],
        batch_stats: bool,
    ) -> Result<Var> {
        let (shape, ydata) = {
            let xt = self.value(x);
            let c = *xt.shape().last().ok_or_else(|| anyhow!("bn {name}: scalar input"))?;
            if [gamma.len(), beta.len(), mean.len(), var.len()] != [c, c, c, c] {
                bail!("bn {name}: channel mismatch ({c} channels)");
            }
            let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
            let ydata: Vec<f32> = xt
                .data()
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    let ch = i % c;
                    (v - mean[ch]) * inv[ch] * gamma[ch] + beta[ch]
                })
                .collect();
            (xt.shape().to_vec(), ydata)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(
            Op::Bn {
                x,
                name: name.to_string(),
                gamma: gamma.to_vec(),
                mean: mean.to_vec(),
                var: var.to_vec(),
                batch_stats,
            },
            out,
        ))
    }

    /// Fake-quantized clipped activation (`kernels/actquant.py`):
    /// `levels ≥ 1` quantizes `clip(x, 0, bound)` onto `levels` uniform
    /// steps, `levels < 1` keeps the bare clip. `pact` names the trainable
    /// clip parameter receiving the above-bound gradient mass (None → the
    /// bound is the fixed ReLU6 constant).
    pub fn act_quant(
        &mut self,
        x: Var,
        bound: f32,
        levels: f32,
        pact: Option<String>,
    ) -> Result<Var> {
        let (shape, ydata) = {
            let xt = self.value(x);
            let ydata: Vec<f32> = if levels >= 1.0 {
                xt.data()
                    .iter()
                    .map(|&v| {
                        let xc = v.clamp(0.0, bound);
                        (xc / bound * levels).round() / levels * bound
                    })
                    .collect()
            } else {
                xt.data().iter().map(|&v| v.clamp(0.0, bound)).collect()
            };
            (xt.shape().to_vec(), ydata)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::ActQuant { x, bound, levels, pact }, out))
    }

    pub fn add(&mut self, a: Var, b: Var) -> Result<Var> {
        let (shape, ydata) = {
            let (ta, tb) = (self.value(a), self.value(b));
            if ta.shape() != tb.shape() {
                bail!("add: {:?} vs {:?}", ta.shape(), tb.shape());
            }
            let ydata: Vec<f32> = ta.data().iter().zip(tb.data()).map(|(&x, &y)| x + y).collect();
            (ta.shape().to_vec(), ydata)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::Add { a, b }, out))
    }

    /// `[N,H,W,C] → [N,C]`: mean over the spatial axes.
    pub fn global_avg_pool(&mut self, x: Var) -> Result<Var> {
        let (n, c, ydata) = {
            let xt = self.value(x);
            let s = xt.shape();
            if s.len() != 4 {
                bail!("global_avg_pool: input {s:?} is not NHWC");
            }
            let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
            let mut y = vec![0.0f32; n * c];
            for ni in 0..n {
                for p in 0..h * w {
                    let src = &xt.data()[(ni * h * w + p) * c..][..c];
                    let dst = &mut y[ni * c..(ni + 1) * c];
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d += v;
                    }
                }
            }
            let inv = 1.0 / (h * w) as f32;
            for v in &mut y {
                *v *= inv;
            }
            (n, c, y)
        };
        let out = Tensor::new(vec![n, c], ydata)?;
        Ok(self.push(Op::GlobalAvgPool { x }, out))
    }

    /// `x[:, ::s, ::s, :]` — strided spatial subsample.
    pub fn subsample(&mut self, x: Var, stride: usize) -> Result<Var> {
        let (shape, ydata) = {
            let xt = self.value(x);
            let s = xt.shape();
            if s.len() != 4 {
                bail!("subsample: input {s:?} is not NHWC");
            }
            let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
            let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
            let mut y = vec![0.0f32; n * oh * ow * c];
            for ni in 0..n {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let src = &xt.data()[((ni * h + oy * stride) * w + ox * stride) * c..][..c];
                        y[((ni * oh + oy) * ow + ox) * c..][..c].copy_from_slice(src);
                    }
                }
            }
            (vec![n, oh, ow, c], y)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::Subsample { x, stride }, out))
    }

    /// Zero-pad the channel axis up to `cout` (ResNet option-A shortcut).
    pub fn pad_channels(&mut self, x: Var, cout: usize) -> Result<Var> {
        let (shape, cin, ydata) = {
            let xt = self.value(x);
            let s = xt.shape();
            let cin = *s.last().ok_or_else(|| anyhow!("pad_channels: scalar input"))?;
            if cout < cin {
                bail!("pad_channels: {cout} < {cin}");
            }
            let pix = xt.len() / cin;
            let mut y = vec![0.0f32; pix * cout];
            for p in 0..pix {
                y[p * cout..p * cout + cin].copy_from_slice(&xt.data()[p * cin..(p + 1) * cin]);
            }
            let mut shape = s.to_vec();
            *shape.last_mut().unwrap() = cout;
            (shape, cin, y)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::PadChannels { x, cin }, out))
    }

    /// Concatenate NHWC tensors along the channel axis.
    pub fn concat(&mut self, vars: &[Var]) -> Result<Var> {
        let (shape, parts, ydata) = {
            let base = self.value(vars[0]).shape().to_vec();
            if base.len() != 4 {
                bail!("concat: input {base:?} is not NHWC");
            }
            let mut parts = Vec::with_capacity(vars.len());
            let mut ctotal = 0usize;
            for &v in vars {
                let s = self.value(v).shape();
                if s[..3] != base[..3] {
                    bail!("concat: {s:?} vs {base:?}");
                }
                parts.push((v, s[3]));
                ctotal += s[3];
            }
            let pix = base[0] * base[1] * base[2];
            let mut y = vec![0.0f32; pix * ctotal];
            let mut off = 0usize;
            for &(v, c) in &parts {
                let src = self.value(v).data();
                for p in 0..pix {
                    y[p * ctotal + off..p * ctotal + off + c]
                        .copy_from_slice(&src[p * c..(p + 1) * c]);
                }
                off += c;
            }
            let mut shape = base;
            shape[3] = ctotal;
            (shape, parts, y)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::Concat { parts }, out))
    }

    /// 3×3 stride-1 average pool with edge ("SAME", clamp-index) padding —
    /// the Inception pool branch.
    pub fn avg_pool3x3_edge(&mut self, x: Var) -> Result<Var> {
        let (shape, ydata) = {
            let xt = self.value(x);
            let s = xt.shape();
            if s.len() != 4 {
                bail!("avg_pool3x3: input {s:?} is not NHWC");
            }
            let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
            let mut y = vec![0.0f32; xt.len()];
            for ni in 0..n {
                for oy in 0..h {
                    for ox in 0..w {
                        let dst = &mut y[((ni * h + oy) * w + ox) * c..][..c];
                        for dy in 0..3 {
                            let iy = (oy + dy).saturating_sub(1).min(h - 1);
                            for dx in 0..3 {
                                let ix = (ox + dx).saturating_sub(1).min(w - 1);
                                let src = &xt.data()[((ni * h + iy) * w + ix) * c..][..c];
                                for (d, &v) in dst.iter_mut().zip(src) {
                                    *d += v;
                                }
                            }
                        }
                        for v in dst.iter_mut() {
                            *v /= 9.0;
                        }
                    }
                }
            }
            (s.to_vec(), ydata)
        };
        let out = Tensor::new(shape, ydata)?;
        Ok(self.push(Op::AvgPool3x3Edge { x }, out))
    }
}

/// Biased per-channel batch statistics over `[N, H, W, C]` (the axes JAX's
/// `jnp.mean/var(axis=(0,1,2))` reduces).
pub fn batch_stats(x: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let c = *x.shape().last().unwrap_or(&1);
    let rows = x.len() / c.max(1);
    let mut mean = vec![0.0f64; c];
    for row in x.data().chunks(c) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= rows as f64;
    }
    let mut var = vec![0.0f64; c];
    for row in x.data().chunks(c) {
        for ((vv, &v), &m) in var.iter_mut().zip(row).zip(&mean) {
            let d = v as f64 - m;
            *vv += d * d;
        }
    }
    for v in &mut var {
        *v /= rows as f64;
    }
    (mean.iter().map(|&m| m as f32).collect(), var.iter().map(|&v| v as f32).collect())
}

/// Gradients produced by one backward pass.
#[derive(Default)]
pub struct Grads {
    vars: Vec<Option<Tensor>>,
    /// Parameter-space cotangents: `weff:<layer>` (effective conv/dense
    /// weight), `w:<layer>/b`, `bn:<n>/gamma|beta`, `pact:<site>`.
    pub keys: BTreeMap<String, Tensor>,
}

impl Grads {
    fn accumulate(&mut self, v: Var, g: Tensor) {
        match self.vars[v.0].as_mut() {
            Some(t) => {
                for (a, &b) in t.data_mut().iter_mut().zip(g.data()) {
                    *a += b;
                }
            }
            None => self.vars[v.0] = Some(g),
        }
    }

    fn add_key(&mut self, key: String, shape: &[usize], data: Vec<f32>) {
        match self.keys.get_mut(&key) {
            Some(t) => {
                for (a, b) in t.data_mut().iter_mut().zip(data) {
                    *a += b;
                }
            }
            None => {
                self.keys.insert(key, Tensor::new(shape.to_vec(), data).unwrap());
            }
        }
    }
}

/// Reverse pass from `root` seeded with `seed = dL/d(root)`.
pub fn backward(tape: &Tape, root: Var, seed: Tensor) -> Result<Grads> {
    backward_impl(tape, root, seed, None)
}

/// Reverse pass for one shard of a data-parallel step: batch-summed leaf
/// gradients (dW, db, dγ/dβ, dPACT) are handed to `hook` as per-sample
/// partials instead of being accumulated locally, and the train-mode BN
/// input cotangent is computed from the *global* Σdy / Σdy·x̂ obtained via
/// `hook.exchange` — so every per-element result is independent of the
/// shard partition. `Grads.keys` comes back empty in this mode; the
/// orchestrator reduces the deposits instead.
pub fn backward_sharded(
    tape: &Tape,
    root: Var,
    seed: Tensor,
    hook: &dyn ShardHook,
) -> Result<Grads> {
    backward_impl(tape, root, seed, Some(hook))
}

fn backward_impl(
    tape: &Tape,
    root: Var,
    seed: Tensor,
    hook: Option<&dyn ShardHook>,
) -> Result<Grads> {
    let mut g = Grads { vars: vec![None; tape.nodes.len()], keys: BTreeMap::new() };
    if seed.shape() != tape.value(root).shape() {
        bail!("backward: seed {:?} vs root {:?}", seed.shape(), tape.value(root).shape());
    }
    g.vars[root.0] = Some(seed);
    for idx in (0..=root.0).rev() {
        let dy = match g.vars[idx].take() {
            Some(t) => t,
            None => continue,
        };
        match &tape.nodes[idx].op {
            Op::Input => {}
            Op::Conv { x, layer, w, geom } => {
                let wt = match w {
                    WeightRep::Dense(t) => t,
                    WeightRep::Planes(_) => {
                        bail!("conv {layer}: bit-plane weights are inference-only (no backward)")
                    }
                };
                let (rows, k, cout) = (geom.rows(), geom.kdim(), geom.cout);
                let patches = gemm::im2col(tape.value(*x).data(), geom);
                if let Some(h) = hook {
                    // Per-sample dW partials: same total flops as the one
                    // big GEMM, but each partial depends only on its own
                    // sample — the canonical reduce happens downstream.
                    let spp = geom.oh * geom.ow;
                    for si in 0..geom.n {
                        let pr = &patches[si * spp * k..(si + 1) * spp * k];
                        let dr = &dy.data()[si * spp * cout..(si + 1) * spp * cout];
                        let dwi = gemm::matmul_tn(pr, dr, spp, k, cout);
                        h.deposit(
                            format!("weff:{layer}"),
                            h.sample_base() + si,
                            Tensor::new(wt.shape().to_vec(), dwi)?,
                        );
                    }
                } else {
                    let dw = gemm::matmul_tn(&patches, dy.data(), rows, k, cout);
                    g.add_key(format!("weff:{layer}"), wt.shape(), dw);
                }
                let dpatches = gemm::matmul_nt(dy.data(), wt.data(), rows, cout, k);
                let mut dx = vec![0.0f32; tape.value(*x).len()];
                gemm::col2im_add(&dpatches, geom, &mut dx);
                g.accumulate(*x, Tensor::new(tape.value(*x).shape().to_vec(), dx)?);
            }
            Op::Dense { x, layer, w, in_dim, out_dim } => {
                let wt = match w {
                    WeightRep::Dense(t) => t,
                    WeightRep::Planes(_) => {
                        bail!("dense {layer}: bit-plane weights are inference-only (no backward)")
                    }
                };
                let n = tape.value(*x).shape()[0];
                if let Some(h) = hook {
                    let xd = tape.value(*x).data();
                    for si in 0..n {
                        let xr = &xd[si * in_dim..(si + 1) * in_dim];
                        let dr = &dy.data()[si * out_dim..(si + 1) * out_dim];
                        let dwi = gemm::matmul_tn(xr, dr, 1, *in_dim, *out_dim);
                        h.deposit(
                            format!("weff:{layer}"),
                            h.sample_base() + si,
                            Tensor::new(vec![*in_dim, *out_dim], dwi)?,
                        );
                        h.deposit(
                            format!("w:{layer}/b"),
                            h.sample_base() + si,
                            Tensor::new(vec![*out_dim], dr.to_vec())?,
                        );
                    }
                } else {
                    let dw =
                        gemm::matmul_tn(tape.value(*x).data(), dy.data(), n, *in_dim, *out_dim);
                    g.add_key(format!("weff:{layer}"), &[*in_dim, *out_dim], dw);
                    let mut db = vec![0.0f32; *out_dim];
                    for row in dy.data().chunks(*out_dim) {
                        for (d, &v) in db.iter_mut().zip(row) {
                            *d += v;
                        }
                    }
                    g.add_key(format!("w:{layer}/b"), &[*out_dim], db);
                }
                let dx = gemm::matmul_nt(dy.data(), wt.data(), n, *out_dim, *in_dim);
                g.accumulate(*x, Tensor::new(vec![n, *in_dim], dx)?);
            }
            Op::Bn { x, name, gamma, mean, var, batch_stats } => {
                let xt = tape.value(*x);
                let c = gamma.len();
                let rows = xt.len() / c;
                let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                // channel reductions: Σdy, Σdy·x̂ (also the affine grads)
                let mut dbeta = vec![0.0f64; c];
                let mut dgamma = vec![0.0f64; c];
                let mut rows_for_dx = rows;
                if let Some(h) = hook {
                    // Per-sample partials: deposit the affine grads for the
                    // canonical downstream reduce, and (train mode) obtain
                    // the global Σdy / Σdy·x̂ the dx formula needs via the
                    // fixed-order exchange.
                    let n_local = xt.shape()[0];
                    let r_per = rows / n_local.max(1);
                    let mut partials: Vec<Vec<f64>> = Vec::with_capacity(n_local);
                    for si in 0..n_local {
                        let mut p = vec![0.0f64; 2 * c];
                        let span = si * r_per * c..(si + 1) * r_per * c;
                        for (row, dyr) in
                            xt.data()[span.clone()].chunks(c).zip(dy.data()[span].chunks(c))
                        {
                            for ch in 0..c {
                                let xhat = (row[ch] - mean[ch]) * inv[ch];
                                p[ch] += dyr[ch] as f64;
                                p[c + ch] += (dyr[ch] * xhat) as f64;
                            }
                        }
                        h.deposit(
                            format!("bn:{name}/beta"),
                            h.sample_base() + si,
                            Tensor::new(vec![c], p[..c].iter().map(|&v| v as f32).collect())?,
                        );
                        h.deposit(
                            format!("bn:{name}/gamma"),
                            h.sample_base() + si,
                            Tensor::new(vec![c], p[c..].iter().map(|&v| v as f32).collect())?,
                        );
                        partials.push(p);
                    }
                    if *batch_stats {
                        let global = h.exchange(partials)?;
                        dbeta = global[..c].to_vec();
                        dgamma = global[c..].to_vec();
                        rows_for_dx = r_per * h.global_samples();
                    }
                } else {
                    for (row, dyr) in xt.data().chunks(c).zip(dy.data().chunks(c)) {
                        for ch in 0..c {
                            let xhat = (row[ch] - mean[ch]) * inv[ch];
                            dbeta[ch] += dyr[ch] as f64;
                            dgamma[ch] += (dyr[ch] * xhat) as f64;
                        }
                    }
                    g.add_key(
                        format!("bn:{name}/gamma"),
                        &[c],
                        dgamma.iter().map(|&v| v as f32).collect(),
                    );
                    g.add_key(
                        format!("bn:{name}/beta"),
                        &[c],
                        dbeta.iter().map(|&v| v as f32).collect(),
                    );
                }
                let mut dx = vec![0.0f32; xt.len()];
                if *batch_stats {
                    let rinv = 1.0 / rows_for_dx as f32;
                    for (i, (row, dyr)) in
                        xt.data().chunks(c).zip(dy.data().chunks(c)).enumerate()
                    {
                        for ch in 0..c {
                            let xhat = (row[ch] - mean[ch]) * inv[ch];
                            let dxhat = dyr[ch] * gamma[ch];
                            dx[i * c + ch] = inv[ch]
                                * (dxhat
                                    - rinv * (dbeta[ch] as f32) * gamma[ch]
                                    - rinv * xhat * (dgamma[ch] as f32) * gamma[ch]);
                        }
                    }
                } else {
                    for (i, dyr) in dy.data().chunks(c).enumerate() {
                        for ch in 0..c {
                            dx[i * c + ch] = dyr[ch] * gamma[ch] * inv[ch];
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::ActQuant { x, bound, levels: _, pact } => {
                let xt = tape.value(*x);
                let mut dx = vec![0.0f32; xt.len()];
                for ((d, &v), &gy) in dx.iter_mut().zip(xt.data()).zip(dy.data()) {
                    if v > 0.0 && v < *bound {
                        *d = gy;
                    }
                }
                if let Some(site) = pact {
                    // above-bound gradient mass flows to the PACT clip
                    let dbound_over = |lo: usize, hi: usize| -> f64 {
                        xt.data()[lo..hi]
                            .iter()
                            .zip(&dy.data()[lo..hi])
                            .filter(|(&v, _)| v >= *bound)
                            .map(|(_, &gy)| gy as f64)
                            .sum()
                    };
                    match hook {
                        Some(h) => {
                            let n_local = xt.shape()[0];
                            let per = xt.len() / n_local.max(1);
                            for si in 0..n_local {
                                let db = dbound_over(si * per, (si + 1) * per);
                                h.deposit(
                                    format!("pact:{site}"),
                                    h.sample_base() + si,
                                    Tensor::scalar(db as f32),
                                );
                            }
                        }
                        None => {
                            let db = dbound_over(0, xt.len()) as f32;
                            g.add_key(format!("pact:{site}"), &[], vec![db]);
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::Add { a, b } => {
                g.accumulate(*a, dy.clone());
                g.accumulate(*b, dy);
            }
            Op::GlobalAvgPool { x } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let inv = 1.0 / (h * w) as f32;
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    let dyr = &dy.data()[ni * c..(ni + 1) * c];
                    for p in 0..h * w {
                        let dst = &mut dx[(ni * h * w + p) * c..][..c];
                        for (d, &v) in dst.iter_mut().zip(dyr) {
                            *d = v * inv;
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
            Op::Subsample { x, stride } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let (oh, ow) = (h.div_ceil(*stride), w.div_ceil(*stride));
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let src = &dy.data()[((ni * oh + oy) * ow + ox) * c..][..c];
                            dx[((ni * h + oy * stride) * w + ox * stride) * c..][..c]
                                .copy_from_slice(src);
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
            Op::PadChannels { x, cin } => {
                let xt = tape.value(*x);
                let cout = *tape.nodes[idx].out.shape().last().unwrap();
                let pix = xt.len() / cin;
                let mut dx = vec![0.0f32; xt.len()];
                for p in 0..pix {
                    dx[p * cin..(p + 1) * cin]
                        .copy_from_slice(&dy.data()[p * cout..p * cout + cin]);
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::Concat { parts } => {
                let ctotal: usize = parts.iter().map(|&(_, c)| c).sum();
                let pix = dy.len() / ctotal;
                let mut off = 0usize;
                for &(v, c) in parts {
                    let xt = tape.value(v);
                    let mut dx = vec![0.0f32; xt.len()];
                    for p in 0..pix {
                        dx[p * c..(p + 1) * c]
                            .copy_from_slice(&dy.data()[p * ctotal + off..p * ctotal + off + c]);
                    }
                    g.accumulate(v, Tensor::new(xt.shape().to_vec(), dx)?);
                    off += c;
                }
            }
            Op::AvgPool3x3Edge { x } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    for oy in 0..h {
                        for ox in 0..w {
                            let src = &dy.data()[((ni * h + oy) * w + ox) * c..][..c];
                            for ddy in 0..3 {
                                let iy = (oy + ddy).saturating_sub(1).min(h - 1);
                                for ddx in 0..3 {
                                    let ix = (ox + ddx).saturating_sub(1).min(w - 1);
                                    let dst = &mut dx[((ni * h + iy) * w + ix) * c..][..c];
                                    for (d, &v) in dst.iter_mut().zip(src) {
                                        *d += v / 9.0;
                                    }
                                }
                            }
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
        }
    }
    Ok(g)
}
