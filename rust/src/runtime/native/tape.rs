//! Reverse-mode tape for the native backend.
//!
//! Since the layer-graph IR landed (DESIGN.md §11) the tape no longer
//! *builds* forward computations — `ir::exec::run_on_tape` walks a
//! compiled plan, evaluates each node with the shared kernels, and pushes
//! one tape [`Node`] per graph node, so `Var(i)` on the tape **is** graph
//! node `i`. This file owns what remains: the op record, the value store,
//! and `backward`, which walks the records in reverse producing
//! input-space cotangents plus a keyed map of parameter gradients
//! (effective weights under `weff:<layer>`, biases, BN affines, PACT
//! clips).
//!
//! Semantics mirror `python/compile` (the lowered JAX graphs) operation by
//! operation: SAME-padded NHWC conv via im2col + the `tensor::gemm`
//! runtime-dispatched kernels (the backward's `matmul_tn`/`matmul_nt`
//! pack their strided views directly on the SIMD backend — no transpose
//! materialization), batch-norm with biased batch statistics, the
//! fake-quant STE of `kernels/actquant.py` (pass-through inside
//! `(0, bound)`, above-bound mass to the PACT clip), and the option-A
//! shortcut / concat / pooling glue.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::gemm::{self, BitPlaneMatrix, ConvGeom};
use crate::tensor::Tensor;

pub const BN_MOMENTUM: f32 = 0.1;
pub const BN_EPS: f32 = 1e-5;

/// Handle to a tape node; equals the graph [`NodeId`] it was recorded for.
///
/// [`NodeId`]: crate::ir::graph::NodeId
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize);

/// Address of one leaf-gradient deposit stream: the graph node that owns
/// the parameter plus the state key its reduced total lands under.
///
/// Keying by node id (not call order, not bare strings) makes the slots
/// partition-invariant *by construction*: every shard records against the
/// same compiled graph, so the same parameter maps to the same slot no
/// matter how the batch was split or in what order the ops ran.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct DepositSlot {
    pub node: usize,
    pub key: String,
}

impl DepositSlot {
    pub fn new(node: usize, key: String) -> DepositSlot {
        DepositSlot { node, key }
    }
}

/// Cross-shard reduction hooks for data-parallel training
/// (`runtime::native::shard`, DESIGN.md §10).
///
/// The tape calls these at every point where the math couples samples
/// across the batch. Implementations must return values that depend only on
/// the *global* batch — per-sample partials combined in a canonical
/// fixed-order tree — never on how samples were partitioned into shards;
/// that contract is what makes sharded training bit-identical to the
/// single-shard path at any shard count.
pub trait ShardHook {
    /// Total sample count across all shards.
    fn global_samples(&self) -> usize;
    /// Global index of this shard's first sample.
    fn sample_base(&self) -> usize;
    /// Exchange one f64 vector per local sample (in shard order) against
    /// the other shards; returns the canonical fixed-order tree fold over
    /// all global samples. Errors if a peer shard aborted.
    fn exchange(&self, local: Vec<Vec<f64>>) -> Result<Vec<f64>>;
    /// Deposit one per-sample leaf-gradient partial into `slot` for the
    /// given *global* sample index (reduced later in canonical order).
    fn deposit(&self, slot: DepositSlot, sample: usize, grad: Tensor);
}

/// Effective weight of a conv/dense layer for one forward pass.
pub enum WeightRep {
    /// Dense f32 (training paths; backward supported).
    Dense(Tensor),
    /// Sign-split plane bitsets (inference path; forward only, cost
    /// proportional to set weight bits). Behind `Arc` so a serving layer
    /// can prebuild the bitsets once and share them across every batch.
    Planes(Arc<BitPlaneMatrix>),
}

pub(crate) enum Op {
    Input,
    Conv { x: Var, layer: String, w: WeightRep, geom: ConvGeom },
    Dense { x: Var, layer: String, w: WeightRep, in_dim: usize, out_dim: usize },
    Bias { x: Var, layer: String, out_dim: usize },
    Bn { x: Var, name: String, gamma: Vec<f32>, mean: Vec<f32>, var: Vec<f32>, batch_stats: bool },
    ActQuant { x: Var, bound: f32, levels: f32, pact: Option<String> },
    Add { a: Var, b: Var },
    GlobalAvgPool { x: Var },
    Subsample { x: Var, stride: usize },
    PadChannels { x: Var, cin: usize },
    Concat { parts: Vec<(Var, usize)> },
    AvgPool3x3Edge { x: Var },
}

pub(crate) struct Node {
    pub op: Op,
    pub out: Tensor,
}

/// The value store one planned forward leaves behind for `backward`.
#[derive(Default)]
pub struct Tape {
    pub(crate) nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape { nodes: Vec::new() }
    }

    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].out
    }

    pub(crate) fn push(&mut self, op: Op, out: Tensor) -> Var {
        self.nodes.push(Node { op, out });
        Var(self.nodes.len() - 1)
    }
}

/// Biased per-channel batch statistics over `[N, H, W, C]` (the axes JAX's
/// `jnp.mean/var(axis=(0,1,2))` reduces).
pub fn batch_stats(x: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let c = *x.shape().last().unwrap_or(&1);
    let rows = x.len() / c.max(1);
    let mut mean = vec![0.0f64; c];
    for row in x.data().chunks(c) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= rows as f64;
    }
    let mut var = vec![0.0f64; c];
    for row in x.data().chunks(c) {
        for ((vv, &v), &m) in var.iter_mut().zip(row).zip(&mean) {
            let d = v as f64 - m;
            *vv += d * d;
        }
    }
    for v in &mut var {
        *v /= rows as f64;
    }
    (mean.iter().map(|&m| m as f32).collect(), var.iter().map(|&v| v as f32).collect())
}

/// Gradients produced by one backward pass.
#[derive(Default)]
pub struct Grads {
    vars: Vec<Option<Tensor>>,
    /// Parameter-space cotangents: `weff:<layer>` (effective conv/dense
    /// weight), `w:<layer>/b`, `bn:<n>/gamma|beta`, `pact:<site>`.
    pub keys: BTreeMap<String, Tensor>,
}

impl Grads {
    fn accumulate(&mut self, v: Var, g: Tensor) {
        match self.vars[v.0].as_mut() {
            Some(t) => {
                for (a, &b) in t.data_mut().iter_mut().zip(g.data()) {
                    *a += b;
                }
            }
            None => self.vars[v.0] = Some(g),
        }
    }

    fn add_key(&mut self, key: String, shape: &[usize], data: Vec<f32>) {
        match self.keys.get_mut(&key) {
            Some(t) => {
                for (a, b) in t.data_mut().iter_mut().zip(data) {
                    *a += b;
                }
            }
            None => {
                self.keys.insert(key, Tensor::new(shape.to_vec(), data).unwrap());
            }
        }
    }
}

/// Reverse pass from `root` seeded with `seed = dL/d(root)`.
pub fn backward(tape: &Tape, root: Var, seed: Tensor) -> Result<Grads> {
    backward_impl(tape, root, seed, None)
}

/// Reverse pass for one shard of a data-parallel step: batch-summed leaf
/// gradients (dW, db, dγ/dβ, dPACT) are handed to `hook` as per-sample
/// partials instead of being accumulated locally, and the train-mode BN
/// input cotangent is computed from the *global* Σdy / Σdy·x̂ obtained via
/// `hook.exchange` — so every per-element result is independent of the
/// shard partition. `Grads.keys` comes back empty in this mode; the
/// orchestrator reduces the deposits instead.
pub fn backward_sharded(
    tape: &Tape,
    root: Var,
    seed: Tensor,
    hook: &dyn ShardHook,
) -> Result<Grads> {
    backward_impl(tape, root, seed, Some(hook))
}

fn backward_impl(
    tape: &Tape,
    root: Var,
    seed: Tensor,
    hook: Option<&dyn ShardHook>,
) -> Result<Grads> {
    let mut g = Grads { vars: vec![None; tape.nodes.len()], keys: BTreeMap::new() };
    if seed.shape() != tape.value(root).shape() {
        bail!("backward: seed {:?} vs root {:?}", seed.shape(), tape.value(root).shape());
    }
    g.vars[root.0] = Some(seed);
    for idx in (0..=root.0).rev() {
        let dy = match g.vars[idx].take() {
            Some(t) => t,
            None => continue,
        };
        match &tape.nodes[idx].op {
            Op::Input => {}
            Op::Conv { x, layer, w, geom } => {
                let wt = match w {
                    WeightRep::Dense(t) => t,
                    WeightRep::Planes(_) => {
                        bail!("conv {layer}: bit-plane weights are inference-only (no backward)")
                    }
                };
                let (rows, k, cout) = (geom.rows(), geom.kdim(), geom.cout);
                let patches = gemm::im2col(tape.value(*x).data(), geom);
                if let Some(h) = hook {
                    // Per-sample dW partials: same total flops as the one
                    // big GEMM, but each partial depends only on its own
                    // sample — the canonical reduce happens downstream.
                    let spp = geom.oh * geom.ow;
                    for si in 0..geom.n {
                        let pr = &patches[si * spp * k..(si + 1) * spp * k];
                        let dr = &dy.data()[si * spp * cout..(si + 1) * spp * cout];
                        let dwi = gemm::matmul_tn(pr, dr, spp, k, cout);
                        h.deposit(
                            DepositSlot::new(idx, format!("weff:{layer}")),
                            h.sample_base() + si,
                            Tensor::new(wt.shape().to_vec(), dwi)?,
                        );
                    }
                } else {
                    let dw = gemm::matmul_tn(&patches, dy.data(), rows, k, cout);
                    g.add_key(format!("weff:{layer}"), wt.shape(), dw);
                }
                let dpatches = gemm::matmul_nt(dy.data(), wt.data(), rows, cout, k);
                let mut dx = vec![0.0f32; tape.value(*x).len()];
                gemm::col2im_add(&dpatches, geom, &mut dx);
                g.accumulate(*x, Tensor::new(tape.value(*x).shape().to_vec(), dx)?);
            }
            Op::Dense { x, layer, w, in_dim, out_dim } => {
                let wt = match w {
                    WeightRep::Dense(t) => t,
                    WeightRep::Planes(_) => {
                        bail!("dense {layer}: bit-plane weights are inference-only (no backward)")
                    }
                };
                let n = tape.value(*x).shape()[0];
                if let Some(h) = hook {
                    let xd = tape.value(*x).data();
                    for si in 0..n {
                        let xr = &xd[si * in_dim..(si + 1) * in_dim];
                        let dr = &dy.data()[si * out_dim..(si + 1) * out_dim];
                        let dwi = gemm::matmul_tn(xr, dr, 1, *in_dim, *out_dim);
                        h.deposit(
                            DepositSlot::new(idx, format!("weff:{layer}")),
                            h.sample_base() + si,
                            Tensor::new(vec![*in_dim, *out_dim], dwi)?,
                        );
                    }
                } else {
                    let dw =
                        gemm::matmul_tn(tape.value(*x).data(), dy.data(), n, *in_dim, *out_dim);
                    g.add_key(format!("weff:{layer}"), &[*in_dim, *out_dim], dw);
                }
                let dx = gemm::matmul_nt(dy.data(), wt.data(), n, *out_dim, *in_dim);
                g.accumulate(*x, Tensor::new(vec![n, *in_dim], dx)?);
            }
            Op::Bias { x, layer, out_dim } => {
                if let Some(h) = hook {
                    let n = tape.value(*x).shape()[0];
                    for si in 0..n {
                        let dr = &dy.data()[si * out_dim..(si + 1) * out_dim];
                        h.deposit(
                            DepositSlot::new(idx, format!("w:{layer}/b")),
                            h.sample_base() + si,
                            Tensor::new(vec![*out_dim], dr.to_vec())?,
                        );
                    }
                } else {
                    let mut db = vec![0.0f32; *out_dim];
                    for row in dy.data().chunks(*out_dim) {
                        for (d, &v) in db.iter_mut().zip(row) {
                            *d += v;
                        }
                    }
                    g.add_key(format!("w:{layer}/b"), &[*out_dim], db);
                }
                g.accumulate(*x, dy);
            }
            Op::Bn { x, name, gamma, mean, var, batch_stats } => {
                let xt = tape.value(*x);
                let c = gamma.len();
                let rows = xt.len() / c;
                let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                // channel reductions: Σdy, Σdy·x̂ (also the affine grads)
                let mut dbeta = vec![0.0f64; c];
                let mut dgamma = vec![0.0f64; c];
                let mut rows_for_dx = rows;
                if let Some(h) = hook {
                    // Per-sample partials: deposit the affine grads for the
                    // canonical downstream reduce, and (train mode) obtain
                    // the global Σdy / Σdy·x̂ the dx formula needs via the
                    // fixed-order exchange.
                    let n_local = xt.shape()[0];
                    let r_per = rows / n_local.max(1);
                    let mut partials: Vec<Vec<f64>> = Vec::with_capacity(n_local);
                    for si in 0..n_local {
                        let mut p = vec![0.0f64; 2 * c];
                        let span = si * r_per * c..(si + 1) * r_per * c;
                        for (row, dyr) in
                            xt.data()[span.clone()].chunks(c).zip(dy.data()[span].chunks(c))
                        {
                            for ch in 0..c {
                                let xhat = (row[ch] - mean[ch]) * inv[ch];
                                p[ch] += dyr[ch] as f64;
                                p[c + ch] += (dyr[ch] * xhat) as f64;
                            }
                        }
                        h.deposit(
                            DepositSlot::new(idx, format!("bn:{name}/beta")),
                            h.sample_base() + si,
                            Tensor::new(vec![c], p[..c].iter().map(|&v| v as f32).collect())?,
                        );
                        h.deposit(
                            DepositSlot::new(idx, format!("bn:{name}/gamma")),
                            h.sample_base() + si,
                            Tensor::new(vec![c], p[c..].iter().map(|&v| v as f32).collect())?,
                        );
                        partials.push(p);
                    }
                    if *batch_stats {
                        let global = h.exchange(partials)?;
                        dbeta = global[..c].to_vec();
                        dgamma = global[c..].to_vec();
                        rows_for_dx = r_per * h.global_samples();
                    }
                } else {
                    for (row, dyr) in xt.data().chunks(c).zip(dy.data().chunks(c)) {
                        for ch in 0..c {
                            let xhat = (row[ch] - mean[ch]) * inv[ch];
                            dbeta[ch] += dyr[ch] as f64;
                            dgamma[ch] += (dyr[ch] * xhat) as f64;
                        }
                    }
                    g.add_key(
                        format!("bn:{name}/gamma"),
                        &[c],
                        dgamma.iter().map(|&v| v as f32).collect(),
                    );
                    g.add_key(
                        format!("bn:{name}/beta"),
                        &[c],
                        dbeta.iter().map(|&v| v as f32).collect(),
                    );
                }
                let mut dx = vec![0.0f32; xt.len()];
                if *batch_stats {
                    let rinv = 1.0 / rows_for_dx as f32;
                    for (i, (row, dyr)) in
                        xt.data().chunks(c).zip(dy.data().chunks(c)).enumerate()
                    {
                        for ch in 0..c {
                            let xhat = (row[ch] - mean[ch]) * inv[ch];
                            let dxhat = dyr[ch] * gamma[ch];
                            dx[i * c + ch] = inv[ch]
                                * (dxhat
                                    - rinv * (dbeta[ch] as f32) * gamma[ch]
                                    - rinv * xhat * (dgamma[ch] as f32) * gamma[ch]);
                        }
                    }
                } else {
                    for (i, dyr) in dy.data().chunks(c).enumerate() {
                        for ch in 0..c {
                            dx[i * c + ch] = dyr[ch] * gamma[ch] * inv[ch];
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::ActQuant { x, bound, levels: _, pact } => {
                let xt = tape.value(*x);
                let mut dx = vec![0.0f32; xt.len()];
                for ((d, &v), &gy) in dx.iter_mut().zip(xt.data()).zip(dy.data()) {
                    if v > 0.0 && v < *bound {
                        *d = gy;
                    }
                }
                if let Some(site) = pact {
                    // above-bound gradient mass flows to the PACT clip
                    let dbound_over = |lo: usize, hi: usize| -> f64 {
                        xt.data()[lo..hi]
                            .iter()
                            .zip(&dy.data()[lo..hi])
                            .filter(|(&v, _)| v >= *bound)
                            .map(|(_, &gy)| gy as f64)
                            .sum()
                    };
                    match hook {
                        Some(h) => {
                            let n_local = xt.shape()[0];
                            let per = xt.len() / n_local.max(1);
                            for si in 0..n_local {
                                let db = dbound_over(si * per, (si + 1) * per);
                                h.deposit(
                                    DepositSlot::new(idx, format!("pact:{site}")),
                                    h.sample_base() + si,
                                    Tensor::scalar(db as f32),
                                );
                            }
                        }
                        None => {
                            let db = dbound_over(0, xt.len()) as f32;
                            g.add_key(format!("pact:{site}"), &[], vec![db]);
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::Add { a, b } => {
                g.accumulate(*a, dy.clone());
                g.accumulate(*b, dy);
            }
            Op::GlobalAvgPool { x } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let inv = 1.0 / (h * w) as f32;
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    let dyr = &dy.data()[ni * c..(ni + 1) * c];
                    for p in 0..h * w {
                        let dst = &mut dx[(ni * h * w + p) * c..][..c];
                        for (d, &v) in dst.iter_mut().zip(dyr) {
                            *d = v * inv;
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
            Op::Subsample { x, stride } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let (oh, ow) = (h.div_ceil(*stride), w.div_ceil(*stride));
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let src = &dy.data()[((ni * oh + oy) * ow + ox) * c..][..c];
                            dx[((ni * h + oy * stride) * w + ox * stride) * c..][..c]
                                .copy_from_slice(src);
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
            Op::PadChannels { x, cin } => {
                let xt = tape.value(*x);
                let cout = *tape.nodes[idx].out.shape().last().unwrap();
                let pix = xt.len() / cin;
                let mut dx = vec![0.0f32; xt.len()];
                for p in 0..pix {
                    dx[p * cin..(p + 1) * cin]
                        .copy_from_slice(&dy.data()[p * cout..p * cout + cin]);
                }
                g.accumulate(*x, Tensor::new(xt.shape().to_vec(), dx)?);
            }
            Op::Concat { parts } => {
                let ctotal: usize = parts.iter().map(|&(_, c)| c).sum();
                let pix = dy.len() / ctotal;
                let mut off = 0usize;
                for &(v, c) in parts {
                    let xt = tape.value(v);
                    let mut dx = vec![0.0f32; xt.len()];
                    for p in 0..pix {
                        dx[p * c..(p + 1) * c]
                            .copy_from_slice(&dy.data()[p * ctotal + off..p * ctotal + off + c]);
                    }
                    g.accumulate(v, Tensor::new(xt.shape().to_vec(), dx)?);
                    off += c;
                }
            }
            Op::AvgPool3x3Edge { x } => {
                let xt = tape.value(*x);
                let s = xt.shape();
                let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
                let mut dx = vec![0.0f32; xt.len()];
                for ni in 0..n {
                    for oy in 0..h {
                        for ox in 0..w {
                            let src = &dy.data()[((ni * h + oy) * w + ox) * c..][..c];
                            for ddy in 0..3 {
                                let iy = (oy + ddy).saturating_sub(1).min(h - 1);
                                for ddx in 0..3 {
                                    let ix = (ox + ddx).saturating_sub(1).min(w - 1);
                                    let dst = &mut dx[((ni * h + iy) * w + ix) * c..][..c];
                                    for (d, &v) in dst.iter_mut().zip(src) {
                                        *d += v / 9.0;
                                    }
                                }
                            }
                        }
                    }
                }
                g.accumulate(*x, Tensor::new(s.to_vec(), dx)?);
            }
        }
    }
    Ok(g)
}
