//! Native execution of the manifest entry points: train / eval / hvp.
//!
//! Every role executes a compiled layer-graph plan (`ir::plan`): train
//! drives the reverse-mode tape over the retain-all train plan (via the
//! data-parallel shard orchestrator), eval and the HVP center loss run the
//! fused infer plan inside the thread-local activation arena. This module
//! owns what surrounds the plan: weight preparation per quantization mode,
//! the STE gradient mapping, the loss/regularizer, and the optimizer.
//!
//! One function per role, mirroring `python/compile/train.py` step for step:
//!
//! * **train** — forward under the entry's weight mode (fp / bit / DoReFa /
//!   LSQ STE) and activation mode (ReLU6 / PACT), CE + α·Σ c_l·B_GL loss
//!   (paper Eq. 5), reverse pass, PyTorch-convention SGD-momentum update
//!   with the `[0, 2]` plane clamp, BN running-stat writeback.
//! * **eval** — forward only; in bit mode the convolutions and the dense
//!   head run on the bit-plane GEMM (`tensor::gemm::BitPlaneMatrix`), so
//!   inference cost shrinks with every plane the regularizer empties and
//!   §3.3 trims away.
//! * **hvp** — HAWQ's Hessian-vector product, computed as the central
//!   difference of the analytic CE gradient at `w ± εv` (the fp "ref"
//!   graph: clip-only activations, eval-mode BN). The AOT artifact uses
//!   forward-over-reverse autodiff; the central difference agrees to O(ε²)
//!   and feeds the same block power iteration.
//!
//! STE gradient conventions (identical to `quantize.py` under
//! `x + stop_gradient(round(x) − x)`):
//!   bit     dL/dwp_b = +s·2^b/denom · dL/dW (− for wn), dL/ds = Σ dW·V/denom
//!   dorefa  identity (levels ≥ 1), zero for a dead (levels < 1) layer
//!   lsq     dL/dw masked to the un-clipped region,
//!           dL/dstep = Σ dW·(Round(code) − code·1_inside)
//!   act     pass-through inside (0, bound); above-bound mass → PACT clip

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::data::Batch;
use crate::ir::exec;
use crate::ir::plan::ModelPlans;
use crate::model::state::ModelState;
use crate::quant::bitplane::NB;
use crate::runtime::engine::{RunInputs, RunOutputs};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::native::models::{self, NativeModel};
use crate::runtime::native::shard;
use crate::runtime::native::tape::{backward, WeightRep};
use crate::tensor::gemm::BitPlaneMatrix;
use crate::tensor::Tensor;

/// SGD momentum (paper App. A; `train.py::MOMENTUM`).
const MOMENTUM: f32 = 0.9;
/// Group-Lasso smoothing at the origin (`kernels/ref.py::BGL_EPS`).
const BGL_EPS: f64 = 1e-12;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WMode {
    Fp,
    Bit,
    Dorefa,
    Lsq,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AMode {
    Relu6,
    Pact,
    /// Analysis path (HVP): bare `clip(x, 0, 6)`, no quantization.
    Ref,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Entry {
    Train(WMode, AMode),
    Eval(WMode, AMode),
    Hvp,
}

impl Entry {
    pub fn parse(name: &str) -> Result<Entry> {
        if name == "hvp" {
            return Ok(Entry::Hvp);
        }
        let (base, act) = name
            .rsplit_once('_')
            .ok_or_else(|| anyhow!("malformed artifact name {name:?}"))?;
        let am = match act {
            "relu6" => AMode::Relu6,
            "pact" => AMode::Pact,
            other => bail!("unknown activation mode {other:?} in {name:?}"),
        };
        Ok(match base {
            "fp_train" => Entry::Train(WMode::Fp, am),
            "fp_eval" => Entry::Eval(WMode::Fp, am),
            "bsq_train" => Entry::Train(WMode::Bit, am),
            "q_eval" => Entry::Eval(WMode::Bit, am),
            "dorefa_train" => Entry::Train(WMode::Dorefa, am),
            "dorefa_eval" => Entry::Eval(WMode::Dorefa, am),
            "lsq_train" => Entry::Train(WMode::Lsq, am),
            "lsq_eval" => Entry::Eval(WMode::Lsq, am),
            other => bail!("unknown entry point {other:?}"),
        })
    }
}

// -- weight gradient mapping -------------------------------------------------

/// How a layer's effective-weight cotangent maps back to state-space keys.
pub(crate) enum WGradMap {
    /// `w:<l>` += dW (fp master weights; also the DoReFa STE identity).
    Direct,
    /// No gradient (inference reps, dead DoReFa layers).
    Zero,
    /// Bit representation: per-plane coefficients s·2^b/denom and the
    /// rounded codes over denom (the dL/ds factor).
    Bit { coef: Vec<f32>, rv_over_denom: Vec<f32> },
    /// LSQ: clip mask for dW, per-element step cotangent factor.
    Lsq { inside: Vec<f32>, dstep: Vec<f32> },
}

// -- weight preparation ------------------------------------------------------

/// Forward-only weight resolution: [`prepare_weights`] minus the gradient
/// maps — what an inference bind (`ir::exec::bind`) consumes. Public so
/// the serving layer, benches, and the IR property tests share one path.
pub fn eval_weights(
    model: &NativeModel,
    state: &ModelState,
    wm: WMode,
    wlv: Option<&[f32]>,
    bitplane_infer: bool,
) -> Result<BTreeMap<String, WeightRep>> {
    Ok(prepare_weights(model, state, wm, wlv, bitplane_infer)?.0)
}

/// Resolve every quantized layer's effective weight for one pass, plus the
/// map from effective-weight cotangents back to state keys.
pub(crate) fn prepare_weights(
    model: &NativeModel,
    state: &ModelState,
    wm: WMode,
    wlv: Option<&[f32]>,
    bitplane_infer: bool,
) -> Result<(BTreeMap<String, WeightRep>, BTreeMap<String, WGradMap>)> {
    let mut reps = BTreeMap::new();
    let mut gmaps = BTreeMap::new();
    for (i, q) in model.qlayers.iter().enumerate() {
        let (rep, gmap) = match wm {
            WMode::Fp => {
                let w = state.get(&format!("w:{}", q.name))?;
                (WeightRep::Dense(w.clone()), WGradMap::Direct)
            }
            WMode::Bit => prepare_bit(state, q, bitplane_infer)?,
            WMode::Dorefa => {
                let w = state.get(&format!("w:{}", q.name))?;
                let levels = wlv.and_then(|v| v.get(i)).copied().ok_or_else(|| {
                    anyhow!("wlv has no entry for layer {} ({})", i, q.name)
                })?;
                if levels < 1.0 {
                    // n = 0 layer: weight collapses to zero, no gradient
                    (WeightRep::Dense(Tensor::zeros(&q.shape)), WGradMap::Zero)
                } else {
                    let s = w.max_abs().max(1e-8);
                    let wq = w.map(|v| {
                        let ws = v / s;
                        s * (ws.abs() * levels).round() / levels * ws.signum_or_zero()
                    });
                    (WeightRep::Dense(wq), WGradMap::Direct)
                }
            }
            WMode::Lsq => {
                let w = state.get(&format!("w:{}", q.name))?;
                let st = state.get(&format!("step:{}", q.name))?.item()?.max(1e-8);
                let lv = wlv
                    .and_then(|v| v.get(i))
                    .copied()
                    .ok_or_else(|| anyhow!("wlv has no entry for layer {} ({})", i, q.name))?
                    .max(1.0);
                let mut inside = vec![0.0f32; w.len()];
                let mut dstep = vec![0.0f32; w.len()];
                let mut wq = vec![0.0f32; w.len()];
                for (e, &v) in w.data().iter().enumerate() {
                    let raw = v / st;
                    let within = (-lv..=lv).contains(&raw);
                    let code = raw.clamp(-lv, lv);
                    inside[e] = if within { 1.0 } else { 0.0 };
                    dstep[e] = code.round() - if within { code } else { 0.0 };
                    wq[e] = code.round() * st;
                }
                (
                    WeightRep::Dense(Tensor::new(q.shape.clone(), wq)?),
                    WGradMap::Lsq { inside, dstep },
                )
            }
        };
        reps.insert(q.name.clone(), rep);
        gmaps.insert(q.name.clone(), gmap);
    }
    Ok((reps, gmaps))
}

/// Shared f64 plane accumulation for the bit representation: per-element
/// weighted plane sums `v`, the level denominator `max(Σ_b mask_b 2^b, 1)`,
/// and the dynamic-range scale.
fn bit_accumulate(state: &ModelState, q: &models::NativeLayer) -> Result<(Vec<f64>, f64, f32)> {
    let wp = state.get(&format!("wp:{}", q.name))?;
    let wn = state.get(&format!("wn:{}", q.name))?;
    let mask = state.get(&format!("mask:{}", q.name))?;
    let scale = state.get(&format!("scale:{}", q.name))?.item()?;
    let elems = wp.len() / NB;
    if elems != q.params() {
        bail!("layer {}: planes hold {elems} elems, shape says {}", q.name, q.params());
    }

    let mut v = vec![0.0f64; elems];
    let mut denom = 0.0f64;
    for (b, &m) in mask.data().iter().enumerate().take(NB) {
        if m == 0.0 {
            continue;
        }
        let w2 = (1u64 << b) as f64;
        denom += w2;
        for ((acc, &pv), &nv) in v.iter_mut().zip(wp.row(b, elems)).zip(wn.row(b, elems)) {
            *acc += (pv - nv) as f64 * w2;
        }
    }
    Ok((v, denom.max(1.0), scale))
}

/// Build one layer's inference-path bit-plane weight from its state planes.
///
/// This is the single code path behind both the engine's `q_eval_*`
/// artifacts and the serving registry's prebuilt weights — sharing it keeps
/// a served checkpoint bit-identical to the engine eval of the same state.
pub fn bitplane_weight(
    state: &ModelState,
    q: &models::NativeLayer,
) -> Result<Arc<BitPlaneMatrix>> {
    let (v, denom, scale) = bit_accumulate(state, q)?;
    // |Round(v)| ≤ 2·denom ≤ 1022: fits i16, needs ≤ 10 planes.
    let codes: Vec<i16> = v.iter().map(|a| a.round() as i16).collect();
    let max_mag = codes.iter().map(|c| c.unsigned_abs()).max().unwrap_or(0);
    let bits = (16 - (max_mag as u16).leading_zeros() as usize).max(1);
    let n_out = *q.shape.last().unwrap_or(&1);
    let k = codes.len() / n_out;
    let delta = (scale as f64 / denom) as f32;
    Ok(Arc::new(BitPlaneMatrix::from_codes(&codes, k, n_out, bits, delta)))
}

/// Bit-representation weight: `W = s·Round[Σ_b mask_b (wp_b − wn_b) 2^b] /
/// max(Σ_b mask_b 2^b, 1)` (paper Eq. 2/3). The plane accumulation runs in
/// f64 so the rounded codes match `quant::packed` bit for bit — which keeps
/// re-quantization an exact no-op on the represented weight here too.
fn prepare_bit(
    state: &ModelState,
    q: &models::NativeLayer,
    bitplane_infer: bool,
) -> Result<(WeightRep, WGradMap)> {
    if bitplane_infer {
        return Ok((WeightRep::Planes(bitplane_weight(state, q)?), WGradMap::Zero));
    }
    let (v, denom, scale) = bit_accumulate(state, q)?;
    let mask = state.get(&format!("mask:{}", q.name))?;

    let weff: Vec<f32> = v.iter().map(|a| (scale as f64 * a.round() / denom) as f32).collect();
    let rv_over_denom: Vec<f32> = v.iter().map(|a| (a.round() / denom) as f32).collect();
    let coef: Vec<f32> = (0..NB)
        .map(|b| {
            if mask.data()[b] != 0.0 {
                (scale as f64 * (1u64 << b) as f64 / denom) as f32
            } else {
                0.0
            }
        })
        .collect();
    Ok((
        WeightRep::Dense(Tensor::new(q.shape.clone(), weff)?),
        WGradMap::Bit { coef, rv_over_denom },
    ))
}

trait SignumOrZero {
    fn signum_or_zero(self) -> f32;
}

impl SignumOrZero for f32 {
    /// `jnp.sign` semantics: sign(0) = 0 (f32::signum gives ±1 at 0).
    fn signum_or_zero(self) -> f32 {
        if self == 0.0 {
            0.0
        } else {
            self.signum()
        }
    }
}

/// Map `weff:<layer>` cotangents onto state keys per the layer's STE rule.
pub(crate) fn map_weight_grads(
    model: &NativeModel,
    gmaps: BTreeMap<String, WGradMap>,
    grads: &mut BTreeMap<String, Tensor>,
) -> Result<()> {
    for q in &model.qlayers {
        let dweff = match grads.remove(&format!("weff:{}", q.name)) {
            Some(t) => t,
            None => continue, // layer unused by this graph
        };
        match gmaps.get(&q.name) {
            Some(WGradMap::Direct) => {
                accumulate(grads, format!("w:{}", q.name), dweff);
            }
            Some(WGradMap::Zero) | None => {}
            Some(WGradMap::Bit { coef, rv_over_denom }) => {
                let elems = dweff.len();
                let mut dwp = vec![0.0f32; NB * elems];
                let mut dwn = vec![0.0f32; NB * elems];
                for (b, &c) in coef.iter().enumerate() {
                    if c == 0.0 {
                        continue;
                    }
                    for (e, &g) in dweff.data().iter().enumerate() {
                        dwp[b * elems + e] = c * g;
                        dwn[b * elems + e] = -c * g;
                    }
                }
                let mut pshape = vec![NB];
                pshape.extend_from_slice(&q.shape);
                accumulate(grads, format!("wp:{}", q.name), Tensor::new(pshape.clone(), dwp)?);
                accumulate(grads, format!("wn:{}", q.name), Tensor::new(pshape, dwn)?);
                let dscale: f64 = dweff
                    .data()
                    .iter()
                    .zip(rv_over_denom)
                    .map(|(&g, &r)| (g * r) as f64)
                    .sum();
                accumulate(grads, format!("scale:{}", q.name), Tensor::scalar(dscale as f32));
            }
            Some(WGradMap::Lsq { inside, dstep }) => {
                let dw: Vec<f32> = dweff.data().iter().zip(inside).map(|(&g, &m)| g * m).collect();
                accumulate(grads, format!("w:{}", q.name), Tensor::new(q.shape.clone(), dw)?);
                let ds: f64 = dweff.data().iter().zip(dstep).map(|(&g, &d)| (g * d) as f64).sum();
                accumulate(grads, format!("step:{}", q.name), Tensor::scalar(ds as f32));
            }
        }
    }
    Ok(())
}

pub(crate) fn accumulate(grads: &mut BTreeMap<String, Tensor>, key: String, t: Tensor) {
    match grads.get_mut(&key) {
        Some(dst) => {
            for (a, &b) in dst.data_mut().iter_mut().zip(t.data()) {
                *a += b;
            }
        }
        None => {
            grads.insert(key, t);
        }
    }
}

// -- loss / regularizer ------------------------------------------------------

/// Per-sample softmax-CE terms, correct-prediction count, and dL/dlogits
/// for `L = (Σ ce_i) / n_global`. `n_global` is the full-batch sample count
/// (equal to `y.len()` on the unsharded path; the data-parallel shards pass
/// the global batch size so dL/dlogits carries the right mean factor while
/// the CE terms stay sample-granular for the canonical reduce).
pub(crate) fn ce_rows(
    logits: &Tensor,
    y: &[i32],
    n_global: usize,
) -> Result<(Vec<f64>, usize, Tensor)> {
    let s = logits.shape();
    if s.len() != 2 || s[0] != y.len() || n_global == 0 {
        bail!("logits {s:?} vs {} labels (global {n_global})", y.len());
    }
    let (n, c) = (s[0], s[1]);
    let mut dl = vec![0.0f32; n * c];
    let mut ce = Vec::with_capacity(n);
    let mut correct = 0usize;
    for (i, (row, &yi)) in logits.data().chunks(c).zip(y).enumerate() {
        let yi = yi as usize;
        if yi >= c {
            bail!("label {yi} out of range ({c} classes)");
        }
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sumexp: f64 = row.iter().map(|&l| ((l - max) as f64).exp()).sum();
        let lse = max as f64 + sumexp.ln();
        ce.push(lse - row[yi] as f64);
        let mut arg = 0usize;
        for (j, &l) in row.iter().enumerate() {
            if l > row[arg] {
                arg = j;
            }
            let p = ((l as f64 - lse).exp()) as f32;
            dl[i * c + j] = (p - if j == yi { 1.0 } else { 0.0 }) / n_global as f32;
        }
        if arg == yi {
            correct += 1;
        }
    }
    Ok((ce, correct, Tensor::new(vec![n, c], dl)?))
}

/// Softmax CE + accuracy + dL/dlogits for L = mean CE (single-shard view).
fn ce_acc_grad(logits: &Tensor, y: &[i32]) -> Result<(f32, f32, Tensor)> {
    let (ce, correct, dl) = ce_rows(logits, y, y.len())?;
    let n = y.len().max(1);
    // sequential sum in sample order — the pre-sharding accumulation order
    let total: f64 = ce.iter().sum();
    Ok(((total / n as f64) as f32, correct as f32 / n as f32, dl))
}

/// Σ_l regw_l·B_GL(W^l) (paper Eq. 4/5) and its plane gradients, with the
/// loss coefficient α already folded into the gradients.
pub(crate) fn bgl_and_grads(
    model: &NativeModel,
    state: &ModelState,
    regw: &[f32],
    alpha: f32,
) -> Result<(f32, BTreeMap<String, Tensor>)> {
    let mut total = 0.0f64;
    let mut grads = BTreeMap::new();
    for (i, q) in model.qlayers.iter().enumerate() {
        let rw = *regw.get(i).ok_or_else(|| anyhow!("regw has no entry {i}"))? as f64;
        let wp = state.get(&format!("wp:{}", q.name))?;
        let wn = state.get(&format!("wn:{}", q.name))?;
        let mask = state.get(&format!("mask:{}", q.name))?;
        let elems = wp.len() / NB;
        let mut dwp = vec![0.0f32; NB * elems];
        let mut dwn = vec![0.0f32; NB * elems];
        for (b, &m) in mask.data().iter().enumerate().take(NB) {
            if m == 0.0 {
                continue;
            }
            let (prow, nrow) = (wp.row(b, elems), wn.row(b, elems));
            let ssq: f64 = prow.iter().chain(nrow).map(|&v| (v as f64) * (v as f64)).sum();
            let norm = (ssq + BGL_EPS).sqrt();
            total += rw * norm;
            let coef = (alpha as f64 * rw / norm) as f32;
            for (e, (&pv, &nv)) in prow.iter().zip(nrow).enumerate() {
                dwp[b * elems + e] = coef * pv;
                dwn[b * elems + e] = coef * nv;
            }
        }
        let mut pshape = vec![NB];
        pshape.extend_from_slice(&q.shape);
        grads.insert(format!("wp:{}", q.name), Tensor::new(pshape.clone(), dwp)?);
        grads.insert(format!("wn:{}", q.name), Tensor::new(pshape, dwn)?);
    }
    Ok((total as f32, grads))
}

// -- optimizer ---------------------------------------------------------------

/// PyTorch-convention SGD: `m ← μm + (g + wd·w); w ← w − lr·m`, with weight
/// decay off for planes and scales and the `[0, 2]` plane clamp after every
/// step (paper §3.1). Trainables are exactly the keys the artifact carries
/// momentum slots for.
pub(crate) fn sgd_update(
    state: &mut ModelState,
    spec: &ArtifactSpec,
    grads: &mut BTreeMap<String, Tensor>,
    lr: f32,
    wd: f32,
) -> Result<()> {
    for item in &spec.inputs {
        let Some(key) = item.name.strip_prefix("m:") else { continue };
        let mut w = state
            .remove(key)
            .ok_or_else(|| anyhow!("state missing trainable {key:?}"))?;
        let mut mom = state
            .remove(&item.name)
            .ok_or_else(|| anyhow!("state missing momentum {:?}", item.name))?;
        let g = grads.remove(key);
        if let Some(gt) = &g {
            if gt.len() != w.len() {
                bail!("grad for {key:?} has {} elems, want {}", gt.len(), w.len());
            }
        }
        let decay = if key.starts_with("wp:") || key.starts_with("wn:") || key.starts_with("scale:")
        {
            0.0
        } else {
            wd
        };
        let clamp = key.starts_with("wp:") || key.starts_with("wn:");
        let gdata = g.map(|t| t.into_data());
        for (e, (wv, mv)) in w.data_mut().iter_mut().zip(mom.data_mut()).enumerate() {
            let gv = gdata.as_ref().map(|d| d[e]).unwrap_or(0.0);
            *mv = MOMENTUM * *mv + gv + decay * *wv;
            *wv -= lr * *mv;
            if clamp {
                *wv = wv.clamp(0.0, 2.0);
            }
        }
        state.insert(key.to_string(), w);
        state.insert(item.name.clone(), mom);
    }
    Ok(())
}

// -- input plumbing ----------------------------------------------------------

pub(crate) fn hyper(inputs: &RunInputs, name: &str) -> Result<f32> {
    inputs.hypers.get(name).copied().ok_or_else(|| anyhow!("missing hyper {name:?}"))
}

pub(crate) fn vec_input(inputs: &RunInputs, name: &str, want: usize) -> Result<Vec<f32>> {
    let v = inputs.vecs.get(name).ok_or_else(|| anyhow!("missing vec {name:?}"))?;
    if v.len() != want {
        bail!("vec {name}: {} entries ≠ {want}", v.len());
    }
    Ok(v.clone())
}

// -- entry points ------------------------------------------------------------

/// Execute one artifact natively; mirrors `Executable::run` semantics
/// (state updated in place, metrics/probes returned). Every role runs a
/// compiled plan: train entries drive the data-parallel sharded tape over
/// the train plan (`shards` = 0 means auto; any value yields bit-identical
/// results — see `runtime::native::shard`), eval and the HVP center loss
/// run the fused infer plan inside the thread-local arena.
pub fn execute(
    model: &NativeModel,
    plans: &ModelPlans,
    spec: &ArtifactSpec,
    state: &mut ModelState,
    batch: Option<&Batch>,
    inputs: &RunInputs,
    shards: usize,
) -> Result<RunOutputs> {
    match Entry::parse(&spec.name)? {
        Entry::Train(wm, am) => {
            shard::train_step(model, &plans.train, spec, state, batch, inputs, wm, am, shards)
        }
        Entry::Eval(wm, am) => eval_step(model, plans, state, batch, inputs, wm, am),
        Entry::Hvp => hvp_step(model, plans, state, batch, inputs),
    }
}

pub(crate) fn need_batch<'b>(batch: Option<&'b Batch>) -> Result<&'b Batch> {
    batch.ok_or_else(|| anyhow!("artifact needs a batch"))
}

/// Forward a batch through the fused infer plan and reduce to
/// `(loss, acc)` — the shared tail of eval and the HVP center. Uses a
/// pass-local arena, not the thread-local one: the engine is stateless
/// per call, and a training thread that evaluates occasionally must not
/// pin a batch-sized arena for its remaining lifetime (the serving
/// workers, whose every pass needs it, are who keep the thread-local).
fn planned_eval(
    model: &NativeModel,
    plans: &ModelPlans,
    state: &ModelState,
    reps: BTreeMap<String, WeightRep>,
    actlv: &[f32],
    am: AMode,
    b: &Batch,
) -> Result<(f32, f32)> {
    let bound = exec::bind(&plans.infer, model, state, reps, actlv, am)?;
    // The plan bakes the geometry in; reject a mis-shaped batch whose
    // element count happens to fit (the old per-op checks did this).
    let s = b.x.shape();
    let want = &plans.infer.graph.nodes[0].shape;
    if s.len() != 4 || s[1..] != want[..] {
        bail!("eval batch {s:?} does not match {} input [m, {want:?}]", model.name);
    }
    let m = s[0];
    let mut arena = exec::Arena::default();
    let logits = bound.execute(b.x.data(), m, &mut arena)?;
    let logits = Tensor::new(vec![m, bound.classes()], logits.to_vec())?;
    let (ce, acc, _) = ce_acc_grad(&logits, b.y.data())?;
    Ok((ce, acc))
}

fn eval_step(
    model: &NativeModel,
    plans: &ModelPlans,
    state: &mut ModelState,
    batch: Option<&Batch>,
    inputs: &RunInputs,
    wm: WMode,
    am: AMode,
) -> Result<RunOutputs> {
    let b = need_batch(batch)?;
    let actlv = vec_input(inputs, "actlv", model.act_sites.len())?;
    let wlv = match wm {
        WMode::Dorefa | WMode::Lsq => Some(vec_input(inputs, "wlv", model.qlayers.len())?),
        _ => None,
    };
    // Bit mode runs on the plane bitsets: compute ∝ set weight bits. The
    // O(NB·elems) pack repeats per batch (the engine is stateless and the
    // planes can change between calls); it is dwarfed by the GEMMs, whose
    // work carries the extra M = batch·spatial factor.
    let reps = eval_weights(model, state, wm, wlv.as_deref(), wm == WMode::Bit)?;
    let (ce, acc) = planned_eval(model, plans, state, reps, &actlv, am, b)?;
    let mut out = RunOutputs::default();
    out.metrics.insert("loss".into(), ce);
    out.metrics.insert("acc".into(), acc);
    Ok(out)
}

/// Central-difference Hessian-vector product of the fp CE loss (HAWQ).
fn hvp_step(
    model: &NativeModel,
    plans: &ModelPlans,
    state: &mut ModelState,
    batch: Option<&Batch>,
    inputs: &RunInputs,
) -> Result<RunOutputs> {
    let b = need_batch(batch)?;

    // center loss (reported like the artifact's `loss` output)
    let reps = eval_weights(model, state, WMode::Fp, None, false)?;
    let (loss, _) = planned_eval(model, plans, state, reps, &[], AMode::Ref, b)?;

    let mut out = RunOutputs::default();
    out.metrics.insert("loss".into(), loss);

    let mut vnorm2 = 0.0f64;
    for q in &model.qlayers {
        if let Some(v) = inputs.probes.get(&format!("v:{}", q.name)) {
            vnorm2 += v.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
    }
    if vnorm2.sqrt() < 1e-12 {
        // zero probe ⇒ Hv = 0 (matches the linear-in-v artifact exactly)
        for q in &model.qlayers {
            out.probes.insert(format!("hv:{}", q.name), Tensor::zeros(&q.shape));
        }
        return Ok(out);
    }
    let mut wnorm2 = 0.0f64;
    for q in &model.qlayers {
        let w = state.get(&format!("w:{}", q.name))?;
        wnorm2 += w.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
    }
    let eps = (1e-3 * (wnorm2.sqrt() + 1.0) / vnorm2.sqrt()) as f32;

    let mut sided: Vec<BTreeMap<String, Tensor>> = Vec::with_capacity(2);
    for sign in [1.0f32, -1.0] {
        perturb(model, state, inputs, sign * eps)?;
        let grads = fp_ref_grads(model, plans, state, b);
        perturb(model, state, inputs, -sign * eps)?; // restore
        sided.push(grads?);
    }
    let (gp, gm) = (&sided[0], &sided[1]);
    for q in &model.qlayers {
        let key = format!("weff:{}", q.name);
        let mut hv = Tensor::zeros(&q.shape);
        if let (Some(p), Some(m)) = (gp.get(&key), gm.get(&key)) {
            for ((h, &a), &bv) in hv.data_mut().iter_mut().zip(p.data()).zip(m.data()) {
                *h = (a - bv) / (2.0 * eps);
            }
        }
        out.probes.insert(format!("hv:{}", q.name), hv);
    }
    Ok(out)
}

fn perturb(
    model: &NativeModel,
    state: &mut ModelState,
    inputs: &RunInputs,
    step: f32,
) -> Result<()> {
    for q in &model.qlayers {
        if let Some(v) = inputs.probes.get(&format!("v:{}", q.name)) {
            let w = state.get_mut(&format!("w:{}", q.name))?;
            if w.len() != v.len() {
                bail!("probe v:{} has {} elems, weight has {}", q.name, v.len(), w.len());
            }
            for (wv, &pv) in w.data_mut().iter_mut().zip(v.data()) {
                *wv += step * pv;
            }
        }
    }
    Ok(())
}

/// Analytic CE gradient w.r.t. the fp weights on the "ref" graph
/// (clip-only activations, eval-mode BN) — the inner kernel of the HVP.
fn fp_ref_grads(
    model: &NativeModel,
    plans: &ModelPlans,
    state: &ModelState,
    b: &Batch,
) -> Result<BTreeMap<String, Tensor>> {
    let reps = eval_weights(model, state, WMode::Fp, None, false)?;
    let x = b.x.clone();
    let run = exec::run_on_tape(&plans.train, model, state, reps, &[], AMode::Ref, false, x, None)?;
    let (_, _, dlogits) = ce_acc_grad(run.tape.value(run.logits), b.y.data())?;
    Ok(backward(&run.tape, run.logits, dlogits)?.keys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, CorpusSpec, Loader};
    use crate::model::state::ModelState;
    use crate::runtime::native::manifest_for;
    use crate::util::Pcg32;

    fn tiny_setup() -> (std::sync::Arc<NativeModel>, crate::runtime::Manifest, Batch) {
        let model = models::get("tinynet").unwrap();
        let man = manifest_for("tinynet").unwrap();
        let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(64, 32));
        let mut loader = Loader::eval(&corpus.train, man.batch);
        let batch = loader.next_batch();
        (model, man, batch)
    }

    #[test]
    fn entry_parse_covers_registry() {
        assert_eq!(Entry::parse("hvp").unwrap(), Entry::Hvp);
        assert_eq!(
            Entry::parse("bsq_train_relu6").unwrap(),
            Entry::Train(WMode::Bit, AMode::Relu6)
        );
        assert_eq!(Entry::parse("q_eval_pact").unwrap(), Entry::Eval(WMode::Bit, AMode::Pact));
        assert_eq!(
            Entry::parse("dorefa_eval_relu6").unwrap(),
            Entry::Eval(WMode::Dorefa, AMode::Relu6)
        );
        assert!(Entry::parse("nope_relu6").is_err());
        assert!(Entry::parse("bsq_train_tanh").is_err());
    }

    /// Finite-difference check of the smooth (fp, clip-only) backward path:
    /// conv, BN (batch stats), dense, bias, global pool, CE.
    #[test]
    fn fp_gradients_match_finite_differences() {
        let (model, man, batch) = tiny_setup();
        let plan = crate::ir::plan::cached(&model, crate::ir::plan::PlanMode::Train).unwrap();
        let state = ModelState::init_fp(&man, 5);
        let actlv = vec![0.0f32; model.act_sites.len()];
        let grads = {
            let (reps, gmaps) = prepare_weights(&model, &state, WMode::Fp, None, false).unwrap();
            let run = exec::run_on_tape(
                &plan,
                &model,
                &state,
                reps,
                &actlv,
                AMode::Relu6,
                true,
                batch.x.clone(),
                None,
            )
            .unwrap();
            let (_, _, dl) = ce_acc_grad(run.tape.value(run.logits), batch.y.data()).unwrap();
            let mut g = backward(&run.tape, run.logits, dl).unwrap().keys;
            map_weight_grads(&model, gmaps, &mut g).unwrap();
            g
        };

        let loss_of = |s: &ModelState| -> f32 {
            let reps = eval_weights(&model, s, WMode::Fp, None, false).unwrap();
            let run = exec::run_on_tape(
                &plan,
                &model,
                s,
                reps,
                &actlv,
                AMode::Relu6,
                true,
                batch.x.clone(),
                None,
            )
            .unwrap();
            let (ce, _, _) = ce_acc_grad(run.tape.value(run.logits), batch.y.data()).unwrap();
            ce
        };

        let mut rng = Pcg32::seeded(9);
        // a handful of random coordinates across parameter kinds
        for key in ["w:conv1", "w:conv2", "w:fc", "w:fc/b", "bn:conv2/gamma", "bn:conv1/beta"] {
            let n = state.get(key).unwrap().len();
            for _ in 0..3 {
                let e = rng.below(n as u32) as usize;
                let eps = 2e-3f32;
                let mut sp = state.clone();
                sp.get_mut(key).unwrap().data_mut()[e] += eps;
                let mut sm = state.clone();
                sm.get_mut(key).unwrap().data_mut()[e] -= eps;
                let fd = (loss_of(&sp) - loss_of(&sm)) / (2.0 * eps);
                let an = grads.get(key).map(|t| t.data()[e]).unwrap_or(0.0);
                // f32 forward noise bounds the agreement; the signal is
                // catching sign/scale/structure bugs, not ulp accuracy
                assert!(
                    (fd - an).abs() <= 2e-2 * fd.abs().max(an.abs()).max(0.05),
                    "{key}[{e}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn bit_grad_mapping_applies_ste_coefficients() {
        // dL/dwp_b = s·2^b/denom · dL/dW for active planes, 0 for masked
        let (model, man, _) = tiny_setup();
        let mut state = ModelState::init_fp(&man, 1);
        state.to_bit_representation(&man, 4).unwrap();
        let q = model.layer("conv1").unwrap();
        let (_, gmaps) = prepare_weights(&model, &state, WMode::Bit, None, false).unwrap();
        let elems = q.params();
        let mut grads = BTreeMap::new();
        grads.insert("weff:conv1".to_string(), Tensor::full(&q.shape, 1.0));
        map_weight_grads(&model, gmaps, &mut grads).unwrap();
        let scale = state.get("scale:conv1").unwrap().item().unwrap();
        let denom = 15.0f32; // 2^4 − 1
        let dwp = grads.get("wp:conv1").unwrap();
        for b in 0..NB {
            let want = if b < 4 { scale * (1 << b) as f32 / denom } else { 0.0 };
            for e in 0..elems {
                assert!((dwp.data()[b * elems + e] - want).abs() < 1e-6);
            }
        }
        // scale grad = Σ dW·V/denom = Σ round(v)/denom over all elems
        assert!(grads.contains_key("scale:conv1"));
    }

    #[test]
    fn bgl_matches_reference_formula() {
        let (model, man, _) = tiny_setup();
        let mut state = ModelState::init_fp(&man, 2);
        state.to_bit_representation(&man, 3).unwrap();
        let regw = vec![1.0f32; model.qlayers.len()];
        let (bgl, grads) = bgl_and_grads(&model, &state, &regw, 1.0).unwrap();
        // reference: Σ_l Σ_b mask·sqrt(Σ wp²+wn² + eps)
        let mut want = 0.0f64;
        for q in &model.qlayers {
            let wp = state.get(&format!("wp:{}", q.name)).unwrap();
            let wn = state.get(&format!("wn:{}", q.name)).unwrap();
            let elems = wp.len() / NB;
            for b in 0..3 {
                let ssq: f64 = wp
                    .row(b, elems)
                    .iter()
                    .chain(wn.row(b, elems))
                    .map(|&v| (v as f64) * (v as f64))
                    .sum();
                want += (ssq + BGL_EPS).sqrt();
            }
        }
        assert!((bgl as f64 - want).abs() < 1e-3 * want.max(1.0), "{bgl} vs {want}");
        // gradient of an active binary plane entry is wp/norm ∈ {0, 1/norm}
        assert!(grads.get("wp:conv1").unwrap().data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sgd_clamps_planes_and_skips_decay_on_scales() {
        let (model, man, _) = tiny_setup();
        let _ = &model;
        let mut state = ModelState::init_fp(&man, 3);
        state.to_bit_representation(&man, 8).unwrap();
        let spec = man.artifact("bsq_train_relu6").unwrap().clone();
        state.ensure_momenta(&crate::model::momentum_slots(&spec.inputs));
        let scale_before = state.get("scale:conv1").unwrap().item().unwrap();
        let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();
        // huge negative plane grad → update would exceed 2.0 without clamp
        let wp_shape = state.get("wp:conv1").unwrap().shape().to_vec();
        grads.insert("wp:conv1".into(), Tensor::full(&wp_shape, -100.0));
        sgd_update(&mut state, &spec, &mut grads, 1.0, 0.5).unwrap();
        let wp = state.get("wp:conv1").unwrap();
        assert!(wp.data().iter().all(|&v| (0.0..=2.0).contains(&v)));
        assert_eq!(wp.data().iter().cloned().fold(0.0f32, f32::max), 2.0);
        // no grad + zero decay ⇒ scale unchanged
        let scale_after = state.get("scale:conv1").unwrap().item().unwrap();
        assert_eq!(scale_before, scale_after);
        // decayed float bias shrank (wd = 0.5, zero grad, zero momentum)
        // (biases start at 0 so check gamma instead: 1 → 1 − lr·wd·1 = 0.5)
        assert!((state.get("bn:conv1/gamma").unwrap().data()[0] - 0.5).abs() < 1e-6);
    }
}
