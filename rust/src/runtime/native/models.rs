//! Native model zoo — the Rust twin of `python/compile/models.py`.
//!
//! Same four architectures, same layer names/shapes/order, same BN groups
//! and activation-site numbering (sites are numbered in builder call
//! order, which matches definition order in every model). The metadata
//! feeds the synthesized manifests; the [`graph`] constructors record each
//! architecture as a layer-graph IR (`ir::graph`) that the planner
//! compiles and every executor — train tape, engine eval, serving — runs.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};
use once_cell::sync::Lazy;

use crate::ir::graph::{Graph, GraphBuilder};

#[derive(Debug, Clone)]
pub struct NativeLayer {
    pub name: String,
    /// HWIO for convs, `[in, out]` for dense.
    pub shape: Vec<usize>,
    pub kind: &'static str,
}

impl NativeLayer {
    fn conv(name: impl Into<String>, kh: usize, kw: usize, cin: usize, cout: usize) -> NativeLayer {
        NativeLayer { name: name.into(), shape: vec![kh, kw, cin, cout], kind: "conv" }
    }

    fn dense(name: impl Into<String>, cin: usize, cout: usize) -> NativeLayer {
        NativeLayer { name: name.into(), shape: vec![cin, cout], kind: "dense" }
    }

    pub fn params(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    pub batch: usize,
    pub input_hw: (usize, usize),
    pub in_ch: usize,
    pub num_classes: usize,
    pub qlayers: Vec<NativeLayer>,
    pub bn_names: Vec<String>,
    pub act_sites: Vec<String>,
    pub dense_bias: Vec<String>,
    /// Artifact entry points this model exposes (python `model.py` registry).
    pub entries: Vec<&'static str>,
}

impl NativeModel {
    pub fn layer(&self, name: &str) -> Result<&NativeLayer> {
        self.qlayers
            .iter()
            .find(|q| q.name == name)
            .ok_or_else(|| anyhow!("model {} has no layer {name:?}", self.name))
    }
}

const RELU6_SET: [&str; 6] = [
    "fp_train_relu6",
    "fp_eval_relu6",
    "bsq_train_relu6",
    "q_eval_relu6",
    "dorefa_train_relu6",
    "dorefa_eval_relu6",
];
const PACT_SET: [&str; 4] =
    ["bsq_train_pact", "q_eval_pact", "dorefa_train_pact", "dorefa_eval_pact"];
const LSQ_SET: [&str; 2] = ["lsq_train_relu6", "lsq_eval_relu6"];

fn tinynet() -> NativeModel {
    let qlayers = vec![
        NativeLayer::conv("conv1", 3, 3, 3, 8),
        NativeLayer::conv("conv2", 3, 3, 8, 16),
        NativeLayer::conv("conv3", 3, 3, 16, 16),
        NativeLayer::dense("fc", 16, 10),
    ];
    let convs: Vec<String> = vec!["conv1".into(), "conv2".into(), "conv3".into()];
    NativeModel {
        name: "tinynet".into(),
        batch: 16,
        input_hw: (16, 16),
        in_ch: 3,
        num_classes: 10,
        qlayers,
        bn_names: convs.clone(),
        act_sites: convs,
        dense_bias: vec!["fc".into()],
        entries: RELU6_SET.iter().copied().chain(["hvp"]).collect(),
    }
}

fn resnet20() -> NativeModel {
    let width = 16usize;
    let widths = [width, 2 * width, 4 * width];
    let mut qlayers = vec![NativeLayer::conv("conv1", 3, 3, 3, width)];
    let mut bns = vec!["conv1".to_string()];
    let mut cin = width;
    for (s, &w) in widths.iter().enumerate() {
        for b in 0..3 {
            for c in 1..=2 {
                let nm = format!("s{s}b{b}c{c}");
                qlayers.push(NativeLayer::conv(nm.clone(), 3, 3, if c == 1 { cin } else { w }, w));
                bns.push(nm);
            }
            cin = w;
        }
    }
    qlayers.push(NativeLayer::dense("fc", widths[2], 10));
    NativeModel {
        name: "resnet20".into(),
        batch: 32,
        input_hw: (32, 32),
        in_ch: 3,
        num_classes: 10,
        qlayers,
        act_sites: bns.clone(),
        bn_names: bns,
        dense_bias: vec!["fc".into()],
        entries: RELU6_SET
            .iter()
            .copied()
            .chain(PACT_SET.iter().copied())
            .chain(LSQ_SET.iter().copied())
            .chain(["hvp"])
            .collect(),
    }
}

fn resnet50_sim() -> NativeModel {
    let (width, expansion, blocks) = (16usize, 4usize, [2usize, 2, 2]);
    let widths: Vec<usize> = (0..blocks.len()).map(|i| width << i).collect();
    let mut qlayers = vec![NativeLayer::conv("conv1", 3, 3, 3, width)];
    let mut bns = vec!["conv1".to_string()];
    let mut acts = vec!["conv1".to_string()];
    let mut cin = width;
    for (s, (&nb, &w)) in blocks.iter().zip(&widths).enumerate() {
        for b in 0..nb {
            let pre = format!("s{s}b{b}");
            let cout = w * expansion;
            qlayers.push(NativeLayer::conv(format!("{pre}c1"), 1, 1, cin, w));
            qlayers.push(NativeLayer::conv(format!("{pre}c2"), 3, 3, w, w));
            qlayers.push(NativeLayer::conv(format!("{pre}c3"), 1, 1, w, cout));
            for c in ["c1", "c2", "c3"] {
                bns.push(format!("{pre}{c}"));
                acts.push(format!("{pre}{c}"));
            }
            if b == 0 {
                qlayers.push(NativeLayer::conv(format!("{pre}proj"), 1, 1, cin, cout));
                bns.push(format!("{pre}proj"));
            }
            cin = cout;
        }
    }
    qlayers.push(NativeLayer::dense("fc", widths[2] * expansion, 100));
    NativeModel {
        name: "resnet50_sim".into(),
        batch: 32,
        input_hw: (32, 32),
        in_ch: 3,
        num_classes: 100,
        qlayers,
        bn_names: bns,
        act_sites: acts,
        dense_bias: vec!["fc".into()],
        entries: RELU6_SET.to_vec(),
    }
}

fn inception_sim() -> NativeModel {
    fn cba(
        q: &mut Vec<NativeLayer>,
        s: &mut Vec<String>,
        name: String,
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
    ) {
        q.push(NativeLayer::conv(name.clone(), kh, kw, cin, cout));
        s.push(name);
    }
    let mut qlayers: Vec<NativeLayer> = Vec::new();
    let mut sites: Vec<String> = Vec::new();
    cba(&mut qlayers, &mut sites, "stem1".into(), 3, 3, 3, 16);
    cba(&mut qlayers, &mut sites, "stem2".into(), 3, 3, 16, 16);
    cba(&mut qlayers, &mut sites, "stem3".into(), 3, 3, 16, 32);
    let mut cin = 32usize;
    for m in 0..3 {
        let (b1, b3r, b3, d3r, d3, pp) = (16, 12, 16, 12, 16, 8);
        let pre = format!("mix{m}");
        cba(&mut qlayers, &mut sites, format!("{pre}_b1"), 1, 1, cin, b1);
        cba(&mut qlayers, &mut sites, format!("{pre}_b3r"), 1, 1, cin, b3r);
        cba(&mut qlayers, &mut sites, format!("{pre}_b3"), 3, 3, b3r, b3);
        cba(&mut qlayers, &mut sites, format!("{pre}_d3r"), 1, 1, cin, d3r);
        cba(&mut qlayers, &mut sites, format!("{pre}_d3a"), 3, 3, d3r, d3);
        cba(&mut qlayers, &mut sites, format!("{pre}_d3b"), 3, 3, d3, d3);
        cba(&mut qlayers, &mut sites, format!("{pre}_pp"), 1, 1, cin, pp);
        cin = b1 + b3 + d3 + pp;
    }
    qlayers.push(NativeLayer::dense("fc", cin, 100));
    NativeModel {
        name: "inception_sim".into(),
        batch: 32,
        input_hw: (32, 32),
        in_ch: 3,
        num_classes: 100,
        qlayers,
        bn_names: sites.clone(),
        act_sites: sites,
        dense_bias: vec!["fc".into()],
        entries: RELU6_SET.to_vec(),
    }
}

static REGISTRY: Lazy<BTreeMap<&'static str, Arc<NativeModel>>> = Lazy::new(|| {
    let mut m = BTreeMap::new();
    m.insert("tinynet", Arc::new(tinynet()));
    m.insert("resnet20", Arc::new(resnet20()));
    m.insert("resnet50_sim", Arc::new(resnet50_sim()));
    m.insert("inception_sim", Arc::new(inception_sim()));
    m
});

pub fn get(name: &str) -> Result<Arc<NativeModel>> {
    REGISTRY
        .get(name)
        .cloned()
        .ok_or_else(|| anyhow!("native backend has no model {name:?} (have {:?})", model_names()))
}

pub fn model_names() -> Vec<&'static str> {
    REGISTRY.keys().copied().collect()
}

// -- forward graphs ----------------------------------------------------------

/// Build the model's forward as a layer graph — the declarative twin of
/// the old per-pass `Fwd` walk, recorded once and compiled by `ir::plan`.
pub fn graph(model: &NativeModel) -> Result<Graph> {
    let mut g = GraphBuilder::new(model);
    let x0 = g.input();
    let out = match model.name.as_str() {
        "tinynet" => {
            let x = g.conv_bn_act(x0, "conv1", 1)?;
            let x = g.conv_bn_act(x, "conv2", 2)?;
            let x = g.conv_bn_act(x, "conv3", 1)?;
            let p = g.global_avg_pool(x)?;
            g.dense(p, "fc")?
        }
        "resnet20" => {
            let widths = [16usize, 32, 64];
            let mut x = g.conv_bn_act(x0, "conv1", 1)?;
            for (s, &w) in widths.iter().enumerate() {
                for b in 0..3 {
                    let stride = if s > 0 && b == 0 { 2 } else { 1 };
                    let sc = g.pad_shortcut(x, w, stride)?;
                    let y = g.conv_bn_act(x, &format!("s{s}b{b}c1"), stride)?;
                    let y = g.conv(y, &format!("s{s}b{b}c2"), 1)?;
                    let y = g.bn(y, &format!("s{s}b{b}c2"))?;
                    let y = g.add(y, sc)?;
                    x = g.act(y)?;
                }
            }
            let p = g.global_avg_pool(x)?;
            g.dense(p, "fc")?
        }
        "resnet50_sim" => {
            let blocks = [2usize, 2, 2];
            let mut x = g.conv_bn_act(x0, "conv1", 1)?;
            for (s, &nb) in blocks.iter().enumerate() {
                for b in 0..nb {
                    let pre = format!("s{s}b{b}");
                    let stride = if s > 0 && b == 0 { 2 } else { 1 };
                    let sc = if b == 0 {
                        let p = g.conv(x, &format!("{pre}proj"), stride)?;
                        g.bn(p, &format!("{pre}proj"))?
                    } else {
                        x
                    };
                    let y = g.conv_bn_act(x, &format!("{pre}c1"), 1)?;
                    let y = g.conv_bn_act(y, &format!("{pre}c2"), stride)?;
                    let y = g.conv(y, &format!("{pre}c3"), 1)?;
                    let y = g.bn(y, &format!("{pre}c3"))?;
                    let y = g.add(y, sc)?;
                    x = g.act(y)?;
                }
            }
            let p = g.global_avg_pool(x)?;
            g.dense(p, "fc")?
        }
        "inception_sim" => {
            let mut x = g.conv_bn_act(x0, "stem1", 1)?;
            x = g.conv_bn_act(x, "stem2", 2)?;
            x = g.conv_bn_act(x, "stem3", 1)?;
            for m in 0..3 {
                if m == 1 {
                    x = g.subsample(x, 2)?; // stride-2 transition between blocks
                }
                let pre = format!("mix{m}");
                let y1 = g.conv_bn_act(x, &format!("{pre}_b1"), 1)?;
                let y3 = g.conv_bn_act(x, &format!("{pre}_b3r"), 1)?;
                let y3 = g.conv_bn_act(y3, &format!("{pre}_b3"), 1)?;
                let yd = g.conv_bn_act(x, &format!("{pre}_d3r"), 1)?;
                let yd = g.conv_bn_act(yd, &format!("{pre}_d3a"), 1)?;
                let yd = g.conv_bn_act(yd, &format!("{pre}_d3b"), 1)?;
                let yp = g.avg_pool3x3_edge(x)?;
                let yp = g.conv_bn_act(yp, &format!("{pre}_pp"), 1)?;
                x = g.concat(&[y1, y3, yd, yp])?;
            }
            let p = g.global_avg_pool(x)?;
            g.dense(p, "fc")?
        }
        other => return Err(anyhow!("no native forward for model {other:?}")),
    };
    g.finish(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_mirrors_python_zoo() {
        let t = get("tinynet").unwrap();
        assert_eq!(t.qlayers.len(), 4);
        assert_eq!(t.batch, 16);
        assert_eq!(t.qlayers.iter().map(|q| q.params()).sum::<usize>(), 216 + 1152 + 2304 + 160);

        let r = get("resnet20").unwrap();
        assert_eq!(r.qlayers.len(), 20);
        assert_eq!(r.bn_names.len(), 19);
        assert_eq!(r.act_sites.len(), 19);
        assert!(r.entries.contains(&"bsq_train_pact"));
        assert!(r.entries.contains(&"hvp"));

        let r50 = get("resnet50_sim").unwrap();
        // 1 stem + 6 blocks × 3 convs + 3 projections + 1 fc = 23
        assert_eq!(r50.qlayers.len(), 23);
        assert_eq!(r50.num_classes, 100);
        // projections carry BN but no activation site
        assert_eq!(r50.bn_names.len(), r50.act_sites.len() + 3);

        let inc = get("inception_sim").unwrap();
        assert_eq!(inc.qlayers.len(), 3 + 3 * 7 + 1);
        assert_eq!(inc.layer("fc").unwrap().shape, vec![56, 100]);
        assert!(get("nope").is_err());
    }

    #[test]
    fn resnet20_layer_shapes_match_paper_model() {
        let r = get("resnet20").unwrap();
        assert_eq!(r.layer("conv1").unwrap().shape, vec![3, 3, 3, 16]);
        assert_eq!(r.layer("s1b0c1").unwrap().shape, vec![3, 3, 16, 32]);
        assert_eq!(r.layer("s1b0c2").unwrap().shape, vec![3, 3, 32, 32]);
        assert_eq!(r.layer("s2b2c2").unwrap().shape, vec![3, 3, 64, 64]);
        assert_eq!(r.layer("fc").unwrap().shape, vec![64, 10]);
    }
}
