//! `runtime::native` — the host compute backend behind [`Engine`].
//!
//! When the PJRT client is the offline stub (no XLA library in the build
//! environment), the engine falls back to this backend: a pure-Rust
//! implementation of every manifest entry point (train / eval / hvp) on the
//! `tensor::gemm` kernels — cache-blocked parallel f32 GEMM + im2col for
//! training, and the bit-plane GEMM for quantized inference, whose cost is
//! proportional to the set weight bits and therefore *drops* as the BSQ
//! regularizer empties planes and §3.3 trims them.
//!
//! Because there are no AOT artifacts on disk in this mode, the manifest is
//! synthesized from the native model zoo ([`models`]) with exactly the
//! statespec contract `python/compile/statespec.py` defines — the
//! coordinator, baselines and experiment drivers run unchanged.
//!
//! [`Engine`]: crate::runtime::Engine

pub mod models;
pub mod shard;
pub mod step;
pub mod tape;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::data::Batch;
use crate::model::state::ModelState;
use crate::quant::bitplane::NB;
use crate::runtime::engine::{RunInputs, RunOutputs};
use crate::runtime::manifest::{ArtifactSpec, IoItem, Manifest, QLayerMeta, Role};

use self::models::NativeModel;
use self::step::{AMode, Entry, WMode};

/// Marker root for synthesized artifact paths (they exist only as cache
/// keys; nothing is read from disk).
const NATIVE_ROOT: &str = "native";

/// The native backend: models are a static registry and every executable is
/// derived from its artifact spec. The one piece of configuration is the
/// data-parallel shard count of the training step (`0` = auto: available
/// parallelism) — results are bit-identical at any value, so the knob only
/// trades threads for wall clock (DESIGN.md §10).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend {
    shards: usize,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { shards: 0 }
    }

    pub fn with_shards(shards: usize) -> NativeBackend {
        NativeBackend { shards }
    }

    /// Requested shard count (0 = auto).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Synthesize the manifest for `model` (the disk-artifact counterpart
    /// is `Manifest::load`).
    pub fn manifest(&self, model: &str) -> Result<Manifest> {
        manifest_for(model)
    }
}

/// A compiled native executable: the model, its compiled layer-graph
/// plans (shared `Arc`s from the global `ir::plan` cache), and a validated
/// entry point, carrying the backend's shard configuration.
pub struct NativeExec {
    model: Arc<NativeModel>,
    plans: crate::ir::plan::ModelPlans,
    shards: usize,
}

impl NativeExec {
    /// Resolve the model + entry from a synthesized spec (`native/<m>/<e>`)
    /// and compile its plans — graph build, fusion, and the arena layout
    /// all fail here, at load time, not at step time.
    pub fn for_spec(spec: &ArtifactSpec, shards: usize) -> Result<NativeExec> {
        let model_name = spec
            .file
            .parent()
            .and_then(Path::file_name)
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow!("not a native artifact path: {}", spec.file.display()))?;
        let model = models::get(model_name)?;
        Entry::parse(&spec.name)?; // fail at load time, not step time
        let plans = crate::ir::plan::model_plans(&model)?;
        Ok(NativeExec { model, plans, shards })
    }

    pub fn run(
        &self,
        spec: &ArtifactSpec,
        state: &mut ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<RunOutputs> {
        step::execute(&self.model, &self.plans, spec, state, batch, inputs, self.shards)
    }
}

// -- manifest synthesis (the statespec.py contract) --------------------------

/// Build the full manifest for a native model: metadata plus one
/// [`ArtifactSpec`] per registered entry point.
pub fn manifest_for(name: &str) -> Result<Manifest> {
    let m = models::get(name)?;
    let dir = PathBuf::from(NATIVE_ROOT).join(&m.name);
    let mut artifacts = std::collections::BTreeMap::new();
    for entry in &m.entries {
        artifacts.insert(entry.to_string(), artifact_spec(&m, entry, &dir)?);
    }
    Ok(Manifest {
        model: m.name.clone(),
        batch: m.batch,
        nb: NB,
        input_hw: m.input_hw,
        in_ch: m.in_ch,
        num_classes: m.num_classes,
        qlayers: m
            .qlayers
            .iter()
            .map(|q| QLayerMeta {
                name: q.name.clone(),
                shape: q.shape.clone(),
                kind: q.kind.to_string(),
                params: q.params(),
            })
            .collect(),
        bn_names: m.bn_names.clone(),
        act_sites: m.act_sites.clone(),
        dense_bias: m.dense_bias.clone(),
        artifacts,
        dir,
    })
}

fn item(name: impl Into<String>, shape: Vec<usize>, dtype: &str, role: Role) -> IoItem {
    IoItem { name: name.into(), shape, dtype: dtype.to_string(), role }
}

fn batch_items(m: &NativeModel) -> Vec<IoItem> {
    let (h, w) = m.input_hw;
    vec![
        item("x", vec![m.batch, h, w, m.in_ch], "f32", Role::X),
        item("y", vec![m.batch], "i32", Role::Y),
    ]
}

fn bias_items(m: &NativeModel) -> Vec<IoItem> {
    m.dense_bias
        .iter()
        .map(|d| {
            let out = m
                .qlayers
                .iter()
                .find(|q| &q.name == d)
                .map(|q| *q.shape.last().unwrap())
                .unwrap_or(m.num_classes);
            item(format!("w:{d}/b"), vec![out], "f32", Role::State)
        })
        .collect()
}

fn fp_weight_items(m: &NativeModel) -> Vec<IoItem> {
    let mut items: Vec<IoItem> = m
        .qlayers
        .iter()
        .map(|q| item(format!("w:{}", q.name), q.shape.clone(), "f32", Role::State))
        .collect();
    items.extend(bias_items(m));
    items
}

fn bit_weight_items(m: &NativeModel) -> Vec<IoItem> {
    let mut items = Vec::new();
    for q in &m.qlayers {
        let mut pshape = vec![NB];
        pshape.extend_from_slice(&q.shape);
        items.push(item(format!("wp:{}", q.name), pshape.clone(), "f32", Role::State));
        items.push(item(format!("wn:{}", q.name), pshape, "f32", Role::State));
        items.push(item(format!("mask:{}", q.name), vec![NB], "f32", Role::State));
        items.push(item(format!("scale:{}", q.name), vec![], "f32", Role::State));
    }
    items.extend(bias_items(m));
    items
}

fn bn_items(m: &NativeModel) -> Vec<IoItem> {
    let mut items = Vec::new();
    for n in &m.bn_names {
        let c = m
            .qlayers
            .iter()
            .find(|q| &q.name == n)
            .map(|q| *q.shape.last().unwrap())
            .expect("bn without conv");
        for p in ["gamma", "beta", "mean", "var"] {
            items.push(item(format!("bn:{n}/{p}"), vec![c], "f32", Role::State));
        }
    }
    items
}

fn pact_items(m: &NativeModel) -> Vec<IoItem> {
    m.act_sites.iter().map(|s| item(format!("pact:{s}"), vec![], "f32", Role::State)).collect()
}

fn lsq_items(m: &NativeModel) -> Vec<IoItem> {
    m.qlayers.iter().map(|q| item(format!("step:{}", q.name), vec![], "f32", Role::State)).collect()
}

fn momentum_items(trainables: &[IoItem]) -> Vec<IoItem> {
    trainables
        .iter()
        .map(|t| item(format!("m:{}", t.name), t.shape.clone(), "f32", Role::State))
        .collect()
}

fn vec_items(m: &NativeModel, which: &[&str]) -> Vec<IoItem> {
    let mut out = Vec::new();
    if which.contains(&"regw") {
        out.push(item("regw", vec![m.qlayers.len()], "f32", Role::Vec));
    }
    if which.contains(&"wlv") {
        out.push(item("wlv", vec![m.qlayers.len()], "f32", Role::Vec));
    }
    if which.contains(&"actlv") {
        out.push(item("actlv", vec![m.act_sites.len()], "f32", Role::Vec));
    }
    out
}

fn hyper_items(names: &[&str]) -> Vec<IoItem> {
    names.iter().map(|n| item(*n, vec![], "f32", Role::Hyper)).collect()
}

fn metric_items(names: &[&str]) -> Vec<IoItem> {
    names.iter().map(|n| item(*n, vec![], "f32", Role::Metric)).collect()
}

fn is_trainable(i: &IoItem) -> bool {
    !i.name.starts_with("mask:") && !i.name.contains("/mean") && !i.name.contains("/var")
}

fn artifact_spec(m: &NativeModel, entry: &str, dir: &Path) -> Result<ArtifactSpec> {
    let file = dir.join(entry);
    let (inputs, outputs) = match Entry::parse(entry)? {
        Entry::Train(wm, am) => {
            let weight_in = match wm {
                WMode::Fp | WMode::Dorefa => fp_weight_items(m),
                WMode::Bit => bit_weight_items(m),
                WMode::Lsq => {
                    let mut w = fp_weight_items(m);
                    w.extend(lsq_items(m));
                    w
                }
            };
            let vecs = match wm {
                WMode::Fp => vec_items(m, &["actlv"]),
                WMode::Bit => vec_items(m, &["regw", "actlv"]),
                WMode::Dorefa | WMode::Lsq => vec_items(m, &["wlv", "actlv"]),
            };
            let hypers = if wm == WMode::Bit {
                hyper_items(&["lr", "wd", "alpha"])
            } else {
                hyper_items(&["lr", "wd"])
            };
            let bn_in = bn_items(m);
            let pact_in = if am == AMode::Pact { pact_items(m) } else { Vec::new() };
            let trainables: Vec<IoItem> = weight_in
                .iter()
                .chain(&bn_in)
                .chain(&pact_in)
                .filter(|i| is_trainable(i))
                .cloned()
                .collect();
            let momenta = momentum_items(&trainables);
            let mut inputs = batch_items(m);
            inputs.extend(weight_in);
            inputs.extend(bn_in.clone());
            inputs.extend(pact_in);
            inputs.extend(momenta.clone());
            inputs.extend(vecs);
            inputs.extend(hypers);
            let bn_stats: Vec<IoItem> = bn_in
                .into_iter()
                .filter(|i| i.name.contains("/mean") || i.name.contains("/var"))
                .collect();
            let metrics: &[&str] = if wm == WMode::Bit {
                &["loss", "ce", "acc", "bgl"]
            } else {
                &["loss", "ce", "acc"]
            };
            let mut outputs = trainables;
            outputs.extend(momenta);
            outputs.extend(bn_stats);
            outputs.extend(metric_items(metrics));
            (inputs, outputs)
        }
        Entry::Eval(wm, am) => {
            let weight_in = match wm {
                WMode::Fp | WMode::Dorefa => fp_weight_items(m),
                WMode::Bit => bit_weight_items(m),
                WMode::Lsq => {
                    let mut w = fp_weight_items(m);
                    w.extend(lsq_items(m));
                    w
                }
            };
            let vecs = match wm {
                WMode::Fp | WMode::Bit => vec_items(m, &["actlv"]),
                WMode::Dorefa | WMode::Lsq => vec_items(m, &["wlv", "actlv"]),
            };
            let pact_in = if am == AMode::Pact { pact_items(m) } else { Vec::new() };
            let mut inputs = batch_items(m);
            inputs.extend(weight_in);
            inputs.extend(bn_items(m));
            inputs.extend(pact_in);
            inputs.extend(vecs);
            (inputs, metric_items(&["loss", "acc"]))
        }
        Entry::Hvp => {
            let mut inputs = batch_items(m);
            inputs.extend(fp_weight_items(m));
            inputs.extend(bn_items(m));
            inputs.extend(
                m.qlayers
                    .iter()
                    .map(|q| item(format!("v:{}", q.name), q.shape.clone(), "f32", Role::Probe)),
            );
            let mut outputs: Vec<IoItem> = m
                .qlayers
                .iter()
                .map(|q| item(format!("hv:{}", q.name), q.shape.clone(), "f32", Role::ProbeOut))
                .collect();
            outputs.extend(metric_items(&["loss"]));
            (inputs, outputs)
        }
    };
    Ok(ArtifactSpec { name: entry.to_string(), file, inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{momentum_slots, ModelState};

    #[test]
    fn manifests_synthesize_for_every_model() {
        for name in models::model_names() {
            let man = manifest_for(name).unwrap();
            assert_eq!(man.model, name);
            assert_eq!(man.nb, NB);
            assert!(!man.artifacts.is_empty(), "{name} has no artifacts");
            for q in &man.qlayers {
                assert_eq!(q.shape.iter().product::<usize>(), q.params);
            }
        }
    }

    #[test]
    fn fp_state_checks_against_synthesized_spec() {
        let man = manifest_for("tinynet").unwrap();
        let spec = man.artifact("fp_train_relu6").unwrap();
        let mut state = ModelState::init_fp(&man, 0);
        state.ensure_momenta(&momentum_slots(&spec.inputs));
        state.check_against(&spec.inputs).unwrap();
        // eval spec needs no momenta
        let espec = man.artifact("fp_eval_relu6").unwrap();
        assert!(momentum_slots(&espec.inputs).is_empty());
        ModelState::init_fp(&man, 0).check_against(&espec.inputs).unwrap();
    }

    #[test]
    fn bit_state_checks_against_bsq_spec() {
        let man = manifest_for("tinynet").unwrap();
        let spec = man.artifact("bsq_train_relu6").unwrap();
        let mut state = ModelState::init_fp(&man, 1);
        state.to_bit_representation(&man, 8).unwrap();
        state.ensure_momenta(&momentum_slots(&spec.inputs));
        state.check_against(&spec.inputs).unwrap();
        // masks are configuration, not trainables: no momentum slot
        assert!(spec.inputs.iter().all(|i| !i.name.starts_with("m:mask:")));
        // planes and scales are trainable
        assert!(spec.inputs.iter().any(|i| i.name == "m:wp:conv1"));
        assert!(spec.inputs.iter().any(|i| i.name == "m:scale:conv1"));
        // bgl metric present on the bit path
        assert!(spec.outputs.iter().any(|o| o.name == "bgl" && o.role == Role::Metric));
    }

    #[test]
    fn pact_and_lsq_specs_carry_their_parameters() {
        let man = manifest_for("resnet20").unwrap();
        let pact = man.artifact("bsq_train_pact").unwrap();
        assert!(pact.inputs.iter().any(|i| i.name.starts_with("pact:")));
        assert!(pact.inputs.iter().any(|i| i.name.starts_with("m:pact:")));
        let lsq = man.artifact("lsq_train_relu6").unwrap();
        assert!(lsq.inputs.iter().any(|i| i.name.starts_with("step:")));
        assert!(lsq.inputs.iter().any(|i| i.name == "wlv"));
    }

    #[test]
    fn hvp_spec_has_probes_and_probe_outs() {
        let man = manifest_for("tinynet").unwrap();
        let hvp = man.artifact("hvp").unwrap();
        assert_eq!(hvp.inputs.iter().filter(|i| i.role == Role::Probe).count(), 4);
        assert_eq!(hvp.outputs.iter().filter(|o| o.role == Role::ProbeOut).count(), 4);
        // no actlv: the ref path ignores it (python aot.py parity)
        assert!(hvp.inputs.iter().all(|i| i.name != "actlv"));
    }

    #[test]
    fn exec_resolves_model_from_spec_path() {
        let man = manifest_for("tinynet").unwrap();
        let spec = man.artifact("q_eval_relu6").unwrap();
        let exe = NativeExec::for_spec(spec, 0).unwrap();
        assert_eq!(exe.model.name, "tinynet");
        let bogus = ArtifactSpec {
            name: "q_eval_relu6".into(),
            file: PathBuf::from("native/nope/q_eval_relu6"),
            inputs: vec![],
            outputs: vec![],
        };
        assert!(NativeExec::for_spec(&bogus, 0).is_err());
    }
}
