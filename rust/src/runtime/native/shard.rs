//! Data-parallel sharded training for the native backend (DESIGN.md §10).
//!
//! [`train_step`] splits each minibatch across `N` contiguous sample shards
//! and runs the reverse-mode tape forward/backward per shard on scoped
//! worker threads (the same `std::thread::scope` idiom as `serve::worker`
//! and `tensor::gemm`). Every reduction that couples samples across the
//! batch is computed at **per-sample granularity** and combined through a
//! **deterministic fixed-order tree fold** ([`tree_fold`]) whose shape
//! depends only on the global batch size — never on the shard count or on
//! thread scheduling. Concretely:
//!
//! * per-row forward/backward kernels are already partition-invariant (the
//!   blocked GEMM accumulates each output element in a fixed K order);
//! * BN batch statistics and the BN-backward Σdy / Σdy·x̂ sums are
//!   exchanged as per-sample f64 partials at lockstep barrier points;
//! * leaf gradients (dW, db, dγ/dβ, dPACT) are deposited per sample and
//!   tree-reduced on the coordinating thread before the unchanged
//!   single-threaded STE mapping + B_GL regularizer + SGD tail.
//!
//! The single-shard path runs the *same* canonical reductions, so training
//! is bit-identical at any shard count — the same bit-identity discipline
//! `tests/packed_diff.rs` established for quantization, now guaranteed for
//! the gradient step (asserted by `tests/shard_train.rs`).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard};

use anyhow::{anyhow, bail, Result};

use crate::data::Batch;
use crate::faults;
use crate::ir::exec;
use crate::ir::plan::CompiledPlan;
use crate::model::state::ModelState;
use crate::runtime::engine::{RunInputs, RunOutputs};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::native::models::NativeModel;
use crate::runtime::native::step::{self, AMode, WMode};
use crate::runtime::native::tape::{backward_sharded, DepositSlot, ShardHook, WeightRep};
use crate::tensor::{gemm, IntTensor, Tensor};

/// Sentinel message for workers unwound by a peer's failure; filtered when
/// picking the error to report.
const ABORTED: &str = "shard aborted by a peer worker";

/// Resolve a requested shard count: 0 means "auto" (available parallelism).
pub fn resolve_shards(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Contiguous, non-empty sample ranges covering `samples`. The effective
/// shard count is `min(shards, samples)` — a batch smaller than the shard
/// count must never spawn empty-range workers (they would deadlock the
/// lockstep barriers and waste threads).
pub fn shard_ranges(samples: usize, shards: usize) -> Vec<Range<usize>> {
    let e = shards.max(1).min(samples.max(1));
    let base = samples / e;
    let rem = samples % e;
    let mut ranges = Vec::with_capacity(e);
    let mut start = 0usize;
    for i in 0..e {
        let len = base + usize::from(i < rem);
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// Deterministic fixed-order pairwise tree fold: level by level, adjacent
/// items are combined `(0,1), (2,3), …` with an odd tail carried unchanged.
/// The reduction shape depends only on `items.len()`, so any partition of
/// the items across producers yields the same bits — unlike atomic or
/// arrival-order accumulation. Returns `None` on empty input.
pub fn tree_fold<T>(mut items: Vec<T>, mut combine: impl FnMut(&mut T, &T)) -> Option<T> {
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                combine(&mut a, &b);
            }
            next.push(a);
        }
        items = next;
    }
    items.pop()
}

fn tree_add_f64(items: Vec<Vec<f64>>) -> Option<Vec<f64>> {
    tree_fold(items, |a, b| {
        for (x, y) in a.iter_mut().zip(b) {
            *x += y;
        }
    })
}

fn tree_add_tensors(items: Vec<Tensor>) -> Option<Tensor> {
    tree_fold(items, |a, b| {
        for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
            *x += y;
        }
    })
}

/// Poison-tolerant lock: a worker that panics while holding one of the
/// coordination mutexes (injected faults do exactly this) poisons it, but
/// the protected state is plain data that is never left half-updated by
/// the panicking critical sections here — so recovery is safe, and it is
/// what keeps `AbortBarrier::abort` able to release every peer instead of
/// cascading opaque `PoisonError` panics.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// -- abortable lockstep barrier ----------------------------------------------

/// A reusable barrier whose waiters can be released with an error when a
/// peer fails: a worker hitting a `Result::Err` between sync points must
/// not leave the others blocked forever (std's `Barrier` has no unhappy
/// path).
struct AbortBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    parties: usize,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    aborted: bool,
}

impl AbortBarrier {
    fn new(parties: usize) -> AbortBarrier {
        AbortBarrier {
            state: Mutex::new(BarrierState { arrived: 0, generation: 0, aborted: false }),
            cv: Condvar::new(),
            parties,
        }
    }

    fn wait(&self) -> Result<()> {
        let mut st = lock(&self.state);
        // Injection point under the barrier mutex on purpose: a panic here
        // unwinds with the guard held, poisoning the mutex — the exact
        // hazard the poison-tolerant locking must survive.
        faults::fire(faults::SHARD_BARRIER, 0);
        if st.aborted {
            bail!("{ABORTED}");
        }
        st.arrived += 1;
        if st.arrived == self.parties {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return Ok(());
        }
        let gen = st.generation;
        st = self
            .cv
            .wait_while(st, |s| s.generation == gen && !s.aborted)
            .unwrap_or_else(|e| e.into_inner());
        if st.aborted {
            bail!("{ABORTED}");
        }
        Ok(())
    }

    /// Sticky: every current and future waiter errors out.
    fn abort(&self) {
        let mut st = lock(&self.state);
        st.aborted = true;
        self.cv.notify_all();
    }
}

// -- shared reduction state ---------------------------------------------------

/// Exchange buffer for in-flight per-sample partials (BN statistics and
/// BN-backward sums): one slot per global sample, reused across sync
/// points — all workers hit the same sequence of exchanges because every
/// shard executes the same graph.
struct SyncShared {
    barrier: AbortBarrier,
    slots: Mutex<Vec<Option<Vec<f64>>>>,
    /// The current round's folded result, computed once by whichever worker
    /// reaches it first (keyed by round number so no clearing pass is
    /// needed); the rest clone the channel-sized result instead of each
    /// redundantly re-folding all N slots under the lock.
    folded: Mutex<(u64, Vec<f64>)>,
}

impl SyncShared {
    fn new(parties: usize, samples: usize) -> SyncShared {
        SyncShared {
            barrier: AbortBarrier::new(parties),
            slots: Mutex::new(vec![None; samples]),
            folded: Mutex::new((0, Vec::new())),
        }
    }
}

/// One worker's view of the shared reduction state — the [`ShardHook`] the
/// tape calls into. Leaf-gradient deposits buffer in a worker-local map
/// (no cross-thread contention — shards own disjoint sample ranges; the
/// coordinating thread merges and reduces after joins); only the BN
/// exchanges synchronize.
struct WorkerCtx<'a> {
    shared: &'a SyncShared,
    range: Range<usize>,
    total: usize,
    /// Exchange round counter; every worker runs the same sequence of
    /// exchanges, so the counters agree by construction.
    round: Cell<u64>,
    /// Per-slot `(global sample, partial)` deposits from this shard. Slots
    /// are keyed by compiled-graph node id + state key, so every shard
    /// addresses the same parameter identically regardless of partition.
    local_grads: RefCell<BTreeMap<DepositSlot, Vec<(usize, Tensor)>>>,
}

impl<'a> WorkerCtx<'a> {
    fn new(shared: &'a SyncShared, range: Range<usize>, total: usize) -> WorkerCtx<'a> {
        WorkerCtx {
            shared,
            range,
            total,
            round: Cell::new(0),
            local_grads: RefCell::new(BTreeMap::new()),
        }
    }

    fn abort(&self) {
        self.shared.barrier.abort();
    }

    fn take_deposits(&self) -> BTreeMap<DepositSlot, Vec<(usize, Tensor)>> {
        std::mem::take(&mut *self.local_grads.borrow_mut())
    }
}

impl ShardHook for WorkerCtx<'_> {
    fn global_samples(&self) -> usize {
        self.total
    }

    fn sample_base(&self) -> usize {
        self.range.start
    }

    fn exchange(&self, local: Vec<Vec<f64>>) -> Result<Vec<f64>> {
        if local.len() != self.range.len() {
            self.abort();
            bail!("exchange: {} partials for a {}-sample shard", local.len(), self.range.len());
        }
        {
            let mut slots = lock(&self.shared.slots);
            for (i, v) in local.into_iter().enumerate() {
                slots[self.range.start + i] = Some(v);
            }
        }
        self.shared.barrier.wait()?; // every shard's partials are visible
        let round = self.round.get() + 1;
        self.round.set(round);
        let folded = {
            let mut cache = lock(&self.shared.folded);
            if cache.0 != round {
                // First worker past the barrier folds for everyone. Taking
                // (not cloning) the slots also clears them, so the
                // empty-slot guard stays meaningful on every round.
                let mut slots = lock(&self.shared.slots);
                let all: Option<Vec<Vec<f64>>> = slots.iter_mut().map(Option::take).collect();
                match all.and_then(tree_add_f64) {
                    Some(v) => *cache = (round, v),
                    None => {
                        self.abort();
                        bail!("exchange: a sample slot was left empty");
                    }
                }
            }
            cache.1.clone()
        };
        self.shared.barrier.wait()?; // all read before the slots are reused
        Ok(folded)
    }

    fn deposit(&self, slot: DepositSlot, sample: usize, grad: Tensor) {
        self.local_grads.borrow_mut().entry(slot).or_default().push((sample, grad));
    }
}

/// Global biased batch statistics from per-sample partials: the sharded
/// twin of `tape::batch_stats`, two fixed-order exchanges (channel sums,
/// then mean-centered squares) so mean and variance depend only on the
/// global batch.
pub(crate) fn sharded_batch_stats(
    hook: &dyn ShardHook,
    x: &Tensor,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let c = *x.shape().last().unwrap_or(&1);
    let n_local = x.shape().first().copied().unwrap_or(1).max(1);
    let r_per = x.len() / c.max(1) / n_local;
    let rows_g = (r_per * hook.global_samples()) as f64;

    let mut sums = Vec::with_capacity(n_local);
    for si in 0..n_local {
        let mut s = vec![0.0f64; c];
        for row in x.data()[si * r_per * c..(si + 1) * r_per * c].chunks(c) {
            for (a, &v) in s.iter_mut().zip(row) {
                *a += v as f64;
            }
        }
        sums.push(s);
    }
    let mean: Vec<f64> = hook.exchange(sums)?.into_iter().map(|s| s / rows_g).collect();

    let mut sqs = Vec::with_capacity(n_local);
    for si in 0..n_local {
        let mut s = vec![0.0f64; c];
        for row in x.data()[si * r_per * c..(si + 1) * r_per * c].chunks(c) {
            for (a, (&v, m)) in s.iter_mut().zip(row.iter().zip(&mean)) {
                let d = v as f64 - m;
                *a += d * d;
            }
        }
        sqs.push(s);
    }
    let var: Vec<f64> = hook.exchange(sqs)?.into_iter().map(|s| s / rows_g).collect();

    Ok((
        mean.into_iter().map(|v| v as f32).collect(),
        var.into_iter().map(|v| v as f32).collect(),
    ))
}

// -- the sharded train step ---------------------------------------------------

struct WorkerOut {
    /// Per-sample CE terms, in shard order.
    ce_rows: Vec<f64>,
    correct: usize,
    /// BN running-stat updates (identical on every worker — computed from
    /// the exchanged global statistics).
    new_stats: Vec<(String, Vec<f32>, Vec<f32>)>,
    /// This shard's per-slot `(global sample, partial)` leaf gradients.
    deposits: BTreeMap<DepositSlot, Vec<(usize, Tensor)>>,
}

fn clone_reps(reps: &BTreeMap<String, WeightRep>) -> BTreeMap<String, WeightRep> {
    reps.iter()
        .map(|(k, v)| {
            let rep = match v {
                WeightRep::Dense(t) => WeightRep::Dense(t.clone()),
                WeightRep::Planes(p) => WeightRep::Planes(p.clone()),
            };
            (k.clone(), rep)
        })
        .collect()
}

fn slice_batch(b: &Batch, r: &Range<usize>) -> Result<Batch> {
    let s = b.x.shape();
    let pix: usize = s[1..].iter().product();
    let mut shape = s.to_vec();
    shape[0] = r.len();
    Ok(Batch {
        x: Tensor::new(shape, b.x.data()[r.start * pix..r.end * pix].to_vec())?,
        y: IntTensor::new(vec![r.len()], b.y.data()[r.start..r.end].to_vec())?,
    })
}

fn worker_body(
    plan: &CompiledPlan,
    model: &NativeModel,
    state: &ModelState,
    reps: BTreeMap<String, WeightRep>,
    actlv: Vec<f32>,
    am: AMode,
    sub: Batch,
    ctx: &WorkerCtx,
) -> Result<WorkerOut> {
    let run = exec::run_on_tape(plan, model, state, reps, &actlv, am, true, sub.x, Some(ctx))?;
    let (ce_rows, correct, dlogits) =
        step::ce_rows(run.tape.value(run.logits), sub.y.data(), ctx.global_samples())?;
    backward_sharded(&run.tape, run.logits, dlogits, ctx)?;
    Ok(WorkerOut { ce_rows, correct, new_stats: run.new_stats, deposits: ctx.take_deposits() })
}

/// One data-parallel training step: the native backend's train entry point
/// (`fp_train` / `bsq_train` / `dorefa_train` / `lsq_train`), bit-identical
/// at any `shards` (0 = auto: available parallelism). Every worker walks
/// the same compiled train plan, so gradient deposit slots agree across
/// shards by construction.
pub(crate) fn train_step(
    model: &NativeModel,
    plan: &CompiledPlan,
    spec: &ArtifactSpec,
    state: &mut ModelState,
    batch: Option<&Batch>,
    inputs: &RunInputs,
    wm: WMode,
    am: AMode,
    shards: usize,
) -> Result<RunOutputs> {
    let b = step::need_batch(batch)?;
    let lr = step::hyper(inputs, "lr")?;
    let wd = step::hyper(inputs, "wd")?;
    let actlv = step::vec_input(inputs, "actlv", model.act_sites.len())?;
    let wlv = match wm {
        WMode::Dorefa | WMode::Lsq => Some(step::vec_input(inputs, "wlv", model.qlayers.len())?),
        _ => None,
    };
    let (alpha, regw) = if wm == WMode::Bit {
        (step::hyper(inputs, "alpha")?, step::vec_input(inputs, "regw", model.qlayers.len())?)
    } else {
        (0.0, Vec::new())
    };

    let n = *b.x.shape().first().unwrap_or(&0);
    if n == 0 {
        bail!("train step on an empty batch");
    }
    let ranges = shard_ranges(n, resolve_shards(shards));
    let e = ranges.len();

    // One weight preparation for every shard (the reps are consumed by the
    // forward graph, so each worker gets its own clone).
    let (reps, gmaps) = step::prepare_weights(model, state, wm, wlv.as_deref(), false)?;

    let shared = SyncShared::new(e, n);
    // Keep the inner GEMM fan-out within the host budget: E shard workers
    // each get their slice of the cores instead of 16 threads apiece.
    // `worker_budget` derives from the once-resolved host probe, so every
    // step agrees on the split without re-reading procfs.
    let gemm_cap = gemm::worker_budget(e);

    // Slice every sub-batch before any worker exists: a failure here must
    // never strand already-running peers at a barrier.
    let subs: Vec<Batch> = ranges.iter().map(|r| slice_batch(b, r)).collect::<Result<_>>()?;

    let state_ref: &ModelState = state;
    let mut outs: Vec<Result<WorkerOut>> = Vec::with_capacity(e);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(e);
        for (wi, (r, sub)) in ranges.iter().zip(subs).enumerate() {
            let reps_w = clone_reps(&reps);
            let actlv_w = actlv.clone();
            let ctx = WorkerCtx::new(&shared, r.clone(), n);
            handles.push(s.spawn(move || {
                gemm::set_thread_parallelism_cap(gemm_cap);
                let out = catch_unwind(AssertUnwindSafe(|| {
                    // Keyed by shard index, so occurrence N of shard.worker#K
                    // is shard K's N-th train step — a deterministic clock at
                    // any thread interleaving.
                    faults::fire(faults::SHARD_WORKER, wi as u64);
                    worker_body(plan, model, state_ref, reps_w, actlv_w, am, sub, &ctx)
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("shard worker panicked: {}", faults::panic_message(p)))
                });
                if out.is_err() {
                    ctx.abort(); // release peers blocked at a barrier
                }
                out
            }));
        }
        for h in handles {
            outs.push(h.join().expect("shard worker thread vanished"));
        }
    });

    // Prefer the root-cause error over the peers' abort notifications.
    if outs.iter().any(|o| o.is_err()) {
        let mut aborted_only = None;
        for o in outs {
            if let Err(err) = o {
                if err.to_string().contains(ABORTED) {
                    aborted_only.get_or_insert(err);
                } else {
                    return Err(err);
                }
            }
        }
        return Err(aborted_only.unwrap());
    }
    let mut results: Vec<WorkerOut> = outs.into_iter().map(|o| o.unwrap()).collect();

    // Metrics: canonical tree fold over the per-sample CE terms; the
    // correct-prediction count is an exact integer sum.
    let mut ce_rows: Vec<f64> = Vec::with_capacity(n);
    let mut correct = 0usize;
    for r in &results {
        ce_rows.extend(&r.ce_rows);
        correct += r.correct;
    }
    let ce = (tree_fold(ce_rows, |a, b| *a += *b).unwrap_or(0.0) / n as f64) as f32;
    let acc = correct as f32 / n as f32;

    // Leaf gradients: merge every shard's deposits into per-slot sample
    // vectors (indexed by global sample — shards own disjoint ranges),
    // then fixed-order tree reduce. Slots carry the compiled-graph node id
    // plus the state key; the reduced total lands under the key.
    let mut samples_by_slot: BTreeMap<DepositSlot, Vec<Option<Tensor>>> = BTreeMap::new();
    for r in &mut results {
        for (slot, parts) in std::mem::take(&mut r.deposits) {
            let slots = samples_by_slot.entry(slot).or_insert_with(|| vec![None; n]);
            for (sample, t) in parts {
                slots[sample] = Some(t);
            }
        }
    }
    let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();
    for (slot, samples) in samples_by_slot {
        let parts: Vec<Tensor> = samples
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                t.ok_or_else(|| anyhow!("no gradient partial for {:?} sample {i}", slot.key))
            })
            .collect::<Result<_>>()?;
        let total = tree_add_tensors(parts)
            .ok_or_else(|| anyhow!("empty partial set for {:?}", slot.key))?;
        step::accumulate(&mut grads, slot.key, total);
    }

    // From here on the step is single-threaded and identical to the
    // pre-sharding implementation: STE mapping, regularizer, SGD, BN
    // running-stat writeback.
    step::map_weight_grads(model, gmaps, &mut grads)?;
    let (bgl, loss) = if wm == WMode::Bit {
        let (bgl, bgl_grads) = step::bgl_and_grads(model, state, &regw, alpha)?;
        for (k, t) in bgl_grads {
            step::accumulate(&mut grads, k, t);
        }
        (bgl, ce + alpha * bgl)
    } else {
        (0.0, ce)
    };
    step::sgd_update(state, spec, &mut grads, lr, wd)?;
    for (name, m, v) in results.remove(0).new_stats {
        state.get_mut(&format!("bn:{name}/mean"))?.data_mut().copy_from_slice(&m);
        state.get_mut(&format!("bn:{name}/var"))?.data_mut().copy_from_slice(&v);
    }

    let mut out = RunOutputs::default();
    out.metrics.insert("loss".into(), loss);
    out.metrics.insert("ce".into(), ce);
    out.metrics.insert("acc".into(), acc);
    if wm == WMode::Bit {
        out.metrics.insert("bgl".into(), bgl);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_contiguously_with_no_empties() {
        for (samples, shards) in
            [(16, 1), (16, 4), (7, 3), (1, 8), (5, 5), (5, 9), (32, 6), (3, 2)]
        {
            let ranges = shard_ranges(samples, shards);
            assert_eq!(ranges.len(), shards.min(samples).max(1), "{samples}/{shards}");
            let mut next = 0usize;
            for r in &ranges {
                assert!(!r.is_empty(), "{samples}/{shards}: empty range {r:?}");
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, samples);
            // balanced within one sample
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "{lens:?}");
        }
    }

    #[test]
    fn tree_fold_shape_depends_only_on_count() {
        // 7 items: ((0+1)+(2+3)) + ((4+5)+6) under pairwise rounds
        let order = tree_fold(
            (0..7).map(|i| vec![i]).collect::<Vec<_>>(),
            |a: &mut Vec<i32>, b: &Vec<i32>| {
                let merged = [&a[..], &b[..]].concat();
                *a = merged;
            },
        )
        .unwrap();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5, 6]);
        assert!(tree_fold(Vec::<i32>::new(), |_, _| {}).is_none());
        assert_eq!(tree_fold(vec![42], |_, _| unreachable!()), Some(42));
    }

    #[test]
    fn abort_barrier_releases_waiters() {
        let b = std::sync::Arc::new(AbortBarrier::new(2));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.wait());
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.abort();
        assert!(waiter.join().unwrap().is_err());
        // sticky for late arrivals too
        assert!(b.wait().is_err());
    }

    #[test]
    fn abort_barrier_survives_a_poisoned_mutex() {
        // A worker that panics while holding the barrier mutex (what an
        // injected shard.barrier fault does) poisons it. The poisoning
        // regression: abort() must still release blocked peers, and late
        // wait() calls must error rather than cascade PoisonError panics.
        let b = std::sync::Arc::new(AbortBarrier::new(2));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.wait());
        std::thread::sleep(std::time::Duration::from_millis(20));

        let b3 = b.clone();
        let panicker = std::thread::spawn(move || {
            let _guard = b3.state.lock().unwrap();
            panic!("injected: poison the barrier mutex");
        });
        assert!(panicker.join().is_err());
        assert!(b.state.is_poisoned());

        b.abort(); // must not panic, must wake the waiter
        assert!(waiter.join().unwrap().is_err());
        assert!(b.wait().is_err());
    }
}
