//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute per step.
//!
//! Wraps the `xla` crate (PJRT C API): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. Compiled
//! executables are cached per artifact file for the process lifetime, so
//! the hot path is a single `execute` plus host-side literal marshalling.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use log::{debug, info};

use crate::data::Batch;
use crate::model::state::ModelState;
use crate::runtime::manifest::{ArtifactSpec, Role};
// Offline stand-in for the real `xla` PJRT bindings (crates.io is
// unreachable from this build environment); see xla_stub.rs to swap the
// real backend in. All call sites below are written against the real API.
use crate::runtime::xla_stub as xla;
use crate::tensor::Tensor;

/// Scalar hyperparameters + named configuration vectors for one run.
#[derive(Debug, Clone, Default)]
pub struct RunInputs {
    pub hypers: HashMap<String, f32>,
    pub vecs: HashMap<String, Vec<f32>>,
    pub probes: HashMap<String, Tensor>,
}

impl RunInputs {
    pub fn hyper(mut self, k: &str, v: f32) -> Self {
        self.hypers.insert(k.to_string(), v);
        self
    }

    pub fn vec(mut self, k: &str, v: Vec<f32>) -> Self {
        self.vecs.insert(k.to_string(), v);
        self
    }
}

/// Scalar metrics + probe outputs from one run.
#[derive(Debug, Clone, Default)]
pub struct RunOutputs {
    pub metrics: HashMap<String, f32>,
    pub probes: HashMap<String, Tensor>,
}

impl RunOutputs {
    pub fn metric(&self, name: &str) -> Result<f32> {
        self.metrics.get(name).copied().ok_or_else(|| anyhow!("no metric {name:?}"))
    }
}

/// The PJRT engine: one CPU client + a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Rc<Executable>>>,
}

// Rc<Executable> is only handed out within a thread; the Mutex guards the map.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load + compile an artifact (cached by file path).
    pub fn load(&self, spec: &ArtifactSpec) -> Result<Rc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&spec.file) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("parsing {}: {e}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.file.display()))?;
        info!("compiled {} in {:.2}s", spec.name, t0.elapsed().as_secs_f64());
        let wrapped = Rc::new(Executable { exe, spec: spec.clone() });
        cache.insert(spec.file.clone(), wrapped.clone());
        Ok(wrapped)
    }
}

impl Executable {
    /// Execute one step: marshal inputs by role, run, scatter outputs.
    ///
    /// `state` tensors named by `state`-role outputs are updated in place;
    /// metrics and probe outputs are returned.
    pub fn run(
        &self,
        state: &mut ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<RunOutputs> {
        let literals = self.gather_inputs(state, batch, inputs)?;
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e}", self.spec.name))?;
        debug!("{}: execute {:.1}ms", self.spec.name, t0.elapsed().as_secs_f64() * 1e3);
        drop(literals);

        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.spec.name))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }

        let mut out = RunOutputs::default();
        for (item, lit) in self.spec.outputs.iter().zip(parts) {
            match item.role {
                Role::State => {
                    let dst = state.get_mut(&item.name)?;
                    lit.copy_raw_to::<f32>(dst.data_mut())
                        .map_err(|e| anyhow!("reading output {}: {e}", item.name))?;
                }
                Role::Metric => {
                    let v: f32 = lit
                        .get_first_element()
                        .map_err(|e| anyhow!("metric {}: {e}", item.name))?;
                    out.metrics.insert(item.name.clone(), v);
                }
                Role::ProbeOut => {
                    let mut t = Tensor::zeros(&item.shape);
                    lit.copy_raw_to::<f32>(t.data_mut())
                        .map_err(|e| anyhow!("probe {}: {e}", item.name))?;
                    out.probes.insert(item.name.clone(), t);
                }
                ref r => bail!("{}: unexpected output role {r:?}", item.name),
            }
        }
        Ok(out)
    }

    fn gather_inputs(
        &self,
        state: &ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<Vec<xla::Literal>> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for item in &self.spec.inputs {
            let lit = match item.role {
                Role::X => {
                    let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
                    f32_literal(b.x.data(), &item.shape)?
                }
                Role::Y => {
                    let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
                    i32_literal(b.y.data(), &item.shape)?
                }
                Role::State => {
                    let t = state.get(&item.name)?;
                    if t.shape() != item.shape.as_slice() {
                        bail!(
                            "input {}: state shape {:?} ≠ artifact {:?}",
                            item.name,
                            t.shape(),
                            item.shape
                        );
                    }
                    f32_literal(t.data(), &item.shape)?
                }
                Role::Hyper => {
                    let v = *inputs
                        .hypers
                        .get(&item.name)
                        .ok_or_else(|| anyhow!("missing hyper {:?}", item.name))?;
                    f32_literal(&[v], &item.shape)?
                }
                Role::Vec => {
                    let v = inputs
                        .vecs
                        .get(&item.name)
                        .ok_or_else(|| anyhow!("missing vec {:?}", item.name))?;
                    if v.len() != item.elements() {
                        bail!("vec {}: {} entries ≠ {:?}", item.name, v.len(), item.shape);
                    }
                    f32_literal(v, &item.shape)?
                }
                Role::Probe => match inputs.probes.get(&item.name) {
                    Some(t) => f32_literal(t.data(), &item.shape)?,
                    None => f32_literal(&vec![0.0; item.elements()], &item.shape)?,
                },
                ref r => bail!("{}: unexpected input role {r:?}", item.name),
            };
            literals.push(lit);
        }
        Ok(literals)
    }
}

fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("f32 literal {shape:?}: {e}"))
}

fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("i32 literal {shape:?}: {e}"))
}

/// Batch-less convenience: artifacts whose inputs are all state/hyper/vec.
pub fn artifacts_root() -> PathBuf {
    std::env::var("BSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Load a model manifest from the artifacts root.
pub fn load_manifest(model: &str) -> Result<crate::runtime::manifest::Manifest> {
    let dir = artifacts_root().join(model);
    crate::runtime::manifest::Manifest::load(&dir)
        .with_context(|| format!("loading manifest for {model} (run `make artifacts`?)"))
}
