//! Runtime engine: PJRT artifacts when available, native host backend
//! otherwise.
//!
//! The PJRT path loads AOT HLO-text artifacts, compiles once, executes per
//! step (`HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`). When the `xla` bindings are the offline
//! stub (no XLA C library in the build environment), [`Engine::cpu`] falls
//! back to [`runtime::native`](crate::runtime::native): the same manifest
//! roles executed on host kernels, with manifests synthesized from the
//! model zoo instead of read from disk.
//!
//! Executables are cached per artifact path behind `Arc`, and `Engine` is
//! `Send + Sync`, so compiled artifacts can be shared across the parallel
//! backend's worker threads. On the native backend every executable also
//! carries its model's compiled layer-graph plans (`ir::plan`, cached
//! behind `Arc` per `(model, mode)` exactly like the executables), so
//! structure is compiled once and every step only executes.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use log::{debug, info};

use crate::data::Batch;
use crate::model::state::ModelState;
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::native::{NativeBackend, NativeExec};
// Offline stand-in for the real `xla` PJRT bindings (crates.io is
// unreachable from this build environment); see xla_stub.rs to swap the
// real backend in. All call sites below are written against the real API.
use crate::runtime::xla_stub as xla;
use crate::tensor::Tensor;

/// Scalar hyperparameters + named configuration vectors for one run.
#[derive(Debug, Clone, Default)]
pub struct RunInputs {
    pub hypers: HashMap<String, f32>,
    pub vecs: HashMap<String, Vec<f32>>,
    pub probes: HashMap<String, Tensor>,
}

impl RunInputs {
    pub fn hyper(mut self, k: &str, v: f32) -> Self {
        self.hypers.insert(k.to_string(), v);
        self
    }

    pub fn vec(mut self, k: &str, v: Vec<f32>) -> Self {
        self.vecs.insert(k.to_string(), v);
        self
    }
}

/// Scalar metrics + probe outputs from one run.
#[derive(Debug, Clone, Default)]
pub struct RunOutputs {
    pub metrics: HashMap<String, f32>,
    pub probes: HashMap<String, Tensor>,
}

impl RunOutputs {
    pub fn metric(&self, name: &str) -> Result<f32> {
        self.metrics.get(name).copied().ok_or_else(|| anyhow!("no metric {name:?}"))
    }
}

enum Backend {
    Pjrt(xla::PjRtClient),
    Native(NativeBackend),
}

/// The engine: a device backend + a compile cache shared across threads.
pub struct Engine {
    backend: Backend,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

enum ExecImpl {
    Pjrt(xla::PjRtLoadedExecutable),
    Native(NativeExec),
}

pub struct Executable {
    imp: ExecImpl,
    pub spec: ArtifactSpec,
}

// Engine/Executable cross thread boundaries (scoped workers share compiled
// artifacts); fail the build loudly if a field ever breaks that.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<Executable>();
};

impl Engine {
    /// Preferred constructor: the PJRT CPU client when the real bindings
    /// are linked, otherwise the native host backend.
    ///
    /// Only the offline-stub error triggers the fallback — a *real* PJRT
    /// stack failing to come up (missing plugin, bad install) propagates,
    /// so results are never silently computed on a different backend than
    /// the one the operator configured.
    pub fn cpu() -> Result<Engine> {
        match xla::PjRtClient::cpu() {
            Ok(client) => {
                info!(
                    "PJRT client up: platform={} devices={}",
                    client.platform_name(),
                    client.device_count()
                );
                Ok(Engine { backend: Backend::Pjrt(client), cache: Mutex::new(HashMap::new()) })
            }
            Err(e) if e.to_string().contains("offline xla stub") => {
                info!("PJRT is the offline stub; using the native host backend");
                Ok(Engine::native())
            }
            Err(e) => Err(anyhow!("PJRT cpu client: {e}")),
        }
    }

    /// The native host backend, explicitly (auto shard count).
    pub fn native() -> Engine {
        Engine { backend: Backend::Native(NativeBackend::new()), cache: Mutex::new(HashMap::new()) }
    }

    /// The native host backend with an explicit data-parallel shard count
    /// for the training step (0 = auto). Any value produces bit-identical
    /// training results — the knob only trades threads for wall clock.
    pub fn native_with_shards(shards: usize) -> Engine {
        Engine {
            backend: Backend::Native(NativeBackend::with_shards(shards)),
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Rebuild this engine with the given shard count (native backend only;
    /// a PJRT engine is returned unchanged). The executable cache is
    /// dropped so already-loaded artifacts pick the new count up.
    pub fn with_shards(self, shards: usize) -> Engine {
        match self.backend {
            Backend::Native(_) => Engine::native_with_shards(shards),
            backend => {
                if shards != 0 {
                    log::warn!(
                        "--shards {shards} ignored: the PJRT backend owns its own parallelism"
                    );
                }
                Engine { backend, cache: self.cache }
            }
        }
    }

    /// Resolved data-parallel shard count of the native training step
    /// (1 on the PJRT path — the device owns its own parallelism).
    pub fn shards(&self) -> usize {
        match &self.backend {
            Backend::Native(b) => crate::runtime::native::shard::resolve_shards(b.shards()),
            Backend::Pjrt(_) => 1,
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    /// Resolve a model's manifest: from the artifacts directory on the PJRT
    /// path, synthesized from the model zoo on the native path.
    pub fn manifest(&self, model: &str) -> Result<crate::runtime::manifest::Manifest> {
        match &self.backend {
            Backend::Pjrt(_) => load_manifest(model),
            Backend::Native(b) => b.manifest(model),
        }
    }

    /// The native model graph behind `model` — the serving layer drives the
    /// host forward kernels directly (prebuilt bit-plane weights, dynamic
    /// batch sizes), which only the native backend supports. A real PJRT
    /// stack compiles artifacts at a fixed batch size, so serving on it
    /// needs a padding front-end that is not wired yet: fail loudly rather
    /// than silently computing on a backend the operator did not configure.
    pub fn native_model(
        &self,
        model: &str,
    ) -> Result<Arc<crate::runtime::native::models::NativeModel>> {
        match &self.backend {
            Backend::Native(_) => crate::runtime::native::models::get(model),
            Backend::Pjrt(_) => bail!(
                "serving requires the native backend (the PJRT path compiles \
                 fixed-batch artifacts; no serving front-end for it yet)"
            ),
        }
    }

    /// Compiled layer-graph plans for a native model (train + eval/serve)
    /// — shared `Arc`s from the same global cache the native executables
    /// use, so the serving registry and the CLI never recompile.
    pub fn native_plans(&self, model: &str) -> Result<crate::ir::plan::ModelPlans> {
        match &self.backend {
            Backend::Native(_) => crate::ir::plan::plans_for(model),
            Backend::Pjrt(_) => bail!(
                "compiled layer-graph plans exist only on the native backend \
                 (the PJRT path executes AOT artifacts)"
            ),
        }
    }

    /// Load + compile an artifact (cached by file path).
    pub fn load(&self, spec: &ArtifactSpec) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&spec.file) {
            return Ok(exe.clone());
        }
        let imp = match &self.backend {
            Backend::Pjrt(client) => {
                let t0 = Instant::now();
                let proto = xla::HloModuleProto::from_text_file(&spec.file)
                    .map_err(|e| anyhow!("parsing {}: {e}", spec.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e}", spec.file.display()))?;
                info!("compiled {} in {:.2}s", spec.name, t0.elapsed().as_secs_f64());
                ExecImpl::Pjrt(exe)
            }
            Backend::Native(b) => ExecImpl::Native(NativeExec::for_spec(spec, b.shards())?),
        };
        let wrapped = Arc::new(Executable { imp, spec: spec.clone() });
        cache.insert(spec.file.clone(), wrapped.clone());
        Ok(wrapped)
    }
}

impl Executable {
    /// Execute one step: marshal inputs by role, run, scatter outputs.
    ///
    /// `state` tensors named by `state`-role outputs are updated in place;
    /// metrics and probe outputs are returned.
    pub fn run(
        &self,
        state: &mut ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<RunOutputs> {
        match &self.imp {
            ExecImpl::Native(exe) => exe.run(&self.spec, state, batch, inputs),
            ExecImpl::Pjrt(exe) => self.run_pjrt(exe, state, batch, inputs),
        }
    }

    fn run_pjrt(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        state: &mut ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<RunOutputs> {
        let literals = self.gather_inputs(state, batch, inputs)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e}", self.spec.name))?;
        debug!("{}: execute {:.1}ms", self.spec.name, t0.elapsed().as_secs_f64() * 1e3);
        drop(literals);

        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.spec.name))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }

        let mut out = RunOutputs::default();
        for (item, lit) in self.spec.outputs.iter().zip(parts) {
            match item.role {
                Role::State => {
                    let dst = state.get_mut(&item.name)?;
                    lit.copy_raw_to::<f32>(dst.data_mut())
                        .map_err(|e| anyhow!("reading output {}: {e}", item.name))?;
                }
                Role::Metric => {
                    let v: f32 = lit
                        .get_first_element()
                        .map_err(|e| anyhow!("metric {}: {e}", item.name))?;
                    out.metrics.insert(item.name.clone(), v);
                }
                Role::ProbeOut => {
                    let mut t = Tensor::zeros(&item.shape);
                    lit.copy_raw_to::<f32>(t.data_mut())
                        .map_err(|e| anyhow!("probe {}: {e}", item.name))?;
                    out.probes.insert(item.name.clone(), t);
                }
                ref r => bail!("{}: unexpected output role {r:?}", item.name),
            }
        }
        Ok(out)
    }

    fn gather_inputs(
        &self,
        state: &ModelState,
        batch: Option<&Batch>,
        inputs: &RunInputs,
    ) -> Result<Vec<xla::Literal>> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for item in &self.spec.inputs {
            let lit = match item.role {
                Role::X => {
                    let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
                    f32_literal(b.x.data(), &item.shape)?
                }
                Role::Y => {
                    let b = batch.ok_or_else(|| anyhow!("artifact needs a batch"))?;
                    i32_literal(b.y.data(), &item.shape)?
                }
                Role::State => {
                    let t = state.get(&item.name)?;
                    if t.shape() != item.shape.as_slice() {
                        bail!(
                            "input {}: state shape {:?} ≠ artifact {:?}",
                            item.name,
                            t.shape(),
                            item.shape
                        );
                    }
                    f32_literal(t.data(), &item.shape)?
                }
                Role::Hyper => {
                    let v = *inputs
                        .hypers
                        .get(&item.name)
                        .ok_or_else(|| anyhow!("missing hyper {:?}", item.name))?;
                    f32_literal(&[v], &item.shape)?
                }
                Role::Vec => {
                    let v = inputs
                        .vecs
                        .get(&item.name)
                        .ok_or_else(|| anyhow!("missing vec {:?}", item.name))?;
                    if v.len() != item.elements() {
                        bail!("vec {}: {} entries ≠ {:?}", item.name, v.len(), item.shape);
                    }
                    f32_literal(v, &item.shape)?
                }
                Role::Probe => match inputs.probes.get(&item.name) {
                    Some(t) => f32_literal(t.data(), &item.shape)?,
                    None => f32_literal(&vec![0.0; item.elements()], &item.shape)?,
                },
                ref r => bail!("{}: unexpected input role {r:?}", item.name),
            };
            literals.push(lit);
        }
        Ok(literals)
    }
}

fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("f32 literal {shape:?}: {e}"))
}

fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("i32 literal {shape:?}: {e}"))
}

/// Artifacts root for the PJRT path (`BSQ_ARTIFACTS` overrides).
pub fn artifacts_root() -> PathBuf {
    std::env::var("BSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Load a model manifest from the artifacts root (PJRT path; the native
/// backend synthesizes its manifests via [`Engine::manifest`] instead).
pub fn load_manifest(model: &str) -> Result<crate::runtime::manifest::Manifest> {
    let dir = artifacts_root().join(model);
    crate::runtime::manifest::Manifest::load(&dir)
        .with_context(|| format!("loading manifest for {model} (run `make artifacts`?)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_engine_falls_back_to_native_on_stub() {
        // the offline stub cannot create a PJRT client, so Engine::cpu()
        // must come up native instead of erroring out
        let engine = Engine::cpu().unwrap();
        assert!(engine.is_native());
    }

    #[test]
    fn native_engine_loads_and_caches_executables() {
        let engine = Engine::native();
        let man = engine.manifest("tinynet").unwrap();
        let spec = man.artifact("fp_train_relu6").unwrap();
        let a = engine.load(spec).unwrap();
        let b = engine.load(spec).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must hit the cache");
        assert_eq!(a.spec.name, "fp_train_relu6");
    }

    #[test]
    fn shards_knob_resolves_and_survives_rebuild() {
        let e = Engine::native_with_shards(3);
        assert_eq!(e.shards(), 3);
        let e = e.with_shards(5);
        assert_eq!(e.shards(), 5);
        // 0 = auto: resolves to at least one shard
        assert!(Engine::native().shards() >= 1);
    }

    #[test]
    fn native_manifest_covers_model_zoo() {
        let engine = Engine::native();
        for model in ["tinynet", "resnet20", "resnet50_sim", "inception_sim"] {
            let man = engine.manifest(model).unwrap();
            assert!(!man.artifacts.is_empty(), "{model}: no artifacts");
        }
        assert!(engine.manifest("nope").is_err());
    }
}
