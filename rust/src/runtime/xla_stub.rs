//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no crates.io access and no XLA C library, so
//! the real `xla` crate (PJRT C API bindings) cannot be a hard dependency.
//! This module mirrors exactly the API surface `runtime::engine` consumes;
//! every entry point fails at *runtime* with a clear message while keeping
//! the whole coordinator compiling and unit-testable offline. Everything
//! that needs a device (`Engine::cpu()` onward) is behind artifact checks
//! (`make artifacts`), so tests and benches skip gracefully.
//!
//! Swapping in the real backend: add the `xla` crate to Cargo.toml and
//! replace the `use crate::runtime::xla_stub as xla;` alias in
//! `runtime/engine.rs` with `use xla;` — the call sites are written
//! against the real crate's API and need no changes.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `Display`-driven usage.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend unavailable: this build uses the offline xla stub \
         (see runtime/xla_stub.rs for how to link the real bindings)"
            .to_string(),
    )
}

pub type Result<T> = std::result::Result<T, XlaError>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _literals: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        Err(unavailable())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
