//! Plan compilation: fusion, liveness, and the static activation-memory
//! arena (DESIGN.md §11).
//!
//! `compile` turns a model's [`Graph`] into a [`CompiledPlan`]: the
//! schedule (the graph's topological order), per-node arena offsets from a
//! liveness scan, and the scratch high-water marks the executor needs.
//! Everything is in **per-sample f32 elements** — every activation scales
//! linearly with the batch axis, so one plan serves any batch size and the
//! executor multiplies offsets by `m` at run time (interval disjointness
//! is preserved under that scaling).
//!
//! Two modes:
//!
//! * [`PlanMode::Train`] — every activation is retained to the end of the
//!   pass (the reverse-mode tape reads them all), so liveness degenerates
//!   to a flat layout and no fusion runs (BN backward needs the conv
//!   output, act backward the BN output).
//! * [`PlanMode::Infer`] — forward-only. The conv→bn→act fusion pass
//!   collapses each triple into one node (three same-shaped buffers become
//!   one, with BN and the activation applied in place), and buffers are
//!   recycled the moment their last consumer retires: a first-fit free
//!   list with coalescing assigns offsets so that two simultaneously-live
//!   values never alias.
//!
//! Plans are cached behind `Arc` per `(model, mode)` — the native
//! executables, the serving registry, and `bsq-repro info` all share the
//! same compiled instance, exactly like the engine's `Executable` cache.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;
use once_cell::sync::Lazy;

use crate::ir::graph::{Graph, GraphNode, GraphOp, NodeId};
use crate::runtime::native::models::{self, NativeModel};
use crate::tensor::gemm;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlanMode {
    /// Retain-all layout for tape execution (train, HVP gradients).
    Train,
    /// Liveness-reused arena + conv→bn→act fusion (eval, serving).
    Infer,
}

/// Scratch high-water marks in per-sample f32 elements: im2col patches,
/// their transpose (also the dense bit-plane input transpose), and the
/// column-major bit-plane GEMM output. `packb` is the SIMD GEMM's
/// packed-B panel high-water — **batch-independent** (B is always the
/// weight operand on the forward path), kept out of [`total`] because it
/// lives in the kernel's own thread-local scratch, not the arena.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchSpec {
    pub patches: usize,
    pub transposed: usize,
    pub colmajor: usize,
    pub packb: usize,
}

impl ScratchSpec {
    /// Per-sample arena-side scratch (excludes the batch-independent
    /// `packb`, which `Arena::prepare` reserves in the GEMM's own TLS).
    pub fn total(&self) -> usize {
        self.patches + self.transposed + self.colmajor
    }
}

/// One `(model, mode)`'s compiled execution plan.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledPlan {
    pub graph: Graph,
    pub mode: PlanMode,
    /// Per-node arena offset (per-sample f32 elements).
    pub offsets: Vec<usize>,
    /// Index of each node's last consumer; `usize::MAX` keeps a buffer
    /// live to the end (the logits, and everything in train mode).
    pub last_use: Vec<usize>,
    /// Arena high-water mark (per-sample f32 elements).
    pub arena_elems: usize,
    /// Sum of every activation's size — what an alloc-per-node pass pays.
    pub naive_elems: usize,
    pub scratch: ScratchSpec,
    /// conv→bn→act triples collapsed by the fusion pass (0 in train mode).
    pub fused: usize,
}

impl CompiledPlan {
    pub fn schedule_len(&self) -> usize {
        self.graph.nodes.len()
    }

    pub fn arena_bytes(&self, batch: usize) -> usize {
        self.arena_elems * 4 * batch
    }

    pub fn naive_bytes(&self, batch: usize) -> usize {
        self.naive_elems * 4 * batch
    }

    pub fn scratch_bytes(&self, batch: usize) -> usize {
        self.scratch.total() * 4 * batch
    }
}

/// The conv→bn→act fusion pass (infer plans only): each triple where the
/// conv feeds exactly the BN of the same layer and the BN feeds exactly
/// one act-quant collapses into a [`GraphOp::FusedConvBnAct`] node. BN and
/// the activation are elementwise, so applying them in place over the conv
/// output is bit-identical to the unfused three-node chain — the win is
/// one arena buffer instead of three.
fn fuse_conv_bn_act(graph: Graph) -> (Graph, usize) {
    let cons = graph.consumers();
    let n = graph.nodes.len();
    let mut absorbed = vec![false; n];
    let mut fuse_with: Vec<Option<(NodeId, NodeId)>> = vec![None; n];
    for (i, node) in graph.nodes.iter().enumerate() {
        let GraphOp::Conv { layer, .. } = &node.op else { continue };
        let &[b] = cons[i].as_slice() else { continue };
        let GraphOp::Bn { name } = &graph.nodes[b].op else { continue };
        if name != layer {
            continue;
        }
        let &[a] = cons[b].as_slice() else { continue };
        let GraphOp::ActQuant { .. } = &graph.nodes[a].op else { continue };
        fuse_with[i] = Some((b, a));
        absorbed[b] = true;
        absorbed[a] = true;
    }

    let mut remap = vec![usize::MAX; n];
    let mut nodes: Vec<GraphNode> = Vec::with_capacity(n);
    let mut fused = 0usize;
    for (i, node) in graph.nodes.iter().enumerate() {
        if absorbed[i] {
            continue;
        }
        let inputs: Vec<NodeId> = node.inputs.iter().map(|&p| remap[p]).collect();
        let id = nodes.len();
        match (&node.op, fuse_with[i]) {
            (GraphOp::Conv { layer, stride }, Some((b, a))) => {
                let GraphOp::ActQuant { site } = &graph.nodes[a].op else { unreachable!() };
                nodes.push(GraphNode {
                    op: GraphOp::FusedConvBnAct {
                        layer: layer.clone(),
                        stride: *stride,
                        site: *site,
                    },
                    inputs,
                    shape: node.shape.clone(),
                });
                remap[i] = id;
                remap[b] = id;
                remap[a] = id;
                fused += 1;
            }
            (op, _) => {
                nodes.push(GraphNode { op: op.clone(), inputs, shape: node.shape.clone() });
                remap[i] = id;
            }
        }
    }
    let output = remap[graph.output];
    (
        Graph { model: graph.model, nodes, output, act_sites: graph.act_sites },
        fused,
    )
}

/// First-fit block allocator over a sorted, coalescing free list; extends
/// the high-water mark when nothing fits. Fully deterministic.
fn arena_alloc(free: &mut Vec<(usize, usize)>, high: &mut usize, need: usize) -> usize {
    for idx in 0..free.len() {
        let (off, len) = free[idx];
        if len >= need {
            if len == need {
                free.remove(idx);
            } else {
                free[idx] = (off + need, len - need);
            }
            return off;
        }
    }
    let off = *high;
    *high += need;
    off
}

fn arena_free(free: &mut Vec<(usize, usize)>, off: usize, len: usize) {
    let pos = free.partition_point(|&(o, _)| o < off);
    free.insert(pos, (off, len));
    if pos + 1 < free.len() && free[pos].0 + free[pos].1 == free[pos + 1].0 {
        free[pos].1 += free[pos + 1].1;
        free.remove(pos + 1);
    }
    if pos > 0 && free[pos - 1].0 + free[pos - 1].1 == free[pos].0 {
        free[pos - 1].1 += free[pos].1;
        free.remove(pos);
    }
}

/// Compile `(model, mode)` into a plan. Deterministic: the same inputs
/// yield the same plan, bit for bit (`tests/prop_ir.rs` asserts this).
pub fn compile(model: &NativeModel, mode: PlanMode) -> Result<CompiledPlan> {
    let base = models::graph(model)?;
    let (graph, fused) = match mode {
        PlanMode::Train => (base, 0),
        PlanMode::Infer => fuse_conv_bn_act(base),
    };
    let n = graph.nodes.len();

    // Liveness: a buffer is live from its defining node through its last
    // consumer (inclusive). Output and train-mode buffers live forever.
    let mut last_use = vec![0usize; n];
    for (i, node) in graph.nodes.iter().enumerate() {
        last_use[i] = i;
        for &p in &node.inputs {
            last_use[p] = last_use[p].max(i);
        }
    }
    last_use[graph.output] = usize::MAX;
    if mode == PlanMode::Train {
        for lu in &mut last_use {
            *lu = usize::MAX;
        }
    }

    // Offsets: allocate at definition, free after the last consumer ran.
    // A node's inputs all have `last_use >= current`, so they are still
    // allocated when its output is placed — live buffers never alias.
    let mut dying: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (i, &lu) in last_use.iter().enumerate() {
        if lu != usize::MAX {
            dying[lu].push(i);
        }
    }
    let mut offsets = vec![0usize; n];
    let mut high = 0usize;
    let mut free: Vec<(usize, usize)> = Vec::new();
    for i in 0..n {
        offsets[i] = arena_alloc(&mut free, &mut high, graph.nodes[i].elems());
        for &d in &dying[i] {
            arena_free(&mut free, offsets[d], graph.nodes[d].elems());
        }
    }

    let naive_elems = graph.nodes.iter().map(GraphNode::elems).sum();
    let scratch = scratch_spec(model, &graph)?;
    Ok(CompiledPlan {
        graph,
        mode,
        offsets,
        last_use,
        arena_elems: high,
        naive_elems,
        scratch,
        fused,
    })
}

fn scratch_spec(model: &NativeModel, graph: &Graph) -> Result<ScratchSpec> {
    let mut spec = ScratchSpec::default();
    for node in &graph.nodes {
        match &node.op {
            GraphOp::Conv { layer, .. } | GraphOp::FusedConvBnAct { layer, .. } => {
                let k = model.layer(layer)?;
                let kdim = k.shape[0] * k.shape[1] * k.shape[2];
                let rows = node.shape[0] * node.shape[1]; // per-sample oh·ow
                spec.patches = spec.patches.max(rows * kdim);
                spec.transposed = spec.transposed.max(rows * kdim);
                spec.colmajor = spec.colmajor.max(rows * k.shape[3]);
                spec.packb = spec.packb.max(gemm::packed_b_elems(kdim, k.shape[3]));
            }
            GraphOp::Dense { layer } => {
                let k = model.layer(layer)?;
                spec.transposed = spec.transposed.max(k.shape[0]);
                spec.colmajor = spec.colmajor.max(k.shape[1]);
                spec.packb = spec.packb.max(gemm::packed_b_elems(k.shape[0], k.shape[1]));
            }
            _ => {}
        }
    }
    Ok(spec)
}

/// The two plans every native model needs, shared `Arc`s from the global
/// cache.
#[derive(Clone)]
pub struct ModelPlans {
    pub train: Arc<CompiledPlan>,
    pub infer: Arc<CompiledPlan>,
}

static PLAN_CACHE: Lazy<Mutex<HashMap<(String, PlanMode), Arc<CompiledPlan>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Cached compile: one `Arc<CompiledPlan>` per `(model, mode)` process-wide.
pub fn cached(model: &NativeModel, mode: PlanMode) -> Result<Arc<CompiledPlan>> {
    let key = (model.name.clone(), mode);
    if let Some(hit) = PLAN_CACHE.lock().unwrap().get(&key) {
        return Ok(hit.clone());
    }
    // Compile outside the lock; the entry API keeps the first instance.
    let built = Arc::new(compile(model, mode)?);
    let mut cache = PLAN_CACHE.lock().unwrap();
    Ok(cache.entry(key).or_insert(built).clone())
}

pub fn model_plans(model: &NativeModel) -> Result<ModelPlans> {
    Ok(ModelPlans {
        train: cached(model, PlanMode::Train)?,
        infer: cached(model, PlanMode::Infer)?,
    })
}

/// Plans by model name (the CLI / serving entry point).
pub fn plans_for(name: &str) -> Result<ModelPlans> {
    model_plans(&models::get(name)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_plan_reuses_memory_and_fuses() {
        let m = models::get("resnet20").unwrap();
        let train = compile(&m, PlanMode::Train).unwrap();
        let infer = compile(&m, PlanMode::Infer).unwrap();
        assert_eq!(train.fused, 0);
        assert_eq!(train.arena_elems, train.naive_elems, "train retains everything");
        assert!(infer.fused >= 10, "resnet20 has {} fused triples", infer.fused);
        assert!(
            infer.arena_elems < infer.naive_elems / 4,
            "liveness reuse must beat naive by a wide margin: {} vs {}",
            infer.arena_elems,
            infer.naive_elems
        );
        // fusion shortens the schedule by 2 nodes per triple
        assert_eq!(
            infer.graph.nodes.len() + 2 * infer.fused,
            train.graph.nodes.len()
        );
    }

    // The no-aliasing property over every (model, mode) lives in
    // `tests/prop_ir.rs::arena_plan_never_aliases_live_buffers` — one
    // copy, kept with the rest of the IR property suite.

    #[test]
    fn cache_returns_shared_arcs() {
        let m = models::get("tinynet").unwrap();
        let a = cached(&m, PlanMode::Infer).unwrap();
        let b = cached(&m, PlanMode::Infer).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let plans = plans_for("tinynet").unwrap();
        assert!(Arc::ptr_eq(&plans.infer, &a));
        assert!(!Arc::ptr_eq(&plans.train, &a));
    }

    #[test]
    fn allocator_first_fit_coalesces() {
        let mut free = Vec::new();
        let mut high = 0usize;
        let a = arena_alloc(&mut free, &mut high, 10);
        let b = arena_alloc(&mut free, &mut high, 5);
        let c = arena_alloc(&mut free, &mut high, 7);
        assert_eq!((a, b, c, high), (0, 10, 15, 22));
        arena_free(&mut free, a, 10);
        arena_free(&mut free, b, 5);
        // coalesced into [0, 15): a 12-elem request fits without growth
        let d = arena_alloc(&mut free, &mut high, 12);
        assert_eq!((d, high), (0, 22));
        // remaining sliver [12, 15) serves a 3-elem request
        assert_eq!(arena_alloc(&mut free, &mut high, 3), 12);
        assert_eq!(high, 22);
    }
}
