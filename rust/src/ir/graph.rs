//! Layer-graph IR: typed nodes with explicit edges (DESIGN.md §11).
//!
//! A [`Graph`] is the *structure* of one model's forward computation,
//! constructed once per model and shared by every execution mode — the
//! training tape, the engine's eval entries, HVP, and the serving hot
//! path. Node ids are assigned at construction, never at execution, so
//! they are stable keys: the sharded trainer keys its gradient deposits by
//! node id (`tape::DepositSlot`) instead of call order, and the planner
//! (`ir::plan`) attaches liveness and arena offsets to the very ids the
//! executor (`ir::exec`) walks.
//!
//! Shape inference runs *during* construction: every node records its
//! per-sample output shape (`[h, w, c]` NHWC, `[c]` once pooled), with no
//! batch axis — every op's output scales linearly in the batch dimension,
//! so one compiled plan serves any batch size.

use anyhow::{anyhow, bail, Result};

use crate::runtime::native::models::NativeModel;

/// Index of a node in its graph; stable across executions by construction.
pub type NodeId = usize;

/// The typed op set — exactly what the model zoo's four forwards need.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphOp {
    Input,
    Conv { layer: String, stride: usize },
    Bn { name: String },
    /// Quantized activation; `site` indexes `model.act_sites` and is fixed
    /// at construction (the deleted `Fwd` shim numbered sites per call, at
    /// run time).
    ActQuant { site: usize },
    Dense { layer: String },
    /// Adds the `w:<layer>/b` vector (kept separate from [`GraphOp::Dense`]
    /// so the matmul and the bias add have their own liveness).
    Bias { layer: String },
    Add,
    Subsample { stride: usize },
    /// Channel zero-pad — the tail of the ResNet option-A shortcut.
    PadShortcut { cout: usize },
    Concat,
    GlobalAvgPool,
    AvgPool3x3Edge,
    /// conv→bn→act collapsed by the eval/serve fusion pass (`ir::plan`);
    /// never present in training graphs. The BN name equals the conv layer
    /// name (true throughout the model zoo; the pass checks it).
    FusedConvBnAct { layer: String, stride: usize, site: usize },
}

impl GraphOp {
    /// Display name for per-kind node counts (`bsq-repro info`).
    pub fn kind(&self) -> &'static str {
        match self {
            GraphOp::Input => "input",
            GraphOp::Conv { .. } => "conv",
            GraphOp::Bn { .. } => "bn",
            GraphOp::ActQuant { .. } => "act-quant",
            GraphOp::Dense { .. } => "dense",
            GraphOp::Bias { .. } => "bias",
            GraphOp::Add => "add",
            GraphOp::Subsample { .. } => "subsample",
            GraphOp::PadShortcut { .. } => "pad-shortcut",
            GraphOp::Concat => "concat",
            GraphOp::GlobalAvgPool => "global-avg-pool",
            GraphOp::AvgPool3x3Edge => "avg-pool",
            GraphOp::FusedConvBnAct { .. } => "fused-conv-bn-act",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct GraphNode {
    pub op: GraphOp,
    pub inputs: Vec<NodeId>,
    /// Per-sample output shape (no batch axis).
    pub shape: Vec<usize>,
}

impl GraphNode {
    /// Per-sample element count of this node's activation.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model's forward structure: nodes in topological order (every input
/// id is smaller than its consumer — the builder appends).
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub model: String,
    pub nodes: Vec<GraphNode>,
    pub output: NodeId,
    /// Activation-quant sites consumed (== `model.act_sites.len()`).
    pub act_sites: usize,
}

impl Graph {
    /// Consumer lists per node (edges reversed).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &p in &n.inputs {
                out[p].push(i);
            }
        }
        out
    }

    /// `(kind, count)` pairs in first-appearance order.
    pub fn kind_counts(&self) -> Vec<(&'static str, usize)> {
        let mut counts: Vec<(&'static str, usize)> = Vec::new();
        for n in &self.nodes {
            let k = n.op.kind();
            match counts.iter_mut().find(|(name, _)| *name == k) {
                Some((_, c)) => *c += 1,
                None => counts.push((k, 1)),
            }
        }
        counts
    }
}

/// Records a model's forward as a graph, inferring per-sample shapes as it
/// goes — the declarative twin of the deleted imperative `Fwd` walker,
/// with the same method names so the zoo's builders read unchanged.
pub struct GraphBuilder<'m> {
    model: &'m NativeModel,
    nodes: Vec<GraphNode>,
    sites: usize,
}

impl<'m> GraphBuilder<'m> {
    pub fn new(model: &'m NativeModel) -> GraphBuilder<'m> {
        let (h, w) = model.input_hw;
        let nodes = vec![GraphNode {
            op: GraphOp::Input,
            inputs: Vec::new(),
            shape: vec![h, w, model.in_ch],
        }];
        GraphBuilder { model, nodes, sites: 0 }
    }

    /// The input node (always node 0).
    pub fn input(&self) -> NodeId {
        0
    }

    fn shape(&self, id: NodeId) -> &[usize] {
        &self.nodes[id].shape
    }

    fn push(&mut self, op: GraphOp, inputs: Vec<NodeId>, shape: Vec<usize>) -> NodeId {
        self.nodes.push(GraphNode { op, inputs, shape });
        self.nodes.len() - 1
    }

    pub fn conv(&mut self, x: NodeId, name: &str, stride: usize) -> Result<NodeId> {
        let kshape = self.model.layer(name)?.shape.clone();
        if kshape.len() != 4 {
            bail!("conv {name}: kernel shape {kshape:?} is not HWIO");
        }
        let s = self.shape(x).to_vec();
        if s.len() != 3 || s[2] != kshape[2] {
            bail!("conv {name}: input {s:?} vs kernel {kshape:?}");
        }
        let out = vec![s[0].div_ceil(stride), s[1].div_ceil(stride), kshape[3]];
        Ok(self.push(GraphOp::Conv { layer: name.to_string(), stride }, vec![x], out))
    }

    pub fn bn(&mut self, x: NodeId, name: &str) -> Result<NodeId> {
        if !self.model.bn_names.iter().any(|n| n == name) {
            bail!("model {} has no BN group {name:?}", self.model.name);
        }
        let shape = self.shape(x).to_vec();
        Ok(self.push(GraphOp::Bn { name: name.to_string() }, vec![x], shape))
    }

    /// Quantized activation; sites are numbered in construction order,
    /// matching the zoo's definition order (the old call-order contract).
    pub fn act(&mut self, x: NodeId) -> Result<NodeId> {
        let site = self.sites;
        if site >= self.model.act_sites.len() {
            bail!("model {} has no act site {site}", self.model.name);
        }
        self.sites += 1;
        let shape = self.shape(x).to_vec();
        Ok(self.push(GraphOp::ActQuant { site }, vec![x], shape))
    }

    pub fn conv_bn_act(&mut self, x: NodeId, name: &str, stride: usize) -> Result<NodeId> {
        let y = self.conv(x, name, stride)?;
        let y = self.bn(y, name)?;
        self.act(y)
    }

    /// Dense head: a matmul node plus its bias node (`w:<name>/b`).
    pub fn dense(&mut self, x: NodeId, name: &str) -> Result<NodeId> {
        let kshape = self.model.layer(name)?.shape.clone();
        if kshape.len() != 2 {
            bail!("dense {name}: weight shape {kshape:?} is not [in, out]");
        }
        let s = self.shape(x).to_vec();
        if s.len() != 1 || s[0] != kshape[0] {
            bail!("dense {name}: input {s:?} vs weight {kshape:?}");
        }
        let d = self.push(GraphOp::Dense { layer: name.to_string() }, vec![x], vec![kshape[1]]);
        Ok(self.push(GraphOp::Bias { layer: name.to_string() }, vec![d], vec![kshape[1]]))
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> Result<NodeId> {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b));
        if sa != sb {
            bail!("add: {sa:?} vs {sb:?}");
        }
        Ok(self.push(GraphOp::Add, vec![a, b], sa))
    }

    pub fn global_avg_pool(&mut self, x: NodeId) -> Result<NodeId> {
        let s = self.shape(x).to_vec();
        if s.len() != 3 {
            bail!("global_avg_pool: input {s:?} is not [h, w, c]");
        }
        Ok(self.push(GraphOp::GlobalAvgPool, vec![x], vec![s[2]]))
    }

    pub fn subsample(&mut self, x: NodeId, stride: usize) -> Result<NodeId> {
        let s = self.shape(x).to_vec();
        if s.len() != 3 {
            bail!("subsample: input {s:?} is not [h, w, c]");
        }
        let out = vec![s[0].div_ceil(stride), s[1].div_ceil(stride), s[2]];
        Ok(self.push(GraphOp::Subsample { stride }, vec![x], out))
    }

    pub fn concat(&mut self, parts: &[NodeId]) -> Result<NodeId> {
        let base = self.shape(parts[0]).to_vec();
        if base.len() != 3 {
            bail!("concat: input {base:?} is not [h, w, c]");
        }
        let mut ctotal = 0usize;
        for &p in parts {
            let s = self.shape(p);
            if s[..2] != base[..2] {
                bail!("concat: {s:?} vs {base:?}");
            }
            ctotal += s[2];
        }
        Ok(self.push(GraphOp::Concat, parts.to_vec(), vec![base[0], base[1], ctotal]))
    }

    pub fn avg_pool3x3_edge(&mut self, x: NodeId) -> Result<NodeId> {
        let s = self.shape(x).to_vec();
        if s.len() != 3 {
            bail!("avg_pool3x3: input {s:?} is not [h, w, c]");
        }
        Ok(self.push(GraphOp::AvgPool3x3Edge, vec![x], s))
    }

    /// ResNet option-A shortcut: strided subsample + zero channel padding.
    pub fn pad_shortcut(&mut self, x: NodeId, cout: usize, stride: usize) -> Result<NodeId> {
        let mut v = x;
        if stride > 1 {
            v = self.subsample(v, stride)?;
        }
        let s = self.shape(v).to_vec();
        let cin = *s.last().ok_or_else(|| anyhow!("pad_shortcut: scalar input"))?;
        if cout > cin {
            let shape = vec![s[0], s[1], cout];
            v = self.push(GraphOp::PadShortcut { cout }, vec![v], shape);
        }
        Ok(v)
    }

    pub fn finish(self, output: NodeId) -> Result<Graph> {
        if self.sites != self.model.act_sites.len() {
            bail!(
                "graph for {} consumed {} act sites, model declares {}",
                self.model.name,
                self.sites,
                self.model.act_sites.len()
            );
        }
        Ok(Graph {
            model: self.model.name.clone(),
            nodes: self.nodes,
            output,
            act_sites: self.sites,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::models;

    #[test]
    fn builder_infers_shapes_and_sites() {
        let m = models::get("tinynet").unwrap();
        let g = models::graph(&m).unwrap();
        assert_eq!(g.nodes[0].shape, vec![16, 16, 3]);
        assert_eq!(g.act_sites, 3);
        // conv2 runs at stride 2: its triple lives at 16×16 → 8×8
        let conv2 = g
            .nodes
            .iter()
            .find(|n| matches!(&n.op, GraphOp::Conv { layer, .. } if layer == "conv2"))
            .unwrap();
        assert_eq!(conv2.shape, vec![8, 8, 16]);
        // the head: global pool to [16], dense+bias to [10]
        assert_eq!(g.nodes[g.output].shape, vec![10]);
        assert_eq!(g.nodes[g.output].op.kind(), "bias");
        // topological by construction
        for (i, n) in g.nodes.iter().enumerate() {
            assert!(n.inputs.iter().all(|&p| p < i));
        }
    }

    #[test]
    fn every_zoo_model_builds_a_graph() {
        for name in models::model_names() {
            let m = models::get(name).unwrap();
            let g = models::graph(&m).unwrap();
            assert_eq!(g.act_sites, m.act_sites.len(), "{name}");
            assert!(g.nodes.len() > m.qlayers.len(), "{name}");
            let counts = g.kind_counts();
            let get = |k: &str| counts.iter().find(|(n, _)| *n == k).map_or(0, |(_, c)| *c);
            assert_eq!(get("conv") + get("dense"), m.qlayers.len(), "{name}");
            assert_eq!(get("act-quant"), m.act_sites.len(), "{name}");
            assert_eq!(get("bias"), 1, "{name}");
        }
    }
}
