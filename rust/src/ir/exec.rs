//! Plan execution (DESIGN.md §11): one set of forward kernels behind two
//! drivers.
//!
//! * [`run_on_tape`] — the training/HVP driver. Walks a train-mode plan,
//!   evaluates every node with the shared slice kernels, and records one
//!   tape node per graph node, so `Var(i)` equals graph node `i` and the
//!   reverse pass deposits leaf gradients under stable node-id slots
//!   (`tape::DepositSlot`). This is the direct descendant of the deleted
//!   imperative `Fwd` walk: same kernels, same evaluation order, same
//!   bits.
//! * [`BoundPlan::execute`] — the inference driver. [`bind`] resolves a
//!   plan against one model state (weights, biases, BN statistics with
//!   precomputed `1/√(σ²+ε)`, PACT clips, activation levels) into a list
//!   of bound ops with no name lookups left; `execute` then runs the
//!   schedule inside a caller-owned [`Arena`] — every activation lives at
//!   its planned offset (scaled by the batch size), conv→bn→act triples
//!   apply BN and the activation in place over the conv output, and a
//!   layer whose plane bitsets are fully trimmed short-circuits to a
//!   zero-fill (dead-layer elision). In steady state (arena grown once,
//!   thread GEMM cap at 1) a forward pass performs **zero heap
//!   allocations** — `tests/serve_alloc.rs` asserts this with a counting
//!   allocator.
//!
//! The per-node safety story for the arena: the planner guarantees a
//! node's output range never overlaps any live input, so the executor
//! splits the buffer at the output range and reads inputs from the two
//! remaining shared halves — entirely safe Rust, no aliasing.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::ir::graph::{GraphOp, NodeId};
use crate::ir::plan::{CompiledPlan, PlanMode};
use crate::model::state::ModelState;
use crate::runtime::native::models::NativeModel;
use crate::runtime::native::shard::sharded_batch_stats;
use crate::runtime::native::step::AMode;
use crate::runtime::native::tape::{
    batch_stats, Op, ShardHook, Tape, Var, WeightRep, BN_EPS, BN_MOMENTUM,
};
use crate::tensor::gemm::{self, BitPlaneMatrix, ConvGeom};
use crate::tensor::Tensor;

// -- scratch + arena ---------------------------------------------------------

/// Grow-only kernel scratch: im2col patches, their transpose (also the
/// dense-layer input transpose), and the column-major bit-plane output.
/// Separate buffers (not arena ranges) so conv kernels can borrow all
/// three mutably alongside the activation buffer without unsafe.
#[derive(Default)]
pub struct Scratch {
    patches: Vec<f32>,
    transposed: Vec<f32>,
    colmajor: Vec<f32>,
}

impl Scratch {
    fn ensure(&mut self, patches: usize, transposed: usize, colmajor: usize) {
        if self.patches.len() < patches {
            self.patches.resize(patches, 0.0);
        }
        if self.transposed.len() < transposed {
            self.transposed.resize(transposed, 0.0);
        }
        if self.colmajor.len() < colmajor {
            self.colmajor.resize(colmajor, 0.0);
        }
    }
}

/// One reusable activation arena + kernel scratch. Grow-only: after the
/// first pass at a given batch size every later pass allocates nothing.
#[derive(Default)]
pub struct Arena {
    buf: Vec<f32>,
    scratch: Scratch,
}

impl Arena {
    pub fn prepare(&mut self, plan: &CompiledPlan, m: usize) {
        let need = plan.arena_elems * m;
        if self.buf.len() < need {
            self.buf.resize(need, 0.0);
        }
        let s = plan.scratch;
        self.scratch.ensure(s.patches * m, s.transposed * m, s.colmajor * m);
        // The SIMD GEMM's packed-B panels live in the kernel's own
        // thread-local scratch; reserving the plan's high-water here keeps
        // the serving steady state allocation-free (tests/serve_alloc.rs).
        // Batch-independent: B is always the weight operand on this path.
        gemm::reserve_pack_scratch(s.packb);
    }

    /// Currently reserved bytes (arena + scratch) — observability only.
    pub fn bytes(&self) -> usize {
        4 * (self.buf.len()
            + self.scratch.patches.len()
            + self.scratch.transposed.len()
            + self.scratch.colmajor.len())
    }
}

std::thread_local! {
    static TL_ARENA: RefCell<Arena> = RefCell::new(Arena::default());
}

/// Run `f` against this thread's persistent arena — the serving workers'
/// zero-steady-state-allocation entry point.
pub fn with_thread_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    TL_ARENA.with(|a| f(&mut a.borrow_mut()))
}

// -- shared forward kernels --------------------------------------------------

enum WRef<'a> {
    Dense(&'a Tensor),
    Planes(&'a BitPlaneMatrix),
}

impl WeightRep {
    fn view(&self) -> WRef<'_> {
        match self {
            WeightRep::Dense(t) => WRef::Dense(t),
            WeightRep::Planes(p) => WRef::Planes(p),
        }
    }
}

fn conv_apply(xd: &[f32], geom: &ConvGeom, w: WRef, scratch: &mut Scratch, out: &mut [f32]) {
    let (rows, k, cout) = (geom.rows(), geom.kdim(), geom.cout);
    match w {
        WRef::Dense(wt) => {
            scratch.ensure(rows * k, 0, 0);
            let patches = &mut scratch.patches[..rows * k];
            gemm::im2col_into(xd, geom, patches);
            out.fill(0.0);
            gemm::matmul_into(out, patches, wt.data(), rows, k, cout);
        }
        WRef::Planes(bpm) => {
            scratch.ensure(rows * k, rows * k, cout * rows);
            let Scratch { patches, transposed, colmajor } = scratch;
            let patches = &mut patches[..rows * k];
            let transposed = &mut transposed[..rows * k];
            let colmajor = &mut colmajor[..cout * rows];
            gemm::im2col_into(xd, geom, patches);
            gemm::transpose_into(transposed, patches, rows, k);
            bpm.matmul_t_into(colmajor, transposed, rows);
            gemm::transpose_into(out, colmajor, cout, rows);
        }
    }
}

fn dense_apply(
    xd: &[f32],
    n: usize,
    in_dim: usize,
    out_dim: usize,
    w: WRef,
    scratch: &mut Scratch,
    out: &mut [f32],
) {
    match w {
        WRef::Dense(wt) => {
            out.fill(0.0);
            gemm::matmul_into(out, xd, wt.data(), n, in_dim, out_dim);
        }
        WRef::Planes(bpm) => {
            scratch.ensure(0, n * in_dim, n * out_dim);
            let Scratch { transposed, colmajor, .. } = scratch;
            let tr = &mut transposed[..n * in_dim];
            let cm = &mut colmajor[..n * out_dim];
            gemm::transpose_into(tr, xd, n, in_dim);
            bpm.matmul_t_into(cm, tr, n);
            gemm::transpose_into(out, cm, out_dim, n);
        }
    }
}

fn bias_apply(xd: &[f32], b: &[f32], out: &mut [f32]) {
    for (orow, xrow) in out.chunks_mut(b.len()).zip(xd.chunks(b.len())) {
        for ((o, &x), &bv) in orow.iter_mut().zip(xrow).zip(b) {
            *o = x + bv;
        }
    }
}

/// `(v − μ)·inv·γ + β` in place — `inv = 1/√(σ²+ε)` precomputed, the same
/// expression (and element order) the tape path evaluates; row-chunked so
/// the hot loop carries no per-element modulo.
fn bn_inplace(data: &mut [f32], gamma: &[f32], beta: &[f32], mean: &[f32], inv: &[f32]) {
    let c = gamma.len();
    for row in data.chunks_mut(c) {
        for (ch, v) in row.iter_mut().enumerate() {
            *v = (*v - mean[ch]) * inv[ch] * gamma[ch] + beta[ch];
        }
    }
}

/// Fake-quant clipped activation in place (`kernels/actquant.py`):
/// `levels ≥ 1` rounds `clip(x, 0, bound)` onto `levels` uniform steps,
/// `levels < 1` keeps the bare clip.
fn act_inplace(data: &mut [f32], bound: f32, levels: f32) {
    if levels >= 1.0 {
        for v in data.iter_mut() {
            let xc = v.clamp(0.0, bound);
            *v = (xc / bound * levels).round() / levels * bound;
        }
    } else {
        for v in data.iter_mut() {
            *v = v.clamp(0.0, bound);
        }
    }
}

fn add_apply(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
}

fn subsample_apply(
    xd: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
    out: &mut [f32],
) {
    let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &xd[((ni * h + oy * stride) * w + ox * stride) * c..][..c];
                out[((ni * oh + oy) * ow + ox) * c..][..c].copy_from_slice(src);
            }
        }
    }
}

fn pad_channels_apply(xd: &[f32], pix: usize, cin: usize, cout: usize, out: &mut [f32]) {
    out.fill(0.0);
    for p in 0..pix {
        out[p * cout..p * cout + cin].copy_from_slice(&xd[p * cin..(p + 1) * cin]);
    }
}

fn global_avg_pool_apply(xd: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    out.fill(0.0);
    for ni in 0..n {
        for p in 0..h * w {
            let src = &xd[(ni * h * w + p) * c..][..c];
            let dst = &mut out[ni * c..(ni + 1) * c];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    }
    let inv = 1.0 / (h * w) as f32;
    for v in out.iter_mut() {
        *v *= inv;
    }
}

fn avg_pool3x3_edge_apply(xd: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    out.fill(0.0);
    for ni in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let dst = &mut out[((ni * h + oy) * w + ox) * c..][..c];
                for dy in 0..3 {
                    let iy = (oy + dy).saturating_sub(1).min(h - 1);
                    for dx in 0..3 {
                        let ix = (ox + dx).saturating_sub(1).min(w - 1);
                        let src = &xd[((ni * h + iy) * w + ix) * c..][..c];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v;
                        }
                    }
                }
                for v in dst.iter_mut() {
                    *v /= 9.0;
                }
            }
        }
    }
}

// -- parameter resolution ----------------------------------------------------

fn bn_state(state: &ModelState, name: &str) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    Ok((
        state.get(&format!("bn:{name}/gamma"))?.data().to_vec(),
        state.get(&format!("bn:{name}/beta"))?.data().to_vec(),
        state.get(&format!("bn:{name}/mean"))?.data().to_vec(),
        state.get(&format!("bn:{name}/var"))?.data().to_vec(),
    ))
}

/// Resolve one activation site's `(bound, levels, pact-key)` — the exact
/// rules of the deleted `Fwd::act`.
fn act_site_params(
    model: &NativeModel,
    state: &ModelState,
    am: AMode,
    site: usize,
    actlv: &[f32],
) -> Result<(f32, f32, Option<String>)> {
    match am {
        AMode::Ref => Ok((6.0, 0.0, None)),
        AMode::Relu6 => {
            let lv = *actlv
                .get(site)
                .ok_or_else(|| anyhow!("actlv has no entry for site {site}"))?;
            Ok((6.0, lv, None))
        }
        AMode::Pact => {
            let lv = *actlv
                .get(site)
                .ok_or_else(|| anyhow!("actlv has no entry for site {site}"))?;
            let sname = model
                .act_sites
                .get(site)
                .ok_or_else(|| anyhow!("model has no act site {site}"))?
                .clone();
            let p = state.get(&format!("pact:{sname}"))?.item()?;
            // keep the clip strictly positive; grad flows where p ≥ min
            let pact = if p >= 0.05 { Some(sname) } else { None };
            Ok((p.max(0.05), lv, pact))
        }
    }
}

fn take_rep(reps: &mut BTreeMap<String, WeightRep>, layer: &str) -> Result<WeightRep> {
    reps.remove(layer)
        .ok_or_else(|| anyhow!("layer {layer:?} has no prepared weight (or was reused)"))
}

// -- the tape driver (training / HVP gradients) ------------------------------

pub(crate) struct TrainRun {
    pub tape: Tape,
    pub logits: Var,
    /// BN running-stat updates collected in train mode: (name, mean, var).
    pub new_stats: Vec<(String, Vec<f32>, Vec<f32>)>,
}

/// Execute a train-mode plan while recording the reverse-mode tape —
/// one tape node per graph node, in schedule order.
pub(crate) fn run_on_tape(
    plan: &CompiledPlan,
    model: &NativeModel,
    state: &ModelState,
    mut reps: BTreeMap<String, WeightRep>,
    actlv: &[f32],
    am: AMode,
    train: bool,
    x: Tensor,
    hook: Option<&dyn ShardHook>,
) -> Result<TrainRun> {
    if plan.mode != PlanMode::Train {
        bail!("tape execution needs a train-mode plan (fused nodes have no backward)");
    }
    let mut tape = Tape::new();
    let mut new_stats: Vec<(String, Vec<f32>, Vec<f32>)> = Vec::new();
    let mut scratch = Scratch::default();
    let mut input = Some(x);
    for node in &plan.graph.nodes {
        let arg = |i: usize| Var(node.inputs[i]);
        match &node.op {
            GraphOp::Input => {
                let t = input.take().ok_or_else(|| anyhow!("graph has two input nodes"))?;
                tape.push(Op::Input, t);
            }
            GraphOp::Conv { layer, stride } => {
                let rep = take_rep(&mut reps, layer)?;
                let kshape = model.layer(layer)?.shape.clone();
                let (geom, out) = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    if s.len() != 4 || s[3] != kshape[2] {
                        bail!("conv {layer}: input {s:?} vs kernel {kshape:?}");
                    }
                    let geom = ConvGeom::same(
                        s[0], s[1], s[2], kshape[2], kshape[0], kshape[1], kshape[3], *stride,
                    );
                    let mut out = Tensor::zeros(&[geom.n, geom.oh, geom.ow, geom.cout]);
                    conv_apply(xt.data(), &geom, rep.view(), &mut scratch, out.data_mut());
                    (geom, out)
                };
                tape.push(Op::Conv { x: arg(0), layer: layer.clone(), w: rep, geom }, out);
            }
            GraphOp::Dense { layer } => {
                let rep = take_rep(&mut reps, layer)?;
                let kshape = model.layer(layer)?.shape.clone();
                if kshape.len() != 2 {
                    bail!("dense {layer}: weight shape {kshape:?} is not [in, out]");
                }
                let (in_dim, out_dim) = (kshape[0], kshape[1]);
                if let WeightRep::Dense(wt) = &rep {
                    if wt.shape() != [in_dim, out_dim] {
                        bail!("dense {layer}: weight {:?} vs [{in_dim}, {out_dim}]", wt.shape());
                    }
                }
                let out = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    if s.len() != 2 || s[1] != in_dim {
                        bail!("dense {layer}: input {s:?} is not [N, {in_dim}]");
                    }
                    let mut out = Tensor::zeros(&[s[0], out_dim]);
                    dense_apply(
                        xt.data(),
                        s[0],
                        in_dim,
                        out_dim,
                        rep.view(),
                        &mut scratch,
                        out.data_mut(),
                    );
                    out
                };
                tape.push(
                    Op::Dense { x: arg(0), layer: layer.clone(), w: rep, in_dim, out_dim },
                    out,
                );
            }
            GraphOp::Bias { layer } => {
                let b = state.get(&format!("w:{layer}/b"))?.data().to_vec();
                let out = {
                    let xt = tape.value(arg(0));
                    if xt.shape().last() != Some(&b.len()) {
                        bail!("bias {layer}: input {:?} vs bias [{}]", xt.shape(), b.len());
                    }
                    let mut out = Tensor::zeros(xt.shape());
                    bias_apply(xt.data(), &b, out.data_mut());
                    out
                };
                tape.push(Op::Bias { x: arg(0), layer: layer.clone(), out_dim: b.len() }, out);
            }
            GraphOp::Bn { name } => {
                let (gamma, beta, run_m, run_v) = bn_state(state, name)?;
                let (mean, var, use_batch) = if train {
                    let (bm, bv) = match hook {
                        Some(h) => sharded_batch_stats(h, tape.value(arg(0)))?,
                        None => batch_stats(tape.value(arg(0))),
                    };
                    let nm: Vec<f32> = run_m
                        .iter()
                        .zip(&bm)
                        .map(|(&r, &b)| (1.0 - BN_MOMENTUM) * r + BN_MOMENTUM * b)
                        .collect();
                    let nv: Vec<f32> = run_v
                        .iter()
                        .zip(&bv)
                        .map(|(&r, &b)| (1.0 - BN_MOMENTUM) * r + BN_MOMENTUM * b)
                        .collect();
                    new_stats.push((name.clone(), nm, nv));
                    (bm, bv, true)
                } else {
                    (run_m, run_v, false)
                };
                let out = {
                    let xt = tape.value(arg(0));
                    let c = *xt.shape().last().unwrap_or(&0);
                    if [gamma.len(), beta.len(), mean.len(), var.len()] != [c, c, c, c] {
                        bail!("bn {name}: channel mismatch ({c} channels)");
                    }
                    let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
                    let mut out = Tensor::zeros(xt.shape());
                    out.data_mut().copy_from_slice(xt.data());
                    bn_inplace(out.data_mut(), &gamma, &beta, &mean, &inv);
                    out
                };
                let op = Op::Bn {
                    x: arg(0),
                    name: name.clone(),
                    gamma,
                    mean,
                    var,
                    batch_stats: use_batch,
                };
                tape.push(op, out);
            }
            GraphOp::ActQuant { site } => {
                let (bound, levels, pact) = act_site_params(model, state, am, *site, actlv)?;
                let out = {
                    let xt = tape.value(arg(0));
                    let mut out = Tensor::zeros(xt.shape());
                    out.data_mut().copy_from_slice(xt.data());
                    act_inplace(out.data_mut(), bound, levels);
                    out
                };
                tape.push(Op::ActQuant { x: arg(0), bound, levels, pact }, out);
            }
            GraphOp::Add => {
                let out = {
                    let (ta, tb) = (tape.value(arg(0)), tape.value(arg(1)));
                    if ta.shape() != tb.shape() {
                        bail!("add: {:?} vs {:?}", ta.shape(), tb.shape());
                    }
                    let mut out = Tensor::zeros(ta.shape());
                    add_apply(ta.data(), tb.data(), out.data_mut());
                    out
                };
                tape.push(Op::Add { a: arg(0), b: arg(1) }, out);
            }
            GraphOp::Subsample { stride } => {
                let out = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    if s.len() != 4 {
                        bail!("subsample: input {s:?} is not NHWC");
                    }
                    let (oh, ow) = (s[1].div_ceil(*stride), s[2].div_ceil(*stride));
                    let mut out = Tensor::zeros(&[s[0], oh, ow, s[3]]);
                    subsample_apply(xt.data(), s[0], s[1], s[2], s[3], *stride, out.data_mut());
                    out
                };
                tape.push(Op::Subsample { x: arg(0), stride: *stride }, out);
            }
            GraphOp::PadShortcut { cout } => {
                let (cin, out) = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    let cin = *s.last().ok_or_else(|| anyhow!("pad_channels: scalar input"))?;
                    if *cout < cin {
                        bail!("pad_channels: {cout} < {cin}");
                    }
                    let pix = xt.len() / cin;
                    let mut shape = s.to_vec();
                    *shape.last_mut().unwrap() = *cout;
                    let mut out = Tensor::zeros(&shape);
                    pad_channels_apply(xt.data(), pix, cin, *cout, out.data_mut());
                    (cin, out)
                };
                tape.push(Op::PadChannels { x: arg(0), cin }, out);
            }
            GraphOp::Concat => {
                let (parts, out) = {
                    let base = tape.value(arg(0)).shape().to_vec();
                    if base.len() != 4 {
                        bail!("concat: input {base:?} is not NHWC");
                    }
                    let mut parts = Vec::with_capacity(node.inputs.len());
                    let mut ctotal = 0usize;
                    for &p in &node.inputs {
                        let s = tape.value(Var(p)).shape();
                        if s[..3] != base[..3] {
                            bail!("concat: {s:?} vs {base:?}");
                        }
                        parts.push((Var(p), s[3]));
                        ctotal += s[3];
                    }
                    let pix = base[0] * base[1] * base[2];
                    let mut shape = base;
                    shape[3] = ctotal;
                    let mut out = Tensor::zeros(&shape);
                    let mut off = 0usize;
                    for &(v, c) in &parts {
                        let src = tape.value(v).data();
                        for p in 0..pix {
                            out.data_mut()[p * ctotal + off..p * ctotal + off + c]
                                .copy_from_slice(&src[p * c..(p + 1) * c]);
                        }
                        off += c;
                    }
                    (parts, out)
                };
                tape.push(Op::Concat { parts }, out);
            }
            GraphOp::GlobalAvgPool => {
                let out = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    if s.len() != 4 {
                        bail!("global_avg_pool: input {s:?} is not NHWC");
                    }
                    let mut out = Tensor::zeros(&[s[0], s[3]]);
                    global_avg_pool_apply(xt.data(), s[0], s[1], s[2], s[3], out.data_mut());
                    out
                };
                tape.push(Op::GlobalAvgPool { x: arg(0) }, out);
            }
            GraphOp::AvgPool3x3Edge => {
                let out = {
                    let xt = tape.value(arg(0));
                    let s = xt.shape();
                    if s.len() != 4 {
                        bail!("avg_pool3x3: input {s:?} is not NHWC");
                    }
                    let mut out = Tensor::zeros(s);
                    avg_pool3x3_edge_apply(xt.data(), s[0], s[1], s[2], s[3], out.data_mut());
                    out
                };
                tape.push(Op::AvgPool3x3Edge { x: arg(0) }, out);
            }
            GraphOp::FusedConvBnAct { .. } => {
                bail!("fused node in a train-mode plan (planner invariant broken)")
            }
        }
    }
    Ok(TrainRun { tape, logits: Var(plan.graph.output), new_stats })
}

/// Forward a train-mode plan to logits on the tape path and return them —
/// the reference executor `tests/prop_ir.rs` holds the arena executor to
/// (this path is the direct descendant of the pre-IR `Fwd` walk).
pub fn tape_logits(
    model: &NativeModel,
    state: &ModelState,
    reps: BTreeMap<String, WeightRep>,
    actlv: &[f32],
    am: AMode,
    x: Tensor,
) -> Result<Tensor> {
    let plan = crate::ir::plan::cached(model, PlanMode::Train)?;
    let run = run_on_tape(&plan, model, state, reps, actlv, am, false, x, None)?;
    Ok(run.tape.value(run.logits).clone())
}

// -- the bound inference plan ------------------------------------------------

struct BnParams {
    gamma: Vec<f32>,
    beta: Vec<f32>,
    mean: Vec<f32>,
    inv: Vec<f32>,
}

impl BnParams {
    fn resolve(state: &ModelState, name: &str, c: usize) -> Result<BnParams> {
        let (gamma, beta, mean, var) = bn_state(state, name)?;
        if [gamma.len(), beta.len(), mean.len(), var.len()] != [c, c, c, c] {
            bail!("bn {name}: channel mismatch ({c} channels)");
        }
        let inv = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
        Ok(BnParams { gamma, beta, mean, inv })
    }
}

struct ActParams {
    bound: f32,
    levels: f32,
}

struct ConvSpec {
    w: WeightRep,
    h: usize,
    wd: usize,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    /// Plane bitsets fully trimmed: the GEMM is skipped, output zero-filled.
    dead: bool,
}

enum BoundOp {
    Input,
    Conv(ConvSpec),
    FusedConvBnAct { conv: ConvSpec, bn: BnParams, act: ActParams },
    Bn(BnParams),
    Act(ActParams),
    Dense { w: WeightRep, in_dim: usize, out_dim: usize, dead: bool },
    Bias { b: Vec<f32> },
    Add,
    Subsample { h: usize, w: usize, c: usize, stride: usize },
    PadChannels { pix: usize, cin: usize, cout: usize },
    Concat { pix: usize, widths: Vec<usize> },
    GlobalAvgPool { h: usize, w: usize, c: usize },
    AvgPool3x3Edge { h: usize, w: usize, c: usize },
}

/// An infer-mode plan resolved against one model state: every parameter
/// fetched, every weight bound, nothing left to look up per pass. Shared
/// read-only across serving threads (`Send + Sync`).
pub struct BoundPlan {
    plan: Arc<CompiledPlan>,
    ops: Vec<BoundOp>,
    sample_elems: usize,
    classes: usize,
    elided: usize,
}

const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<BoundPlan>();
};

fn conv_spec(
    model: &NativeModel,
    reps: &mut BTreeMap<String, WeightRep>,
    layer: &str,
    stride: usize,
    in_shape: &[usize],
) -> Result<ConvSpec> {
    let w = take_rep(reps, layer)?;
    let kshape = model.layer(layer)?.shape.clone();
    if kshape.len() != 4 {
        bail!("conv {layer}: kernel shape {kshape:?} is not HWIO");
    }
    if in_shape.len() != 3 || in_shape[2] != kshape[2] {
        bail!("conv {layer}: input {in_shape:?} vs kernel {kshape:?}");
    }
    if let WeightRep::Dense(wt) = &w {
        if wt.shape() != kshape.as_slice() {
            bail!("conv {layer}: weight {:?} vs kernel {kshape:?}", wt.shape());
        }
    }
    let dead = matches!(&w, WeightRep::Planes(p) if p.nnz_bits() == 0);
    Ok(ConvSpec {
        w,
        h: in_shape[0],
        wd: in_shape[1],
        kh: kshape[0],
        kw: kshape[1],
        cin: kshape[2],
        cout: kshape[3],
        stride,
        dead,
    })
}

/// Resolve an infer-mode plan against a model state — the "link" step
/// between compile and execute. Consumes the prepared weights (a serving
/// layer binds once per checkpoint and shares the result).
pub fn bind(
    plan: &Arc<CompiledPlan>,
    model: &NativeModel,
    state: &ModelState,
    mut reps: BTreeMap<String, WeightRep>,
    actlv: &[f32],
    am: AMode,
) -> Result<BoundPlan> {
    if plan.mode != PlanMode::Infer {
        bail!("bind needs an infer-mode plan");
    }
    let graph = &plan.graph;
    let mut ops = Vec::with_capacity(graph.nodes.len());
    let mut elided = 0usize;
    for node in &graph.nodes {
        let in_shape = |i: usize| graph.nodes[node.inputs[i]].shape.as_slice();
        let op = match &node.op {
            GraphOp::Input => BoundOp::Input,
            GraphOp::Conv { layer, stride } => {
                let spec = conv_spec(model, &mut reps, layer, *stride, in_shape(0))?;
                elided += usize::from(spec.dead);
                BoundOp::Conv(spec)
            }
            GraphOp::FusedConvBnAct { layer, stride, site } => {
                let spec = conv_spec(model, &mut reps, layer, *stride, in_shape(0))?;
                elided += usize::from(spec.dead);
                let bn = BnParams::resolve(state, layer, spec.cout)?;
                let (bound, levels, _) = act_site_params(model, state, am, *site, actlv)?;
                BoundOp::FusedConvBnAct { conv: spec, bn, act: ActParams { bound, levels } }
            }
            GraphOp::Bn { name } => {
                let c = *node.shape.last().unwrap_or(&0);
                BoundOp::Bn(BnParams::resolve(state, name, c)?)
            }
            GraphOp::ActQuant { site } => {
                let (bound, levels, _) = act_site_params(model, state, am, *site, actlv)?;
                BoundOp::Act(ActParams { bound, levels })
            }
            GraphOp::Dense { layer } => {
                let w = take_rep(&mut reps, layer)?;
                let kshape = model.layer(layer)?.shape.clone();
                if kshape.len() != 2 {
                    bail!("dense {layer}: weight shape {kshape:?} is not [in, out]");
                }
                if let WeightRep::Dense(wt) = &w {
                    if wt.shape() != kshape.as_slice() {
                        bail!("dense {layer}: weight {:?} vs {kshape:?}", wt.shape());
                    }
                }
                let dead = matches!(&w, WeightRep::Planes(p) if p.nnz_bits() == 0);
                elided += usize::from(dead);
                BoundOp::Dense { w, in_dim: kshape[0], out_dim: kshape[1], dead }
            }
            GraphOp::Bias { layer } => {
                let b = state.get(&format!("w:{layer}/b"))?.data().to_vec();
                if node.shape.last() != Some(&b.len()) {
                    bail!("bias {layer}: node {:?} vs bias [{}]", node.shape, b.len());
                }
                BoundOp::Bias { b }
            }
            GraphOp::Add => BoundOp::Add,
            GraphOp::Subsample { stride } => {
                let s = in_shape(0);
                BoundOp::Subsample { h: s[0], w: s[1], c: s[2], stride: *stride }
            }
            GraphOp::PadShortcut { cout } => {
                let s = in_shape(0);
                BoundOp::PadChannels { pix: s[0] * s[1], cin: s[2], cout: *cout }
            }
            GraphOp::Concat => {
                let widths: Vec<usize> =
                    (0..node.inputs.len()).map(|i| in_shape(i)[2]).collect();
                BoundOp::Concat { pix: node.shape[0] * node.shape[1], widths }
            }
            GraphOp::GlobalAvgPool => {
                let s = in_shape(0);
                BoundOp::GlobalAvgPool { h: s[0], w: s[1], c: s[2] }
            }
            GraphOp::AvgPool3x3Edge => {
                let s = in_shape(0);
                BoundOp::AvgPool3x3Edge { h: s[0], w: s[1], c: s[2] }
            }
        };
        ops.push(op);
    }
    Ok(BoundPlan {
        sample_elems: graph.nodes[0].elems(),
        classes: graph.nodes[graph.output].elems(),
        plan: plan.clone(),
        ops,
        elided,
    })
}

impl BoundPlan {
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    /// Elements of one input sample (`h·w·c`).
    pub fn sample_elems(&self) -> usize {
        self.sample_elems
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Layers whose plane bitsets were fully trimmed — their GEMMs are
    /// elided (zero-fill) by the executor.
    pub fn elided_layers(&self) -> usize {
        self.elided
    }

    /// Run one batch of `m` samples; returns the logits slice `[m·classes]`
    /// living inside the arena. Zero heap allocations once the arena has
    /// seen this batch size (and the thread GEMM cap is 1).
    pub fn execute<'a>(&self, x: &[f32], m: usize, arena: &'a mut Arena) -> Result<&'a [f32]> {
        let r = self.run(x, m, arena)?;
        Ok(&arena.buf[r])
    }

    /// Like [`BoundPlan::execute`] but appends the logits to `out` — the
    /// serving workers' marshalling-free variant.
    pub fn execute_into(
        &self,
        x: &[f32],
        m: usize,
        arena: &mut Arena,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let r = self.run(x, m, arena)?;
        out.extend_from_slice(&arena.buf[r]);
        Ok(())
    }

    fn run(&self, x: &[f32], m: usize, arena: &mut Arena) -> Result<Range<usize>> {
        if m == 0 {
            bail!("empty batch");
        }
        if x.len() != m * self.sample_elems {
            bail!(
                "input carries {} elements, want {} ({} samples × {})",
                x.len(),
                m * self.sample_elems,
                m,
                self.sample_elems
            );
        }
        arena.prepare(&self.plan, m);
        let Arena { buf, scratch } = arena;
        let offsets = &self.plan.offsets;
        let nodes = &self.plan.graph.nodes;
        for (id, op) in self.ops.iter().enumerate() {
            let start = offsets[id] * m;
            let end = start + nodes[id].elems() * m;
            // The planner guarantees live ranges never alias, so inputs sit
            // entirely left or entirely right of this node's output range.
            let (left, rest) = buf.split_at_mut(start);
            let (out, right) = rest.split_at_mut(end - start);
            let (left, right): (&[f32], &[f32]) = (left, right);
            let read = move |p: NodeId| {
                let ps = offsets[p] * m;
                let pe = ps + nodes[p].elems() * m;
                debug_assert!(pe <= start || ps >= end, "live-range aliasing");
                if pe <= start {
                    &left[ps..pe]
                } else {
                    &right[ps - end..pe - end]
                }
            };
            let arg = |i: usize| read(nodes[id].inputs[i]);
            match op {
                BoundOp::Input => out.copy_from_slice(x),
                BoundOp::Conv(spec) => {
                    let geom = ConvGeom::same(
                        m, spec.h, spec.wd, spec.cin, spec.kh, spec.kw, spec.cout, spec.stride,
                    );
                    if spec.dead {
                        out.fill(0.0);
                    } else {
                        conv_apply(arg(0), &geom, spec.w.view(), scratch, out);
                    }
                }
                BoundOp::FusedConvBnAct { conv, bn, act } => {
                    let geom = ConvGeom::same(
                        m, conv.h, conv.wd, conv.cin, conv.kh, conv.kw, conv.cout, conv.stride,
                    );
                    if conv.dead {
                        out.fill(0.0);
                    } else {
                        conv_apply(arg(0), &geom, conv.w.view(), scratch, out);
                    }
                    bn_inplace(out, &bn.gamma, &bn.beta, &bn.mean, &bn.inv);
                    act_inplace(out, act.bound, act.levels);
                }
                BoundOp::Bn(p) => {
                    out.copy_from_slice(arg(0));
                    bn_inplace(out, &p.gamma, &p.beta, &p.mean, &p.inv);
                }
                BoundOp::Act(p) => {
                    out.copy_from_slice(arg(0));
                    act_inplace(out, p.bound, p.levels);
                }
                BoundOp::Dense { w, in_dim, out_dim, dead } => {
                    if *dead {
                        out.fill(0.0);
                    } else {
                        dense_apply(arg(0), m, *in_dim, *out_dim, w.view(), scratch, out);
                    }
                }
                BoundOp::Bias { b } => bias_apply(arg(0), b, out),
                BoundOp::Add => add_apply(arg(0), arg(1), out),
                BoundOp::Subsample { h, w, c, stride } => {
                    subsample_apply(arg(0), m, *h, *w, *c, *stride, out)
                }
                BoundOp::PadChannels { pix, cin, cout } => {
                    pad_channels_apply(arg(0), m * pix, *cin, *cout, out)
                }
                BoundOp::Concat { pix, widths } => {
                    let ctotal: usize = widths.iter().sum();
                    let rows = m * pix;
                    let mut off = 0usize;
                    for (i, &c) in widths.iter().enumerate() {
                        let src = read(nodes[id].inputs[i]);
                        for p in 0..rows {
                            out[p * ctotal + off..p * ctotal + off + c]
                                .copy_from_slice(&src[p * c..(p + 1) * c]);
                        }
                        off += c;
                    }
                }
                BoundOp::GlobalAvgPool { h, w, c } => {
                    global_avg_pool_apply(arg(0), m, *h, *w, *c, out)
                }
                BoundOp::AvgPool3x3Edge { h, w, c } => {
                    avg_pool3x3_edge_apply(arg(0), m, *h, *w, *c, out)
                }
            }
        }
        let o = self.plan.graph.output;
        Ok(offsets[o] * m..offsets[o] * m + nodes[o].elems() * m)
    }
}
