//! `ir` — the layer-graph intermediate representation (DESIGN.md §11).
//!
//! BSQ's runtime invariant is that a model's *structure* is fixed while
//! its per-layer bit content shrinks underneath it. This subsystem makes
//! that split explicit: [`graph`] records each zoo model's forward once as
//! typed nodes with explicit edges and construction-time shape inference,
//! [`plan`] compiles a graph into a schedule plus a liveness-based
//! activation arena (with conv→bn→act fusion and dead-layer elision on
//! eval/serve plans), and [`exec`] runs compiled plans — on the
//! reverse-mode tape for training (one tape node per graph node, stable
//! node-id gradient slots) or inside a reusable arena for inference with
//! zero steady-state heap allocations.
//!
//! Every native entry point — train, eval, HVP, and serving — executes a
//! compiled plan; there is no imperative per-pass graph walk left.

pub mod exec;
pub mod graph;
pub mod plan;

pub use exec::{bind, tape_logits, with_thread_arena, Arena, BoundPlan};
pub use graph::{Graph, GraphBuilder, GraphOp, NodeId};
pub use plan::{compile, plans_for, CompiledPlan, ModelPlans, PlanMode};
