//! # bsq — BSQ (ICLR 2021) reproduction
//!
//! Bit-level sparsity for mixed-precision neural-network quantization
//! (Yang, Duan, Chen & Li), built as a three-layer Rust + JAX + Pallas
//! system: Pallas kernels (L1) and JAX training graphs (L2) are AOT-lowered
//! to HLO text at build time; this crate (L3) owns everything at runtime —
//! data pipeline, training orchestration, the dynamic precision-adjustment
//! state machine, baselines, and the experiment harnesses that regenerate
//! every table and figure of the paper. See DESIGN.md.
//!
//! Layout:
//! * [`util`] — offline substrates (JSON, PRNG, CLI, bench harness, logging)
//! * [`tensor`] — host tensors
//! * [`ir`] — layer-graph IR: graphs, compiled activation-memory plans,
//!   and the planned executors every native entry runs through
//! * [`quant`] — bit planes, re-quantization/precision adjustment (§3.3),
//!   scheme accounting, Eq. 5 reweighing
//! * [`data`] — synthetic corpora + augmentation + loaders
//! * [`runtime`] — PJRT engine + artifact manifests
//! * [`model`] — named state maps + checkpoints
//! * [`coordinator`] — training pipelines (pretrain → BSQ → finetune)
//! * [`baselines`] — DoReFa / PACT / LSQ / HAWQ comparators
//! * [`experiments`] — per-table/figure harnesses
//! * [`serve`] — batched quantized-inference serving (registry → batcher →
//!   worker pool over the bit-plane GEMM eval path)
//! * [`store`] — content-addressed model store: checkpoints keyed by
//!   digest, manifest-pinned deploys, byte-budgeted LRU residency
//! * [`faults`] — deterministic schedule-driven fault injection, the
//!   substrate of the chaos suite (`tests/chaos.rs`)
//!
//! Training on the native backend is data-parallel sharded
//! ([`runtime::native::shard`]): each minibatch fans across scoped worker
//! shards and gradients combine through a deterministic fixed-order tree
//! reduce, so results are bit-identical at any shard count.

// Numeric-kernel idioms this codebase keeps on purpose: graph/geometry
// builders legitimately take many scalar dimensions, indexed loops over
// several parallel buffers read better than zipped iterator pyramids, and
// the keyed-gradient plumbing passes (map, map) pairs around.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod faults;
pub mod ir;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod util;
