//! # bsq — BSQ (ICLR 2021) reproduction
//!
//! Bit-level sparsity for mixed-precision neural-network quantization
//! (Yang, Duan, Chen & Li), built as a three-layer Rust + JAX + Pallas
//! system: Pallas kernels (L1) and JAX training graphs (L2) are AOT-lowered
//! to HLO text at build time; this crate (L3) owns everything at runtime —
//! data pipeline, training orchestration, the dynamic precision-adjustment
//! state machine, baselines, and the experiment harnesses that regenerate
//! every table and figure of the paper. See DESIGN.md.
//!
//! Layout:
//! * [`util`] — offline substrates (JSON, PRNG, CLI, bench harness, logging)
//! * [`tensor`] — host tensors
//! * [`quant`] — bit planes, re-quantization/precision adjustment (§3.3),
//!   scheme accounting, Eq. 5 reweighing
//! * [`data`] — synthetic corpora + augmentation + loaders
//! * [`runtime`] — PJRT engine + artifact manifests
//! * [`model`] — named state maps + checkpoints
//! * [`coordinator`] — training pipelines (pretrain → BSQ → finetune)
//! * [`baselines`] — DoReFa / PACT / LSQ / HAWQ comparators
//! * [`experiments`] — per-table/figure harnesses
//! * [`serve`] — batched quantized-inference serving (registry → batcher →
//!   worker pool over the bit-plane GEMM eval path)

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
