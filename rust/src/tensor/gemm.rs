//! High-performance host kernels: blocked parallel f32 GEMM, im2col
//! convolution lowering, and the bit-plane GEMM that makes inference cost
//! scale with the bit sparsity BSQ induces (DESIGN.md §8).
//!
//! Two matmul families back `runtime::native`:
//!
//! * **Dense f32** — [`matmul`] and the transposed variants: cache-blocked
//!   (KC×NC tiles so one B panel stays in L1/L2 across a row sweep) and
//!   parallel over output-row chunks via `std::thread::scope`. This is the
//!   training path and the baseline every speedup is measured against.
//! * **Bit-plane** — [`BitPlaneMatrix::matmul_t`] consumes the sign-split
//!   u64 plane bitsets of `quant::packed` directly and evaluates
//!   `x·W = δ · Σ_b 2^b (x·P_b⁺ − x·P_b⁻)` by walking set bits with
//!   trailing-zeros/clear-lowest loops. Work is exactly proportional to the
//!   number of set weight bits: planes trimmed by §3.3 re-quantization (or
//!   emptied by the regularizer) are skipped with a single popcount check,
//!   so throughput grows as BSQ sparsifies the model.
//!
//! Layout conventions (all row-major): `matmul(a, b) = A[M,K]·B[K,N]`;
//! activations NHWC; conv kernels HWIO, whose flattening `[kh·kw·cin, cout]`
//! matches the im2col patch column order bit for bit.

use crate::quant::packed::PackedCodes;

// -- dense blocked GEMM ------------------------------------------------------

/// K-tile: one `A` row segment + the matching `B` panel rows stay cache-hot.
const KC: usize = 128;
/// N-tile: the `B` panel width swept per K-tile (f32s; 4 KiB rows).
const NC: usize = 1024;
/// Below this many multiply-adds a single thread wins (spawn overhead).
const PAR_THRESHOLD: usize = 1 << 21;

std::thread_local! {
    /// Per-thread cap on the GEMM worker fan-out. The data-parallel shard
    /// workers (`runtime::native::shard`) lower it to their slice of the
    /// cores so E shards × inner GEMM threads never oversubscribe the host.
    /// Capping never changes results: the row split only partitions work,
    /// each output element keeps its fixed accumulation order.
    static PAR_CAP: std::cell::Cell<usize> = std::cell::Cell::new(usize::MAX);
}

/// Cap this thread's GEMM fan-out (minimum 1). Thread-local: scoped worker
/// threads set their own budget without touching their neighbours'.
pub fn set_thread_parallelism_cap(cap: usize) {
    PAR_CAP.with(|c| c.set(cap.max(1)));
}

/// Host parallelism the kernels would use uncapped.
pub fn max_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn worker_count(work: usize) -> usize {
    if work < PAR_THRESHOLD {
        return 1;
    }
    // Check the cap before probing the host: a capped thread (serving
    // workers, shard workers at full fan-out) must stay allocation-free —
    // `available_parallelism` can read procfs/cgroups on first use.
    let cap = PAR_CAP.with(|c| c.get());
    if cap <= 1 {
        return 1;
    }
    max_parallelism().clamp(1, 16).min(cap)
}

/// C[M,N] = A[M,K] · B[K,N] (freshly allocated).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(&mut c, a, b, m, k, n);
    c
}

/// C[M,N] += A[M,K] · B[K,N], parallel over row chunks of C.
pub fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not M×K");
    assert_eq!(b.len(), k * n, "B is not K×N");
    assert_eq!(c.len(), m * n, "C is not M×N");
    if m == 0 || n == 0 {
        return;
    }
    let workers = worker_count(m * k * n).min(m);
    if workers <= 1 {
        return gemm_block(c, a, b, m, k, n);
    }
    let rows_per = m.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, cchunk) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cchunk.len() / n;
            let achunk = &a[ci * rows_per * k..ci * rows_per * k + rows * k];
            s.spawn(move || gemm_block(cchunk, achunk, b, rows, k, n));
        }
    });
}

/// Serial cache-blocked kernel: KC×NC panels, vectorizable inner j loop.
fn gemm_block(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for nb in (0..n).step_by(NC) {
            let nend = (nb + NC).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + nb..i * n + nend];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue; // dead rows/cols cost nothing
                    }
                    let brow = &b[kk * n + nb..kk * n + nend];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// Out-of-place transpose: `src` is `[rows, cols]`, result is `[cols, rows]`.
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut dst = vec![0.0f32; src.len()];
    transpose_into(&mut dst, src, rows, cols);
    dst
}

/// [`transpose`] into a caller-owned buffer (fully overwritten) — the
/// planned executor's allocation-free variant.
pub fn transpose_into(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), src.len());
    // tile to keep both access streams within a few cache lines
    const T: usize = 32;
    for rb in (0..rows).step_by(T) {
        for cb in (0..cols).step_by(T) {
            for r in rb..(rb + T).min(rows) {
                for c in cb..(cb + T).min(cols) {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

/// C[M,N] = Aᵀ·B for A stored `[K, M]` (e.g. dW = patchesᵀ·dY).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    matmul(&transpose(a, k, m), b, m, k, n)
}

/// C[M,N] = A·Bᵀ for B stored `[N, K]` (e.g. dX = dY·Wᵀ).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul(a, &transpose(b, n, k), m, k, n)
}

// -- im2col convolution lowering ---------------------------------------------

/// Geometry of one SAME-padded strided convolution (XLA semantics:
/// `out = ceil(in/stride)`, total padding `max((out−1)·stride + k − in, 0)`
/// split low-side-floor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub cout: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
    pub pad_top: usize,
    pub pad_left: usize,
}

impl ConvGeom {
    pub fn same(
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
    ) -> ConvGeom {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let pad_h = ((oh - 1) * stride + kh).saturating_sub(h);
        let pad_w = ((ow - 1) * stride + kw).saturating_sub(w);
        ConvGeom {
            n,
            h,
            w,
            cin,
            kh,
            kw,
            cout,
            stride,
            oh,
            ow,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
        }
    }

    /// Patch rows R = N·OH·OW.
    pub fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// Patch width K = kh·kw·cin (the HWIO flattening order).
    pub fn kdim(&self) -> usize {
        self.kh * self.kw * self.cin
    }
}

/// Extract SAME-padded patches: `x` is NHWC, result is `[R, K]` with the
/// column order matching a flattened HWIO kernel. Out-of-image taps stay 0.
pub fn im2col(x: &[f32], g: &ConvGeom) -> Vec<f32> {
    let mut out = vec![0.0f32; g.rows() * g.kdim()];
    im2col_into(x, g, &mut out);
    out
}

/// [`im2col`] into a caller-owned buffer — zero-filled first so the padding
/// taps stay 0 when the buffer is recycled scratch.
pub fn im2col_into(x: &[f32], g: &ConvGeom, out: &mut [f32]) {
    assert_eq!(x.len(), g.n * g.h * g.w * g.cin);
    let kdim = g.kdim();
    assert_eq!(out.len(), g.rows() * kdim);
    out.fill(0.0);
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let row = &mut out[((ni * g.oh + oy) * g.ow + ox) * kdim..][..kdim];
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let src =
                            &x[((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin..][..g.cin];
                        row[(ky * g.kw + kx) * g.cin..][..g.cin].copy_from_slice(src);
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add patch cotangents back onto the input
/// image buffer (`dx` must be zero-initialized NHWC of the input shape).
pub fn col2im_add(patches: &[f32], g: &ConvGeom, dx: &mut [f32]) {
    assert_eq!(dx.len(), g.n * g.h * g.w * g.cin);
    let kdim = g.kdim();
    assert_eq!(patches.len(), g.rows() * kdim);
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let row = &patches[((ni * g.oh + oy) * g.ow + ox) * kdim..][..kdim];
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let dst = &mut dx
                            [((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin..][..g.cin];
                        let src = &row[(ky * g.kw + kx) * g.cin..][..g.cin];
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
            }
        }
    }
}

// -- bit-plane GEMM ----------------------------------------------------------

/// A quantized weight matrix held as sign-split per-plane bitsets, laid out
/// for GEMM: for each plane `b` and output column `j`, one row of
/// `words = ceil(K/64)` u64s whose bit `k` says weight `(k, j)` has bit `b`
/// of its magnitude set (in `pos` for positive codes, `neg` for negative).
///
/// Constructed from the `quant::packed` integer codes; planes at or above
/// `bits` (trimmed by §3.3 re-quantization) are never materialized, and
/// empty surviving planes are skipped per multiply via `plane_pop`.
#[derive(Debug, Clone)]
pub struct BitPlaneMatrix {
    k: usize,
    n: usize,
    words: usize,
    bits: usize,
    delta: f32,
    pos: Vec<u64>,
    neg: Vec<u64>,
    plane_pop: Vec<u64>,
}

impl BitPlaneMatrix {
    /// Build from raw signed codes stored row-major `[K, N]` (the HWIO /
    /// `[in, out]` flattening). `bits` caps the materialized planes; `delta`
    /// is the LSB step δ = s/(2^bits − 1).
    pub fn from_codes(codes: &[i16], k: usize, n: usize, bits: usize, delta: f32) -> Self {
        assert_eq!(codes.len(), k * n, "codes are not K×N");
        let words = k.div_ceil(64).max(1);
        let bits = bits.min(16);
        let mut pos = vec![0u64; bits * n * words];
        let mut neg = vec![0u64; bits * n * words];
        for (e, &c) in codes.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let kk = e / n;
            let j = e % n;
            let (planes, mut mag) =
                if c > 0 { (&mut pos, c as u64) } else { (&mut neg, (c as i64).unsigned_abs()) };
            let word = kk >> 6;
            let bit = 1u64 << (kk & 63);
            while mag != 0 {
                let b = mag.trailing_zeros() as usize;
                if b >= bits {
                    break; // only higher bits remain
                }
                planes[(b * n + j) * words + word] |= bit;
                mag &= mag - 1;
            }
        }
        let plane_pop = (0..bits)
            .map(|b| {
                let span = b * n * words..(b + 1) * n * words;
                let ones = |w: &u64| w.count_ones() as u64;
                pos[span.clone()].iter().map(ones).sum::<u64>()
                    + neg[span].iter().map(ones).sum::<u64>()
            })
            .collect();
        BitPlaneMatrix { k, n, words, bits, delta, pos, neg, plane_pop }
    }

    /// Build from a packed layer: the trailing weight-shape axis is the
    /// output dimension (cout for HWIO convs, out for `[in, out]` dense).
    ///
    /// Mid-training codes can run one bit wider than the layer's nominal
    /// precision (the §3.3 n+1 growth: continuous planes reach 2.0), so the
    /// materialized plane count covers the widest code actually present —
    /// the product always equals `p.dequantize()`, never a truncation.
    pub fn from_packed(p: &PackedCodes) -> Self {
        let n = p.wshape.last().copied().unwrap_or(1).max(1);
        let k = p.elems() / n;
        let widest = p
            .codes
            .iter()
            .map(|c| 16 - c.unsigned_abs().leading_zeros() as usize)
            .max()
            .unwrap_or(0);
        Self::from_codes(&p.codes, k, n, p.bits.max(widest), p.delta() as f32)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Active (materialized) plane count.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Total set weight bits — the exact work the multiply performs.
    pub fn nnz_bits(&self) -> u64 {
        self.plane_pop.iter().sum()
    }

    /// Planes that actually hold bits (empty ones are skipped wholesale).
    pub fn occupied_planes(&self) -> usize {
        self.plane_pop.iter().filter(|&&p| p != 0).count()
    }

    /// `C = Xᵀ·W·δ` over the bitsets: `xt` is X *transposed*, `[K, M]`
    /// row-major (column `k` of X contiguous over the M batch rows), the
    /// result is `[N, M]` (output-major; [`transpose`] restores `[M, N]`).
    ///
    /// Cost ∝ M × set bits: each set bit triggers one length-M fused
    /// scale-add of a contiguous activation column, planes with zero
    /// popcount cost one branch.
    pub fn matmul_t(&self, xt: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * m];
        self.matmul_t_into(&mut out, xt, m);
        out
    }

    /// [`BitPlaneMatrix::matmul_t`] into a caller-owned `[N, M]` buffer
    /// (zeroed first — recycled arena scratch carries stale values). The
    /// parallel column split honors the thread-local cap, so a capped
    /// serving worker runs it allocation-free.
    pub fn matmul_t_into(&self, out: &mut [f32], xt: &[f32], m: usize) {
        assert_eq!(xt.len(), self.k * m, "Xᵀ is not K×M");
        assert_eq!(out.len(), self.n * m, "out is not N×M");
        out.fill(0.0);
        if m == 0 || self.nnz_bits() == 0 {
            return;
        }
        let work = self.nnz_bits() as usize * m;
        let workers = worker_count(work).min(self.n.max(1));
        if workers <= 1 {
            self.columns_into(out, xt, m, 0);
            return;
        }
        let cols_per = self.n.div_ceil(workers);
        std::thread::scope(|s| {
            for (ci, chunk) in out.chunks_mut(cols_per * m).enumerate() {
                s.spawn(move || self.columns_into(chunk, xt, m, ci * cols_per));
            }
        });
    }

    /// Accumulate output columns `[j0, j0 + chunk.len()/m)` into `chunk`.
    fn columns_into(&self, chunk: &mut [f32], xt: &[f32], m: usize, j0: usize) {
        for (cj, col) in chunk.chunks_mut(m).enumerate() {
            let j = j0 + cj;
            for b in 0..self.bits {
                if self.plane_pop[b] == 0 {
                    continue; // trimmed or regularized-away plane: free
                }
                let w2 = self.delta * (1u32 << b) as f32;
                for (planes, scale) in [(&self.pos, w2), (&self.neg, -w2)] {
                    let row = &planes[(b * self.n + j) * self.words..][..self.words];
                    for (wi, &word) in row.iter().enumerate() {
                        let mut wbits = word;
                        while wbits != 0 {
                            let kk = (wi << 6) + wbits.trailing_zeros() as usize;
                            wbits &= wbits - 1;
                            let src = &xt[kk * m..][..m];
                            for (cv, &sv) in col.iter_mut().zip(src) {
                                *cv += scale * sv;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
            }
        }
        c.into_iter().map(|v| v as f32).collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!((x - y).abs() <= tol * scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_over_shapes() {
        let mut rng = Pcg32::seeded(1);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 7), (16, 63, 17), (8, 64, 9), (5, 65, 33), (130, 40, 12)]
        {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            close(&matmul(&a, &b, m, k, n), &naive(&a, &b, m, k, n), 1e-5);
        }
    }

    #[test]
    fn parallelism_cap_does_not_change_results() {
        let mut rng = Pcg32::seeded(9);
        // large enough to clear PAR_THRESHOLD so the cap actually bites
        let (m, k, n) = (64, 256, 160);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let uncapped = matmul(&a, &b, m, k, n);
        set_thread_parallelism_cap(1);
        let capped = matmul(&a, &b, m, k, n);
        set_thread_parallelism_cap(usize::MAX);
        assert_eq!(uncapped, capped, "row-chunked GEMM must be bit-stable under the cap");
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Pcg32::seeded(2);
        let (m, k, n) = (9, 21, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = naive(&a, &b, m, k, n);
        close(&matmul_tn(&transpose(&a, m, k), &b, k, m, n), &want, 1e-5);
        close(&matmul_nt(&a, &transpose(&b, k, n), m, k, n), &want, 1e-5);
        // transpose is an involution
        assert_eq!(transpose(&transpose(&a, m, k), k, m), a);
    }

    #[test]
    fn same_geometry_matches_xla_rules() {
        // stride 1, 3×3: pad 1/1 both sides, output = input
        let g = ConvGeom::same(1, 16, 16, 3, 3, 3, 8, 1);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (16, 16, 1, 1));
        // stride 2, 16→8: total pad 1, low side gets floor(1/2) = 0
        let g = ConvGeom::same(1, 16, 16, 3, 3, 3, 8, 2);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (8, 8, 0, 0));
        // stride 2 on odd input 15→8: total pad = 7·2+3−15 = 2 → top 1
        let g = ConvGeom::same(1, 15, 15, 1, 3, 3, 1, 2);
        assert_eq!((g.oh, g.ow, g.pad_top), (8, 8, 1));
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // <im2col(x), P> == <x, col2im(P)> for random P — the exact adjoint
        // property conv backward relies on.
        let mut rng = Pcg32::seeded(3);
        for &stride in &[1usize, 2] {
            let g = ConvGeom::same(2, 7, 5, 3, 3, 3, 4, stride);
            let x: Vec<f32> = (0..g.n * g.h * g.w * g.cin).map(|_| rng.normal()).collect();
            let p: Vec<f32> = (0..g.rows() * g.kdim()).map(|_| rng.normal()).collect();
            let cols = im2col(&x, &g);
            let mut dx = vec![0.0f32; x.len()];
            col2im_add(&p, &g, &mut dx);
            let lhs: f64 = cols.iter().zip(&p).map(|(&a, &b)| (a * b) as f64).sum();
            let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| (a * b) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        }
    }

    fn random_codes(rng: &mut Pcg32, len: usize, bits: usize) -> Vec<i16> {
        let cap = ((1u32 << bits) - 1) as i32;
        (0..len)
            .map(|_| {
                let mag = rng.below(cap as u32 + 1) as i32;
                if rng.bool(0.5) {
                    mag as i16
                } else {
                    (-mag) as i16
                }
            })
            .collect()
    }

    #[test]
    fn bitplane_matmul_matches_dense() {
        let mut rng = Pcg32::seeded(4);
        for &(m, k, n) in &[(4, 63, 5), (3, 64, 8), (6, 65, 7), (2, 130, 3)] {
            for bits in [1usize, 3, 8] {
                let codes = random_codes(&mut rng, k * n, bits);
                let delta = 0.043f32;
                let bpm = BitPlaneMatrix::from_codes(&codes, k, n, bits, delta);
                let dense: Vec<f32> = codes.iter().map(|&c| c as f32 * delta).collect();
                let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
                let want = naive(&x, &dense, m, k, n);
                let got_t = bpm.matmul_t(&transpose(&x, m, k), m);
                close(&transpose(&got_t, n, m), &want, 1e-4);
            }
        }
    }

    #[test]
    fn trimmed_planes_are_skipped() {
        let mut rng = Pcg32::seeded(5);
        let (k, n) = (70, 6);
        let codes = random_codes(&mut rng, k * n, 8);
        // sign-magnitude right shift simulates a 3-plane LSB trim
        let shifted: Vec<i16> = codes
            .iter()
            .map(|&c| {
                let m = (c.unsigned_abs() >> 3) as i16;
                if c < 0 {
                    -m
                } else {
                    m
                }
            })
            .collect();
        let full = BitPlaneMatrix::from_codes(&codes, k, n, 8, 1.0);
        let trimmed = BitPlaneMatrix::from_codes(&shifted, k, n, 5, 8.0);
        assert!(trimmed.nnz_bits() < full.nnz_bits());
        assert!(trimmed.occupied_planes() <= 5);
        // value equivalence of the trim: codes>>3 at δ=8 ≈ dropping low bits
        let x: Vec<f32> = (0..2 * k).map(|_| rng.normal()).collect();
        let xt = transpose(&x, 2, k);
        let yt = trimmed.matmul_t(&xt, 2);
        let dense: Vec<f32> = shifted.iter().map(|&c| c as f32 * 8.0).collect();
        close(&transpose(&yt, n, 2), &naive(&x, &dense, 2, k, n), 1e-4);
    }

    #[test]
    fn empty_matrix_multiplies_to_zero() {
        let bpm = BitPlaneMatrix::from_codes(&[0i16; 12], 4, 3, 8, 1.0);
        assert_eq!(bpm.nnz_bits(), 0);
        let out = bpm.matmul_t(&[1.0f32; 8], 2);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_packed_uses_trailing_axis_as_output() {
        use crate::quant::to_bitplanes;
        use crate::tensor::Tensor;
        let mut rng = Pcg32::seeded(6);
        let w = Tensor::randn(&[3, 3, 2, 5], 0.4, &mut rng);
        let packed = to_bitplanes(&w, 6).unwrap().pack();
        let bpm = BitPlaneMatrix::from_packed(&packed);
        assert_eq!((bpm.k(), bpm.n()), (18, 5));
        let dense = packed.dequantize();
        let x: Vec<f32> = (0..4 * 18).map(|_| rng.normal()).collect();
        let want = naive(&x, dense.data(), 4, 18, 5);
        let got = transpose(&bpm.matmul_t(&transpose(&x, 4, 18), 4), 5, 4);
        close(&got, &want, 1e-4);
    }
}
