//! The original cache-blocked scalar kernels, retained verbatim — the
//! fallback backend for non-x86 hosts and `BSQ_FORCE_SCALAR=1`, the
//! reference side of the differential tests (`tests/gemm_diff.rs`), and
//! the baseline every SIMD speedup in `BENCH_gemm.json` is measured
//! against. Do not "optimize" these: their value is being the unchanged
//! pre-SIMD semantics.

/// K-tile: one `A` row segment + the matching `B` panel rows stay cache-hot.
const KC: usize = 128;
/// N-tile: the `B` panel width swept per K-tile (f32s; 4 KiB rows).
const NC: usize = 1024;

/// Serial cache-blocked kernel: KC×NC panels, vectorizable inner j loop.
pub(super) fn gemm_block(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for nb in (0..n).step_by(NC) {
            let nend = (nb + NC).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + nb..i * n + nend];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue; // dead rows/cols cost nothing
                    }
                    let brow = &b[kk * n + nb..kk * n + nend];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// Scalar bit-plane column kernel: accumulate output columns
/// `[j0, j0 + chunk.len()/m)` into `chunk` by walking set bits of each
/// occupied plane. Raw-parts signature (the `BitPlaneMatrix` fields) so
/// both backends share one dispatch site in `bitplane.rs`.
///
/// Per-element operation order — `(plane b ascending, sign pos-then-neg,
/// word ascending, bit ascending)` with an unfused `mul` then `add` — is
/// the contract the AVX2 variant reproduces bitwise.
#[allow(clippy::too_many_arguments)]
pub(super) fn bitplane_columns(
    chunk: &mut [f32],
    xt: &[f32],
    m: usize,
    j0: usize,
    bits: usize,
    n: usize,
    words: usize,
    delta: f32,
    pos: &[u64],
    neg: &[u64],
    plane_pop: &[u64],
) {
    for (cj, col) in chunk.chunks_mut(m).enumerate() {
        let j = j0 + cj;
        for b in 0..bits {
            if plane_pop[b] == 0 {
                continue; // trimmed or regularized-away plane: free
            }
            let w2 = delta * (1u32 << b) as f32;
            for (planes, scale) in [(pos, w2), (neg, -w2)] {
                let row = &planes[(b * n + j) * words..][..words];
                for (wi, &word) in row.iter().enumerate() {
                    let mut wbits = word;
                    while wbits != 0 {
                        let kk = (wi << 6) + wbits.trailing_zeros() as usize;
                        wbits &= wbits - 1;
                        let src = &xt[kk * m..][..m];
                        for (cv, &sv) in col.iter_mut().zip(src) {
                            *cv += scale * sv;
                        }
                    }
                }
            }
        }
    }
}
