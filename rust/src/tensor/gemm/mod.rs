//! High-performance host kernels: runtime-dispatched dense f32 GEMM,
//! im2col convolution lowering, and the bit-plane GEMM that makes
//! inference cost scale with the bit sparsity BSQ induces (DESIGN.md §8,
//! §13).
//!
//! Two matmul families back `runtime::native`:
//!
//! * **Dense f32** — [`matmul`] and the transposed variants. On x86-64
//!   hosts with AVX2+FMA these run a register-blocked packed-panel
//!   microkernel ([`kernel_avx2`]); everywhere else (and under
//!   `BSQ_FORCE_SCALAR=1`) the original cache-blocked scalar kernel
//!   ([`kernel_scalar`]) runs unchanged. This is the training path and
//!   the baseline every speedup is measured against.
//! * **Bit-plane** — [`BitPlaneMatrix::matmul_t`] consumes the sign-split
//!   u64 plane bitsets of `quant::packed` directly and evaluates
//!   `x·W = δ · Σ_b 2^b (x·P_b⁺ − x·P_b⁻)` by walking set bits with
//!   trailing-zeros/clear-lowest loops. Work is exactly proportional to
//!   the number of set weight bits: planes trimmed by §3.3
//!   re-quantization (or emptied by the regularizer) are skipped with a
//!   single popcount check. The AVX2 variant widens each set bit's
//!   fused scale-add to 256-bit lanes over the batch dimension and is
//!   **bit-identical** to the scalar walk (same per-element operation
//!   order, unfused mul+add in both).
//!
//! **Dispatch contract** (DESIGN.md §13): the backend and the host thread
//! budget are resolved exactly once per process into a [`OnceLock`]
//! ([`active_backend`] / [`max_parallelism`]); every entry point reads
//! the resolved backend *before* fanning out worker threads, so one call
//! runs one kernel family end to end. `BSQ_FORCE_SCALAR=1` pins the
//! scalar backend for the whole process (the forced-scalar CI leg);
//! [`with_backend`] overrides it on the current thread for differential
//! tests and benches.
//!
//! **Partition invariance**: for every kernel and every backend, the
//! accumulation order of each output element is a fixed function of the
//! operand shapes — independent of thread count, row/column partition,
//! batch size, and microkernel tile position. SIMD-vs-SIMD results are
//! therefore bitwise stable across shard counts and thread caps
//! (`tests/shard_train.rs`, `tests/gemm_diff.rs`); scalar-vs-SIMD dense
//! results may differ within FMA rounding tolerance (documented ≤1e-4
//! relative).
//!
//! Layout conventions (all row-major): `matmul(a, b) = A[M,K]·B[K,N]`;
//! activations NHWC; conv kernels HWIO, whose flattening `[kh·kw·cin, cout]`
//! matches the im2col patch column order bit for bit.

use std::sync::OnceLock;

mod bitplane;
#[cfg(target_arch = "x86_64")]
mod kernel_avx2;
mod kernel_scalar;
mod pack;

pub use bitplane::BitPlaneMatrix;
pub use pack::{packed_b_elems, reserve_pack_scratch};

// -- runtime dispatch --------------------------------------------------------

/// Which dense/bit-plane kernel family executes a GEMM call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The original cache-blocked scalar kernels, retained verbatim —
    /// non-x86 hosts, `BSQ_FORCE_SCALAR=1`, and differential testing.
    Scalar,
    /// Packed-panel 8×8 FMA microkernel + 256-bit bit-plane scale-adds.
    Avx2Fma,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2Fma => "avx2+fma",
        }
    }

    /// Can this backend run on the current host?
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Avx2Fma => avx2_fma_detected(),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_detected() -> bool {
    false
}

/// Host facts resolved once per process: thread budget and kernel backend.
/// One probe, one CPUID walk, one env read — never repeated per GEMM call
/// (`available_parallelism` can touch procfs/cgroups, and per-call env
/// reads would put syscalls on the serving hot path).
struct Host {
    threads: usize,
    backend: Backend,
}

static HOST: OnceLock<Host> = OnceLock::new();

fn host() -> &'static Host {
    HOST.get_or_init(|| {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let forced = std::env::var_os("BSQ_FORCE_SCALAR").is_some_and(|v| v != "0");
        let backend = if !forced && Backend::Avx2Fma.available() {
            Backend::Avx2Fma
        } else {
            Backend::Scalar
        };
        Host { threads, backend }
    })
}

std::thread_local! {
    /// Per-thread backend override for differential tests and the
    /// per-kernel bench columns. `None` = the process-wide resolution.
    static BACKEND_OVERRIDE: std::cell::Cell<Option<Backend>> =
        const { std::cell::Cell::new(None) };
}

/// The backend the next GEMM call on this thread will dispatch to.
pub fn active_backend() -> Backend {
    BACKEND_OVERRIDE.with(|c| c.get()).unwrap_or_else(|| host().backend)
}

/// Is the SIMD backend both present on this host and not disabled by
/// `BSQ_FORCE_SCALAR`?
pub fn simd_available() -> bool {
    host().backend == Backend::Avx2Fma
}

/// Run `f` with `backend` pinned on the current thread — the differential
/// tests' and benches' way of exercising both dispatch paths in one
/// process. Entry points resolve the backend before spawning their worker
/// threads, so the override covers the whole call even though it lives in
/// thread-local storage. Panics if the backend cannot run here.
pub fn with_backend<R>(backend: Backend, f: impl FnOnce() -> R) -> R {
    assert!(backend.available(), "backend {} is not available on this host", backend.name());
    struct Restore(Option<Backend>);
    impl Drop for Restore {
        fn drop(&mut self) {
            BACKEND_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(BACKEND_OVERRIDE.with(|c| c.replace(Some(backend))));
    f()
}

// -- thread budget -----------------------------------------------------------

/// Below this many multiply-adds a single thread wins (spawn overhead).
const PAR_THRESHOLD: usize = 1 << 21;

std::thread_local! {
    /// Per-thread cap on the GEMM worker fan-out. The data-parallel shard
    /// workers (`runtime::native::shard`) lower it to their slice of the
    /// cores so E shards × inner GEMM threads never oversubscribe the host.
    /// Capping never changes results: the row split only partitions work,
    /// each output element keeps its fixed accumulation order.
    static PAR_CAP: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

/// Cap this thread's GEMM fan-out (minimum 1). Thread-local: scoped worker
/// threads set their own budget without touching their neighbours'.
pub fn set_thread_parallelism_cap(cap: usize) {
    PAR_CAP.with(|c| c.set(cap.max(1)));
}

/// Host parallelism the kernels would use uncapped — the once-resolved
/// probe, not a live syscall.
pub fn max_parallelism() -> usize {
    host().threads
}

/// Per-worker inner-GEMM thread budget when `parts` coordinated workers
/// (shard workers, serve-pool workers) share the host. Derived from the
/// same once-resolved probe as [`max_parallelism`], so pool sizing and
/// kernel dispatch agree on the host for the life of the process.
pub fn worker_budget(parts: usize) -> usize {
    (host().threads / parts.max(1)).max(1)
}

fn worker_count(work: usize) -> usize {
    if work < PAR_THRESHOLD {
        return 1;
    }
    // Check the cap first: a capped thread (serving workers, shard workers
    // at full fan-out) answers from two thread-local reads.
    let cap = PAR_CAP.with(|c| c.get());
    if cap <= 1 {
        return 1;
    }
    host().threads.clamp(1, 16).min(cap)
}

// -- dense GEMM entry points -------------------------------------------------

/// C[M,N] = A[M,K] · B[K,N] (freshly allocated).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(&mut c, a, b, m, k, n);
    c
}

/// C[M,N] += A[M,K] · B[K,N], parallel over row chunks of C.
pub fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not M×K");
    assert_eq!(b.len(), k * n, "B is not K×N");
    assert_eq!(c.len(), m * n, "C is not M×N");
    if m == 0 || n == 0 {
        return;
    }
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2Fma => kernel_avx2::gemm(c, m, k, n, a, k, 1, b, n, 1),
        _ => scalar_parallel(c, a, b, m, k, n),
    }
}

/// C[M,N] = Aᵀ·B for A stored `[K, M]` (e.g. dW = patchesᵀ·dY).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_tn_into(&mut c, a, b, k, m, n);
    c
}

/// C[M,N] += Aᵀ·B for A stored `[K, M]`. The SIMD path packs the strided
/// panels directly (no transpose is materialized); the scalar path keeps
/// the original transpose-then-multiply.
pub fn matmul_tn_into(c: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize) {
    assert_eq!(a.len(), k * m, "A is not K×M");
    assert_eq!(b.len(), k * n, "B is not K×N");
    assert_eq!(c.len(), m * n, "C is not M×N");
    if m == 0 || n == 0 {
        return;
    }
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2Fma => kernel_avx2::gemm(c, m, k, n, a, 1, m, b, n, 1),
        _ => scalar_parallel(c, &transpose(a, k, m), b, m, k, n),
    }
}

/// C[M,N] = A·Bᵀ for B stored `[N, K]` (e.g. dX = dY·Wᵀ).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nt_into(&mut c, a, b, m, k, n);
    c
}

/// C[M,N] += A·Bᵀ for B stored `[N, K]` — same dispatch split as
/// [`matmul_tn_into`].
pub fn matmul_nt_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not M×K");
    assert_eq!(b.len(), n * k, "B is not N×K");
    assert_eq!(c.len(), m * n, "C is not M×N");
    if m == 0 || n == 0 {
        return;
    }
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2Fma => kernel_avx2::gemm(c, m, k, n, a, k, 1, b, 1, k),
        _ => scalar_parallel(c, a, &transpose(b, n, k), m, k, n),
    }
}

/// The scalar backend's parallel driver: row chunks over
/// [`kernel_scalar::gemm_block`], exactly the pre-SIMD `matmul_into`.
fn scalar_parallel(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    let workers = worker_count(m * k * n).min(m);
    if workers <= 1 {
        return kernel_scalar::gemm_block(c, a, b, m, k, n);
    }
    let rows_per = m.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, cchunk) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cchunk.len() / n;
            let achunk = &a[ci * rows_per * k..ci * rows_per * k + rows * k];
            s.spawn(move || kernel_scalar::gemm_block(cchunk, achunk, b, rows, k, n));
        }
    });
}

// -- transpose ---------------------------------------------------------------

/// Out-of-place transpose: `src` is `[rows, cols]`, result is `[cols, rows]`.
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut dst = vec![0.0f32; src.len()];
    transpose_into(&mut dst, src, rows, cols);
    dst
}

/// [`transpose`] into a caller-owned buffer (fully overwritten) — the
/// planned executor's allocation-free variant.
pub fn transpose_into(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), src.len());
    // tile to keep both access streams within a few cache lines
    const T: usize = 32;
    for rb in (0..rows).step_by(T) {
        for cb in (0..cols).step_by(T) {
            for r in rb..(rb + T).min(rows) {
                for c in cb..(cb + T).min(cols) {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

// -- im2col convolution lowering ---------------------------------------------

/// Geometry of one SAME-padded strided convolution (XLA semantics:
/// `out = ceil(in/stride)`, total padding `max((out−1)·stride + k − in, 0)`
/// split low-side-floor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub cout: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
    pub pad_top: usize,
    pub pad_left: usize,
}

impl ConvGeom {
    pub fn same(
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
    ) -> ConvGeom {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let pad_h = ((oh - 1) * stride + kh).saturating_sub(h);
        let pad_w = ((ow - 1) * stride + kw).saturating_sub(w);
        ConvGeom {
            n,
            h,
            w,
            cin,
            kh,
            kw,
            cout,
            stride,
            oh,
            ow,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
        }
    }

    /// Patch rows R = N·OH·OW.
    pub fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// Patch width K = kh·kw·cin (the HWIO flattening order).
    pub fn kdim(&self) -> usize {
        self.kh * self.kw * self.cin
    }
}

/// Extract SAME-padded patches: `x` is NHWC, result is `[R, K]` with the
/// column order matching a flattened HWIO kernel. Out-of-image taps stay 0.
pub fn im2col(x: &[f32], g: &ConvGeom) -> Vec<f32> {
    let mut out = vec![0.0f32; g.rows() * g.kdim()];
    im2col_into(x, g, &mut out);
    out
}

/// [`im2col`] into a caller-owned buffer — zero-filled first so the padding
/// taps stay 0 when the buffer is recycled scratch.
pub fn im2col_into(x: &[f32], g: &ConvGeom, out: &mut [f32]) {
    assert_eq!(x.len(), g.n * g.h * g.w * g.cin);
    let kdim = g.kdim();
    assert_eq!(out.len(), g.rows() * kdim);
    out.fill(0.0);
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let row = &mut out[((ni * g.oh + oy) * g.ow + ox) * kdim..][..kdim];
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let src =
                            &x[((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin..][..g.cin];
                        row[(ky * g.kw + kx) * g.cin..][..g.cin].copy_from_slice(src);
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add patch cotangents back onto the input
/// image buffer (`dx` must be zero-initialized NHWC of the input shape).
pub fn col2im_add(patches: &[f32], g: &ConvGeom, dx: &mut [f32]) {
    assert_eq!(dx.len(), g.n * g.h * g.w * g.cin);
    let kdim = g.kdim();
    assert_eq!(patches.len(), g.rows() * kdim);
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let row = &patches[((ni * g.oh + oy) * g.ow + ox) * kdim..][..kdim];
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let dst = &mut dx
                            [((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin..][..g.cin];
                        let src = &row[(ky * g.kw + kx) * g.cin..][..g.cin];
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
            }
        }
        c.into_iter().map(|v| v as f32).collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!((x - y).abs() <= tol * scale, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_over_shapes() {
        let mut rng = Pcg32::seeded(1);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 7), (16, 63, 17), (8, 64, 9), (5, 65, 33), (130, 40, 12)]
        {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            close(&matmul(&a, &b, m, k, n), &naive(&a, &b, m, k, n), 1e-5);
        }
    }

    #[test]
    fn parallelism_cap_does_not_change_results() {
        let mut rng = Pcg32::seeded(9);
        // large enough to clear PAR_THRESHOLD so the cap actually bites
        let (m, k, n) = (64, 256, 160);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let uncapped = matmul(&a, &b, m, k, n);
        set_thread_parallelism_cap(1);
        let capped = matmul(&a, &b, m, k, n);
        set_thread_parallelism_cap(usize::MAX);
        assert_eq!(uncapped, capped, "row-chunked GEMM must be bit-stable under the cap");
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Pcg32::seeded(2);
        let (m, k, n) = (9, 21, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = naive(&a, &b, m, k, n);
        close(&matmul_tn(&transpose(&a, m, k), &b, k, m, n), &want, 1e-5);
        close(&matmul_nt(&a, &transpose(&b, k, n), m, k, n), &want, 1e-5);
        // transpose is an involution
        assert_eq!(transpose(&transpose(&a, m, k), k, m), a);
    }

    #[test]
    fn backend_override_scopes_and_restores() {
        let before = active_backend();
        with_backend(Backend::Scalar, || {
            assert_eq!(active_backend(), Backend::Scalar);
            // nesting restores the outer override, not the global default
            with_backend(Backend::Scalar, || assert_eq!(active_backend(), Backend::Scalar));
            assert_eq!(active_backend(), Backend::Scalar);
        });
        assert_eq!(active_backend(), before);
        assert!(Backend::Scalar.available());
    }

    #[test]
    fn budget_helpers_are_consistent() {
        let p = max_parallelism();
        assert!(p >= 1);
        assert_eq!(worker_budget(1), p);
        assert_eq!(worker_budget(0), p); // degenerate part count clamps
        assert_eq!(worker_budget(usize::MAX), 1);
        for parts in 1..=8 {
            assert!(worker_budget(parts) >= 1);
            assert!(worker_budget(parts) <= p);
        }
    }

    #[test]
    fn same_geometry_matches_xla_rules() {
        // stride 1, 3×3: pad 1/1 both sides, output = input
        let g = ConvGeom::same(1, 16, 16, 3, 3, 3, 8, 1);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (16, 16, 1, 1));
        // stride 2, 16→8: total pad 1, low side gets floor(1/2) = 0
        let g = ConvGeom::same(1, 16, 16, 3, 3, 3, 8, 2);
        assert_eq!((g.oh, g.ow, g.pad_top, g.pad_left), (8, 8, 0, 0));
        // stride 2 on odd input 15→8: total pad = 7·2+3−15 = 2 → top 1
        let g = ConvGeom::same(1, 15, 15, 1, 3, 3, 1, 2);
        assert_eq!((g.oh, g.ow, g.pad_top), (8, 8, 1));
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // <im2col(x), P> == <x, col2im(P)> for random P — the exact adjoint
        // property conv backward relies on.
        let mut rng = Pcg32::seeded(3);
        for &stride in &[1usize, 2] {
            let g = ConvGeom::same(2, 7, 5, 3, 3, 3, 4, stride);
            let x: Vec<f32> = (0..g.n * g.h * g.w * g.cin).map(|_| rng.normal()).collect();
            let p: Vec<f32> = (0..g.rows() * g.kdim()).map(|_| rng.normal()).collect();
            let cols = im2col(&x, &g);
            let mut dx = vec![0.0f32; x.len()];
            col2im_add(&p, &g, &mut dx);
            let lhs: f64 = cols.iter().zip(&p).map(|(&a, &b)| (a * b) as f64).sum();
            let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| (a * b) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        }
    }

    fn random_codes(rng: &mut Pcg32, len: usize, bits: usize) -> Vec<i16> {
        let cap = ((1u32 << bits) - 1) as i32;
        (0..len)
            .map(|_| {
                let mag = rng.below(cap as u32 + 1) as i32;
                if rng.bool(0.5) {
                    mag as i16
                } else {
                    (-mag) as i16
                }
            })
            .collect()
    }

    #[test]
    fn bitplane_matmul_matches_dense() {
        let mut rng = Pcg32::seeded(4);
        for &(m, k, n) in &[(4, 63, 5), (3, 64, 8), (6, 65, 7), (2, 130, 3)] {
            for bits in [1usize, 3, 8] {
                let codes = random_codes(&mut rng, k * n, bits);
                let delta = 0.043f32;
                let bpm = BitPlaneMatrix::from_codes(&codes, k, n, bits, delta);
                let dense: Vec<f32> = codes.iter().map(|&c| c as f32 * delta).collect();
                let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
                let want = naive(&x, &dense, m, k, n);
                let got_t = bpm.matmul_t(&transpose(&x, m, k), m);
                close(&transpose(&got_t, n, m), &want, 1e-4);
            }
        }
    }

    #[test]
    fn trimmed_planes_are_skipped() {
        let mut rng = Pcg32::seeded(5);
        let (k, n) = (70, 6);
        let codes = random_codes(&mut rng, k * n, 8);
        // sign-magnitude right shift simulates a 3-plane LSB trim
        let shifted: Vec<i16> = codes
            .iter()
            .map(|&c| {
                let m = (c.unsigned_abs() >> 3) as i16;
                if c < 0 {
                    -m
                } else {
                    m
                }
            })
            .collect();
        let full = BitPlaneMatrix::from_codes(&codes, k, n, 8, 1.0);
        let trimmed = BitPlaneMatrix::from_codes(&shifted, k, n, 5, 8.0);
        assert!(trimmed.nnz_bits() < full.nnz_bits());
        assert!(trimmed.occupied_planes() <= 5);
        // value equivalence of the trim: codes>>3 at δ=8 ≈ dropping low bits
        let x: Vec<f32> = (0..2 * k).map(|_| rng.normal()).collect();
        let xt = transpose(&x, 2, k);
        let yt = trimmed.matmul_t(&xt, 2);
        let dense: Vec<f32> = shifted.iter().map(|&c| c as f32 * 8.0).collect();
        close(&transpose(&yt, n, 2), &naive(&x, &dense, 2, k, n), 1e-4);
    }

    #[test]
    fn empty_matrix_multiplies_to_zero() {
        let bpm = BitPlaneMatrix::from_codes(&[0i16; 12], 4, 3, 8, 1.0);
        assert_eq!(bpm.nnz_bits(), 0);
        let out = bpm.matmul_t(&[1.0f32; 8], 2);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_packed_uses_trailing_axis_as_output() {
        use crate::quant::to_bitplanes;
        use crate::tensor::Tensor;
        let mut rng = Pcg32::seeded(6);
        let w = Tensor::randn(&[3, 3, 2, 5], 0.4, &mut rng);
        let packed = to_bitplanes(&w, 6).unwrap().pack();
        let bpm = BitPlaneMatrix::from_packed(&packed);
        assert_eq!((bpm.k(), bpm.n()), (18, 5));
        let dense = packed.dequantize();
        let x: Vec<f32> = (0..4 * 18).map(|_| rng.normal()).collect();
        let want = naive(&x, dense.data(), 4, 18, 5);
        let got = transpose(&bpm.matmul_t(&transpose(&x, 4, 18), 4), 5, 4);
        close(&got, &want, 1e-4);
    }
}
