//! Panel packing for the AVX2 microkernel (DESIGN.md §13).
//!
//! The microkernel wants both operands contiguous and padded to its
//! register block: B as `[jp][k][NR]` column panels (so one panel row is
//! one aligned-width vector load) and A as `[kc][MR]` tiles (so one tile
//! row is MR broadcast sources for the same k). Packing also absorbs the
//! operand strides: the transposed GEMM variants (`matmul_tn`/`matmul_nt`)
//! pack their strided views directly instead of materializing a transpose.
//!
//! Padding is *zeros*, which is what makes the kernel partition-invariant:
//! every logical element runs through the identical vector-FMA sequence no
//! matter which tile (full or edge) it lands in, and the padding lanes'
//! garbage-free zeros are simply never written back.
//!
//! The packed-B copy lives in a grow-only thread-local buffer so
//! steady-state serving does no heap allocation (`tests/serve_alloc.rs`):
//! the planned executor pre-reserves the high-water size via
//! [`reserve_pack_scratch`] (`ScratchSpec::packb`), and reuse never
//! shrinks. A tiles are 8 KiB stack arrays — nothing to reserve.

/// Microkernel row block (A rows per tile, accumulator registers).
pub(super) const MR: usize = 8;
/// Microkernel column block (B columns per panel, one 256-bit vector).
pub(super) const NR: usize = 8;
/// K extent packed per A tile; 8 KiB per tile keeps it L1-resident.
pub(super) const KC: usize = 256;

std::thread_local! {
    /// Grow-only packed-B scratch. Thread-local so concurrent GEMMs on
    /// different threads (serve workers, shard workers) never contend.
    static PACK: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Elements one packed copy of B `[K, N]` occupies: N rounded up to the
/// panel width NR, times K (padding columns are zero-filled).
pub fn packed_b_elems(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * NR * k
}

/// Pre-grow this thread's packed-B scratch to `elems` f32s. The planned
/// executor calls this from `Arena::prepare` with the plan's high-water
/// packed size so the serving steady state stays allocation-free.
pub fn reserve_pack_scratch(elems: usize) {
    PACK.with(|p| {
        let mut buf = p.borrow_mut();
        if buf.len() < elems {
            buf.resize(elems, 0.0);
        }
    });
}

/// Run `f` over this thread's packed-B scratch, grown (never shrunk) to
/// `elems`. The borrow spans the whole GEMM call; kernels never re-enter.
#[cfg(target_arch = "x86_64")]
pub(super) fn with_pack_buf<R>(elems: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PACK.with(|p| {
        let mut buf = p.borrow_mut();
        if buf.len() < elems {
            buf.resize(elems, 0.0);
        }
        f(&mut buf[..elems])
    })
}

/// Pack all of B (logical `[K, N]`, element `(kk, j)` at
/// `b[kk·b_rs + j·b_cs]`) into `[jp][k][NR]` panels: panel `jp` holds
/// columns `jp·NR ..`, its row `kk` is the NR-wide vector the microkernel
/// loads for that k. Every element of the used prefix is written each
/// call (values or padding zeros), so buffer reuse is safe.
#[cfg(target_arch = "x86_64")]
pub(super) fn pack_b(dst: &mut [f32], b: &[f32], b_rs: usize, b_cs: usize, k: usize, n: usize) {
    for jp in 0..n.div_ceil(NR) {
        let j0 = jp * NR;
        let nr = NR.min(n - j0);
        let panel = &mut dst[jp * k * NR..(jp + 1) * k * NR];
        for kk in 0..k {
            let row = &mut panel[kk * NR..kk * NR + NR];
            for (jr, slot) in row[..nr].iter_mut().enumerate() {
                *slot = b[kk * b_rs + (j0 + jr) * b_cs];
            }
            row[nr..].fill(0.0);
        }
    }
}

/// Pack one A tile (rows `i0 .. i0+mr`, k range `kb .. kb+kc`, element
/// `(i, kk)` at `a[i·a_rs + kk·a_cs]`) into `[kc][MR]` layout: tile row
/// `kc` holds the MR broadcast sources for that k, rows `mr..` padded
/// with zeros so edge tiles run the full-width kernel unchanged.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub(super) fn pack_a_tile(
    dst: &mut [f32; MR * KC],
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    i0: usize,
    mr: usize,
    kb: usize,
    kc: usize,
) {
    for kk in 0..kc {
        let row = &mut dst[kk * MR..kk * MR + MR];
        let col = (kb + kk) * a_cs;
        for (ir, slot) in row[..mr].iter_mut().enumerate() {
            *slot = a[(i0 + ir) * a_rs + col];
        }
        row[mr..].fill(0.0);
    }
}
